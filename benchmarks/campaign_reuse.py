"""Campaign reuse — shared-prefix engine vs independent mode execution.

The :class:`repro.scenarios.engine.CampaignEngine` promise is twofold:
byte-identity with fresh :func:`run_campaign` execution, and amortized
reuse — the recorded faults leg, the shared-prefix snapshot forks, the
virtual (untouched) jobs and the decision-trace memo make *repeated*
evaluation of the same campaign far cheaper than re-running it. This
benchmark measures both claims on the workflows the engine exists for:

* **scoring workflow** — the same campaign is evaluated as a 4-mode
  scored report three times over (the report itself, the regression-gate
  re-check, the what-if baseline). Fresh cost is three full 4-mode
  executions; the engine pays one cold build and serves the rest from
  the mode tree.
* **tuner loop** — the shipped knob auto-tuner
  (:func:`repro.whatif.tuning.tune`, golden-section coordinate descent
  over two planner knobs) run end to end across seeds. Fresh cost is one
  full falcon run per probe per seed; the engine forks each probe from
  the shared-prefix snapshot, keeps untouched jobs virtual, and — since
  converging probes reprice to the same decision sequence — serves most
  late evaluations straight from the decision-trace memo.

Every engine-served result is asserted equal to its fresh counterpart
before any timing is reported — a fast wrong answer is not a speedup.
The full run requires >=2x on both workflows (the ISSUE 10 acceptance
bar); smoke mode trims the horizon and requires >=1.5x.
"""
from __future__ import annotations

import time

from benchmarks.common import print_table, save_rows
from repro.scenarios.campaign import MODES, build_campaign, run_campaign
from repro.scenarios.engine import CampaignEngine
from repro.scenarios.scoring import score_campaign

class _FreshBackend:
    """Drop-in for :class:`CampaignEngine` that executes every request as
    a fresh :func:`run_campaign` — exactly what each tuner evaluation cost
    before the shared-prefix engine existed. Swapping only this backend
    keeps everything else (what-if variant cache, probe sequence,
    arithmetic) identical between the two timed arms."""

    def __init__(self, spec) -> None:
        self.spec = spec

    def run(self, mode, *, planner_knobs=None, decision_hook=None):
        return run_campaign(
            self.spec, mode,
            planner_knobs=planner_knobs, decision_hook=decision_hook,
        )


def _scoring_workflow(preset: str, max_ticks: int | None, passes: int) -> dict:
    spec = build_campaign(preset, seed=0, max_ticks=max_ticks)

    t0 = time.monotonic()
    fresh_reports = []
    for _ in range(passes):
        runs = {m: run_campaign(spec, m) for m in MODES}
        fresh_reports.append(score_campaign(spec, runs))
    fresh_s = time.monotonic() - t0

    t0 = time.monotonic()
    engine = CampaignEngine(spec)
    engine_reports = []
    for _ in range(passes):
        runs = {m: engine.run(m) for m in MODES}
        engine_reports.append(score_campaign(spec, runs))
    engine_s = time.monotonic() - t0

    assert engine_reports == fresh_reports, (
        "engine-served reports diverged from fresh execution"
    )
    return {
        "workflow": "scoring",
        "preset": preset,
        "evaluations": passes * len(MODES),
        "fresh_s": round(fresh_s, 3),
        "engine_s": round(engine_s, 3),
        "speedup": round(fresh_s / engine_s, 2),
        "memo_hits": engine.stats["memo_hits"],
        "trace_hits": engine.stats["trace_hits"],
        "forked_runs": engine.stats["forked_runs"],
    }


def _tuner_loop(
    preset: str, max_ticks: int | None, seeds: int, iters: int,
) -> dict:
    from repro.whatif import WhatIfEngine
    from repro.whatif.tuning import tune

    specs = [
        build_campaign(preset, seed=s, max_ticks=max_ticks)
        for s in range(seeds)
    ]
    knob_names = ("breakeven_scale", "prediction_margin")

    t0 = time.monotonic()
    fresh_art = tune(
        [
            WhatIfEngine(spec, campaign_engine=_FreshBackend(spec))
            for spec in specs
        ],
        knob_names, iters=iters,
    )
    fresh_s = time.monotonic() - t0

    t0 = time.monotonic()
    engines = [WhatIfEngine(spec) for spec in specs]
    art = tune(engines, knob_names, iters=iters)
    engine_s = time.monotonic() - t0

    # Byte-identity first: the probe sequence, every measured objective
    # and the tuned bundle must match the fresh-executed tuner exactly.
    assert art == fresh_art, "engine-backed tuner diverged from fresh"
    stats = [e._campaign.stats for e in engines]
    return {
        "workflow": "tuner",
        "preset": preset,
        "evaluations": seeds * (len(fresh_art["evaluations"]) + len(MODES) + 2),
        "fresh_s": round(fresh_s, 3),
        "engine_s": round(engine_s, 3),
        "speedup": round(fresh_s / engine_s, 2),
        "memo_hits": sum(s["memo_hits"] for s in stats),
        "trace_hits": sum(s["trace_hits"] for s in stats),
        "forked_runs": sum(s["forked_runs"] for s in stats),
    }


def run(smoke: bool = False) -> list[dict]:
    # The smoke horizon is chosen so the plane still intervenes (the fork
    # path, not just the recorded completion, is what CI must exercise).
    max_ticks = 260 if smoke else None
    rows = [
        _scoring_workflow("mixed_fleet", max_ticks, passes=3),
        _tuner_loop(
            "mixed_fleet", max_ticks,
            seeds=1 if smoke else 3, iters=4 if smoke else 8,
        ),
    ]
    floor = 1.5 if smoke else 2.0
    worst = min(r["speedup"] for r in rows)
    assert worst >= floor, (
        f"campaign reuse speedup {worst:.2f}x below the {floor}x floor: "
        f"{rows}"
    )
    save_rows("campaign_reuse", rows)
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv
    print_table(
        "Campaign reuse — shared-prefix engine vs independent runs",
        run(smoke=smoke),
    )
