"""Paper Tables 4-5 — detection accuracy: SlideWindow vs BOCD vs BOCD+V.

Labeled iteration-time traces regenerated with the characterization-study
statistics (computation: rare/short episodes; communication: frequent/longer,
§3.2-3.3). A job is classified fail-slow iff the detector reports >=1 episode;
accuracy/FPR/FNR follow the paper's per-job definitions.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.traces import LabeledTrace, sample_campaign
from repro.core import bocd
from repro.core.detector import (
    detect_slow_iterations,
    detect_slow_iterations_sliding_window,
    verify_change_points,
)

CAMPAIGNS = {
    "computation (Table 4)": dict(seed=11, n_jobs=392, rate=6 / 392,
                                  min_sev=0.12, max_sev=0.35),
    "communication (Table 5)": dict(seed=13, n_jobs=107, rate=43 / 107,
                                    min_sev=0.12, max_sev=0.8),
}


def _predict(algo: str, trace: LabeledTrace) -> bool:
    t = trace.times
    if algo == "SlideWindow":
        return bool(detect_slow_iterations_sliding_window(t))
    if algo == "BOCD":
        # Raw BOCD: report any change-point, no verification (paper baseline).
        return bool(bocd.detect_change_points(t, hazard=1 / 100.0))
    # BOCD+V: change-points + the 10 % before/after verification. A
    # confirmed change-point in EITHER direction marks a fail-slow episode —
    # the paper notes change-points "correspond to the onset or relief of
    # slow iterations"; gradual-onset congestion is often only caught at its
    # (sharp) relief.
    return bool(detect_slow_iterations(t, hazard=1 / 100.0))


def _score(algo: str, traces: list[LabeledTrace]) -> dict:
    tp = fp = tn = fn = 0
    for tr in traces:
        pred, truth = _predict(algo, tr), tr.has_failslow
        if pred and truth:
            tp += 1
        elif pred and not truth:
            fp += 1
        elif not pred and truth:
            fn += 1
        else:
            tn += 1
    n = tp + fp + tn + fn
    return {
        "algorithm": algo,
        "accuracy_pct": round(100 * (tp + tn) / n, 1),
        "fpr_pct": round(100 * fp / max(1, fp + tn), 1),
        "fnr_pct": round(100 * fn / max(1, fn + tp), 1),
        "tp": tp, "fp": fp, "tn": tn, "fn": fn,
    }


def run(smoke: bool = False) -> list[dict]:
    rows = []
    for name, c in CAMPAIGNS.items():
        n_jobs = max(16, c["n_jobs"] // 16) if smoke else c["n_jobs"]
        traces = sample_campaign(
            c["seed"], n_jobs, c["rate"],
            min_severity=c["min_sev"], max_severity=c["max_sev"],
        )
        for algo in ("SlideWindow", "BOCD", "BOCD+V"):
            rows.append({"campaign": name, **_score(algo, traces)})
    save_rows("detection_accuracy", rows)
    return rows


if __name__ == "__main__":
    print_table("Tables 4-5 — detection accuracy", run())
