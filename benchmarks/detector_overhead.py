"""Paper Fig. 18 — runtime overhead of FALCON-DETECT.

Real JAX training (reduced model, CPU) with the detector fully active:
every step's time is fed through the complete tracking path (BOCD update +
run-length posterior + verification). Rather than comparing two separate
runs — CPU step times drift by tens of percent between runs, swamping a
sub-percent effect — we measure the detector's cost *inside* the run: the
time spent in ``detector.observe`` per step over the time spent in the
training step. This is the same quantity the paper reports (mean 0.39 %,
max 1.1 %).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.core.detector import FalconDetect
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import train_step as ts_lib

N_STEPS = 30

CONFIGS = {
    "1T4D1P": dict(tp=1, dp=4, pp=1),
    "2T2D1P": dict(tp=2, dp=2, pp=1),
    "2T1D2P": dict(tp=2, dp=1, pp=2),
    "2T2D2P": dict(tp=2, dp=2, pp=2),
}


def _measure(par: dict, seed: int = 0, n_steps: int = N_STEPS) -> tuple[float, float]:
    """Returns (mean step seconds, mean detector seconds per step)."""
    cfg = get_config("falcon-demo-100m").smoke()
    data = DataConfig(seq_len=64, global_batch=8, slots=2, dp_groups=4)
    params = model_lib.init_params(cfg, seed)
    opt_state = adamw.init(params)
    step_fn = jax.jit(ts_lib.make_train_step(cfg, adamw.AdamWConfig()))

    spec = ClusterSpec(n_nodes=2, gpus_per_node=4)
    model = ModelSpec(layers=12, hidden=768, seq_len=1024, vocab=50257)
    sim = TrainingSimulator(
        cluster=spec, job=JobSpec(model=model, micro_batches=8, **par)
    )
    detector = FalconDetect(cluster=sim, verify_window=8)

    # Warm-up compile outside the timed region.
    batch = jax.tree.map(jax.numpy.asarray, make_batch(cfg, data, 0))
    params, opt_state, _ = step_fn(params, opt_state, batch)
    jax.block_until_ready(params)

    step_s, det_s, now = [], [], 0.0
    for step in range(1, n_steps + 1):
        batch = jax.tree.map(jax.numpy.asarray, make_batch(cfg, data, step))
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        now += dt
        t1 = time.monotonic()
        detector.observe(dt, now)  # full tracking path incl. BOCD
        det_s.append(time.monotonic() - t1)
        step_s.append(dt)
    return float(np.mean(step_s)), float(np.mean(det_s))


def run(smoke: bool = False) -> list[dict]:
    rows = []
    configs = dict(list(CONFIGS.items())[:1]) if smoke else CONFIGS
    for name, par in configs.items():
        step_mean, det_mean = _measure(par, n_steps=8 if smoke else N_STEPS)
        rows.append({
            "parallelism": name,
            "step_ms": round(1e3 * step_mean, 2),
            "detector_ms": round(1e3 * det_mean, 3),
            "overhead_pct": round(100 * det_mean / step_mean, 3),
        })
    rows.append({
        "parallelism": "mean", "step_ms": "", "detector_ms": "",
        "overhead_pct": round(
            float(np.mean([r["overhead_pct"] for r in rows])), 3
        ),
    })
    save_rows("detector_overhead", rows)
    return rows


if __name__ == "__main__":
    print_table("Fig. 18 — detector overhead", run())
