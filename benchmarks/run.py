"""Benchmark harness — one module per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]

Each module exposes ``run() -> list[dict]``; results are printed as aligned
tables and persisted to ``results/bench/<name>.json``. ``--smoke`` runs
every benchmark at toy scale (modules whose ``run`` accepts a ``smoke``
keyword); it exists so CI can execute the full suite end-to-end in minutes
— perf entry points that don't run, rot.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import time
import traceback

from benchmarks.common import print_table

#: (module, paper artifact)
SUITE = [
    ("validation_cost", "Fig. 9 — O(1) communicator validation"),
    ("iteration_estimation", "Fig. 12 — ACF iteration-time estimation"),
    ("detection_accuracy", "Tables 4-5 — detector accuracy"),
    ("microbatch_solver", "Table 6 — micro-batch solver time"),
    ("mitigation_s2", "Figs. 13-14 — S2 micro-batch adjustment"),
    ("mitigation_s3", "Figs. 15-16 — S3 topology adjustment"),
    ("topology_overhead", "Fig. 19 — topology-adjust overhead M vs D"),
    ("characterization", "Table 1 / Fig. 1 — characterization campaign"),
    ("detector_overhead", "Fig. 18 — detector overhead (real JAX steps)"),
    ("end_to_end", "Fig. 20 / Table 7 — 64-GPU end-to-end"),
    ("roofline", "Roofline — dry-run derived terms (deliverable g)"),
    ("fleet_scale", "Fleet-scale fast path — batched detection + vector sim"),
    ("event_rate", "Event rate — event-scoped incremental recompute cost"),
    ("controlplane_overhead", "Control plane — per-tick overhead at 1-64 jobs"),
    ("campaign_throughput", "Scenario campaigns — engine ticks/s vs fleet size"),
    ("whatif_replay", "What-if engine — replay cost vs fresh re-runs"),
    ("campaign_reuse", "Campaign reuse — shared-prefix engine vs fresh runs"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument(
        "--smoke", action="store_true",
        help="toy-scale pass over every benchmark (CI rot check)",
    )
    args = ap.parse_args()

    if args.only and args.only not in {name for name, _ in SUITE}:
        ap.error(
            f"unknown benchmark {args.only!r}; choose from: "
            + ", ".join(name for name, _ in SUITE)
        )

    if args.smoke:
        # Toy-scale numbers must not clobber the tracked full-scale results.
        import tempfile

        from benchmarks import common

        common.RESULTS_DIR = tempfile.mkdtemp(prefix="bench_smoke_")

    failures = []
    for name, title in SUITE:
        if args.only and args.only != name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        t0 = time.monotonic()
        try:
            rows = mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            continue
        dt = time.monotonic() - t0
        if name == "roofline":
            rows = [
                {k: r[k] for k in (
                    "arch", "shape", "compute_s", "memory_s", "collective_s",
                    "dominant", "model_over_hlo", "peak_gib_dev",
                )}
                for r in rows
            ]
        print_table(f"{title}  [{dt:.1f}s]", rows)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nALL BENCHMARKS COMPLETED")


if __name__ == "__main__":
    main()
