"""Paper Figures 13-14 — effectiveness of micro-batch adjustment (S2).

Fig. 13: single-node 8-GPU jobs with DP in {2,4,8}; one GPU is injected with
weak/medium/severe computation fail-slow; S2 redistributes micro-batches by
profiled per-group speed. Fig. 14: a 4-DP job with 0..4 degraded DP groups.

Metric (paper's): slowdown = t_iter / t_healthy; S2's reduction of the excess
slowdown = 1 - (slow_s2 - 1) / (slow_none - 1).
"""
from __future__ import annotations

from benchmarks.common import print_table, save_rows
from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec

SEVERITIES = {"weak": 0.2, "medium": 0.5, "severe": 0.8}
MODEL = ModelSpec(layers=32, hidden=4096, seq_len=2048, vocab=50257)


def _simulate(dp: int, slow_devices: list[int], severity: float) -> dict:
    tp = 8 // dp
    spec = ClusterSpec(n_nodes=1, gpus_per_node=8)
    job = JobSpec(model=MODEL, tp=tp, dp=dp, pp=1, micro_batches=8 * dp)
    sim = TrainingSimulator(cluster=spec, job=job)
    injector = FailSlowInjector([
        Injection(start=0.0, duration=1e9, kind=InjectionKind.GPU_SLOW,
                  target=(d,), severity=severity)
        for d in slow_devices
    ])
    t_healthy = sim.healthy_iteration_time()
    injector.apply(sim.state, 1.0)
    t_none = sim.iteration_time()

    # S2 through the control-plane strategy: profile per-DP-group
    # micro-batch times, redistribute (same solver the trainer dispatches).
    from repro.controlplane.strategies import MicroBatchStrategy, MitigationContext
    from repro.core.events import FailSlowEvent

    outcome = MicroBatchStrategy().apply(
        MitigationContext(adapter=sim, event=FailSlowEvent(start_time=0.0))
    )
    counts = outcome.detail["allocation"]
    t_s2 = sim.iteration_time()
    slow_none = t_none / t_healthy
    slow_s2 = t_s2 / t_healthy
    reduction = 0.0
    if slow_none > 1.0:
        reduction = 100 * (1 - (slow_s2 - 1) / (slow_none - 1))
    return {
        "slowdown_none": round(slow_none, 3),
        "slowdown_s2": round(slow_s2, 3),
        "excess_reduced_pct": round(reduction, 1),
        "allocation": counts,
    }


def run(smoke: bool = False) -> list[dict]:
    rows = []
    severities = {"medium": SEVERITIES["medium"]} if smoke else SEVERITIES
    # Fig. 13: DP in {2,4,8} x severity in {W,M,S}, one slow GPU.
    for dp in (2, 4) if smoke else (2, 4, 8):
        for sev_name, sev in severities.items():
            r = _simulate(dp, [0], sev)
            rows.append({"figure": "13", "dp": dp, "severity": sev_name,
                         "slow_groups": 1, **r})
    # Fig. 14: 4-DP job, 0..4 slow DP groups (medium severity).
    for k in (0, 2) if smoke else range(5):
        tp = 2
        slow = [g * tp for g in range(k)]  # first GPU of each slow group
        r = _simulate(4, slow, SEVERITIES["medium"])
        rows.append({"figure": "14", "dp": 4, "severity": "medium",
                     "slow_groups": k, **r})
    save_rows("mitigation_s2", rows)
    return rows


if __name__ == "__main__":
    print_table("Figs. 13-14 — S2 micro-batch adjustment", run())
