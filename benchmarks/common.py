"""Shared helpers for the paper-reproduction benchmark suite.

Every benchmark module exposes ``run() -> list[dict]`` (rows of one paper
table/figure). ``benchmarks.run`` executes them all, prints aligned tables,
and dumps JSON into ``results/bench/``.
"""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save_rows(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def print_table(title: str, rows: list[dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0])
    widths = {
        c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols
    }
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.monotonic() - self.t0
