"""Paper Figure 12 — accuracy of ACF-based iteration-time estimation.

A simulated job under each hybrid-parallel strategy emits its Monitor
comm-event stream (the op pattern repeats once per iteration, with several
collectives per iteration depending on the strategy); the ACF pipeline must
recover the iteration time without knowing the framework (R1). We report the
relative error vs the simulator's ground-truth iteration time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.core.acf import iteration_times_from_events
from repro.core.events import CommEvent, CommOp

#: (label, per-iteration op pattern) — richer parallelism => more collectives
STRATEGIES = {
    "S-4T1D1P": [CommOp.ALL_REDUCE] * 4,  # TP sync-heavy
    "S-2T2D1P": [CommOp.ALL_REDUCE, CommOp.ALL_REDUCE,
                 CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER],
    "S-2T1D2P": [CommOp.ALL_REDUCE, CommOp.SEND_RECV,
                 CommOp.SEND_RECV, CommOp.ALL_REDUCE],
    "S-1T2D2P": [CommOp.SEND_RECV, CommOp.REDUCE_SCATTER,
                 CommOp.ALL_GATHER, CommOp.SEND_RECV],
    "M-2T2D2P": [CommOp.ALL_REDUCE, CommOp.SEND_RECV, CommOp.REDUCE_SCATTER,
                 CommOp.ALL_GATHER, CommOp.SEND_RECV, CommOp.ALL_REDUCE],
    "M-2T4D1P": [CommOp.ALL_REDUCE, CommOp.ALL_REDUCE,
                 CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER,
                 CommOp.ALL_REDUCE],
}


def run(seed: int = 3, n_iters: int = 200, smoke: bool = False) -> list[dict]:
    if smoke:
        n_iters = min(n_iters, 60)
    rng = np.random.default_rng(seed)
    rows = []
    for label, pattern in STRATEGIES.items():
        true_iter = float(rng.uniform(0.8, 2.5))
        # Collective calls fire at (nearly) the same phase offsets every
        # iteration — the phases are fixed by the program structure; only
        # small timing noise varies across iterations.
        phases = np.sort(rng.uniform(0.05, 0.9, size=len(pattern)))
        events: list[CommEvent] = []
        t = 0.0
        for _ in range(n_iters):
            # The iteration time itself jitters ~1 %.
            it = true_iter * float(rng.normal(1.0, 0.01))
            offs = phases * it + rng.normal(0, 2e-3, size=len(pattern))
            events += [
                CommEvent(op=op, timestamp=t + o)
                for op, o in zip(pattern, np.sort(offs), strict=True)
            ]
            t += it
        est, period = iteration_times_from_events(events)
        est_mean = float(np.mean(est)) if est.size else float("nan")
        rel_err = abs(est_mean - true_iter) / true_iter * 100
        rows.append({
            "strategy": label,
            "ops_per_iter": len(pattern),
            "period_found": period,
            "true_iter_s": round(true_iter, 4),
            "est_iter_s": round(est_mean, 4),
            "rel_error_pct": round(rel_err, 3),
        })
    save_rows("iteration_estimation", rows)
    return rows


if __name__ == "__main__":
    print_table("Fig. 12 — iteration-time estimation accuracy", run())
