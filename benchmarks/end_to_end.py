"""Paper Fig. 17 / Fig. 20 / Table 7 — end-to-end FALCON at scale.

A (16DP, 4PP) 64-GPU job (paper §7.5) with a mixed injected fail-slow trace
(two communication + several computation episodes) is driven through the
*real* FalconTrainer: JAX training steps update a reduced GPT2-family model
while the cluster performance model supplies iteration times. Detection and
mitigation run through :mod:`repro.controlplane` (the trainer registers its
performance model as a job; strategies dispatch through the registry) —
equivalence with the pre-control-plane hand-wired ladder on exactly this
scenario is pinned by tests/test_controlplane.py. Three runs:

  * healthy       — no injections,
  * fail-slow     — injections, FALCON off,
  * FALCON        — injections, detect + multi-level mitigation on.

Reported: average throughput of each run and the slowdown reduction
(paper: 17.1 -> 14.8 -> 16.2 iters/min = 60.1 % of the gap recovered).
"""
from __future__ import annotations

from benchmarks.common import print_table, save_rows
from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.core.planner import DEFAULT_OVERHEADS
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import FalconTrainer

MODEL = ModelSpec(layers=40, hidden=5120, seq_len=2048, vocab=50257)  # 13B-ish
N_STEPS = 1400


def _mixed_trace(sim: TrainingSimulator) -> list[Injection]:
    """Two comm + several comp episodes over the run (paper Fig. 20 bottom).

    Episode lengths follow the paper's scale relationship: fail-slows last
    minutes-to-hours (mean 72 min at scale) while mitigation actions cost
    seconds — i.e. episodes are long relative to the ski-rental break-even
    point, so mitigation has time to pay off.
    """
    t = sim.healthy_iteration_time()
    unit = t  # one iteration
    mk = lambda s, d, kind, tgt, sev: Injection(  # noqa: E731
        start=s * unit, duration=d * unit, kind=kind, target=tgt, severity=sev
    )
    comp = InjectionKind.GPU_SLOW
    comm = InjectionKind.LINK_CONGESTION
    # Inter-node DP-ring link for (16DP,4PP) default placement: devices 7-8
    # sit in different nodes (8 GPUs per node) and are adjacent DP ranks.
    return [
        mk(25, 250, comp, (5,), 0.3),
        mk(150, 200, comp, (12,), 0.5),
        mk(420, 450, comm, (23, 24), 0.7),  # stage-1 DP ring, inter-node
        mk(500, 180, comp, (33,), 0.4),
        mk(950, 350, comm, (7, 8), 0.6),  # stage-0 DP ring, inter-node
        mk(990, 200, comp, (40,), 0.6),
        mk(1280, 100, comp, (21,), 0.35),
        mk(1290, 90, comp, (22,), 0.25),
    ]


def _make_sim() -> TrainingSimulator:
    spec = ClusterSpec(n_nodes=8, gpus_per_node=8)
    job = JobSpec(model=MODEL, tp=1, dp=16, pp=4, micro_batches=64)
    return TrainingSimulator(cluster=spec, job=job)


def _baseline_thpt(inject: bool, n_steps: int = N_STEPS) -> float:
    """Healthy / fail-slow-without-FALCON throughput: these runs involve no
    FALCON machinery, so the (deterministic) performance model alone gives
    their wall time — no need to spin 1400 real JAX steps for them."""
    sim = _make_sim()
    injector = FailSlowInjector(_mixed_trace(sim) if inject else [])
    wall = 0.0
    for _ in range(n_steps):
        injector.apply(sim.state, wall)
        wall += sim.iteration_time()
    return 60.0 * n_steps / wall


def _run_falcon(n_steps: int = N_STEPS) -> tuple[float, list]:
    """The FALCON run trains for real: JAX steps update a reduced model while
    the performance model supplies iteration times and fail-slows."""
    cfg = get_config("falcon-demo-100m").smoke()
    data = DataConfig(seq_len=32, global_batch=8, slots=2, dp_groups=4)
    sim = _make_sim()
    injector = FailSlowInjector(_mixed_trace(sim))
    trainer = FalconTrainer(
        cfg=cfg, data=data,
        opt_cfg=adamw.AdamWConfig(warmup_steps=10),
        perf_model=sim, injector=injector, falcon_enabled=True,
        overheads=dict(DEFAULT_OVERHEADS),
    )
    hist = trainer.run(n_steps)
    wall = hist[-1].wall_time
    return 60.0 * n_steps / wall, hist


def run(smoke: bool = False) -> list[dict]:
    n_steps = 120 if smoke else N_STEPS
    thpt_healthy = _baseline_thpt(inject=False, n_steps=n_steps)
    thpt_slow = _baseline_thpt(inject=True, n_steps=n_steps)
    thpt_falcon, hist = _run_falcon(n_steps=n_steps)
    gap = thpt_healthy - thpt_slow
    recovered = 100 * (thpt_falcon - thpt_slow) / gap if gap > 0 else 0.0
    strategies = [h.strategy for h in hist if h.strategy]
    losses = [h.loss for h in hist]
    rows = [{
        "healthy_iters_per_min": round(thpt_healthy, 2),
        "failslow_iters_per_min": round(thpt_slow, 2),
        "falcon_iters_per_min": round(thpt_falcon, 2),
        "slowdown_reduced_pct": round(recovered, 1),
        "paper_slowdown_reduced_pct": 60.1,
        "strategies_applied": ",".join(strategies),
        "loss_first": round(losses[0], 3),
        "loss_last": round(losses[-1], 3),
    }]
    save_rows("end_to_end", rows)
    return rows


if __name__ == "__main__":
    print_table("Fig. 20 / Table 7 — end-to-end 64-GPU", run())
