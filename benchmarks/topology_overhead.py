"""Paper Fig. 19 — topology-adjustment overhead: memory (M) vs disk (D).

The paper's S3 pauses training, dumps parameters into host memory, swaps via
RDMA, and resumes — vs the checkpoint-to-disk baseline. We measure the real
dump+restore cost of both CheckpointManager paths across model sizes
(~ GPU-memory-utilization levels) and report the speedup (paper: up to
6.72x).
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile

from benchmarks.common import print_table, save_rows
from repro.configs.base import get_config
from repro.models import model as model_lib
from repro.train.checkpoint import CheckpointManager


def _params_of_size(scale: int) -> dict:
    cfg = get_config("falcon-demo-100m").smoke()
    cfg = dataclasses.replace(
        cfg, num_layers=2 * scale, d_model=256, name=f"ckpt-bench-{scale}"
    )
    return model_lib.init_params(cfg, seed=0)


def run(smoke: bool = False) -> list[dict]:
    rows = []
    tmp = tempfile.mkdtemp(prefix="repro_ckpt_bench_")
    try:
        for scale in (1, 2) if smoke else (1, 2, 4, 8):
            params = _params_of_size(scale)
            n_bytes = sum(
                x.size * x.dtype.itemsize for x in jax_leaves(params)
            )
            ckpt = CheckpointManager(os.path.join(tmp, str(scale)))
            m_save = ckpt.save_memory(params)
            ckpt.restore_memory()
            m_restore = ckpt.last_restore_time
            d_save = ckpt.save_disk(params, step=0)
            ckpt.restore_disk(params, step=0)
            d_restore = ckpt.last_restore_time
            m_total, d_total = m_save + m_restore, d_save + d_restore
            rows.append({
                "params_mib": round(n_bytes / 2**20, 1),
                "mem_dump_restore_s": round(m_total, 4),
                "disk_dump_restore_s": round(d_total, 4),
                "speedup_m_over_d": round(d_total / max(m_total, 1e-9), 2),
            })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    save_rows("topology_overhead", rows)
    return rows


def jax_leaves(tree):
    import jax

    return jax.tree.leaves(tree)


if __name__ == "__main__":
    print_table("Fig. 19 — topology adjustment overhead (M vs D)", run())
