"""What-if replay cost — counterfactual attribution vs fresh re-runs.

The what-if engine's economic claim (deliverable: ISSUE 7 satellite f):
a leave-one-out attribution pass over a recorded campaign must cost far
less than re-running the campaign fresh once per counterfactual, because
faults-mode variants re-simulate only the jobs an episode touches
(merging the rest from the baseline) and every variant is cached by its
edit. This benchmark runs the full LOO workload (per-cause drops +
per-decision suppressions) on the ``mixed_fleet`` storm at growing fleet
sizes and reports both ledgers: job-mode runs actually executed vs the
fresh-equivalent count, and wall time vs the measured fresh-campaign
cost x edit count. The reuse ratio must clear 1.5x — if it ever
doesn't, replay is pointless and the benchmark fails loudly.
"""
from __future__ import annotations

import time

from benchmarks.common import print_table, save_rows
from repro.scenarios.campaign import MODES
from repro.whatif import WhatIfEngine, leave_one_out

FLEET_SIZES = (2, 4, 8)


def _measure(n_jobs: int, max_ticks: int | None) -> dict:
    t0 = time.monotonic()
    engine = WhatIfEngine.from_preset(
        "mixed_fleet", n_jobs=n_jobs, seed=0, max_ticks=max_ticks
    )
    # The 4-mode baseline IS the cost of one fresh scoring pipeline run:
    # without the engine, every counterfactual edit would be evaluated by
    # re-running run_and_score on the edited campaign.
    fresh_campaign_wall = time.monotonic() - t0

    t0 = time.monotonic()
    att = leave_one_out(engine)
    loo_wall = time.monotonic() - t0

    stats = engine.stats
    # One counterfactual edit per cause (drop its episodes) plus one per
    # decision (suppress it) — each would be a fresh 4-mode campaign.
    edits = len(att["per_cause"]) + len(att["per_decision"])
    fresh_job_runs = edits * len(MODES) * n_jobs
    fresh_est = edits * fresh_campaign_wall
    reuse_ratio = fresh_job_runs / max(stats["variant_job_runs"], 1)
    return {
        "jobs": n_jobs,
        "episodes": len(engine.spec.schedule),
        "edits": edits,
        "variants": stats["variants"],
        "job_runs": stats["variant_job_runs"],
        "job_runs_fresh": fresh_job_runs,
        "reuse_ratio": round(reuse_ratio, 2),
        "fresh_campaign_s": round(fresh_campaign_wall, 3),
        "loo_wall_s": round(loo_wall, 3),
        "fresh_est_s": round(fresh_est, 3),
        "wall_speedup": round(fresh_est / max(loo_wall, 1e-9), 2),
    }


def run(smoke: bool = False) -> list[dict]:
    max_ticks = 160 if smoke else None
    sizes = (2,) if smoke else FLEET_SIZES
    rows = [_measure(n, max_ticks) for n in sizes]
    for row in rows:
        # The whole point of replay: reusing the recorded baseline must
        # beat fresh re-runs on work actually executed.
        assert row["reuse_ratio"] > 1.5, (
            f"replay reuse did not pay at {row['jobs']} jobs: {row}"
        )
    save_rows("whatif_replay", rows)
    return rows


if __name__ == "__main__":
    print_table("What-if replay cost vs fresh re-runs", run())
