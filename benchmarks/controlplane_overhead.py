"""Control-plane overhead — per-tick cost at 1-64 registered jobs.

Each registered job replays a labeled characterization trace
(:class:`repro.controlplane.TraceReplayAdapter` over
``cluster.traces.sample_campaign``) through the fleet screening path
(:meth:`ControlPlane.tick`): one BatchedBOCD advances every job's stream per
tick, confirmed flags escalate into per-job pinpointing. Reported: wall
time per tick and per job-tick as the registry grows — the fleet fast
path's promise is that per-tick cost stays near-flat in the number of
registered jobs.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.traces import sample_campaign
from repro.controlplane import ControlPlane, Diagnosis, Flag, TraceReplayAdapter

N_ITERS = 400
FLEET_SIZES = (1, 4, 16, 64)


def _measure(n_jobs: int, n_iters: int, seed: int = 0) -> dict:
    traces = sample_campaign(
        seed=seed, n_jobs=n_jobs, failslow_rate=0.4, n_iters=n_iters
    )
    plane = ControlPlane()
    adapters = []
    for i, trace in enumerate(traces):
        adapter = TraceReplayAdapter(trace)
        plane.register_job(f"job{i}", adapter)
        adapters.append(adapter)

    job_ids = [j.job_id for j in plane.jobs]
    ticks = 0
    t0 = time.monotonic()
    for _ in range(n_iters):
        times = np.array([a.next_observation() for a in adapters])
        plane.tick(dict(zip(job_ids, times.tolist(), strict=True)), float(ticks))
        ticks += 1
    elapsed = time.monotonic() - t0

    flags = sum(isinstance(e, Flag) for e in plane.events)
    diagnosed = {
        e.job_id for e in plane.events
        if isinstance(e, Diagnosis) and not e.resolved
    }
    true_failslow = sum(t.has_failslow for t in traces)
    return {
        "n_jobs": n_jobs,
        "ticks": ticks,
        "total_s": round(elapsed, 3),
        "per_tick_us": round(1e6 * elapsed / ticks, 1),
        "per_job_tick_us": round(1e6 * elapsed / (ticks * n_jobs), 2),
        "flags": flags,
        "jobs_diagnosed": len(diagnosed),
        "jobs_with_failslow": true_failslow,
    }


def run(smoke: bool = False) -> list[dict]:
    sizes = (1, 4) if smoke else FLEET_SIZES
    # sample_campaign needs headroom for episode onsets (>=40+80 iters).
    n_iters = 160 if smoke else N_ITERS
    rows = [_measure(n, n_iters) for n in sizes]
    save_rows("controlplane_overhead", rows)
    return rows


if __name__ == "__main__":
    print_table("Control plane — per-tick overhead vs registered jobs", run())
