"""Control-plane overhead — per-tick cost at 1-64 registered jobs.

Each registered job replays a labeled characterization trace
(:class:`repro.controlplane.TraceReplayAdapter` over
``cluster.traces.sample_campaign``) through the fleet screening path
(:meth:`ControlPlane.tick`): one BatchedBOCD advances every job's stream per
tick, confirmed flags escalate into per-job pinpointing. Reported: wall
time per tick and per job-tick as the registry grows — the fleet fast
path's promise is that per-tick cost stays near-flat in the number of
registered jobs — plus the same loop with the observability span tracer
attached (``per_tick_traced_us`` / ``trace_overhead_pct``): the tracing
contract is <5 % per-tick overhead when on and zero extra allocations on
the hot path when off, asserted here in smoke mode.

Also measured: the fused multi-cohort screen
(``fleet_kwargs={"fused": True}`` — every warmed cohort advances in ONE
BatchedBOCD launch per tick instead of one launch per cohort;
``per_tick_fused_us`` / ``fused_delta_pct``). The fused frontier is
bitwise-equivalent to the per-cohort default (pinned by
tests/test_fleet.py), so the delta is pure launch-overhead accounting;
each row asserts the fused loop's flag stream matches the default's.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.traces import sample_campaign
from repro.controlplane import ControlPlane, Diagnosis, Flag, TraceReplayAdapter
from repro.obs import SpanTracer

N_ITERS = 400
FLEET_SIZES = (1, 4, 16, 64)
#: the observability layer's documented tick-overhead budget
TRACE_BUDGET_PCT = 5.0
#: best-of-N repetitions per configuration (min absorbs scheduler noise,
#: which would otherwise flake the smoke-mode budget assertion)
REPEATS = 5


def _tick_loop(
    n_jobs: int, n_iters: int, seed: int, tracer=None, fused: bool = False
) -> tuple:
    traces = sample_campaign(
        seed=seed, n_jobs=n_jobs, failslow_rate=0.4, n_iters=n_iters
    )
    plane = ControlPlane(
        tracer=tracer, fleet_kwargs={"fused": True} if fused else None
    )
    adapters = []
    for i, trace in enumerate(traces):
        adapter = TraceReplayAdapter(trace)
        plane.register_job(f"job{i}", adapter)
        adapters.append(adapter)

    job_ids = [j.job_id for j in plane.jobs]
    ticks = 0
    t0 = time.monotonic()
    for _ in range(n_iters):
        times = np.array([a.next_observation() for a in adapters])
        plane.tick(dict(zip(job_ids, times.tolist(), strict=True)), float(ticks))
        ticks += 1
    elapsed = time.monotonic() - t0
    return plane, traces, ticks, elapsed


def _measure(n_jobs: int, n_iters: int, seed: int = 0) -> dict:
    # Paired repeats: each repeat times both variants back to back (order
    # alternating — frequency scaling and cache warmth favor whichever
    # loop runs first) and yields one overhead estimate from loops that
    # shared system state. The row reports the MEDIAN pair (robust
    # center) and the BEST pair (the achievability bound the smoke gate
    # asserts on: under additive noise, min-over-pairs converges on the
    # true overhead from above). One untimed warmup round first.
    _tick_loop(n_jobs, min(n_iters, 160), seed)
    plane = traces = ticks = None
    plane_f = None
    base = traced = fused = float("inf")
    pair_pcts: list[float] = []
    for rep in range(REPEATS):
        if rep % 2 == 0:
            plane, traces, ticks, elapsed = _tick_loop(n_jobs, n_iters, seed)
            _, _, _, elapsed_t = _tick_loop(
                n_jobs, n_iters, seed, tracer=SpanTracer()
            )
            plane_f, _, _, elapsed_f = _tick_loop(
                n_jobs, n_iters, seed, fused=True
            )
        else:
            plane_f, _, _, elapsed_f = _tick_loop(
                n_jobs, n_iters, seed, fused=True
            )
            _, _, _, elapsed_t = _tick_loop(
                n_jobs, n_iters, seed, tracer=SpanTracer()
            )
            plane, traces, ticks, elapsed = _tick_loop(n_jobs, n_iters, seed)
        base = min(base, elapsed)
        traced = min(traced, elapsed_t)
        fused = min(fused, elapsed_f)
        pair_pcts.append(100.0 * (elapsed_t - elapsed) / elapsed)
    pair_pcts.sort()

    # The fused screen must be behaviorally indistinguishable from the
    # per-cohort default — same typed event stream, launch count aside.
    ev, ev_f = list(plane.events), list(plane_f.events)
    assert len(ev) == len(ev_f) and all(
        type(a) is type(b) and a.__dict__ == b.__dict__
        for a, b in zip(ev, ev_f)
    ), f"fused screen event stream diverged at n_jobs={n_jobs}"

    flags = sum(isinstance(e, Flag) for e in plane.events)
    diagnosed = {
        e.job_id for e in plane.events
        if isinstance(e, Diagnosis) and not e.resolved
    }
    true_failslow = sum(t.has_failslow for t in traces)
    return {
        "n_jobs": n_jobs,
        "ticks": ticks,
        "total_s": round(base, 3),
        "per_tick_us": round(1e6 * base / ticks, 1),
        "per_job_tick_us": round(1e6 * base / (ticks * n_jobs), 2),
        "per_tick_traced_us": round(1e6 * traced / ticks, 1),
        "trace_overhead_pct": round(pair_pcts[len(pair_pcts) // 2], 2),
        "trace_overhead_best_pct": round(pair_pcts[0], 2),
        "per_tick_fused_us": round(1e6 * fused / ticks, 1),
        "fused_delta_pct": round(100.0 * (fused - base) / base, 2),
        "flags": flags,
        "jobs_diagnosed": len(diagnosed),
        "jobs_with_failslow": true_failslow,
    }


def run(smoke: bool = False) -> list[dict]:
    sizes = (1, 4) if smoke else FLEET_SIZES
    # Smoke keeps the small fleets but the full iteration count: a 160-tick
    # loop finishes in ~40 ms, where scheduler jitter alone reads as +-10 %
    # and would flake the budget assertion below.
    n_iters = N_ITERS
    rows = [_measure(n, n_iters) for n in sizes]
    if smoke:
        # Gate on each size's best paired estimate: single-pair readings
        # on a ~300 us/tick denominator carry +-5 % scheduler noise, so
        # the enforceable claim is achievability — at least one
        # noise-shared pair per size must land inside the budget. The
        # reported (median) figure tracks the typical cost.
        worst = max(r["trace_overhead_best_pct"] for r in rows)
        assert worst < TRACE_BUDGET_PCT, (
            f"tracing overhead best-pair {worst:.2f}% exceeds the "
            f"{TRACE_BUDGET_PCT}% per-tick budget: {rows}"
        )
    save_rows("controlplane_overhead", rows)
    return rows


if __name__ == "__main__":
    print_table("Control plane — per-tick overhead vs registered jobs", run())
