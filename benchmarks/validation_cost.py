"""Paper Fig. 9 — O(1) communicator validation.

Verifies that the ring/tree P2P decomposition uses a constant number of
passes regardless of group size (ring: 2 even / 3 odd; tree: 4), that every
pass is node-disjoint (fully parallel), that all links are covered, and that
an injected slow link is pinpointed. Compares against the naive sequential
sweep (O(n) passes).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.core import validation


def run(seed: int = 17, smoke: bool = False) -> list[dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for n in (4, 7, 16) if smoke else (4, 7, 16, 64, 128, 512):
        # --- ring ---
        passes = validation.ring_passes(n)
        links = validation.ring_links(n)
        covered = {frozenset(p) for ps in passes for p in ps} == {
            frozenset(l) for l in links
        }
        slow_link = tuple(links[int(rng.integers(len(links)))])
        measure = lambda pair: 3.0 if set(pair) == set(slow_link) else float(  # noqa: B023,E731
            rng.normal(1.0, 0.02)
        )
        slow, _ = validation.validate_links(passes, measure)
        rows.append({
            "topology": "ring", "ranks": n,
            "passes": len(passes), "naive_passes": len(links),
            "disjoint": validation.check_disjoint(passes),
            "covered": covered,
            "slow_link_found": any(set(s) == set(slow_link) for s in slow),
        })
        # --- tree ---
        parents = validation.binary_tree_parents(n)
        tpasses = validation.tree_passes(parents)
        tlinks = validation.tree_links(parents)
        tcovered = {frozenset(p) for ps in tpasses for p in ps} == {
            frozenset(l) for l in tlinks
        }
        slow_link = tuple(tlinks[int(rng.integers(len(tlinks)))])
        slow, _ = validation.validate_links(tpasses, measure)
        rows.append({
            "topology": "tree", "ranks": n,
            "passes": len(tpasses), "naive_passes": len(tlinks),
            "disjoint": validation.check_disjoint(tpasses),
            "covered": tcovered,
            "slow_link_found": any(set(s) == set(slow_link) for s in slow),
        })
    save_rows("validation_cost", rows)
    return rows


if __name__ == "__main__":
    print_table("Fig. 9 — O(1) communicator validation", run())
