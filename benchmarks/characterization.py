"""Paper Table 1 / Figure 1 — characterization campaign.

The production traces are not public, so the campaign is *regenerated* from
the paper's published statistics (occurrence rates and durations per
category), then FALCON-DETECT measures what a deployment would have seen:
per-category job counts and the JCT slowdown each category inflicts,
computed with the hybrid-parallel iteration-time simulator.

Campaigns (paper §3.1-3.4):
  * 1-node: 392 jobs, GPT2-11B, (2TP,1DP,2PP) on 4 GPUs
  * 4-node: 107 jobs, GPT2-7B, (2TP,4DP,1PP) on 8 GPUs across 4 nodes
  * at-scale: 27 jobs, >=512 GPUs, (8TP,16DP,4PP)
"""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec

#: per-job fail-slow occurrence rates measured in the paper (Table 1)
CAMPAIGNS = {
    # dur_frac: mean episode duration as a fraction of the job (paper: ~10 min
    # of a 70-90 min 1-node job; ~24 min of a ~5 h 4-node job; 72 min mean and
    # recurring episodes for the at-scale month-trace jobs).
    "1-node": dict(
        jobs=392, tp=2, dp=1, pp=2, nodes=1, gpus_per_node=4,
        model=ModelSpec(layers=40, hidden=4736, seq_len=2048, vocab=50257),
        iters=10_000, p=dict(cpu=4 / 392, gpu=2 / 392, link=0.0),
        dur_frac=0.15, max_link_eps=1, n_comp_eps=2,
    ),
    "4-node": dict(
        jobs=107, tp=2, dp=4, pp=1, nodes=4, gpus_per_node=2,
        model=ModelSpec(layers=36, hidden=4032, seq_len=2048, vocab=50257),
        iters=10_000, p=dict(cpu=1 / 107, gpu=0.0, link=42 / 107),
        dur_frac=0.1, max_link_eps=3, n_comp_eps=1,
    ),
    "at-scale": dict(
        jobs=27, tp=8, dp=16, pp=4, nodes=64, gpus_per_node=8,
        model=ModelSpec(layers=96, hidden=12288, seq_len=4096, vocab=50257),
        iters=20_000, p=dict(cpu=0.0, gpu=3 / 27, link=16 / 27),
        dur_frac=0.15, max_link_eps=5, n_comp_eps=1,
    ),
}


def _sample_job(rng, spec: ClusterSpec, p: dict, horizon: float,
                dur_frac: float, max_link_eps: int, n_comp_eps: int = 1):
    inj = []
    mean_dur = dur_frac * horizon
    if rng.random() < p["cpu"]:
        for _ in range(n_comp_eps):
            inj.append(Injection(
                start=float(rng.uniform(0, horizon * 0.8)),
                duration=float(rng.exponential(mean_dur)),
                kind=InjectionKind.CPU_CONTENTION,
                target=(int(rng.integers(spec.n_nodes)),),
                severity=float(rng.uniform(0.2, 0.5)),
            ))
    if rng.random() < p["gpu"]:
        for _ in range(n_comp_eps):
            inj.append(Injection(
                start=float(rng.uniform(0, horizon * 0.8)),
                duration=float(rng.exponential(mean_dur)),
                kind=InjectionKind.GPU_SLOW,
                target=(int(rng.integers(spec.n_devices)),),
                severity=float(rng.uniform(0.2, 0.55)),
            ))
    if spec.n_nodes > 1 and rng.random() < p["link"]:
        # Network congestion recurs (Fig. 5): several episodes per slow job,
        # each hitting a NIC (side-channel contention slows the whole port).
        for _ in range(int(rng.integers(1, max_link_eps + 1))):
            node = int(rng.integers(spec.n_nodes))
            inj.append(Injection(
                start=float(rng.uniform(0, horizon * 0.8)),
                duration=float(rng.exponential(mean_dur)),
                kind=InjectionKind.NIC_CONGESTION,
                target=(node,),
                severity=float(rng.uniform(0.4, 0.9)),
            ))
    return inj


def _job_jct(sim: TrainingSimulator, injector: FailSlowInjector, iters: int) -> tuple[float, float]:
    """(actual JCT, healthy JCT) integrating iteration time over episodes.

    Iteration time is piecewise-constant between injection boundaries, so we
    integrate analytically instead of stepping 10k iterations.
    """
    t_healthy = sim.healthy_iteration_time()
    bounds = sorted(
        {0.0}
        | {i.start for i in injector.injections}
        | {i.end for i in injector.injections}
    )
    total_iters, wall = 0, 0.0
    horizon_iters = iters
    for k, lo in enumerate(bounds):
        if total_iters >= horizon_iters:
            break
        injector.apply(sim.state, lo + 1e-9)
        t_iter = sim.iteration_time()
        hi = bounds[k + 1] if k + 1 < len(bounds) else float("inf")
        if hi == float("inf"):
            n = horizon_iters - total_iters
        else:
            n = min(horizon_iters - total_iters, max(0, int((hi - lo) / t_iter)))
        total_iters += n
        wall += n * t_iter
    return wall, horizon_iters * t_healthy


def run(seed: int = 7, smoke: bool = False) -> list[dict]:
    rows = []
    for name, c in CAMPAIGNS.items():
        if smoke:
            c = dict(c, jobs=min(c["jobs"], 24), iters=min(c["iters"], 2000))
        # crc32, not hash(): str hashes are per-process randomized, which
        # would make a paper-reproduction benchmark non-reproducible.
        rng = np.random.default_rng([seed, zlib.crc32(name.encode())])
        spec = ClusterSpec(n_nodes=c["nodes"], gpus_per_node=c["gpus_per_node"])
        job = JobSpec(model=c["model"], tp=c["tp"], dp=c["dp"], pp=c["pp"],
                      micro_batches=max(8, 2 * c["dp"]))
        counts = {"none": 0, "cpu": 0, "gpu": 0, "link": 0, "multi": 0}
        slowdowns = []
        sim = TrainingSimulator(cluster=spec, job=job)
        horizon = c["iters"] * sim.healthy_iteration_time()
        for _ in range(c["jobs"]):
            inj = _sample_job(
                rng, spec, c["p"], horizon, c["dur_frac"],
                c["max_link_eps"], c["n_comp_eps"],
            )
            injector = FailSlowInjector(inj)
            kinds = {i.kind for i in inj}
            if not inj:
                counts["none"] += 1
            elif len(kinds) > 1:
                counts["multi"] += 1
            elif InjectionKind.CPU_CONTENTION in kinds:
                counts["cpu"] += 1
            elif InjectionKind.GPU_SLOW in kinds:
                counts["gpu"] += 1
            else:
                counts["link"] += 1
            jct, jct0 = _job_jct(sim, injector, c["iters"])
            if inj:
                slowdowns.append(jct / jct0 - 1.0)
        rows.append({
            "campaign": name,
            "jobs": c["jobs"],
            "no_failslow": counts["none"],
            "cpu_contention": counts["cpu"],
            "gpu_degradation": counts["gpu"],
            "network_congestion": counts["link"],
            "multiple": counts["multi"],
            "avg_jct_slowdown_pct": round(
                100 * float(np.mean(slowdowns)) if slowdowns else 0.0, 2
            ),
        })
    save_rows("characterization", rows)
    return rows


if __name__ == "__main__":
    print_table("Table 1 — characterization", run())
