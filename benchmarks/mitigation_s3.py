"""Paper Figures 15-16 — effectiveness of topology adjustment (S3).

Fig. 15: 2-node 16-GPU jobs with PP in {4,8}. The deployment places DP rings
*across* nodes (the paper's setting: DP communication is inter-node RDMA, PP
is the light axis). One inter-node DP-ring link is congested
(weak/medium/severe); S3 computes a placement permutation (QAP local search)
that moves heavy DP traffic off the congested physical link.

Fig. 16: (4DP,4PP) on 4 nodes; 1..4 congested inter-node links each hitting a
*different* PP stage's DP ring. S3's adjustment consolidates the affected
traffic so fewer stage rings touch congested links (paper: 2 slow links over
2 stages -> one stage, 1.7x -> 1.3x).
"""
from __future__ import annotations

from benchmarks.common import print_table, save_rows
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec

MODEL = ModelSpec(layers=32, hidden=4096, seq_len=2048, vocab=50257)
SEVERITIES = {"weak": 0.3, "medium": 0.6, "severe": 0.85}


def _interleaved(job: JobSpec) -> list[int]:
    """Placement with DP outermost physically: position(s,d) -> device d*pp+s.

    Each stage's DP ring then spans all nodes — the paper's deployment where
    DP gradients cross the inter-node network while PP hops stay local.
    """
    topo = job.topology
    perm = [0] * topo.size
    for s in range(job.pp):
        for d in range(job.dp):
            for k in range(job.tp):
                perm[topo.position(s, d, k)] = (d * job.pp + s) * job.tp + k
    return perm


def _apply_s3(sim: TrainingSimulator) -> list[int]:
    """S3 through the control-plane strategy (QAP local search; the event
    carries no pinpointed components, so the general adjustment path runs).
    The strategy re-measures before committing, so a non-improving plan is
    reverted instead of applied blindly."""
    from repro.controlplane.strategies import MitigationContext, TopologyStrategy
    from repro.core.events import FailSlowEvent

    TopologyStrategy(max_rounds=32).apply(
        MitigationContext(adapter=sim, event=FailSlowEvent(start_time=0.0))
    )
    return list(sim.placement)


def _ring_edges(sim: TrainingSimulator, stage: int) -> list[tuple[int, int]]:
    devs = [sim.device_at(stage, d, 0) for d in range(sim.job.dp)]
    return [(devs[i], devs[(i + 1) % len(devs)]) for i in range(len(devs))]


def _affected_stages(sim: TrainingSimulator, congested: set[frozenset]) -> int:
    n = 0
    for s in range(sim.job.pp):
        if any(frozenset(e) in congested for e in _ring_edges(sim, s)):
            n += 1
    return n


def _fig15(pp: int, sev_name: str, severity: float) -> dict:
    """NIC congestion on one node: every inter-node flow through that node is
    slowed (the paper's side-channel contention). With the initial placement
    routing heavy DP rings across nodes, S3's QAP relocates DP traffic
    intra-node and leaves only light PP hops on the congested NIC —
    mitigation is partial, as in the paper."""
    spec = ClusterSpec(n_nodes=2, gpus_per_node=8)
    dp = 16 // pp
    job = JobSpec(model=MODEL, tp=1, dp=dp, pp=pp, micro_batches=4 * dp)
    sim = TrainingSimulator(cluster=spec, job=job, placement=_interleaved(job))
    # Healthy reference: the best placement under healthy links, so S3 gains
    # are never conflated with simply fixing a suboptimal initial layout.
    ref = TrainingSimulator(cluster=spec, job=job, placement=_interleaved(job))
    _apply_s3(ref)
    t_healthy = min(sim.iteration_time(), ref.iteration_time())
    sim.state.degrade_nic(1, 1.0 - severity)
    t_none = sim.iteration_time()
    _apply_s3(sim)
    t_s3 = sim.iteration_time()
    slow_none, slow_s3 = t_none / t_healthy, t_s3 / t_healthy
    red = 100 * (1 - (slow_s3 - 1) / (slow_none - 1)) if slow_none > 1 else 0.0
    return {
        "figure": "15", "scenario": f"pp={pp} {sev_name}",
        "slowdown_none": round(slow_none, 3),
        "slowdown_s3": round(slow_s3, 3),
        "excess_reduced_pct": round(red, 1),
        "stages_affected_before": "-",
        "stages_affected_after": "-",
    }


def _fig16(n_slow_links: int) -> dict:
    """(4DP,4PP) over 4 nodes; each congested link hits a distinct stage."""
    spec = ClusterSpec(n_nodes=4, gpus_per_node=4)
    job = JobSpec(model=MODEL, tp=1, dp=4, pp=4, micro_batches=16)
    sim = TrainingSimulator(cluster=spec, job=job, placement=_interleaved(job))
    ref = TrainingSimulator(cluster=spec, job=job, placement=_interleaved(job))
    _apply_s3(ref)
    t_healthy = min(sim.iteration_time(), ref.iteration_time())
    congested: set[frozenset] = set()
    for s in range(n_slow_links):
        edge = next(
            e for e in _ring_edges(sim, s)
            if spec.node_of(e[0]) != spec.node_of(e[1])
        )
        sim.state.degrade_link(*edge, 0.3)
        congested.add(frozenset(edge))
    t_none = sim.iteration_time()
    before = _affected_stages(sim, congested)
    _apply_s3(sim)
    t_s3 = sim.iteration_time()
    after = _affected_stages(sim, congested)
    slow_none, slow_s3 = t_none / t_healthy, t_s3 / t_healthy
    red = 100 * (1 - (slow_s3 - 1) / (slow_none - 1)) if slow_none > 1 else 0.0
    return {
        "figure": "16", "scenario": f"{n_slow_links} slow links",
        "slowdown_none": round(slow_none, 3),
        "slowdown_s3": round(slow_s3, 3),
        "excess_reduced_pct": round(red, 1),
        "stages_affected_before": before,
        "stages_affected_after": after,
    }


def run(smoke: bool = False) -> list[dict]:
    rows = []
    severities = {"medium": SEVERITIES["medium"]} if smoke else SEVERITIES
    for pp in (4,) if smoke else (4, 8):
        for sev_name, sev in severities.items():
            rows.append(_fig15(pp, sev_name, sev))
    for k in (1, 2) if smoke else (1, 2, 3, 4):
        rows.append(_fig16(k))
    save_rows("mitigation_s3", rows)
    return rows


if __name__ == "__main__":
    print_table("Figs. 15-16 — S3 topology adjustment", run())
