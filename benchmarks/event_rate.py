"""Per-event simulator update cost under campaign-rate fault churn.

The fleet-scale benchmark tracks healthy-step and full-recompute
throughput; this one tracks what dominates a *churny* fleet: the cost of
one fail-slow event — a single injector-style state mutation followed by
``iteration_time()``. The event-scoped invalidation path (typed dirty sets
consumed through per-reader cursors, docs/simulator.md) re-reduces only the
cells the event touches; the baseline column forces the pre-refactor
behavior (``sim.incremental = False``): every event invalidates the whole
memo and triggers the full vectorized recompute.

Events alternate degrade/restore per component class so the active fault
set stays bounded, like a campaign where episodes arrive and resolve; the
``campaign_mix`` row weights the four classes by the fault model's default
cause mix (:mod:`repro.scenarios.faults`: gpu 0.30 / cpu 0.20 / link 0.30 /
nic 0.20). A ``remap`` row times one S2P-style ``remap_groups`` candidate
swap + re-measure. Every mode's final state is checked bit-identical
against the ``iteration_time_reference()`` loop oracle.

Results land in ``results/bench/event_rate.json`` and are mirrored to
``BENCH_events.json`` at the repo root (the tracked perf-trajectory
artifact; acceptance: >= 10x on the campaign mix at 10k devices).
"""
from __future__ import annotations

import json
import os
import statistics
import time

from benchmarks.common import print_table, save_rows
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec

MODEL = ModelSpec(layers=40, hidden=5120, seq_len=2048, vocab=50257)
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_events.json")

#: default cause mix of repro.scenarios.faults.FaultModel
MIX = (("gpu", 0.30), ("cpu", 0.20), ("link", 0.30), ("nic", 0.20))


def _make_sim(n_devices: int) -> TrainingSimulator:
    tp, pp = 8, 8
    dp = n_devices // (tp * pp)
    job = JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp, micro_batches=2 * dp)
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=n_devices // 8), job=job
    )


def _mutate(sim: TrainingSimulator, mode: str, i: int, salt: int) -> None:
    """One fail-slow event: a degrade on even steps, the matching restore
    on odd ones (bounded active set — campaign churn, not accumulation)."""
    n = sim.cluster.n_devices
    nodes = sim.cluster.n_nodes
    eps = 1e-9 * (i + salt)  # every degrade is a fresh value, never a no-op
    if mode == "gpu":
        sim.state.devices[((i // 2) * 37) % n].compute_speed = (
            0.9 - eps if i % 2 == 0 else 1.0
        )
    elif mode == "cpu":
        node = ((i // 2) * 11) % nodes
        per = sim.cluster.gpus_per_node
        v = 0.8 - eps if i % 2 == 0 else 1.0
        for d in range(node * per, (node + 1) * per):
            sim.state.devices[d].host_speed = v
    elif mode == "link":
        j = i // 2
        a = (j * 13) % n
        b = (a + 64) % n
        if i % 2 == 0:
            sim.state.degrade_link(a, b, 0.5 - eps)
        else:
            sim.state.restore_link(a, b)
    else:  # nic
        node = ((i // 2) * 7) % nodes
        if i % 2 == 0:
            sim.state.degrade_nic(node, 0.6 - eps)
        else:
            sim.state.restore_nic(node)


def _per_event_ms(
    sim: TrainingSimulator, mode: str, incremental: bool,
    reps: int, trials: int,
) -> float:
    sim.incremental = incremental
    times = []
    for trial in range(trials):
        sim.state.reset()
        sim.iteration_time()
        t0 = time.perf_counter()
        for i in range(reps):
            _mutate(sim, mode, i, salt=trial * reps)
            sim.iteration_time()
        times.append((time.perf_counter() - t0) / reps * 1e3)
    return statistics.median(times)


def _remap_ms(sim: TrainingSimulator, incremental: bool,
              reps: int, trials: int) -> float:
    """One S2P-style measure-before-commit step: swap two ranks across DP
    groups, re-measure, swap back (the candidate-evaluation inner loop)."""
    sim.incremental = incremental
    tp = sim.job.tp
    times = []
    for _ in range(trials):
        sim.state.reset()
        sim.state.devices[3].compute_speed = 0.5  # something to evaluate
        sim.iteration_time()
        t0 = time.perf_counter()
        for i in range(reps):
            perm = list(sim.placement)
            a = (i * tp) % len(perm)
            b = (a + tp) % len(perm)
            perm[a], perm[b] = perm[b], perm[a]
            sim.remap_groups(perm)
            sim.iteration_time()
        times.append((time.perf_counter() - t0) / reps * 1e3)
    return statistics.median(times)


def _dirty_per_event(sim: TrainingSimulator, mode: str, reps: int = 64) -> float:
    """Mean typed components dirtied per event, read through the
    ClusterAdapter cursor surface (``state_cursor`` / ``dirty_since`` —
    the per-reader protocol of docs/simulator.md). This is the quantity
    the event-scoped recompute's cost is proportional to."""
    sim.state.reset()
    sim.iteration_time()
    total = 0
    cursor = sim.state_cursor()
    for i in range(reps):
        _mutate(sim, mode, i, salt=0)
        ds = sim.dirty_since(cursor)
        cursor = sim.state_cursor()
        total += len(ds.devices) + len(ds.links) + len(ds.nics)
        sim.iteration_time()
    sim.state.reset()
    return total / reps


def _rows_for(n_devices: int, reps: int, trials: int) -> list[dict]:
    sim = _make_sim(n_devices)
    sim.iteration_time()
    rows = []
    mix_full = mix_inc = 0.0
    for mode, weight in MIX:
        full = _per_event_ms(sim, mode, False, reps, trials)
        inc = _per_event_ms(sim, mode, True, reps, trials)
        assert sim.iteration_time() == sim.iteration_time_reference()
        mix_full += weight * full
        mix_inc += weight * inc
        rows.append({
            "devices": n_devices,
            "event": mode,
            "dirty_per_event": round(_dirty_per_event(sim, mode), 1),
            "full_ms": round(full, 4),
            "incremental_ms": round(inc, 4),
            "speedup": round(full / inc, 1),
        })
    rows.append({
        "devices": n_devices,
        "event": "campaign_mix",
        "full_ms": round(mix_full, 4),
        "incremental_ms": round(mix_inc, 4),
        "speedup": round(mix_full / mix_inc, 1),
    })
    full = _remap_ms(sim, False, max(reps // 4, 10), trials)
    inc = _remap_ms(sim, True, max(reps // 4, 10), trials)
    assert sim.iteration_time() == sim.iteration_time_reference()
    rows.append({
        "devices": n_devices,
        "event": "remap_swap",
        "full_ms": round(full, 4),
        "incremental_ms": round(inc, 4),
        "speedup": round(full / inc, 1),
    })
    return rows


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        cfgs = [(256, 60, 2)]
    else:
        cfgs = [(1024, 600, 7), (10240, 600, 7)]
    rows: list[dict] = []
    for n_devices, reps, trials in cfgs:
        rows += _rows_for(n_devices, reps, trials)
    save_rows("event_rate", rows)
    if not smoke:  # the tracked perf-trajectory artifact
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    print_table("Event-rate: per-event update + iteration_time", run())
