"""Fleet-scale fast-path throughput (beyond-paper; ROADMAP north star).

FALCON's production claim is continuous detection over ~10k GPUs at <1 %
overhead (paper §7, Fig. 18). This benchmark tracks the two hot paths this
repo needs for that regime:

* **Detection**: ticks/s of the batched fleet screen
  (:class:`FleetDetect` / :class:`BatchedBOCD`, bounded shared run-length
  frontier) over >=4096 concurrent worker streams, against the looped
  per-worker scalar BOCD the seed used — measured on a subsample and scaled,
  since the loop is exactly linear in workers.
* **Simulation**: iteration-time model throughput at 1k/4k/10k devices —
  memoized healthy steps, forced recomputes (fail-slow events), and the
  original nested-loop reference.

Results land in ``results/bench/fleet_scale.json`` and are mirrored to
``BENCH_fleet.json`` at the repo root so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.core import bocd
from repro.core.detector import FleetDetect

MODEL = ModelSpec(layers=40, hidden=5120, seq_len=2048, vocab=50257)
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")


def _fleet_traces(n_workers: int, n_ticks: int, seed: int = 0) -> np.ndarray:
    """(T, B) iteration times: healthy jitter + 2 % of workers fail-slow."""
    rng = np.random.default_rng(seed)
    x = rng.normal(1.0, 0.01, (n_ticks, n_workers))
    bad = rng.choice(n_workers, max(1, n_workers // 50), replace=False)
    x[n_ticks // 2 :, bad] *= 1.4
    return x


def _detection_rows(
    n_workers: int, n_ticks: int, scalar_workers: int, backend: str = "auto"
) -> dict:
    x = _fleet_traces(n_workers, n_ticks)

    factory = bocd.select_backend(backend)
    fleet = FleetDetect(n_workers=n_workers, backend=factory)
    t0 = time.perf_counter()
    flags = [f for t in range(n_ticks) for f in fleet.tick(x[t])]
    batched_s = time.perf_counter() - t0
    batched_rate = n_workers * n_ticks / batched_s

    # Looped scalar baseline (the seed's only option): one BOCD per worker,
    # same screening statistic per tick. Cost is exactly linear in workers;
    # measure a subsample and scale to the fleet.
    m = min(scalar_workers, n_workers)
    scale = bocd.noise_scale_batch(x[:8, :m])  # same warmup as FleetDetect
    dets = [
        bocd.BOCD(mu0=float(x[0, w] / scale[w])) for w in range(m)
    ]
    t0 = time.perf_counter()
    for t in range(n_ticks):
        for w in range(m):
            dets[w].update(float(x[t, w] / scale[w]))
            dets[w].p_recent_change()
    scalar_s = time.perf_counter() - t0
    scalar_rate = m * n_ticks / scalar_s

    return {
        "workers": n_workers,
        "ticks": n_ticks,
        "backend": factory.name,
        "flags": len(flags),
        "batched_ticks_per_s": round(n_ticks / batched_s, 1),
        "batched_worker_upd_per_s": round(batched_rate),
        "scalar_worker_upd_per_s": round(scalar_rate),
        "speedup": round(batched_rate / scalar_rate, 1),
        "scalar_sample_workers": m,
    }


def _make_sim(n_devices: int) -> tuple[TrainingSimulator, FailSlowInjector]:
    tp, pp = 8, 8
    dp = n_devices // (tp * pp)
    job = JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp, micro_batches=2 * dp)
    sim = TrainingSimulator(cluster=ClusterSpec(n_nodes=n_devices // 8), job=job)
    inj = FailSlowInjector([
        Injection(start=100.0, duration=1e9, kind=InjectionKind.GPU_SLOW,
                  target=(3,), severity=0.4),
    ])
    return sim, inj


def _simulator_rows(n_devices: int, healthy_steps: int, recomputes: int) -> dict:
    sim, inj = _make_sim(n_devices)
    wall = 0.0
    t0 = time.perf_counter()
    for _ in range(healthy_steps):
        inj.apply(sim.state, wall)
        wall += sim.iteration_time()
    healthy_s = time.perf_counter() - t0

    # Keep this column's meaning stable across PRs: the cost of one *full*
    # vectorized pass (the event-scoped incremental path has its own
    # benchmark, benchmarks/event_rate.py).
    sim.incremental = False
    t0 = time.perf_counter()
    for i in range(recomputes):  # every step invalidates -> full recompute
        sim.state.devices[5].compute_speed = 0.9 - 1e-9 * i
        sim.iteration_time()
    recompute_s = time.perf_counter() - t0
    sim.incremental = True

    t0 = time.perf_counter()
    ref_reps = max(1, recomputes // 10)
    for _ in range(ref_reps):
        sim.iteration_time_reference()
    reference_s = (time.perf_counter() - t0) / ref_reps

    return {
        "devices": n_devices,
        "memoized_steps_per_s": round(healthy_steps / healthy_s),
        "recompute_ms": round(1e3 * recompute_s / recomputes, 3),
        "reference_ms": round(1e3 * reference_s, 2),
        "recompute_speedup": round(reference_s / (recompute_s / recomputes), 1),
    }


def _backend_parity_gate() -> dict:
    """Smoke-mode gate: the numpy and Pallas screening backends must raise
    the *same* flags on the same traces (the registry promise the CI
    ``kernels`` job enforces), and the Pallas reduction backend must agree
    with the vectorized simulator within its documented tolerance."""
    n_workers, n_ticks = 96, 60
    x = _fleet_traces(n_workers, n_ticks, seed=7)
    flags: dict[str, list] = {}
    for name in ("batched", "pallas"):
        fleet = FleetDetect(n_workers=n_workers, backend=name)
        flags[name] = sorted(
            (t, f.worker) for t in range(n_ticks) for f in fleet.tick(x[t])
        )
    if flags["batched"] != flags["pallas"]:
        raise SystemExit(
            f"screening backend parity FAILED: numpy raised "
            f"{flags['batched']} but pallas raised {flags['pallas']}"
        )

    from repro.cluster.simulator import REDUCTION_BACKENDS

    sim, inj = _make_sim(512)
    inj.apply(sim.state, 200.0)  # a faulted, non-trivial topology
    want = sim.iteration_time()
    rb = REDUCTION_BACKENDS["pallas"]()
    got = float(rb.iteration_time(sim))
    rel = abs(got - want) / want
    if rel > rb.tolerance:
        raise SystemExit(
            f"reduction backend parity FAILED: pallas {got} vs "
            f"vectorized {want} (rel err {rel:.2e} > {rb.tolerance})"
        )
    return {
        "path": "parity",
        "workers": n_workers,
        "ticks": n_ticks,
        "flags": len(flags["batched"]),
        "backend": "batched==pallas",
        "reduction_rel_err": float(f"{rel:.3g}"),
    }


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        det_cfgs = [(512, 60, 16)]
        sim_cfgs = [(256, 200, 5)]
    else:
        det_cfgs = [(4096, 200, 64), (8192, 200, 64), (16384, 200, 64)]
        sim_cfgs = [(1024, 2000, 50), (4096, 2000, 20), (10240, 1000, 20)]
    rows: list[dict] = []
    for workers, ticks, scalar_workers in det_cfgs:
        # Auto-selection: compiled Pallas on GPU/TPU jax, vectorized numpy
        # on CPU — the backend column records which one this box measured.
        r = _detection_rows(workers, ticks, scalar_workers, backend="auto")
        rows.append({"path": "detection", **r})
    if smoke:
        rows.append(_backend_parity_gate())
    for devices, steps, recomputes in sim_cfgs:
        r = _simulator_rows(devices, steps, recomputes)
        rows.append({"path": "simulation", **r})
    # One aligned table: pad both row schemas to the shared column set.
    cols = list(dict.fromkeys(k for r in rows for k in r))
    rows = [{c: r.get(c, "") for c in cols} for r in rows]
    save_rows("fleet_scale", rows)
    if not smoke:  # the tracked perf-trajectory artifact
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale + numpy-vs-pallas backend parity gate")
    args = ap.parse_args()
    print_table("Fleet-scale fast path", run(smoke=args.smoke))
