"""Campaign engine throughput — ticks/s as the fleet grows.

Drives the full falcon-mode campaign loop (fault translation + injector
apply + vectorized performance model + fleet screen + pinpoint/dedupe +
mitigation dispatch + membership churn) for increasing job counts on the
storm-like fault mix, and reports wall time per tick and per job-tick. The
subsystem's cost promise: per-tick cost stays near-flat in job count (one
batched frontier update per warmed cohort plus O(1) per-job bookkeeping),
so campaign wall time scales with ticks, not with ticks x jobs.
"""
from __future__ import annotations

import time

from benchmarks.common import print_table, save_rows
from repro.scenarios import FaultModel, JobTemplate, ScenarioPreset
from repro.scenarios.campaign import build_campaign, run_campaign

FLEET_SIZES = (2, 4, 8, 16)


def _preset(max_ticks: int) -> ScenarioPreset:
    return ScenarioPreset(
        name="bench_storm",
        description="throughput benchmark workload",
        n_nodes=2, gpus_per_node=8, tick_seconds=5.0, max_ticks=max_ticks,
        join_spread_ticks=max_ticks // 4,
        job_templates=(
            JobTemplate("yi-9b", tp=1, dp=4, pp=2, micro_batches=16),
            JobTemplate("granite-3-8b", tp=2, dp=2, pp=1, micro_batches=16,
                        span_nodes=1),
        ),
        fault_model=FaultModel(rate_per_hour=60.0),
    )


def _measure(n_jobs: int, max_ticks: int) -> dict:
    spec = build_campaign(_preset(max_ticks), n_jobs=n_jobs, seed=0)
    t0 = time.monotonic()
    result = run_campaign(spec, "falcon")
    wall = time.monotonic() - t0
    ticks = max(result.ticks_run, 1)
    return {
        "jobs": n_jobs,
        "nodes": spec.n_nodes,
        "ticks": result.ticks_run,
        "injections": len(spec.schedule),
        "events": len(result.events),
        "wall_s": round(wall, 3),
        "tick_us": round(1e6 * wall / ticks, 1),
        "job_tick_us": round(1e6 * wall / (ticks * n_jobs), 2),
    }


def run(smoke: bool = False) -> list[dict]:
    max_ticks = 80 if smoke else 400
    sizes = (2,) if smoke else FLEET_SIZES
    rows = [_measure(n, max_ticks) for n in sizes]
    save_rows("campaign_throughput", rows)
    return rows


if __name__ == "__main__":
    print_table("Campaign engine throughput", run())
