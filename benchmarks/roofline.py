"""Roofline analysis from the multi-pod dry-run artifacts (deliverable g).

Reads ``results/dryrun/*.json`` (produced by ``repro.launch.dryrun``) and
derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / peak_FLOPs_per_chip
  memory term     = HLO_bytes / HBM_bw_per_chip
  collective term = collective_bytes / ICI_link_bw

``compiled.cost_analysis()`` reports the *per-device partitioned module*, and
XLA counts a ``lax.scan``/``while`` body ONCE regardless of trip count. Our
steps scan over micro-batch slots (train) and over layer periods (all kinds),
so the HLO numbers underestimate per-step work by a known factor. We
therefore also report the analytic MODEL_FLOPS (6·N_active·tokens for
training, 2·N_active·tokens for inference, per device) and use
``max(hlo, model)`` — the conservative estimate — for the bottleneck call.
The MODEL/HLO ratio column makes the undercount visible, as required.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table, save_rows
from repro.cluster.spec import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS_BF16
from repro.configs.base import INPUT_SHAPES, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

#: micro-batch slots in the train step (see launch/dryrun.py SLOTS)
SLOTS = 8

ADVICE = {
    "compute": "shard more FLOPs onto the model axis / raise MXU utilization"
               " (fused attention kernel, larger per-core tiles)",
    "memory": "cut HBM traffic: fuse elementwise chains, keep weights"
              " resident, batch decode requests to reuse parameters",
    "collective": "reduce collective volume: overlap grad reduce-scatter"
                  " with backward, hierarchical pod-local reductions first",
}


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    cfg = get_config(arch)
    info = INPUT_SHAPES[shape]
    n_active = cfg.active_params()
    if info["kind"] == "train":
        tokens = info["seq_len"] * info["global_batch"]
        total = 6.0 * n_active * tokens
    elif info["kind"] == "prefill":
        tokens = info["seq_len"] * info["global_batch"]
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * info["global_batch"]
    return total / n_devices


def analyze(record: dict) -> dict:
    arch, shape, mesh = record["arch"], record["shape"], record["mesh"]
    n_dev = record["n_devices"]
    hlo_flops = record["flops"]
    hlo_bytes = record["bytes_accessed"]
    coll = sum(record["collective_bytes"].values())

    m_flops = model_flops_per_device(arch, shape, n_dev)
    flops_est = max(hlo_flops, m_flops)

    t_compute = flops_est / TPU_PEAK_FLOPS_BF16
    t_memory = hlo_bytes / TPU_HBM_BW
    t_coll = coll / TPU_ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "compute_s": float(f"{t_compute:.3e}"),
        "memory_s": float(f"{t_memory:.3e}"),
        "collective_s": float(f"{t_coll:.3e}"),
        "dominant": dominant,
        "model_flops_dev": float(f"{m_flops:.3e}"),
        "hlo_flops_dev": float(f"{hlo_flops:.3e}"),
        "model_over_hlo": round(m_flops / hlo_flops, 2) if hlo_flops else 0.0,
        "peak_gib_dev": round(record["bytes_per_device"]["peak"] / 2**30, 2),
        "advice": ADVICE[dominant],
    }


def run(mesh_filter: str | None = "16x16") -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        rows.append(analyze(rec))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    save_rows("roofline", rows)
    return rows


if __name__ == "__main__":
    rows = run()
    slim = [
        {k: r[k] for k in (
            "arch", "shape", "compute_s", "memory_s", "collective_s",
            "dominant", "model_over_hlo", "peak_gib_dev",
        )}
        for r in rows
    ]
    print_table("Roofline (single-pod 16x16)", slim)
