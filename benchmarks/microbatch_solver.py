"""Paper Table 6 — time to find the optimal micro-batch distribution.

The paper's cvxpy QP needs ~36 s at 512 DP groups; our exact greedy
list-scheduling solver (provably optimal for this min-max) is microseconds.
We also report the achieved makespan vs a brute-force lower bound on small
instances to confirm optimality is not traded for speed.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_rows
from repro.core.microbatch import makespan, solve_allocation

PAPER_CVXPY_S = {16: 0.01, 32: 0.01, 64: 0.01, 128: 0.11, 256: 6.78, 512: 35.93}


def run(seed: int = 5, smoke: bool = False) -> list[dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for d in (16, 64) if smoke else (16, 32, 64, 128, 256, 512):
        times = rng.uniform(0.8, 1.6, size=d)
        times[rng.integers(d)] *= 2.0  # one straggling DP group
        m = 4 * d  # micro-batches per iteration
        reps = 3 if smoke else 20
        t0 = time.perf_counter()
        for _ in range(reps):
            counts = solve_allocation(times, m)
        dt = (time.perf_counter() - t0) / reps
        rows.append({
            "dp_groups": d,
            "micro_batches": m,
            "solve_time_s": round(dt, 6),
            "paper_cvxpy_s": PAPER_CVXPY_S[d],
            "speedup_vs_paper": round(PAPER_CVXPY_S[d] / max(dt, 1e-9), 1),
            "makespan": round(makespan(counts, times), 4),
        })
    save_rows("microbatch_solver", rows)
    return rows


if __name__ == "__main__":
    print_table("Table 6 — micro-batch solver time", run())
