"""Tests for S3 topology adjustment + straggler consolidation (paper §5.3)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import topology as tp


def uniform_bandwidth(n, bw=1.0):
    b = np.full((n, n), bw)
    np.fill_diagonal(b, np.inf)
    return b


def test_traffic_matrix_dp_heavier_than_pp():
    """Appendix 9.2: Comm_DP >> Comm_PP — DP edges must carry more volume."""
    topo = tp.HybridTopology(tp=1, dp=2, pp=2)
    t = tp.build_traffic_matrix(topo, comm_tp=0.0, comm_dp=100.0, comm_pp=1.0)
    dp_edge = t[topo.position(0, 0, 0), topo.position(0, 1, 0)]
    pp_edge = t[topo.position(0, 0, 0), topo.position(1, 0, 0)]
    assert dp_edge > pp_edge


def test_swap_moves_congested_link_to_light_group():
    """Fig. 10 scenario: 4 nodes, (1TP, 2DP, 2PP); the link between devices
    2-3 is congested. Identity placement routes DP traffic over it; the
    planner must find a permutation that puts light PP traffic there."""
    topo = tp.HybridTopology(tp=1, dp=2, pp=2)
    traffic = tp.build_traffic_matrix(topo, comm_tp=0.0, comm_dp=100.0, comm_pp=1.0)
    bw = uniform_bandwidth(4, 10.0)
    bw[2, 3] = bw[3, 2] = 1.0  # congested physical link

    base_cost = tp.assignment_cost(list(range(4)), traffic, bw)
    perm = tp.plan_topology_adjustment(traffic, bw)
    new_cost = tp.assignment_cost(perm, traffic, bw)
    assert new_cost < base_cost
    # The congested pair (2,3) must no longer carry a DP edge.
    inv = {d: p for p, d in enumerate(perm)}
    p2, p3 = inv[2], inv[3]
    dp_pairs = set()
    for s in range(2):
        a = topo.position(s, 0, 0)
        b = topo.position(s, 1, 0)
        dp_pairs.add(frozenset((a, b)))
    assert frozenset((p2, p3)) not in dp_pairs


def test_consolidation_reduces_straggler_stages():
    """Fig. 11: stragglers scattered over 2 stages must be consolidated
    into 1 (4 GPUs per stage, 2 stragglers)."""
    topo = tp.HybridTopology(tp=2, dp=2, pp=4)
    stragglers = [1, 5]  # stage 0 and stage 1 under identity placement
    assert tp.straggler_stage_count(list(range(topo.size)), stragglers, topo) == 2
    perm = tp.consolidate_stragglers(stragglers, topo)
    assert sorted(perm) == list(range(topo.size))
    assert tp.straggler_stage_count(perm, stragglers, topo) == 1


def test_consolidation_prefers_interior_stages():
    topo = tp.HybridTopology(tp=1, dp=2, pp=4)
    perm = tp.consolidate_stragglers([0], topo)
    slow_pos = perm.index(0)
    stage = topo.stage_of(slow_pos)
    assert stage not in (0, topo.pp - 1)


def test_consolidation_min_stage_formula():
    """ceil(#stragglers / GPUs-per-stage) stages (paper §5.3)."""
    topo = tp.HybridTopology(tp=2, dp=2, pp=4)  # 4 GPUs per stage
    for k in (1, 3, 4, 5, 8):
        stragglers = list(range(k))
        perm = tp.consolidate_stragglers(stragglers, topo)
        want = -(-k // 4)
        assert tp.straggler_stage_count(perm, stragglers, topo) == want


@settings(max_examples=20, deadline=None)
@given(
    dp=st.integers(min_value=1, max_value=3),
    pp=st.integers(min_value=2, max_value=4),
    tpsz=st.integers(min_value=1, max_value=2),
    seed=st.integers(min_value=0, max_value=50),
)
def test_property_adjustment_never_hurts(dp, pp, tpsz, seed):
    """Local search can only improve (bottleneck, total) lexicographic cost."""
    topo = tp.HybridTopology(tp=tpsz, dp=dp, pp=pp)
    traffic = tp.build_traffic_matrix(topo, comm_tp=10.0, comm_dp=50.0, comm_pp=1.0)
    rng = np.random.default_rng(seed)
    n = topo.size
    bw = uniform_bandwidth(n, 10.0)
    # Degrade a random link.
    if n >= 2:
        a, b = rng.choice(n, size=2, replace=False)
        bw[a, b] = bw[b, a] = 0.5
    base = tp.assignment_cost(list(range(n)), traffic, bw)
    perm = tp.plan_topology_adjustment(traffic, bw)
    assert sorted(perm) == list(range(n))
    assert tp.assignment_cost(perm, traffic, bw) <= base


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(min_value=0, max_value=8),
    pp=st.integers(min_value=1, max_value=4),
)
def test_property_consolidation_is_permutation(k, pp):
    topo = tp.HybridTopology(tp=2, dp=1, pp=pp)
    stragglers = list(range(min(k, topo.size)))
    perm = tp.consolidate_stragglers(stragglers, topo)
    assert sorted(perm) == list(range(topo.size))
