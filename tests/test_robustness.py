"""Robustness tests: hang anomalies + the fault-tolerant executor.

* Injector: hang multipliers compose with ordinary throttles on the same
  component and un-compose cleanly when either episode ends.
* Watchdog: zero false positives on healthy high-jitter streams across
  seeds; fires on real silence; a resume-after-stall beat re-anchors
  without poisoning the calibrated deadline.
* End to end: a collective hang produces WatchdogAlarm -> hang-flagged
  Diagnosis -> applied ABORT_REFORM, and the job's stream recovers.
* Planner: the hang break-even caps benefit at work_remaining and never
  enters the B/lambda hold-out zone.
* Executor: injected dispatch failures surface as typed per-attempt
  MitigationResults, roll the simulator back bit-identically, and
  quarantine the strategy after K consecutive failures; a strategy that
  raises (or a wedged adapter) degrades to a typed event, not a crash.
* Campaign acceptance: collective_hang detects >= 95 % of hangs with no
  false alarms and aborts within the preset budget; flaky_executor
  surfaces every injected failure with zero uncaught errors.
"""
import numpy as np
import pytest

from repro.cluster.injector import (
    HANG_EPS,
    FailSlowInjector,
    Injection,
    InjectionKind,
)
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.controlplane import (
    ControlPlane,
    Diagnosis,
    ExecutorPolicy,
    MitigationResult,
    WatchdogAlarm,
    placement_registry,
)
from repro.core.detector import Watchdog
from repro.core.events import FailSlowEvent, RootCause, Strategy
from repro.core.planner import MitigationPlanner
from repro.scenarios import run_and_score

MODEL = ModelSpec(layers=32, hidden=8192, seq_len=2048, vocab=32000,
                  micro_batch=2)

OVERHEADS = {
    Strategy.IGNORE: 0.0,
    Strategy.ADJUST_MICROBATCH: 2.0,
    "S2P": 5.0,
    Strategy.ADJUST_TOPOLOGY: 10.0,
    "S3P": 15.0,
    "ABORT_REFORM": 25.0,
    Strategy.CKPT_AND_RESTART: 1800.0,
}


def make_sim():
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=4),
        job=JobSpec(model=MODEL, tp=2, dp=4, pp=1, micro_batches=16),
    )


# ---------------------------------------------------- injector hang kinds
def test_hang_composes_with_throttle_and_uncomposes_cleanly():
    """A hang stacked on a throttle multiplies (not clobbers), and each
    episode's relief restores exactly the other's multiplier."""
    inj = FailSlowInjector([
        Injection(10.0, 100.0, InjectionKind.GPU_SLOW, (2,), 0.5),
        Injection(50.0, 20.0, InjectionKind.GPU_HANG, (2,), 1.0),
    ])
    sim = make_sim()
    inj.apply(sim.state, 20.0)
    assert sim.state.devices[2].compute_speed == pytest.approx(0.5)
    assert not sim.stalled()
    inj.apply(sim.state, 60.0)  # overlap: throttle x hang
    assert sim.state.devices[2].compute_speed == pytest.approx(0.5 * HANG_EPS)
    assert sim.stalled()
    inj.apply(sim.state, 80.0)  # hang aborted/over: throttle remains
    assert sim.state.devices[2].compute_speed == pytest.approx(0.5)
    assert not sim.stalled()
    inj.apply(sim.state, 200.0)  # both over: baseline restored
    assert sim.state.devices[2].compute_speed == pytest.approx(1.0)


def test_collective_hang_stalls_the_job():
    sim = make_sim()
    inj = FailSlowInjector([
        Injection(0.0, 100.0, InjectionKind.COLLECTIVE_HANG, (2, 4), 1.0,
                  scope="dp"),
    ])
    inj.apply(sim.state, 10.0)
    assert sim.stalled()
    assert sim.iteration_time() > 1e4 * sim.healthy_iteration_time()


# ----------------------------------------------------------- watchdog
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_watchdog_zero_false_positives_on_healthy_jitter(seed):
    """A healthy but jittery cadence (gaps 0.5x-2x nominal) never trips the
    calibrated deadline — the false-positive budget is exactly zero."""
    rng = np.random.default_rng(seed)
    wd = Watchdog()
    now = 0.0
    fired = 0
    for _ in range(500):
        gap = 5.0 * float(rng.uniform(0.5, 2.0))
        if wd.expired("j", now + gap):  # checked right before the late beat
            fired += 1
        now += gap
        wd.beat("j", now)
    assert fired == 0


def test_watchdog_fires_on_silence_and_reanchors_on_resume():
    wd = Watchdog()
    now = 0.0
    for _ in range(10):
        now += 5.0
        wd.beat("j", now)
    deadline = wd.deadline("j")
    assert deadline == pytest.approx(15.0)  # floor_gaps x mean on a 5s beat
    assert not wd.expired("j", now + 6.0)
    assert wd.expired("j", now + 21.0)
    # A resume beat after a long stall re-anchors the heartbeat but must
    # not fold the stall gap into the jitter stats (deadline unchanged).
    wd.beat("j", now + 500.0)
    assert wd.deadline("j") == pytest.approx(deadline)
    assert not wd.expired("j", now + 506.0)


# ------------------------------------------- hang end-to-end (tentpole)
def test_hang_alarm_diagnosis_abort_reform_end_to_end():
    """Silence -> WatchdogAlarm -> hang-flagged Diagnosis -> ABORT_REFORM
    applied -> the stream recovers (the hang injection is aborted)."""
    sim = make_sim()
    injector = FailSlowInjector([
        Injection(300.0, 1e9, InjectionKind.COLLECTIVE_HANG, (2, 4), 1.0,
                  scope="dp"),
    ])
    plane = ControlPlane()
    plane.register_job(
        "A", sim, registry=placement_registry(), overheads=dict(OVERHEADS),
        injector=injector, sample_period=5.0,
    )
    rng = np.random.default_rng(0)
    events = []
    for tick in range(120):
        injector.apply(sim.state, tick * 5.0)
        now = (tick + 1) * 5.0
        if sim.stalled():
            events += plane.tick({}, now)  # hung job emits no sample
        else:
            it = sim.iteration_time() * float(rng.normal(1, 0.003))
            events += plane.tick({"A": it}, now)

    alarms = [e for e in events if isinstance(e, WatchdogAlarm)]
    assert len(alarms) == 1
    assert alarms[0].silence_s > alarms[0].deadline_s > 0.0
    hang_diags = [
        e for e in events
        if isinstance(e, Diagnosis) and not e.resolved and e.event.hang
    ]
    assert hang_diags
    aborts = [
        e for e in events
        if isinstance(e, MitigationResult) and e.kind == "mitigate"
        and e.applied and e.strategy == "ABORT_REFORM"
    ]
    assert len(aborts) == 1
    assert aborts[0].status == "ok"
    assert aborts[0].detail.get("reformed")
    # The abort removed the hung collective: the job streams again.
    assert not sim.stalled()
    assert not any(
        i.kind is InjectionKind.COLLECTIVE_HANG for i in injector.injections
    )


# -------------------------------------------------- hang ski-rental
def _hang_event():
    ev = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.NETWORK_CONGESTION,
        t_healthy=1.0, t_slow=500.0,
    )
    ev.hang = True
    return ev


def test_hang_threshold_caps_benefit_at_work_remaining():
    lam = MitigationPlanner(_hang_event(), dict(OVERHEADS)).prediction_lambda
    # Plenty of work left: acting is clearly profitable -> fire early.
    p = MitigationPlanner(
        _hang_event(), dict(OVERHEADS), work_remaining=lambda: 1e6,
    )
    th = p._threshold(Strategy.ADJUST_TOPOLOGY, delta=499.0, t_now=500.0)
    assert th == pytest.approx(10.0 * lam)
    # Almost no work left: nothing to save -> classic break-even.
    p = MitigationPlanner(
        _hang_event(), dict(OVERHEADS), work_remaining=lambda: 1.0,
    )
    th = p._threshold(Strategy.ADJUST_TOPOLOGY, delta=499.0, t_now=500.0)
    assert th == pytest.approx(10.0)
    # No window callbacks at all: an unbounded hang is always worth ending.
    p = MitigationPlanner(_hang_event(), dict(OVERHEADS))
    th = p._threshold(Strategy.ADJUST_TOPOLOGY, delta=499.0, t_now=500.0)
    assert th == pytest.approx(10.0 * lam)


def test_hang_threshold_never_enters_holdout():
    """The survival-curve hold-out (B/lambda) is bypassed for hangs: the
    threshold is never above the classic overhead, for any window."""
    for work in (0.0, 0.5, 5.0, 50.0, 5e3, float("inf")):
        p = MitigationPlanner(
            _hang_event(), dict(OVERHEADS), work_remaining=lambda w=work: w,
        )
        th = p._threshold(Strategy.ADJUST_TOPOLOGY, delta=499.0, t_now=500.0)
        assert th <= 10.0 + 1e-12


# ----------------------------------------------- snapshot / rollback
def _snap_equal(a, b):
    assert list(a["placement"]) == list(b["placement"])
    assert list(a["allocation"]) == list(b["allocation"])
    assert np.array_equal(a["compute"], b["compute"])
    assert np.array_equal(a["host"], b["host"])
    assert a["link_mult"] == b["link_mult"]
    assert a["nic_mult"] == b["nic_mult"]


def test_snapshot_restore_bit_identical():
    sim = make_sim()
    snap = sim.snapshot()
    t0 = sim.iteration_time()
    # Mutate every surface the snapshot covers.
    sim.state.devices[1].compute_speed = 0.4
    sim.state.degrade_link(0, 4, 0.2)
    sim.set_allocation([5, 5, 3, 3])
    sim.placement = list(reversed(sim.placement))
    assert sim.iteration_time() != t0
    sim.restore(snap)
    _snap_equal(sim.snapshot(), snap)
    assert sim.iteration_time() == t0  # exact, not approx: bit-identical


def test_executor_rollback_bit_identical_and_quarantine():
    """Every dispatch fails: each attempt surfaces as a typed non-ok result,
    the simulator is rolled back to the pre-action snapshot exactly, and
    the strategy is quarantined after K consecutive failures."""
    sim = make_sim()
    plane = ControlPlane(
        executor_policy=ExecutorPolicy(
            max_attempts=2, backoff_base_s=1.0, quarantine_after=2,
        ),
        executor_faults=lambda job_id, strategy, attempt, now: "fail",
    )
    plane.register_job("A", sim, overheads=dict(OVERHEADS), sample_period=5.0)
    rng = np.random.default_rng(2)
    frozen = None
    events = []
    for tick in range(140):
        if tick == 40:
            sim.state.devices[1].compute_speed = 0.4
            frozen = sim.snapshot()  # post-fault, pre-mitigation reference
        it = sim.iteration_time() * float(rng.normal(1, 0.003))
        events += plane.tick({"A": it}, (tick + 1) * 5.0)

    results = [
        e for e in events
        if isinstance(e, MitigationResult) and e.kind == "mitigate"
    ]
    dispatched = [r for r in results if r.strategy is not Strategy.IGNORE]
    assert dispatched
    for r in dispatched:
        assert not r.applied
        assert r.status in ("failed", "timed_out", "rolled_back")
        assert r.detail.get("rolled_back") or r.detail.get("injected")
    # Retries happened (attempt counts past 1) and backoff was charged.
    assert any(r.attempt > 1 for r in dispatched)
    assert any(r.overhead > 0.0 for r in dispatched)
    # Consecutive failures quarantined the rung for this (cause, strategy).
    assert any(r.detail.get("quarantined") for r in dispatched)
    assert plane.job("A")._quarantined
    # Bit-identical rollback: nothing the failed dispatches touched stuck.
    _snap_equal(sim.snapshot(), frozen)


def test_quarantined_strategy_excluded_from_new_planner():
    """A quarantined (cause, strategy) pair is dropped from the candidate
    ladder of the *next* event with that cause."""
    sim = make_sim()
    plane = ControlPlane()
    plane.register_job("A", sim, overheads=dict(OVERHEADS), sample_period=5.0)
    plane.job("A")._quarantined.add(
        (RootCause.GPU_DEGRADATION, Strategy.ADJUST_MICROBATCH)
    )
    rng = np.random.default_rng(4)
    events = []
    for tick in range(140):
        if tick == 40:
            sim.state.devices[1].compute_speed = 0.4
        it = sim.iteration_time() * float(rng.normal(1, 0.003))
        events += plane.tick({"A": it}, (tick + 1) * 5.0)
    dispatched = [
        e.strategy for e in events
        if isinstance(e, MitigationResult) and e.kind == "mitigate"
        and e.applied
    ]
    assert Strategy.ADJUST_MICROBATCH not in dispatched
    assert Strategy.ADJUST_TOPOLOGY in dispatched  # ladder skipped past it


# ------------------------------------------------ graceful degradation
class WedgedPinpointSim(TrainingSimulator):
    """An adapter that raises mid-pinpoint (profiling RPC wedged)."""

    def profile_groups(self):
        raise RuntimeError("profiling channel wedged")


def test_tick_survives_wedged_adapter_and_keeps_other_jobs():
    """One job's adapter raising mid-tick yields a typed kind='error'
    result for that job; the other job's pipeline keeps running."""
    sim_a = WedgedPinpointSim(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=4),
        job=JobSpec(model=MODEL, tp=2, dp=4, pp=1, micro_batches=16),
    )
    sim_b = make_sim()
    plane = ControlPlane()
    plane.register_job("A", sim_a, overheads=dict(OVERHEADS), sample_period=5.0)
    plane.register_job("B", sim_b, overheads=dict(OVERHEADS), sample_period=5.0)
    rng = np.random.default_rng(6)
    events = []
    for tick in range(100):
        if tick == 40:
            sim_a.state.devices[1].compute_speed = 0.4
            sim_b.state.devices[1].compute_speed = 0.4
        ta = sim_a.iteration_time() * float(rng.normal(1, 0.003))
        tb = sim_b.iteration_time() * float(rng.normal(1, 0.003))
        events += plane.tick({"A": ta, "B": tb}, (tick + 1) * 5.0)
    errors = [
        e for e in events
        if isinstance(e, MitigationResult) and e.kind == "error"
    ]
    assert errors and all(e.job_id == "A" for e in errors)
    assert "RuntimeError" in errors[0].detail["error"]
    # B's pipeline was untouched by A's failures: it diagnosed its fault.
    assert any(
        isinstance(e, Diagnosis) and e.job_id == "B" and not e.resolved
        for e in events
    )


def test_raising_strategy_becomes_failed_result():
    """A strategy whose apply() raises is a failed attempt (rolled back),
    not an uncaught exception."""

    class ExplodingStrategy:
        key = "EXPLODE"

        def handles(self, event):
            return True

        def apply(self, ctx):
            raise ValueError("boom")

        def relieve(self, ctx):
            return None

    from repro.controlplane import StrategyRegistry
    from repro.controlplane.strategies import IgnoreStrategy

    sim = make_sim()
    registry = (
        StrategyRegistry()
        .register(IgnoreStrategy())
        .register(ExplodingStrategy(), overhead=1.0)
    )
    plane = ControlPlane(
        executor_policy=ExecutorPolicy(max_attempts=1, quarantine_after=99),
    )
    plane.register_job(
        "A", sim, registry=registry,
        overheads={Strategy.IGNORE: 0.0, "EXPLODE": 1.0},
        sample_period=5.0,
    )
    rng = np.random.default_rng(8)
    events = []
    for tick in range(80):
        if tick == 30:
            sim.state.devices[1].compute_speed = 0.4
        it = sim.iteration_time() * float(rng.normal(1, 0.003))
        events += plane.tick({"A": it}, (tick + 1) * 5.0)
    failed = [
        e for e in events
        if isinstance(e, MitigationResult) and e.strategy == "EXPLODE"
    ]
    assert failed
    assert all(not e.applied for e in failed)
    assert any("ValueError" in e.detail.get("error", "") for e in failed)


# ------------------------------------------------- campaign acceptance
def test_collective_hang_campaign_acceptance():
    """ISSUE acceptance: >= 95 % of injected hangs watchdog-detected, zero
    false alarms on healthy jobs, median time-to-abort under the preset's
    deadline budget, and both jobs still finish under falcon."""
    _, runs, report = run_and_score("collective_hang", seed=0)
    wd = report["robustness"]["watchdog"]
    assert wd["hangs_injected"] >= 2
    assert wd["hang_detection_rate"] >= 0.95
    assert wd["false_alarms"] == 0
    assert wd["median_time_to_abort_s"] <= wd["deadline_budget_s"]
    assert report["robustness"]["executor"]["uncaught_errors"] == 0
    assert all(o.finished for o in runs["falcon"].outcomes.values())
    waste = report["robustness"]["wasted_gpu_time_s"]
    assert waste["falcon"] < 0.1 * waste["faults"]


def test_flaky_executor_campaign_typed_failures():
    """ISSUE acceptance: every injected apply-failure surfaces as a typed
    non-ok MitigationResult (rolled back), with zero uncaught errors."""
    _, runs, report = run_and_score("flaky_executor", seed=0)
    ex = report["robustness"]["executor"]
    counts = ex["dispatch_results"]
    assert counts["failed"] + counts["timed_out"] > 0
    assert counts["ok"] > 0  # retries eventually land some dispatches
    assert ex["retries"] > 0
    assert ex["uncaught_errors"] == 0
    for ev in runs["falcon"].events:
        if (
            isinstance(ev, MitigationResult) and ev.kind == "mitigate"
            and ev.status in ("failed", "timed_out")
        ):
            assert not ev.applied
            assert ev.detail.get("rolled_back")
            assert "injected" in ev.detail or "error" in ev.detail
