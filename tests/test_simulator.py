"""Tests for the cluster performance model + FALCON integration."""
import numpy as np
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.core import microbatch as mb
from repro.core.detector import FalconDetect, suspicious_groups
from repro.core.events import RootCause


def small_job(tp=2, dp=2, pp=2, micro_batches=8):
    model = ModelSpec(layers=24, hidden=4096, seq_len=2048, vocab=50257)
    return JobSpec(model=model, tp=tp, dp=dp, pp=pp, micro_batches=micro_batches)


def make_sim(tp=2, dp=2, pp=2, nodes=2, micro_batches=8):
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=nodes, gpus_per_node=4),
        job=small_job(tp, dp, pp, micro_batches),
    )


def test_healthy_iteration_time_positive_and_stable():
    sim = make_sim()
    t0 = sim.iteration_time()
    assert t0 > 0
    assert sim.iteration_time() == pytest.approx(t0)
    assert sim.healthy_iteration_time() == pytest.approx(t0)


def test_gpu_slowdown_increases_iteration_time():
    sim = make_sim()
    t0 = sim.iteration_time()
    sim.state.devices[0].compute_speed = 0.5
    t1 = sim.iteration_time()
    assert t1 > t0 * 1.2


def test_link_congestion_increases_iteration_time():
    sim = make_sim(tp=1, dp=4, pp=2, nodes=2)
    t0 = sim.iteration_time()
    # Degrade an inter-node link used by the DP ring.
    a = sim.device_at(0, 0, 0)
    b = sim.device_at(0, 1, 0)
    sim.state.degrade_link(a, b, 0.1)
    t1 = sim.iteration_time()
    assert t1 > t0


def test_cpu_contention_slows_whole_node():
    sim = make_sim()
    inj = FailSlowInjector(
        [
            Injection(
                start=0.0, duration=100.0,
                kind=InjectionKind.CPU_CONTENTION, target=(0,), severity=0.3,
            )
        ]
    )
    t0 = sim.iteration_time()
    inj.apply(sim.state, now=10.0)
    assert sim.iteration_time() > t0
    # GEMM benchmark must NOT flag the GPUs (paper case study 1).
    comp = sim.benchmark_compute(list(range(4)))
    assert max(comp.values()) == pytest.approx(min(comp.values()))
    inj.apply(sim.state, now=200.0)  # expired
    assert sim.iteration_time() == pytest.approx(t0)


def test_s2_microbatch_rebalance_recovers_throughput():
    """Fig. 13 mechanics: a slow GPU in one DP group; S2 allocation reduces
    the iteration time versus the even split."""
    sim = make_sim(tp=1, dp=4, pp=1, nodes=1, micro_batches=16)
    sim.state.devices[2].compute_speed = 0.4
    t_slow = sim.iteration_time()
    counts = mb.solve_allocation(sim.per_microbatch_times(), 16)
    sim.set_allocation(counts)
    t_fixed = sim.iteration_time()
    assert t_fixed < t_slow
    t_healthy = sim.healthy_iteration_time()
    mitigated = (t_slow - t_fixed) / (t_slow - t_healthy)
    assert mitigated > 0.4  # recovers >40 % of the injected slowdown


def test_s3_placement_swap_mitigates_congestion():
    """Fig. 10 mechanics: congested inter-node link on the DP ring; a
    placement permutation moving it to PP traffic reduces iteration time."""
    from repro.core import topology as tp_mod

    sim = make_sim(tp=1, dp=2, pp=4, nodes=2, micro_batches=8)
    # Find an inter-node DP-ring link and congest it.
    a = sim.device_at(1, 0, 0)
    b = sim.device_at(1, 1, 0)
    sim.state.degrade_link(a, b, 0.05)
    t_cong = sim.iteration_time()

    topo = sim.job.topology
    m = sim.job.model
    traffic = tp_mod.build_traffic_matrix(
        topo,
        comm_tp=m.comm_tp_bytes(sim.job.tp, sim.job.pp, sim.job.micro_batches),
        comm_dp=m.comm_dp_bytes(sim.job.tp, sim.job.pp),
        comm_pp=m.comm_pp_bytes(sim.job.micro_batches),
    )
    n = sim.job.n_devices
    bw = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            bw[i, j] = sim.state.link_bw(sim.placement[i], sim.placement[j]) if i != j else np.inf
    perm = tp_mod.plan_topology_adjustment(traffic, bw)
    sim.apply_placement(perm)
    t_adj = sim.iteration_time()
    assert t_adj < t_cong


def test_detector_pinpoints_gpu_failslow_in_simulator():
    """End-to-end FALCON-DETECT against the simulator: onset detection,
    profiling, GEMM validation, root-cause = GPU degradation."""
    sim = make_sim(tp=2, dp=2, pp=1, nodes=1, micro_batches=8)
    det = FalconDetect(cluster=sim, verify_window=8)
    now = 0.0
    event = None
    for it in range(120):
        if it == 60:
            sim.state.devices[1].compute_speed = 0.5
        t = sim.iteration_time() * float(np.random.default_rng(it).normal(1, 0.005))
        now += t
        ev = det.observe(t, now)
        event = ev or event
    assert event is not None
    assert event.root_cause == RootCause.GPU_DEGRADATION
    assert "gpu:1" in event.components


def test_detector_pinpoints_link_failslow_in_simulator():
    sim = make_sim(tp=1, dp=4, pp=1, nodes=2, micro_batches=8)
    det = FalconDetect(cluster=sim, verify_window=8)
    a, b = sim.device_at(0, 0, 0), sim.device_at(0, 1, 0)
    now, event = 0.0, None
    for it in range(120):
        if it == 60:
            sim.state.degrade_link(a, b, 0.1)
        t = sim.iteration_time() * float(np.random.default_rng(1000 + it).normal(1, 0.005))
        now += t
        ev = det.observe(t, now)
        event = ev or event
    assert event is not None
    assert event.root_cause == RootCause.NETWORK_CONGESTION
    lo, hi = min(a, b), max(a, b)
    assert any(
        c == f"link:{lo}-{hi}" or c == f"link:{hi}-{lo}" or c == f"link:{a}-{b}" or c == f"link:{b}-{a}"
        for c in event.components
    )


def test_profile_groups_flags_suspicious():
    sim = make_sim(tp=1, dp=4, pp=2, nodes=2)
    a, b = sim.device_at(0, 1, 0), sim.device_at(0, 2, 0)
    sim.state.degrade_link(a, b, 0.2)
    sus = suspicious_groups(sim.profile_groups())
    assert any(g.startswith("dp:") for g in sus)


def test_allocation_and_placement_validation():
    sim = make_sim()
    with pytest.raises(ValueError):
        sim.set_allocation([1, 2, 3])
    with pytest.raises(ValueError):
        sim.apply_placement([0, 0, 1, 2, 3, 4, 5, 6])


def test_restart_resets():
    sim = make_sim(tp=1, dp=4, pp=1, nodes=1, micro_batches=8)
    sim.set_allocation([1, 1, 1, 5])
    sim.apply_placement([3, 2, 1, 0])
    sim.restart()
    assert sim.allocation == [2, 2, 2, 2]
    assert sim.placement == [0, 1, 2, 3]
