"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward/train step + one decode step on CPU; shape and NaN checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_config, list_archs
from repro.models import model as model_lib
from repro.models import transformer

ARCHS = [a for a in list_archs() if a != "falcon-demo-100m"]

#: architectures whose smoke step takes >10 s on CPU — slow-marked so the
#: tier-1 default stays fast; CI's slow step still covers every family
HEAVY_ARCHS = {
    "jamba-1.5-large-398b", "qwen2-vl-72b", "mamba2-2.7b",
    "musicgen-large", "qwen2-moe-a2.7b", "olmoe-1b-7b",
}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_ARCHS else a
    for a in ARCHS
]

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.modality == "vision_embeds":
        batch = {
            "embeds": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), cfg.activation_dtype
            ),
            "positions": jnp.asarray(
                np.broadcast_to(np.arange(S), (3, B, S)).copy(), jnp.int32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        }
    elif cfg.modality == "audio_codes":
        k = cfg.num_codebooks
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S, k))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S, k))),
        }
    else:
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        }
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = get_config(arch).smoke()
    assert cfg.d_model <= 512 and cfg.num_layers <= 2 * len(cfg.period)
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = model_lib.init_params(cfg, seed=0)
    batch = make_batch(cfg, rng)

    logits, aux = jax.jit(
        lambda p, b: model_lib.forward(p, b, cfg, remat=False)
    )(params, batch)
    if cfg.modality == "audio_codes":
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    # One training step worth of gradients.
    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: model_lib.loss_fn(q, b, cfg)[0]
        )(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    leaf_norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(n) for n in leaf_norms)
    assert any(n > 0 for n in leaf_norms)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode_step(arch, rng):
    cfg = get_config(arch).smoke()
    params = model_lib.init_params(cfg, seed=0)
    max_len = 16
    caches = transformer.init_caches(cfg, B, max_len)

    if cfg.modality == "vision_embeds":
        tok = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), cfg.activation_dtype)
    elif cfg.modality == "audio_codes":
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1, cfg.num_codebooks)))
    else:
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)))

    step = jax.jit(
        lambda p, t, c, pos: model_lib.decode_step(p, t, c, pos, cfg)
    )
    pos = jnp.int32(0)
    logits, caches2 = step(params, tok, caches, pos)
    if cfg.modality == "audio_codes":
        assert logits.shape == (B, 1, cfg.num_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # Cache must actually change.
    changed = jax.tree.map(
        lambda a, b2: bool(jnp.any(a != b2)), caches, caches2
    )
    assert any(jax.tree.leaves(changed))

    # Second step at pos=1 still finite.
    logits2, _ = step(params, tok, caches2, jnp.int32(1))
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_parameter_count_sane(arch):
    """Full (unreduced) configs must be registered with believable sizes."""
    cfg = get_config(arch)
    n = cfg.total_params()
    expected = {
        "qwen2-vl-72b": 72e9,
        "musicgen-large": 3.3e9,
        "mamba2-2.7b": 2.7e9,
        "olmoe-1b-7b": 6.9e9,
        "granite-20b": 20e9,
        "mistral-nemo-12b": 12e9,
        "yi-9b": 8.8e9,
        "granite-3-8b": 8e9,
        "jamba-1.5-large-398b": 398e9,
        "qwen2-moe-a2.7b": 14.3e9,
    }[arch]
    assert 0.55 * expected < n < 1.6 * expected, (arch, n / 1e9)


def test_input_shapes_registry():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert INPUT_SHAPES["long_500k"]["seq_len"] == 524288


@pytest.mark.slow
def test_serve_launcher_end_to_end():
    """The serving driver runs prefill + decode with FALCON latency
    monitoring attached (subprocess: exercises the CLI path)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2-2.7b",
         "--requests", "2", "--prompt-len", "16", "--gen", "4"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode throughput" in out.stdout
