"""Placement-aware mitigation tests (S2P/S3P + remap_groups + planner).

* ``remap_groups`` incremental layout refresh is equivalent to a fresh
  simulator built with the same placement (grid, edge tensors, iteration
  time, profiling keys).
* The placement planner concentrates a slow host's devices into the
  minimum number of DP groups and skips no-op proposals.
* On the node-spanning scenario from the ROADMAP (a host fault that hits
  one cell of *every* DP group), S2 alone finds no skew while S2P restores
  it and measurably improves the modeled iteration time.
* The predictive ski-rental break-even: with a duration model that has
  learned short faults, the expensive S4 rung no longer fires where the
  fixed-horizon rule would have fired it.
"""
import numpy as np
import pytest

from repro.cluster.simulator import JobSpec, TrainingSimulator, _Layout
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.controlplane.strategies import (
    MitigationContext,
    PlacementMicroBatchStrategy,
    PlacementTopologyStrategy,
    placement_registry,
)
from repro.core import microbatch as mb_lib
from repro.core.duration import DurationModel
from repro.core.events import FailSlowEvent, RootCause, Strategy
from repro.core.placement import PlacementPlanner, slow_devices_for
from repro.core.planner import MitigationPlanner

MODEL = ModelSpec(layers=40, hidden=5120, seq_len=2048, vocab=32000)


def make_sim(tp=1, dp=8, pp=2, n_nodes=2, gpn=8, micro_batches=32):
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=n_nodes, gpus_per_node=gpn),
        job=JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp,
                    micro_batches=micro_batches),
    )


def slow_host(sim, node, severity=0.5):
    per = sim.cluster.gpus_per_node
    for d in range(node * per, (node + 1) * per):
        sim.state.devices[d].host_speed = 1.0 - severity


# ------------------------------------------------- remap_groups equivalence
@pytest.mark.parametrize("tp,dp,pp", [(1, 8, 2), (2, 4, 2), (4, 4, 1)])
def test_remap_groups_matches_fresh_layout_build(tp, dp, pp):
    sim = make_sim(tp=tp, dp=dp, pp=pp)
    rng = np.random.default_rng(7)
    new_place = list(rng.permutation(sim.job.n_devices))
    sim.iteration_time()  # force the layout cache so the update path runs
    sim.remap_groups(new_place)
    updated = sim._layout()

    fresh_sim = make_sim(tp=tp, dp=dp, pp=pp)
    fresh_sim.placement = list(new_place)
    fresh = _Layout(fresh_sim.placement, fresh_sim.job)

    np.testing.assert_array_equal(updated.grid, fresh.grid)
    for attr in ("tp_edges", "dp_edges", "hop_edges"):
        a, b = getattr(updated, attr), getattr(fresh, attr)
        if a is None or b is None:
            assert a is None and b is None
        else:
            np.testing.assert_array_equal(a[0], b[0])
            np.testing.assert_array_equal(a[1], b[1])
    assert updated.tp_keys == fresh.tp_keys
    assert updated.dp_keys == fresh.dp_keys
    assert sim.iteration_time() == pytest.approx(
        fresh_sim.iteration_time(), abs=0.0
    )
    assert sim.profile_groups() == fresh_sim.profile_groups()
    # And against the loop oracle, under a degraded state for good measure.
    slow_host(sim, 1, 0.5)
    assert sim.iteration_time() == pytest.approx(
        sim.iteration_time_reference(), abs=1e-12
    )


def test_remap_groups_rejects_foreign_devices():
    sim = make_sim()
    with pytest.raises(ValueError):
        sim.remap_groups(list(range(1, sim.job.n_devices + 1)))


# ------------------------------------------------------ placement planner
def test_planner_concentrates_slow_node_into_fewest_groups():
    sim = make_sim()  # tp1 dp8 pp2 over 2 nodes: every group spans both
    planner = PlacementPlanner()
    slow = {d for d in range(16) if d // 8 == 1}
    remap = planner.plan(
        tp=1, dp=8, pp=2, placement=sim.placement, slow=slow,
        node_of=sim.node_of_rank,
    )
    assert remap is not None
    # 8 slow devices / (pp*tp = 2 per group) = 4 groups minimum.
    assert remap.groups_hit_before == 8
    assert remap.groups_hit_after == 4
    assert remap.slow_groups == (4, 5, 6, 7)
    assert sorted(remap.placement) == sorted(sim.placement)
    # Healthy groups must hold no slow device at all.
    grid = np.asarray(remap.placement).reshape(2, 8, 1)
    for d in range(4):
        assert not (set(grid[:, d, 0].tolist()) & slow)


def test_planner_skips_when_already_concentrated():
    sim = make_sim(tp=4, dp=2, pp=1, n_nodes=2, gpn=4, micro_batches=16)
    # Default placement: group 0 = node 0, group 1 = node 1.
    remap = PlacementPlanner().plan(
        tp=4, dp=2, pp=1, placement=sim.placement,
        slow=set(range(4, 8)), node_of=sim.node_of_rank,
    )
    assert remap is None


def test_slow_devices_for_expands_node_components():
    ev = FailSlowEvent(start_time=0.0, components=["node:1", "gpu:2"])
    sim = make_sim()
    assert slow_devices_for(ev, 16, sim.node_of_rank) == {2, *range(8, 16)}


# -------------------------------------------- S2P restores skew (ROADMAP)
def test_s2p_restores_skew_on_node_spanning_host_fault():
    """The ROADMAP loss case: a host fault on a node-spanning dp8 x pp2 job
    slows one cell of every DP group, so S2's solver sees uniform speeds
    and returns the even split. S2P re-shapes the groups, after which the
    solver has skew to exploit and the modeled iteration time drops."""
    sim = make_sim()
    severity = 0.8
    slow_host(sim, 1, severity)
    faulted = sim.iteration_time()

    # S2 alone: no skew — the even split stands and nothing improves.
    even = list(sim.allocation)
    s2_counts = mb_lib.solve_allocation(
        sim.per_microbatch_times(), sim.job.micro_batches,
        offset=sim.job.pp - 1,
    )
    assert s2_counts == even
    event = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.CPU_CONTENTION,
        components=["node:1"], t_healthy=sim.healthy_iteration_time(),
        t_slow=faulted, severity=severity,
    )
    strategy = PlacementMicroBatchStrategy()
    assert strategy.handles(event)
    outcome = strategy.apply(MitigationContext(adapter=sim, event=event))
    assert outcome.applied and not outcome.detail["reverted"]
    assert outcome.detail["shape"] == "concentrated"
    assert outcome.detail["slow_groups"] == [4, 5, 6, 7]
    # Skew restored: the committed allocation is no longer even...
    assert sim.allocation != even
    # ...and starves the concentrated groups in favor of the healthy ones.
    assert min(sim.allocation[:4]) > max(sim.allocation[4:])
    assert sim.iteration_time() < 0.8 * faulted


def test_s2p_reverts_when_concentration_does_not_pay():
    """A weak host fault: concentrating sends DP rings across the
    inter-node fabric for almost no skew gain — measure-before-commit
    must keep the original placement."""
    sim = make_sim()
    slow_host(sim, 1, 0.15)
    before = list(sim.placement)
    event = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.CPU_CONTENTION,
        components=["node:1"],
        t_healthy=sim.healthy_iteration_time(), t_slow=sim.iteration_time(),
    )
    outcome = PlacementMicroBatchStrategy().apply(
        MitigationContext(adapter=sim, event=event)
    )
    assert outcome.applied and outcome.detail["reverted"]
    assert sim.placement == before


def test_s2p_restores_canonical_after_fault_moves_on():
    """A concentrated layout must not outlive its fault: when the next
    diagnosis has nothing to concentrate, S2P measures the canonical
    layout and un-remaps."""
    sim = make_sim()
    slow_host(sim, 1, 0.8)
    event = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.CPU_CONTENTION,
        components=["node:1"],
        t_healthy=sim.healthy_iteration_time(), t_slow=sim.iteration_time(),
    )
    s2p = PlacementMicroBatchStrategy()
    assert not s2p.apply(MitigationContext(adapter=sim, event=event)).detail[
        "reverted"
    ]
    # Host fault ends; a plain single-GPU fault is diagnosed next.
    sim.state.reset()
    sim.state.devices[3].compute_speed = 0.5
    gpu_event = FailSlowEvent(
        start_time=100.0, root_cause=RootCause.GPU_DEGRADATION,
        components=["gpu:3"],
        t_healthy=sim.healthy_iteration_time(), t_slow=sim.iteration_time(),
    )
    outcome = s2p.apply(MitigationContext(adapter=sim, event=gpu_event))
    assert outcome.applied and outcome.detail["shape"] == "canonical"
    assert sim.placement == sorted(sim.placement)


def test_s3p_internalizes_rings_when_nic_congests_remapped_layout():
    sim = make_sim()
    # A previous S2P left the layout concentrated...
    slow_host(sim, 1, 0.8)
    ev = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.CPU_CONTENTION,
        components=["node:1"],
        t_healthy=sim.healthy_iteration_time(), t_slow=sim.iteration_time(),
    )
    PlacementMicroBatchStrategy().apply(MitigationContext(adapter=sim, event=ev))
    assert sim.placement != sorted(sim.placement)
    # ...then the host fault clears and a NIC congests: the concentrated
    # DP rings now cross the congested port.
    sim.state.reset()
    sim.state.degrade_nic(0, 0.3)
    nic_event = FailSlowEvent(
        start_time=200.0, root_cause=RootCause.NETWORK_CONGESTION,
        components=["nic:0"],
        t_healthy=sim.healthy_iteration_time(), t_slow=sim.iteration_time(),
    )
    s3p = PlacementTopologyStrategy()
    assert s3p.handles(nic_event)
    before_t = sim.iteration_time()
    outcome = s3p.apply(MitigationContext(adapter=sim, event=nic_event))
    assert outcome.applied and not outcome.detail["reverted"]
    assert sim.placement == sorted(sim.placement)
    assert sim.iteration_time() < before_t


def test_placement_registry_ladder_order():
    reg = placement_registry()
    ev = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.CPU_CONTENTION,
        components=["node:0"],
    )
    planner = reg.make_planner(ev, overheads={
        Strategy.IGNORE: 0.0, Strategy.ADJUST_MICROBATCH: 1.0,
        "S2P": 2.0, Strategy.ADJUST_TOPOLOGY: 3.0, "S3P": 4.0,
        Strategy.CKPT_AND_RESTART: 5.0,
    })
    # S3P requires nic:/link: evidence, so it is not a candidate here.
    assert planner._candidates == [
        Strategy.IGNORE, Strategy.ADJUST_MICROBATCH, "S2P",
        Strategy.ADJUST_TOPOLOGY, Strategy.CKPT_AND_RESTART,
    ]


# -------------------------------------- predictive ski-rental break-even
def _drive_planner(planner, t_healthy=1.0, t_slow=2.0, iters=400):
    fired = []
    for _ in range(iters):
        s = planner.update(slow_iters=1, current_time=t_slow)
        if s is not None:
            fired.append(s)
    return fired


def test_predictive_break_even_skips_s4_for_learned_short_faults():
    """A ~150 s throttle against a 60 s restart overhead: fixed-horizon
    Alg. 1 pays the restart at t = 120 s — 28 s before the fault's natural
    relief, recovering a fraction of what it spent. The predictive
    break-even, fit on a population of such short faults, sees that the
    expected remaining benefit never clearly exceeds the overhead and
    holds out for the fault's whole lifetime."""
    overheads = {Strategy.IGNORE: 0.0, Strategy.CKPT_AND_RESTART: 60.0}
    cands = (Strategy.IGNORE, Strategy.CKPT_AND_RESTART)
    fault_iters = 74  # just under 150 s of wall clock at t_slow = 2 s

    def make_event():
        return FailSlowEvent(
            start_time=0.0, root_cause=RootCause.GPU_DEGRADATION,
            t_healthy=1.0, t_slow=2.0,
        )

    fixed = MitigationPlanner(make_event(), dict(overheads), candidates=cands)
    fired_fixed = _drive_planner(fixed, iters=fault_iters)
    assert Strategy.CKPT_AND_RESTART in fired_fixed  # classic: at impact 61

    model = DurationModel(prior_weight=0.1)
    for _ in range(30):  # every observed GPU fault lasted ~150 s
        model.observe(RootCause.GPU_DEGRADATION, 150.0)
    predictive = MitigationPlanner(
        make_event(), dict(overheads), candidates=cands, estimator=model,
    )
    fired = _drive_planner(predictive, iters=fault_iters)
    assert Strategy.CKPT_AND_RESTART not in fired
    assert Strategy.IGNORE in fired  # zero-overhead rung unaffected


def test_predictive_break_even_fires_early_for_learned_long_faults():
    overheads = {Strategy.IGNORE: 0.0, Strategy.CKPT_AND_RESTART: 100.0}
    cands = (Strategy.IGNORE, Strategy.CKPT_AND_RESTART)
    model = DurationModel(prior_weight=0.5)
    for _ in range(30):  # every observed GPU fault lasted hours
        model.observe(RootCause.GPU_DEGRADATION, 7200.0)
    event = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.GPU_DEGRADATION,
        t_healthy=1.0, t_slow=2.0,
    )
    predictive = MitigationPlanner(
        event, dict(overheads), candidates=cands, estimator=model,
    )
    impact_at_fire = None
    for _ in range(400):
        s = predictive.update(slow_iters=1, current_time=2.0)
        if s is Strategy.CKPT_AND_RESTART:
            impact_at_fire = predictive.slow_impact
            break
    assert impact_at_fire is not None
    # lambda * overhead, not the classic full overhead
    assert impact_at_fire < overheads[Strategy.CKPT_AND_RESTART]


def test_duration_model_censored_observations_lengthen_the_curve():
    censored = DurationModel(prior_weight=0.0)
    exact = DurationModel(prior_weight=0.0)
    for _ in range(10):
        censored.observe(RootCause.CPU_CONTENTION, 100.0, censored=True)
        censored.observe(RootCause.CPU_CONTENTION, 300.0)
        exact.observe(RootCause.CPU_CONTENTION, 100.0)
        exact.observe(RootCause.CPU_CONTENTION, 300.0)
    # Kaplan-Meier: a censored 100 s episode is a *lower bound*, so the
    # expected remaining at age 50 must exceed the all-exact estimate.
    assert censored.expected_remaining(
        RootCause.CPU_CONTENTION, 50.0
    ) > exact.expected_remaining(RootCause.CPU_CONTENTION, 50.0)


def test_duration_model_prior_spans_characterization_range():
    model = DurationModel()
    # Fresh model: conditional mean remaining is finite, positive, and
    # decreasing in age once the heavy tail is consumed.
    r0 = model.expected_remaining(RootCause.GPU_DEGRADATION, 0.0)
    r1 = model.expected_remaining(RootCause.GPU_DEGRADATION, 30_000.0)
    assert 0.0 < r1 < r0 < 36_000.0
    assert model.expected_remaining(RootCause.GPU_DEGRADATION, 50_000.0) == 0.0


def test_duration_model_survival_curve_is_a_survival_curve():
    model = DurationModel(prior_weight=0.0)
    for d in (100.0, 200.0, 400.0):
        for _ in range(5):
            model.observe(RootCause.CPU_CONTENTION, d)
    cause = RootCause.CPU_CONTENTION
    # Conditional on T > 50: nothing has died by horizon 60.
    assert model.survival(cause, 50.0, 60.0) == pytest.approx(1.0)
    s150 = model.survival(cause, 50.0, 150.0)  # the 100 s third died
    s250 = model.survival(cause, 50.0, 250.0)
    assert s150 == pytest.approx(2.0 / 3.0)
    assert s250 == pytest.approx(1.0 / 3.0)
    assert model.survival(cause, 50.0, 500.0) == pytest.approx(0.0)
    # Conditioning on a later age renormalizes the curve upward.
    assert model.survival(cause, 150.0, 250.0) == pytest.approx(0.5)
    assert model.n_observed(cause) == 15
