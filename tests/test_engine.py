"""Shared-prefix campaign engine: forked-vs-fresh byte identity, snapshot
completeness, lazy-job reconstruction, and the decision-trace memo.

The engine's headline invariant is *exactness*: every run it serves —
recorded completion, snapshot fork, knob bundle, decision hook — must be
byte-identical to a fresh :func:`run_campaign` execution. The tests pin
that equality at the RunResult level (typed events, per-job outcomes,
tick counts), so any state the fork snapshot fails to carry shows up as
an event or outcome diff; the tamper tests additionally prove each
snapshot surface is *load-bearing* (corrupting it changes the branch),
which is what guarantees a newly added mutable field cannot silently be
omitted from :meth:`ControlPlane.snapshot`.
"""
import numpy as np
import pytest

from repro.core.planner import PlannerKnobs
from repro.scenarios.campaign import MODES, build_campaign, run_campaign
from repro.scenarios.engine import CampaignEngine
from repro.scenarios.presets import PRESETS
from repro.scenarios.scoring import run_and_score, score_campaign


def assert_same_run(fresh, got):
    """Full RunResult equality: events bit-for-bit, outcomes field-wise."""
    assert fresh.ticks_run == got.ticks_run
    assert len(fresh.events) == len(got.events)
    for i, (a, b) in enumerate(zip(fresh.events, got.events)):
        assert type(a) is type(b) and a.__dict__ == b.__dict__, (
            f"event {i}: {a!r} != {b!r}"
        )
    assert list(fresh.outcomes) == list(got.outcomes)
    for job_id, a in fresh.outcomes.items():
        b = got.outcomes[job_id]
        for f in ("join_time", "end_time", "iters_done", "steps",
                  "overhead_paid", "stalled_ticks", "mitigations"):
            va, vb = getattr(a, f), getattr(b, f)
            assert va == vb and repr(va) == repr(vb), (job_id, f, va, vb)


def assert_engine_matches_fresh(spec):
    engine = CampaignEngine(spec)
    for mode in MODES:
        assert_same_run(run_campaign(spec, mode), engine.run(mode))
    return engine


# ---------------------------------------------------------------- identity
@pytest.mark.parametrize("preset", [
    "single_gpu_throttle",   # one job, clean fork
    "collective_hang",       # watchdog/hang path through the prefix
    "flaky_executor",        # executor-fault verdicts post-fork
    "mixed_fleet",           # churn + adaptive retunes + every strategy
])
def test_forked_equals_fresh(preset):
    spec = build_campaign(preset, seed=0)
    engine = assert_engine_matches_fresh(spec)
    # The plane modes actually exercised the fork machinery (a campaign
    # whose plane never intervenes would vacuously pass the equality).
    assert engine.stats["forked_runs"] + engine.stats["reused_runs"] >= 2


def test_forked_equals_fresh_other_seed():
    assert_engine_matches_fresh(build_campaign("mixed_fleet", seed=1))


@pytest.mark.slow
@pytest.mark.parametrize("preset", sorted(PRESETS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_forked_equals_fresh_all_presets(preset, seed):
    assert_engine_matches_fresh(build_campaign(preset, seed=seed))


def test_run_and_score_engine_report_matches_fresh():
    _, _, via_engine = run_and_score("collective_hang", seed=0)
    _, _, via_fresh = run_and_score("collective_hang", seed=0, fresh=True)
    assert via_engine == via_fresh


def test_shared_engine_across_scoring_calls():
    spec = build_campaign("single_gpu_throttle", seed=0)
    engine = CampaignEngine(spec)
    _, _, first = run_and_score("single_gpu_throttle", engine=engine)
    _, _, second = run_and_score("single_gpu_throttle", engine=engine)
    assert first == second
    # The second pass is served entirely from the mode tree.
    assert engine.stats["memo_hits"] >= 2


# ------------------------------------------------------ per-job divergence
def test_untouched_jobs_ride_the_recording():
    spec = build_campaign("mixed_fleet", seed=0)
    engine = CampaignEngine(spec)
    faults = engine.run("faults")
    falcon = engine.run("falcon")
    touched = falcon.touched_jobs
    assert touched is not None and touched
    assert touched < set(falcon.outcomes)  # some jobs stayed virtual
    # Every job the plane acted on is in touched_jobs...
    acted = {
        ev.job_id for ev in falcon.events
        if getattr(ev, "job_id", "") and type(ev).__name__ not in
        ("Observation", "ScreenTuning", "Membership")
    }
    assert acted == touched
    # ...and a job the plane never touched keeps its faults-leg outcome
    # bit-for-bit (it never left the recording).
    for job_id in set(falcon.outcomes) - touched:
        a, b = faults.outcomes[job_id], falcon.outcomes[job_id]
        for f in ("end_time", "iters_done", "stalled_ticks", "overhead_paid"):
            assert repr(getattr(a, f)) == repr(getattr(b, f)), (job_id, f)
        assert not b.mitigations


def test_batched_rng_fast_forward_is_bitwise():
    """Lazy materialization fast-forwards a job's jitter stream with ONE
    batched draw; the stream state afterwards must be bitwise identical
    to the per-tick scalar draws the real run made."""
    for k in (1, 7, 304):
        a = np.random.default_rng([0, 7, 3])
        b = np.random.default_rng([0, 7, 3])
        batched = a.normal(1.0, 0.02, size=k)
        scalars = [float(b.normal(1.0, 0.02)) for _ in range(k)]
        assert [repr(float(v)) for v in batched] == [repr(v) for v in scalars]
        assert repr(float(a.normal(1.0, 0.02))) == repr(float(b.normal(1.0, 0.02)))


# ------------------------------------------------- snapshot completeness
def _tampered_branch(preset, mutate):
    """Run the falcon branch from a fork whose snapshot was corrupted by
    ``mutate(blob)``; returns (fresh falcon, tampered branch result)."""
    spec = build_campaign(preset, seed=0)
    engine = CampaignEngine(spec)
    engine._ensure_base()
    kind, fork = engine._falcon_plan()
    assert kind == "fork" and fork is not None
    mutate(fork.blob)
    return run_campaign(spec, "falcon"), engine._full_leg("falcon", fork=fork)


def _runs_differ(a, b):
    if a.ticks_run != b.ticks_run or len(a.events) != len(b.events):
        return True
    if any(
        type(x) is not type(y) or x.__dict__ != y.__dict__
        for x, y in zip(a.events, b.events)
    ):
        return True
    return any(
        repr(a.outcomes[j].iters_done) != repr(b.outcomes[j].iters_done)
        or a.outcomes[j].end_time != b.outcomes[j].end_time
        for j in a.outcomes
    )


def _swap_fleet_cols(blob):
    (ja, sa), (jb, sb) = list(blob["jobs"].items())[:2]
    sa["_fleet_col"], sb["_fleet_col"] = sb["_fleet_col"], sa["_fleet_col"]


@pytest.mark.parametrize("surface,preset,mutate", [
    # Representative mutable surfaces the fork snapshot carries must be
    # load-bearing: corrupting them has to change the branch. A surface
    # whose corruption were invisible could silently be dropped from
    # snapshot() — this test is what makes a missed field fail.
    ("fleet-screen sample history", "mixed_fleet",
     lambda blob: blob["fleet"].__setitem__(
         "history",
         (blob["fleet"]["history"][0] * 1.5, blob["fleet"]["history"][1]))),
    ("fleet drift baseline (ewma)", "mixed_fleet",
     lambda blob: blob["fleet"].__setitem__(
         "ewma", blob["fleet"]["ewma"] * 3.0)),
    ("watchdog cadence", "collective_hang",
     lambda blob: blob["watchdog"]["last"].update(
         {j: t - 100.0 for j, t in blob["watchdog"]["last"].items()})),
    ("per-job screen routing", "mixed_fleet", _swap_fleet_cols),
    ("incident-gap counters", "mixed_fleet",
     lambda blob: blob.__setitem__("watched_s", 0.0)),
])
def test_tampered_snapshot_changes_the_branch(surface, preset, mutate):
    fresh, tampered = _tampered_branch(preset, mutate)
    assert _runs_differ(fresh, tampered), (
        f"corrupting the {surface} snapshot did not change the branch — "
        "the surface is dead weight or the fork is not actually using it"
    )


def test_untampered_fork_blob_roundtrips():
    """Control for the tamper matrix: the same fork, un-corrupted, must
    reproduce the fresh run exactly."""
    fresh, branch = _tampered_branch("mixed_fleet", lambda blob: None)
    assert not _runs_differ(fresh, branch)
    assert_same_run(fresh, branch)


# ------------------------------------------------------------------ memo
def test_memo_identical_knobs_return_cached_run():
    spec = build_campaign("single_gpu_throttle", seed=0)
    engine = CampaignEngine(spec)
    knobs = PlannerKnobs(breakeven_scale=1.3)
    first = engine.run("falcon", planner_knobs=knobs)
    again = engine.run("falcon", planner_knobs=knobs)
    assert again is first
    assert engine.stats["memo_hits"] == 1
    # None normalizes to the default bundle — same memo slot.
    base = engine.run("falcon")
    assert engine.run("falcon", planner_knobs=PlannerKnobs()) is base


def test_memo_decision_trace_serves_equivalent_knobs():
    """A knob bundle that reprices every recorded break-even consult to
    the same decision reuses the scored leg outright — and the served
    result is still byte-identical to a fresh run under those knobs."""
    spec = build_campaign("mixed_fleet", seed=0)
    engine = CampaignEngine(spec)
    engine.run("falcon")
    near = PlannerKnobs(breakeven_scale=1.0 + 1e-9)
    served = engine.run("falcon", planner_knobs=near)
    assert engine.stats["trace_hits"] == 1
    assert_same_run(run_campaign(spec, "falcon", planner_knobs=near), served)


def test_memo_distinct_decisions_run_fresh():
    spec = build_campaign("mixed_fleet", seed=0)
    engine = CampaignEngine(spec)
    base = engine.run("falcon")
    harsh = engine.run("falcon", planner_knobs=PlannerKnobs(breakeven_scale=25.0))
    assert engine.stats["trace_hits"] == 0
    assert _runs_differ(base, harsh)
    assert_same_run(
        run_campaign(
            spec, "falcon", planner_knobs=PlannerKnobs(breakeven_scale=25.0)
        ),
        harsh,
    )


def test_decision_hooks_fork_but_never_memoize():
    class Suppress:
        def __init__(self, jobs):
            self.jobs = jobs

        def allow(self, job_id, strategy, now):
            return job_id not in self.jobs

        def allow_relief(self, job_id, now):
            return True

        def forced(self, job_id, now):
            return []

    spec = build_campaign("mixed_fleet", seed=0)
    engine = CampaignEngine(spec)
    touched = engine.run("falcon").touched_jobs
    victim = sorted(touched)[0]
    fresh = run_campaign(spec, "falcon", decision_hook=Suppress({victim}))
    got = engine.run("falcon", decision_hook=Suppress({victim}))
    assert_same_run(fresh, got)
    assert engine._memo.keys() == {("falcon", PlannerKnobs())}


# ------------------------------------------------------------- reporting
def test_scored_report_identical_from_engine_runs():
    spec = build_campaign("flaky_executor", seed=0)
    engine = CampaignEngine(spec)
    runs_fresh = {m: run_campaign(spec, m) for m in MODES}
    runs_eng = {m: engine.run(m) for m in MODES}
    assert score_campaign(spec, runs_fresh) == score_campaign(spec, runs_eng)
