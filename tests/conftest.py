"""Test-suite bootstrap.

The property tests use ``hypothesis``; this container does not ship it and
installing packages is not allowed. Register the deterministic stub from
``tests/_hypothesis_stub.py`` so the suite still collects and the property
tests run a fixed sample of random examples. When the real library is
available it is used unchanged.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
