"""Test-suite bootstrap.

The property tests use ``hypothesis``; bare containers do not ship it and
installing packages there is not allowed, so ``tests/_hypothesis_stub.py``
provides a deterministic stand-in that runs a fixed sample of random
examples per ``@given`` test.

Detection is spec-based (``importlib.util.find_spec``), not import-based:
a real installed hypothesis must always win. The old try/except-import
bootstrap could silently shadow a real installation — any transitive
``ImportError`` raised *inside* the real package (a broken dependency, a
half-upgraded environment) took the except branch and replaced the library
with the stub without a word. Now the stub is registered only when no
``hypothesis`` distribution exists at all, never overwrites an existing
``sys.modules`` entry, and says so on the first test run (CI installs the
real package and must exercise the genuine shrinking search).
"""
import importlib.util
import os
import sys

_TESTS_DIR = os.path.dirname(__file__)
if _TESTS_DIR not in sys.path:
    sys.path.insert(0, _TESTS_DIR)

HAS_REAL_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

if not HAS_REAL_HYPOTHESIS and "hypothesis" not in sys.modules:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


def pytest_report_header(config):
    return (
        "hypothesis: real package"
        if HAS_REAL_HYPOTHESIS
        else "hypothesis: deterministic stub (tests/_hypothesis_stub.py)"
    )
