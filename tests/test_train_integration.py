"""Integration tests: trainer loop + FALCON end-to-end; adaptive train step;
checkpoint round-trip; optimizer behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.core.events import Strategy
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import train_step as ts_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import FalconTrainer


def tiny_cfg():
    return get_config("falcon-demo-100m").smoke()


def make_sim(dp=4):
    # Compute-dominated job so a slow GPU visibly stretches iterations.
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=4),
        job=JobSpec(
            model=ModelSpec(layers=32, hidden=8192, seq_len=2048, vocab=32000,
                            micro_batch=2),
            tp=2, dp=dp, pp=1, micro_batches=16,
        ),
    )


@pytest.mark.slow
@pytest.mark.slow
def test_loss_decreases_over_training():
    cfg = tiny_cfg()
    data = DataConfig(seq_len=64, global_batch=8, slots=2, dp_groups=1)
    trainer = FalconTrainer(
        cfg=cfg, data=data, opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        perf_model=None, falcon_enabled=False,
    )
    hist = trainer.run(60)
    first = np.mean([r.loss for r in hist[:5]])
    last = np.mean([r.loss for r in hist[-5:]])
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
@pytest.mark.slow
def test_falcon_detects_and_mitigates_injected_failslow():
    """End-to-end: GPU fail-slow injected mid-run; FALCON detects it,
    escalates S1 -> S2, and the post-mitigation iteration time improves."""
    cfg = tiny_cfg()
    data = DataConfig(seq_len=32, global_batch=16, slots=4, dp_groups=4)
    sim = make_sim(dp=4)
    base = sim.iteration_time()
    injector = FailSlowInjector([
        Injection(start=base * 20, duration=1e9, kind=InjectionKind.GPU_SLOW,
                  target=(1,), severity=0.6),
    ])
    trainer = FalconTrainer(
        cfg=cfg, data=data,
        opt_cfg=AdamWConfig(total_steps=60),
        perf_model=sim, injector=injector, falcon_enabled=True,
        overheads={
            Strategy.IGNORE: 0.0,
            Strategy.ADJUST_MICROBATCH: 10.0,
            Strategy.ADJUST_TOPOLOGY: 60.0,
            Strategy.CKPT_AND_RESTART: 1e9,
        },
    )
    hist = trainer.run(60)
    strategies = [r.strategy for r in hist if r.strategy]
    assert "IGNORE" in strategies
    assert "ADJUST_MICROBATCH" in strategies
    slow_peak = max(r.iter_time for r in hist)
    tail = np.mean([r.iter_time for r in hist[-5:]])
    assert tail < slow_peak * 0.75  # S2 recovered most of the slowdown
    # Allocation genuinely moved micro-batches off the slow group.
    assert sim.allocation != [4, 4, 4, 4]
    assert min(sim.allocation) < 4 <= max(sim.allocation)


ADAPTIVE_STEP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import train_step as ts_lib

cfg = get_config("falcon-demo-100m").smoke()
mesh = jax.make_mesh((2, 2), ("data", "model"))
data = DataConfig(seq_len=32, global_batch=8, slots=4, dp_groups=2)
batch = jax.tree.map(jnp.asarray, make_batch(cfg, data, 0))
params = model_lib.init_params(cfg, 0)
opt = adamw.init(params)
with mesh:
    step = ts_lib.make_adaptive_train_step(cfg, AdamWConfig(), mesh)
    counts = jnp.array([4, 2], jnp.int32)  # group 1 slowed: fewer mbs
    p2, o2, m = jax.jit(step)(params, opt, batch, counts)
assert np.isfinite(float(m["loss"]))
moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2)
assert any(jax.tree.leaves(moved))
print("ADAPTIVE-STEP-OK")
"""


def test_adaptive_train_step_multidevice():
    """S2 runtime mechanism under a real (data=2, model=2) mesh: dynamic
    per-DP trip counts execute and update params (subprocess: host device
    count must be fixed before JAX initializes)."""
    import os
    import subprocess
    import sys

    from repro import compat

    if not compat.HAS_MODERN_SHARD_MAP:
        pytest.skip(
            "partial-manual shard_map hard-aborts in this jax's XLA "
            "(hlo_sharding_util IsManualSubgroup check; see ROADMAP)"
        )

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", ADAPTIVE_STEP_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ADAPTIVE-STEP-OK" in out.stdout


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = model_lib.init_params(cfg, 0)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_disk(params, step=7)
    assert mgr.latest_step() == 7
    restored = mgr.restore_disk(params, 7)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.save_memory(params)
    rest2 = mgr.restore_memory()
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rest2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adamw_converges_quadratic():
    opt_cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw.update(opt_cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clipping():
    opt_cfg = AdamWConfig(lr=1e-2, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    p2, _ = adamw.update(opt_cfg, {"w": jnp.full(4, 1e6)}, state, params)
    assert float(jnp.abs(p2["w"]).max()) < 0.1  # huge grad tamed


def test_data_pipeline_deterministic_and_shaped():
    cfg = tiny_cfg()
    data = DataConfig(seq_len=16, global_batch=8, slots=2, dp_groups=2)
    b1 = make_batch(cfg, data, 3)
    b2 = make_batch(cfg, data, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 2 * 2, 16)
    b3 = make_batch(cfg, data, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
