"""The CI workflow's own contracts, covered by tier-1.

The regression gate (`.github/workflows/ci.yml` sweep-gate job) only
protects the repo if the committed baseline actually parses, matches the
schema `repro.launch.sweep` expects, and the gate arithmetic does what the
workflow believes — all of which would otherwise only fail *in* CI, after
the fact.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import sweep as sweep_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(
    REPO, "results", "sweeps", "single_gpu_throttle-j1.baseline.json"
)
HANG_BASELINE = os.path.join(
    REPO, "results", "sweeps", "collective_hang-j2.baseline.json"
)
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")


def load_baseline() -> dict:
    with open(BASELINE) as f:
        return json.load(f)


def test_committed_baseline_parses_and_matches_gate_schema():
    baseline = load_baseline()
    for key in sweep_mod.GATE_SCHEMA_KEYS:
        assert key in baseline, f"baseline missing {key!r}"
    gate = baseline["gate"]
    metric = gate["metric"]
    assert metric in dict(sweep_mod.METRICS), metric
    assert float(gate["max_drop_pct_points"]) > 0
    m = baseline["metrics"][metric]
    assert m["mean"] is not None
    assert m["n"] == baseline["seeds"] > 1
    # The gated preset/shape must match what the workflow runs.
    assert baseline["preset"] == "single_gpu_throttle"
    assert baseline["jobs"] == 1


def test_gate_arithmetic_passes_identity_and_fails_regression():
    baseline = load_baseline()
    identity = {"metrics": baseline["metrics"]}
    passed, _ = sweep_mod.check_gate(identity, baseline)
    assert passed
    metric = baseline["gate"]["metric"]
    allowed = baseline["gate"]["max_drop_pct_points"]
    regressed = {
        "metrics": {
            metric: {
                "mean": baseline["metrics"][metric]["mean"] - allowed - 0.01
            }
        }
    }
    passed, verdict = sweep_mod.check_gate(regressed, baseline)
    assert not passed
    assert metric in verdict


def test_workflow_invokes_the_gate_against_the_committed_baseline():
    with open(WORKFLOW) as f:
        text = f.read()
    assert "repro.launch.sweep" in text
    assert "results/sweeps/single_gpu_throttle-j1.baseline.json" in text
    assert "repro.launch.campaign" in text  # determinism job
    assert "results/campaigns/single_gpu_throttle-j1-s0.json" in text
    assert "benchmarks.run --smoke" in text
    assert "pytest -x -q" in text
    # Robustness gates: hang determinism + sweep gate, flaky-exec smoke.
    assert "results/campaigns/collective_hang-j2-s0.json" in text
    assert "results/campaigns/flaky_executor-j2-s0.json" in text
    assert "results/sweeps/collective_hang-j2.baseline.json" in text
    assert 'run_and_score("flaky_executor", seed=0)' in text
    # Observability gates: sidecar byte-determinism + dashboard artifact.
    assert "results/campaigns/mixed_fleet-j8-s0.$ext" in text
    assert "collective_hang-j2-s0" in text and "--obs" in text
    assert "repro.launch.obs" in text
    assert "mixed_fleet-dashboard.html" in text


def test_committed_obs_sidecars_exist_for_the_ci_diff():
    for base in ("collective_hang-j2-s0", "mixed_fleet-j8-s0"):
        trace_path = os.path.join(
            REPO, "results", "campaigns", f"{base}.trace.json"
        )
        with open(trace_path) as f:
            doc = json.load(f)
        assert doc["displayTimeUnit"] == "ms"
        assert any(e["ph"] == "X" for e in doc["traceEvents"])
        metrics_path = os.path.join(
            REPO, "results", "campaigns", f"{base}.metrics.json"
        )
        with open(metrics_path) as f:
            snap = json.load(f)
        assert {c["name"] for c in snap["counters"]} >= {
            "events_total", "diagnoses_total"
        }


def test_committed_hang_baseline_parses_and_matches_gate_schema():
    with open(HANG_BASELINE) as f:
        baseline = json.load(f)
    for key in sweep_mod.GATE_SCHEMA_KEYS:
        assert key in baseline, f"hang baseline missing {key!r}"
    gate = baseline["gate"]
    assert gate["metric"] in dict(sweep_mod.METRICS)
    assert float(gate["max_drop_pct_points"]) > 0
    m = baseline["metrics"][gate["metric"]]
    assert m["mean"] is not None
    assert m["n"] == baseline["seeds"] > 1
    assert baseline["preset"] == "collective_hang"
    assert baseline["jobs"] == 2
    # Every seed must have watchdog-detected every injected hang.
    wd = baseline["metrics"]["hang_detection_rate"]
    assert wd["mean"] == 1.0 and wd["n"] == baseline["seeds"]


def test_committed_hang_and_flaky_reports_exist_for_the_ci_diff():
    for preset in ("collective_hang", "flaky_executor"):
        path = os.path.join(
            REPO, "results", "campaigns", f"{preset}-j2-s0.json"
        )
        with open(path) as f:
            report = json.load(f)
        assert report["campaign"]["preset"] == preset
        assert report["campaign"]["n_jobs"] == 2
        assert "robustness" in report


def test_committed_determinism_report_exists_for_the_ci_diff():
    path = os.path.join(
        REPO, "results", "campaigns", "single_gpu_throttle-j1-s0.json"
    )
    with open(path) as f:
        report = json.load(f)
    assert report["campaign"]["preset"] == "single_gpu_throttle"
    assert report["campaign"]["seed"] == 0
    assert report["campaign"]["n_jobs"] == 1


@pytest.mark.slow
def test_sweep_cli_gate_mode_end_to_end(tmp_path):
    """The exact command CI runs, end to end, including the exit code."""
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.sweep",
            "--preset", "single_gpu_throttle", "--jobs", "1", "--seeds", "3",
            "--out", str(tmp_path), "--gate", BASELINE, "--quiet",
        ],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "GATE PASS" in out.stdout


@pytest.mark.slow
def test_hang_sweep_cli_gate_mode_end_to_end(tmp_path):
    """The collective_hang gate command CI runs, end to end."""
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.sweep",
            "--preset", "collective_hang", "--jobs", "2", "--seeds", "3",
            "--out", str(tmp_path), "--gate", HANG_BASELINE, "--quiet",
        ],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "GATE PASS" in out.stdout


def test_parallel_sweep_is_byte_identical_to_serial(tmp_path):
    """--workers fans seeds out over processes; the sweep table must be
    byte-identical to the serial run (each seed's report is a pure
    function of its inputs, and map keeps seed order)."""
    serial = sweep_mod.run_sweep(
        "single_gpu_throttle", seeds=2, max_ticks=160, workers=1
    )
    fanned = sweep_mod.run_sweep(
        "single_gpu_throttle", seeds=2, max_ticks=160, workers=2
    )
    assert serial == fanned
    p1 = sweep_mod.write_sweep(serial, str(tmp_path / "serial"))
    p2 = sweep_mod.write_sweep(fanned, str(tmp_path / "fanned"))
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()
