"""Minimal stand-in for the ``hypothesis`` property-testing library.

The test suite only uses ``given``/``settings`` and the ``floats``,
``integers`` and ``lists`` strategies. When the real package is missing
(this container does not ship it and nothing may be installed),
``tests/conftest.py`` registers this module under ``sys.modules``; each
``@given`` test then runs a deterministic sample of random examples drawn
from the declared strategies, so the property tests keep exercising the
code instead of erroring out at collection.
"""
from __future__ import annotations

import inspect
import random

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` module
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value=0, max_value=100, **_kw):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            # Deterministic per-test stream: repeatable across runs.
            rng = random.Random(fn.__name__)
            for _ in range(n):
                drawn_args = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn_args, **kwargs, **drawn_kw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        # Zero-arg signature: pytest must not mistake drawn params for
        # fixtures (real hypothesis hides them the same way).
        wrapper.__signature__ = inspect.Signature()
        wrapper._stub_max_examples = DEFAULT_MAX_EXAMPLES
        return wrapper

    return decorate


def settings(max_examples: int | None = None, **_kw):
    def decorate(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn

    return decorate


class HealthCheck:  # pragma: no cover - referenced via settings kwargs only
    all = ()


def assume(condition) -> bool:  # pragma: no cover - parity helper
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass
