"""Unit + property tests for ACF period detection (paper §4.2)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import acf
from repro.core.events import CommEvent, CommOp


def make_events(pattern, iter_time, n_iters, jitter=0.0, seed=0):
    """Events for `n_iters` iterations with `pattern` ops spread over each."""
    rng = np.random.default_rng(seed)
    events = []
    t = 0.0
    for _ in range(n_iters):
        for j, op in enumerate(pattern):
            ts = t + iter_time * (j / len(pattern))
            if jitter:
                ts += rng.normal(0.0, jitter)
            events.append(CommEvent(op=op, timestamp=ts))
        t += iter_time
    return events


def test_find_period_simple():
    x = np.array([0, 1, 2, 3] * 20, dtype=float)
    assert acf.find_period(x) == 4


def test_find_period_constant_series():
    # All-identical ops: trivially periodic at lag 1.
    x = np.zeros(50)
    assert acf.find_period(x) == 1


def test_find_period_aperiodic():
    rng = np.random.default_rng(0)
    x = rng.normal(size=200)
    p = acf.find_period(x)
    assert p is None or p > 1  # white noise must not read as period-1


def test_iteration_times_from_events_recovers_period_and_time():
    pattern = [CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER,
               CommOp.ALL_GATHER, CommOp.ALL_REDUCE]
    events = make_events(pattern, iter_time=2.5, n_iters=30)
    times, period = acf.iteration_times_from_events(events)
    assert period == 4
    assert times.size > 0
    np.testing.assert_allclose(times, 2.5, rtol=1e-6)


def test_iteration_times_single_op_type():
    # Pure-DP jobs log only AllReduce; period should be 1 and the timestamps
    # should give the iteration time directly.
    events = make_events([CommOp.ALL_REDUCE], iter_time=1.2, n_iters=50)
    times, period = acf.iteration_times_from_events(events)
    assert period == 1
    np.testing.assert_allclose(times, 1.2, rtol=1e-6)


def test_iteration_times_with_slowdown_visible():
    pattern = [CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER, CommOp.ALL_REDUCE]
    fast = make_events(pattern, 1.0, 20)
    t0 = fast[-1].timestamp + 1.0
    slow = [
        CommEvent(op=ev.op, timestamp=ev.timestamp + t0)
        for ev in make_events(pattern, 2.0, 20)
    ]
    times, period = acf.iteration_times_from_events(fast + slow)
    assert period == 3
    assert times[:10].mean() < 1.1
    assert times[-10:].mean() > 1.8


@settings(max_examples=25, deadline=None)
@given(
    period=st.integers(min_value=2, max_value=8),
    n_iters=st.integers(min_value=12, max_value=40),
    iter_time=st.floats(min_value=0.1, max_value=10.0),
)
def test_property_period_recovery(period, n_iters, iter_time):
    """ACF recovers the injected period for any clean periodic op pattern."""
    ops = list(CommOp)
    pattern = [ops[i % len(ops)] for i in range(period)]
    events = make_events(pattern, iter_time, n_iters)
    times, found = acf.iteration_times_from_events(events)
    assert found is not None
    # The found period must divide into the true period structure: identical
    # op patterns can alias to a shorter true period; iteration time must
    # still be a multiple that reproduces iter_time at the pattern level.
    assert period % found == 0
    np.testing.assert_allclose(times, iter_time * found / period, rtol=1e-5)


def test_too_few_events():
    times, period = acf.iteration_times_from_events([])
    assert times.size == 0 and period is None
