"""What-if engine: counterfactual invariants, attribution, knob tuning.

The replay contract is exactness, so the tests pin bit-equality, not
tolerances, wherever the design promises it: removing every fault
reproduces the healthy run, suppressing every decision reproduces the
faults run, and a default knob bundle reproduces the shipped falcon run.
Attribution reconciliation is pinned on a two-episode toy preset whose
episodes hit disjoint jobs — there the leave-one-out deltas must sum to
the totals (no interaction to leave in the residual).
"""
import json

import pytest

from repro.cluster.injector import Injection, InjectionKind
from repro.core.events import FailSlowEvent, RootCause, Strategy
from repro.core.planner import KNOB_BOUNDS, MitigationPlanner, PlannerKnobs
from repro.scenarios.campaign import build_campaign, run_campaign
from repro.scenarios.presets import JobTemplate, ScenarioPreset
from repro.scenarios.scoring import run_and_score
from repro.whatif import (
    DecisionRef,
    DecisionScript,
    Variant,
    WhatIfEngine,
    decisions_of,
    leave_one_out,
    shapley,
    tune,
)


def _toy_preset(max_ticks=260):
    """Two jobs, one clean GPU_SLOW episode each (disjoint slices)."""
    return ScenarioPreset(
        name="toy_whatif",
        description="what-if tier-1: two jobs, one disjoint fault each",
        n_nodes=2, gpus_per_node=4, tick_seconds=5.0, max_ticks=max_ticks,
        default_jobs=2, join_spread_ticks=30,
        job_templates=(
            JobTemplate("yi-9b", tp=1, dp=2, pp=2, micro_batches=8),
        ),
        fixed_schedule=lambda n_nodes, gpn, dt: [
            Injection(100 * dt, 100 * dt, InjectionKind.GPU_SLOW, (1,), 0.5),
            Injection(120 * dt, 90 * dt, InjectionKind.GPU_SLOW, (5,), 0.6),
        ],
    )


def _outcome_tuple(out):
    return (
        out.join_time, out.end_time, out.iters_done, out.steps,
        out.overhead_paid, out.stalled_ticks,
    )


@pytest.fixture(scope="module")
def toy_engine():
    return WhatIfEngine(build_campaign(_toy_preset(), n_jobs=2, seed=0))


# ------------------------------------------------------ replay invariants
def test_drop_all_faults_reproduces_healthy_bitexact(toy_engine):
    spec = toy_engine.spec
    drop = frozenset(range(len(spec.schedule)))
    dropped = run_campaign(spec, "faults", drop_episodes=drop)
    healthy = toy_engine.baseline["healthy"]
    assert set(dropped.outcomes) == set(healthy.outcomes)
    for job_id, out in healthy.outcomes.items():
        assert _outcome_tuple(dropped.outcomes[job_id]) == _outcome_tuple(out)


def test_suppress_all_decisions_reproduces_faults_bitexact(toy_engine):
    spec = toy_engine.spec
    script = DecisionScript(suppress_all=True)
    suppressed = run_campaign(spec, "falcon", decision_hook=script)
    faults = toy_engine.baseline["faults"]
    for job_id, out in faults.outcomes.items():
        assert _outcome_tuple(suppressed.outcomes[job_id]) == _outcome_tuple(out)
    # The decisions were made and recorded as suppressed, not never-planned.
    assert script.hits
    from repro.controlplane import MitigationResult
    kinds = {
        ev.kind for ev in suppressed.events
        if isinstance(ev, MitigationResult)
    }
    assert "suppressed" in kinds and "mitigate" not in kinds


def test_default_knobs_reproduce_falcon_bitexact(toy_engine):
    spec = toy_engine.spec
    run = run_campaign(spec, "falcon", planner_knobs=PlannerKnobs())
    falcon = toy_engine.baseline["falcon"]
    for job_id, out in falcon.outcomes.items():
        assert _outcome_tuple(run.outcomes[job_id]) == _outcome_tuple(out)


def test_faults_replay_only_affected_jobs_is_exact(toy_engine):
    spec = toy_engine.spec
    # Episode 1 touches only j1: dropping it must leave j0's faults
    # outcome byte-identical, via the affected-jobs-only merge.
    variant = Variant(drop_episodes=frozenset({1}))
    assert toy_engine.affected_jobs(frozenset({1})) == ["j1"]
    merged = toy_engine.run_variant("faults", variant)
    full = run_campaign(spec, "faults", drop_episodes={1})
    for job_id in full.outcomes:
        assert _outcome_tuple(merged.outcomes[job_id]) == _outcome_tuple(
            full.outcomes[job_id]
        )
    # Only one job was re-run for the variant.
    assert toy_engine.stats["variant_job_runs"] <= 1 + 0 * len(spec.jobs)


def test_suppressing_one_decision_is_targeted(toy_engine):
    falcon = toy_engine.baseline["falcon"]
    refs = [d for d in decisions_of(falcon) if d.strategy != "IGNORE"]
    assert refs
    ref = refs[0]
    sup = toy_engine.run_variant("falcon", Variant(suppress=(ref,)))
    horizon = falcon.horizon_s
    # The suppressed job's JCT worsens (or stays); the other job, whose
    # fault is disjoint, keeps its falcon outcome bit-exactly.
    other = [j for j in sup.outcomes if j != ref.job_id]
    for job_id in other:
        assert _outcome_tuple(sup.outcomes[job_id]) == _outcome_tuple(
            falcon.outcomes[job_id]
        )
    assert (
        sup.outcomes[ref.job_id].jct(horizon)
        >= falcon.outcomes[ref.job_id].jct(horizon)
    )


def test_forced_decision_dispatches(toy_engine):
    from repro.controlplane import MitigationAction
    falcon = toy_engine.baseline["falcon"]
    refs = [d for d in decisions_of(falcon) if d.strategy != "IGNORE"]
    ref = refs[0]
    # Move the decision 10 ticks later: suppress the original, force a
    # copy. The forced dispatch must appear in the event log at >= t.
    moved = DecisionRef(
        job_id=ref.job_id, strategy=ref.strategy, time=ref.time + 50.0
    )
    run = toy_engine.run_variant(
        "falcon", Variant(suppress=(ref,), force=(moved,))
    )
    forced_times = [
        ev.time for ev in run.events
        if isinstance(ev, MitigationAction)
        and ev.job_id == ref.job_id
        and ev.strategy in (Strategy.__members__.get(ref.strategy), ref.strategy)
        and ev.time >= moved.time
    ]
    assert forced_times, "forced decision never dispatched"


# ------------------------------------------------------------ attribution
def test_loo_deltas_reconcile_on_disjoint_episodes(toy_engine):
    att = leave_one_out(toy_engine)
    totals = att["totals"]
    assert totals["gap_s"] > 0
    # Disjoint episodes on disjoint jobs: LOO is exactly additive, the
    # interaction residual must vanish (tolerance = rounding only).
    assert abs(att["per_cause_residual_s"]) < 1e-6 * max(totals["gap_s"], 1.0) + 1e-3
    assert (
        abs(att["per_cause_mitigated_residual_s"])
        < 1e-6 * max(abs(totals["mitigated_s"]), 1.0) + 1e-3
    )
    # Per-decision values reconcile with the total mitigated seconds.
    tol = 0.05 * max(abs(totals["mitigated_s"]), 1.0) + 1e-3
    assert abs(att["per_decision_residual_s"]) <= tol
    assert json.dumps(att, sort_keys=True)  # deterministic artifact shape


def test_loo_is_deterministic(toy_engine):
    a = leave_one_out(toy_engine)
    b = leave_one_out(toy_engine)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    # Second pass is served from the variant cache: no extra replays.
    assert toy_engine.stats["cache_hits"] > 0


def test_shapley_distributes_total_gap(toy_engine):
    sh = shapley(toy_engine, permutations=4)
    assert abs(sh["residual_s"]) < 1e-3
    assert set(sh["per_episode"]) == {"0", "1"}
    total = sum(r["slowdown_s"] for r in sh["per_episode"].values())
    assert total == pytest.approx(sh["total_gap_s"], abs=1e-3)
    for row in sh["per_episode"].values():
        assert row["slowdown_s"] >= 0


# ----------------------------------------------------------- knob surface
def test_breakeven_scale_scales_thresholds():
    event = FailSlowEvent(
        start_time=0.0, root_cause=RootCause.GPU_DEGRADATION,
        t_healthy=1.0, t_slow=2.0,
    )
    base = MitigationPlanner(event)
    scaled = MitigationPlanner(event, knobs=PlannerKnobs(breakeven_scale=2.0))
    nxt = Strategy.ADJUST_MICROBATCH
    assert scaled._threshold(nxt, 1.0, 10.0) == pytest.approx(
        2.0 * base._threshold(nxt, 1.0, 10.0)
    )
    # The knob bundle overrides the scalar fields.
    assert scaled.breakeven_scale == 2.0
    assert base._threshold(nxt, 1.0, 10.0) == pytest.approx(
        base.overheads[nxt]
    )


def test_knob_bounds_cover_all_knobs():
    assert set(KNOB_BOUNDS) == set(PlannerKnobs().__dataclass_fields__)


def test_tuner_gain_is_non_negative(toy_engine):
    result = tune([toy_engine], knob_names=("breakeven_scale",), iters=4)
    assert result["gain_pct_points"] >= 0.0
    assert result["objective_tuned_pct"] >= result["objective_default_pct"]
    assert result["evaluations"]
    assert json.dumps(result, sort_keys=True)


# ----------------------------------------------------- report round-trip
def test_from_report_roundtrip_and_verification():
    _, _, report = run_and_score("single_gpu_throttle", n_jobs=1, seed=0)
    engine = WhatIfEngine.from_report(report)
    att = leave_one_out(engine)
    # The LOO totals ARE the report's headline number.
    assert att["totals"]["mitigated_pct"] == pytest.approx(
        report["mitigation"]["slowdown_mitigated_pct"], abs=0.01
    )
    # A stale report (different JCTs) must be rejected, not replayed.
    bad = json.loads(json.dumps(report))
    bad["jobs"][0]["jct_s"]["falcon"] += 7.0
    with pytest.raises(ValueError, match="divergence"):
        WhatIfEngine.from_report(bad)


def test_report_event_log_matches_replayed_decisions():
    _, runs, report = run_and_score("single_gpu_throttle", n_jobs=1, seed=0)
    logged = [
        (e["job_id"], e["strategy"], e["time"])
        for e in report["event_log"]
        if e["type"] == "MitigationAction"
    ]
    replayed = [d.key() for d in decisions_of(runs["falcon"])]
    assert sorted(logged) == sorted(replayed)
    assert json.dumps(report["event_log"], sort_keys=True)


def test_sweep_carries_per_cause_columns():
    from repro.launch.sweep import run_sweep
    sweep = run_sweep("single_gpu_throttle", n_jobs=1, seeds=2)
    table = sweep["per_cause_mitigated_pct"]
    assert "gpu_degradation" in table
    assert table["gpu_degradation"]["n"] == 2
    for row in sweep["per_seed"]:
        assert "per_cause_mitigated_pct" in row
    assert json.dumps(sweep, sort_keys=True)
