"""Tests for O(1) ring/tree communicator validation (paper §4.3, Fig. 9)."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import validation as v


def test_even_ring_two_passes():
    passes = v.ring_passes(8)
    assert len(passes) == 2
    assert v.check_disjoint(passes)
    covered = {frozenset(p) for ps in passes for p in ps}
    want = {frozenset(l) for l in v.ring_links(8)}
    assert covered == want


def test_odd_ring_three_passes():
    passes = v.ring_passes(5)
    assert len(passes) == 3
    assert v.check_disjoint(passes)
    covered = {frozenset(p) for ps in passes for p in ps}
    assert covered == {frozenset(l) for l in v.ring_links(5)}


def test_tree_four_passes():
    parents = v.binary_tree_parents(15)
    passes = v.tree_passes(parents)
    assert len(passes) == 4
    assert v.check_disjoint(passes)
    covered = {p for ps in passes for p in ps}
    assert covered == set(v.tree_links(parents))


@settings(max_examples=50, deadline=None)
@given(n=st.integers(min_value=2, max_value=257))
def test_property_ring_o1_passes_cover_all_links(n):
    """O(1): pass count is 1, 2 or 3 for ANY ring size; full coverage; disjoint."""
    passes = v.ring_passes(n)
    assert len(passes) <= 3
    assert v.check_disjoint(passes)
    covered = {frozenset(p) for ps in passes for p in ps}
    assert covered == {frozenset(l) for l in v.ring_links(n)}


@settings(max_examples=50, deadline=None)
@given(n=st.integers(min_value=2, max_value=511))
def test_property_tree_o1_passes(n):
    parents = v.binary_tree_parents(n)
    passes = v.tree_passes(parents)
    assert len(passes) == 4
    assert v.check_disjoint(passes)
    covered = {p for ps in passes for p in ps}
    assert covered == set(v.tree_links(parents))


def test_validate_links_flags_slow_link():
    link_time = {frozenset((i, (i + 1) % 8)): 1.0 for i in range(8)}
    link_time[frozenset((3, 4))] = 5.0  # congested

    def measure(pair):
        return link_time[frozenset(pair)]

    slow, times = v.validate_links(v.ring_passes(8), measure)
    assert [frozenset(p) for p in slow] == [frozenset((3, 4))]
    assert len(times) == 8


def test_validate_links_all_healthy():
    slow, _ = v.validate_links(v.ring_passes(6), lambda p: 1.0)
    assert slow == []
