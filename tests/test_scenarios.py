"""Scenario campaign engine tests.

* Injector regression: overlapping injections on one target compose (the
  later episode must not clobber the earlier multiplier) and relief
  restores the correct baseline; ramped onsets build severity linearly.
* Node/NIC-scoped diagnosis components and cross-job dedupe of host-level
  faults (co-located jobs with disjoint device sets share one pinpoint).
* Campaign determinism: same seed + preset => byte-identical report.
* The tier-1 toy 2-job campaign smoke and the mixed_fleet acceptance run
  (jobs join/leave mid-run, report complete, precision/recall >= 0.9).
"""
import json

import numpy as np
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ClusterState, ModelSpec
from repro.controlplane import ControlPlane, Diagnosis
from repro.core.events import RootCause
from repro.scenarios import (
    FaultModel,
    JobTemplate,
    ScenarioPreset,
    build_campaign,
    run_and_score,
    run_campaign,
    score_campaign,
)
from repro.scenarios.campaign import MODES

MODEL = ModelSpec(layers=32, hidden=4096, seq_len=2048, vocab=32000)


# --------------------------------------------------- injector composition
def _state(n_nodes=2, gpn=4):
    return ClusterState(ClusterSpec(n_nodes=n_nodes, gpus_per_node=gpn))


def test_overlapping_gpu_injections_compose_and_relieve():
    inj = FailSlowInjector([
        Injection(0.0, 100.0, InjectionKind.GPU_SLOW, (1,), 0.5),
        Injection(50.0, 100.0, InjectionKind.GPU_SLOW, (1,), 0.5),
    ])
    st = _state()
    inj.apply(st, 25.0)
    assert st.devices[1].compute_speed == pytest.approx(0.5)
    inj.apply(st, 75.0)  # overlap: multipliers compose, not clobber
    assert st.devices[1].compute_speed == pytest.approx(0.25)
    inj.apply(st, 125.0)  # first ended: the second's multiplier remains
    assert st.devices[1].compute_speed == pytest.approx(0.5)
    inj.apply(st, 200.0)  # both ended: baseline restored
    assert st.devices[1].compute_speed == pytest.approx(1.0)


def test_overlapping_link_and_nic_injections_compose():
    inj = FailSlowInjector([
        Injection(0.0, 100.0, InjectionKind.LINK_CONGESTION, (0, 5), 0.5),
        Injection(20.0, 100.0, InjectionKind.LINK_CONGESTION, (5, 0), 0.5),
        Injection(0.0, 100.0, InjectionKind.NIC_CONGESTION, (1,), 0.4),
        Injection(30.0, 100.0, InjectionKind.NIC_CONGESTION, (1,), 0.5),
    ])
    st = _state()
    inj.apply(st, 50.0)
    assert st.link_mult[(0, 5)] == pytest.approx(0.25)
    assert st.nic_mult[1] == pytest.approx(0.3)
    inj.apply(st, 110.0)
    assert st.link_mult[(0, 5)] == pytest.approx(0.5)
    assert st.nic_mult[1] == pytest.approx(0.5)
    inj.apply(st, 200.0)
    assert not st.link_mult and not st.nic_mult


def test_ramped_injection_builds_linearly_and_memoizes():
    inj = FailSlowInjector([
        Injection(0.0, 100.0, InjectionKind.GPU_SLOW, (2,), 0.4, ramp=50.0),
    ])
    st = _state()
    inj.apply(st, 25.0)  # half-way up the ramp
    assert st.devices[2].compute_speed == pytest.approx(0.8)
    inj.apply(st, 75.0)  # ramp done: full severity
    assert st.devices[2].compute_speed == pytest.approx(0.6)
    v = st.version
    inj.apply(st, 80.0)  # steady state: reapply skipped, version unchanged
    assert st.version == v
    inj.apply(st, 150.0)
    assert st.devices[2].compute_speed == pytest.approx(1.0)


# ------------------------------------------- node/NIC-scoped diagnoses
def _drive(plane, sims, mutate, n=140, when=60, seed=2):
    rng = np.random.default_rng(seed)
    wall = 0.0
    for t in range(n):
        if t == when:
            mutate()
        times = {
            job_id: sim.iteration_time() * float(rng.normal(1, 0.003))
            for job_id, sim in sims.items()
        }
        wall += max(times.values())
        plane.tick(times, wall)


def test_cpu_contention_dedupes_across_colocated_jobs_via_hosts():
    """Two jobs with disjoint GPUs on one host: a single host pinpoint, the
    second diagnosis adopted through the node-scoped component."""

    class CountingSim(TrainingSimulator):
        def __post_init__(self):
            super().__post_init__()
            self.profile_calls = 0

        def profile_groups(self):
            self.profile_calls += 1
            return super().profile_groups()

    def mk():
        return CountingSim(
            cluster=ClusterSpec(n_nodes=1, gpus_per_node=4),
            job=JobSpec(model=MODEL, tp=1, dp=4, pp=1, micro_batches=8),
        )

    sim_a, sim_b = mk(), mk()
    plane = ControlPlane()
    plane.register_job("A", sim_a, hardware=[f"a{i}" for i in range(4)],
                       hosts=["h0"])
    plane.register_job("B", sim_b, hardware=[f"b{i}" for i in range(4)],
                       hosts=["h0"])

    def contend():
        for sim in (sim_a, sim_b):  # same physical host slows both jobs
            for d in range(4):
                sim.state.devices[d].host_speed = 0.5

    _drive(plane, {"A": sim_a, "B": sim_b}, contend)
    open_diags = [d for d in plane.diagnoses() if not d.resolved]
    assert sorted(d.job_id for d in open_diags) == ["A", "B"]
    for d in open_diags:
        assert d.event.root_cause is RootCause.CPU_CONTENTION
        assert d.event.components == ["node:0"]
        assert d.components_global == ("node:h0",)
    by_job = {d.job_id: d for d in open_diags}
    assert by_job["A"].deduped_from is None
    assert by_job["B"].deduped_from == "A"
    assert sim_a.profile_calls + sim_b.profile_calls == 1


def test_nic_congestion_pinpoints_nic_scoped_component():
    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=2),
        job=JobSpec(model=MODEL, tp=1, dp=4, pp=1, micro_batches=8),
    )
    plane = ControlPlane()
    plane.register_job("A", sim, hardware=[f"g{i}" for i in range(4)],
                       hosts=["h0", "h1"])
    _drive(plane, {"A": sim}, lambda: sim.state.degrade_nic(0, 0.25))
    diags = [d for d in plane.diagnoses() if not d.resolved]
    assert diags
    d = diags[0]
    assert d.event.root_cause is RootCause.NETWORK_CONGESTION
    assert any(c.startswith("nic:") for c in d.event.components)
    assert any(c == "nic:h0" for c in d.components_global)


def test_adoption_rejected_when_components_measure_healthy():
    """A co-located job flagging for its *own* fault must not inherit a
    neighbour's diagnosis whose components are healthy on its slice."""
    sim_a = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=2),
        job=JobSpec(model=MODEL, tp=1, dp=4, pp=1, micro_batches=8),
    )
    sim_b = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=1, gpus_per_node=4),
        job=JobSpec(model=MODEL, tp=1, dp=4, pp=1, micro_batches=8),
    )
    plane = ControlPlane()
    # A spans hosts h0-h1; B sits inside h0 with its own GPUs.
    plane.register_job("A", sim_a, hardware=[f"a{i}" for i in range(4)],
                       hosts=["h0", "h1"])
    plane.register_job("B", sim_b, hardware=[f"b{i}" for i in range(4)],
                       hosts=["h0"])

    def faults():
        sim_a.state.degrade_nic(0, 0.25)  # hits A only (B is intra-node)
        sim_b.state.devices[1].compute_speed = 0.5  # B's own GPU fault

    _drive(plane, {"A": sim_a, "B": sim_b}, faults)
    by_job = {}
    for d in plane.diagnoses():
        if not d.resolved:
            by_job.setdefault(d.job_id, d)
    assert by_job["A"].event.root_cause is RootCause.NETWORK_CONGESTION
    assert by_job["B"].event.root_cause is RootCause.GPU_DEGRADATION
    assert by_job["B"].deduped_from is None
    assert by_job["B"].event.components == ["gpu:1"]


# --------------------------------------------------- campaign engine
def _toy_preset(max_ticks=260):
    return ScenarioPreset(
        name="toy_2job",
        description="tier-1 smoke: two small jobs, one fault each",
        n_nodes=2, gpus_per_node=4, tick_seconds=5.0, max_ticks=max_ticks,
        default_jobs=2, join_spread_ticks=30,
        job_templates=(
            JobTemplate("yi-9b", tp=1, dp=2, pp=2, micro_batches=8),
        ),
        fixed_schedule=lambda n_nodes, gpn, dt: [
            Injection(100 * dt, 100 * dt, InjectionKind.GPU_SLOW, (1,), 0.5),
            Injection(120 * dt, 90 * dt, InjectionKind.GPU_SLOW, (5,), 0.6),
        ],
    )


def test_toy_campaign_smoke_tier1():
    """The subsystem's rot check: a 2-job campaign runs all four modes,
    detects both faults, produces the full report shape, and churns."""
    spec, runs, report = run_and_score(_toy_preset(), n_jobs=2, seed=0)
    assert set(runs) == set(MODES)
    det = report["detection"]["overall"]
    assert det["precision"] == 1.0
    assert det["recall"] == 1.0
    assert det["latency_mean_s"] is not None
    assert report["mitigation"]["slowdown_mitigated_pct"] is not None
    joins = [m for m in report["membership"] if m["action"] == "join"]
    leaves = [m for m in report["membership"] if m["action"] == "leave"]
    assert len(joins) == 2 and len(leaves) == 2
    for row in report["jobs"]:
        assert all(row["finished"].values()), row
    assert json.dumps(report)  # JSON-serializable end to end


def test_campaign_determinism_byte_identical():
    """Same (preset, jobs, seed) => byte-identical serialized report."""
    preset = _toy_preset()
    blobs = []
    for _ in range(2):
        _, _, report = run_and_score(preset, n_jobs=2, seed=3)
        blobs.append(json.dumps(report, sort_keys=True))
    assert blobs[0] == blobs[1]


def test_campaign_seed_changes_schedule():
    spec0 = build_campaign("mixed_fleet", n_jobs=4, seed=0)
    spec1 = build_campaign("mixed_fleet", n_jobs=4, seed=1)
    assert spec0.schedule != spec1.schedule


def test_fault_model_statistics():
    """Sampled schedules follow the configured §3 statistics."""
    fm = FaultModel(rate_per_hour=400.0, flap_prob=0.0)
    rng = np.random.default_rng(0)
    injs = fm.sample_schedule(rng, n_nodes=8, gpus_per_node=8,
                              horizon_s=3600.0)
    assert 300 < len(injs) < 500  # Poisson around 400
    kinds = {k: sum(1 for i in injs if i.kind is k) for k in InjectionKind}
    hang_kinds = (InjectionKind.GPU_HANG, InjectionKind.COLLECTIVE_HANG)
    assert all(v > 0 for k, v in kinds.items() if k not in hang_kinds)
    # hang_prob defaults to 0: no hang episodes unless explicitly enabled
    assert all(kinds[k] == 0 for k in hang_kinds)
    durs = np.array([i.duration for i in injs])
    assert durs.min() >= 10.0 and durs.max() <= 40_000.0
    assert np.median(durs) < 3600.0  # log-spacing: most are short
    sevs = np.array([i.severity for i in injs])
    assert 0.08 <= sevs.min() and sevs.max() <= 0.92
    ramps = [i for i in injs if i.ramp > 0]
    assert ramps and all(
        i.kind in (InjectionKind.LINK_CONGESTION, InjectionKind.NIC_CONGESTION)
        for i in ramps
    )


def test_campaign_translates_global_faults_to_affected_jobs_only():
    spec = build_campaign(_toy_preset(), n_jobs=2, seed=0)
    by_job = {p.job_id: p for p in spec.jobs}
    # Device 1 belongs to j0's slice, device 5 to j1's (4 devices each).
    assert [li.target for li in by_job["j0"].local_schedule] == [(1,)]
    assert [li.target for li in by_job["j1"].local_schedule] == [(1,)]
    assert all(i > 0 for p in spec.jobs for i in p.impacts)


def test_mixed_fleet_acceptance_campaign():
    """The acceptance criterion, pinned: `--preset mixed_fleet --jobs 8
    --seed 0` detects with precision/recall 1.0 and — with the placement
    rungs and the predictive ski-rental horizon — mitigates >= 45 % of the
    fail-slow slowdown (was 29 % with the paper ladder alone)."""
    spec, runs, report = run_and_score("mixed_fleet", n_jobs=8, seed=0)
    det = report["detection"]["overall"]
    assert det["precision"] == 1.0
    assert det["recall"] == 1.0
    assert report["mitigation"]["slowdown_mitigated_pct"] >= 45.0
    # Churn: at least one job joins after the campaign starts and at least
    # one leaves before it ends.
    falcon = runs["falcon"]
    joins = sorted(o.join_time for o in falcon.outcomes.values())
    ends = sorted(o.end_time for o in falcon.outcomes.values()
                  if o.end_time is not None)
    assert joins[-1] > 0.0
    assert ends and ends[0] < falcon.horizon_s
    # The report carries every paper metric the issue names.
    assert "per_cause" in report["detection"]
    assert report["detection"]["overall"]["latency_mean_s"] is not None
    assert report["mitigation"]["slowdown_mitigated_pct"] is not None
    assert report["mitigation"]["slowdown_mitigated_ckpt_pct"] is not None
    assert report["mitigation"]["avg_jct_delay_pct"] is not None


def test_scoring_counts_unmatched_diagnosis_as_false_positive():
    """A diagnosis with no ground-truth episode behind it must hit
    precision (guards against scoring that only ever confirms)."""
    spec = build_campaign(_toy_preset(max_ticks=220), n_jobs=2, seed=0)
    runs = {mode: run_campaign(spec, mode) for mode in MODES}
    # Forge a diagnosis far from any injection window.
    from repro.core.events import FailSlowEvent

    fake = Diagnosis(
        job_id="j0", time=40.0,
        event=FailSlowEvent(start_time=40.0,
                            root_cause=RootCause.GPU_DEGRADATION),
    )
    runs["falcon"].events.append(fake)
    report = score_campaign(spec, runs)
    assert report["detection"]["overall"]["false_positives"] >= 1
    assert report["detection"]["overall"]["precision"] < 1.0
