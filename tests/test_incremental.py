"""Event-scoped incremental recomputation — churn equivalence suite.

The invalidation contract (docs/simulator.md): every mutation of a
:class:`ClusterState` lands in a typed mutation log; the simulator consumes
it through a cursor and re-reduces only the touched cells, staying
bit-identical to the kept ``*_reference()`` loop oracles under arbitrary
interleavings of injections, clears, ramps, group remaps, and job churn.
Equality assertions here are exact (``==``), not approximate — the
incremental paths replay the full pass's float operation chains.
"""
import numpy as np
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ClusterState, ModelSpec

MODEL = ModelSpec(layers=24, hidden=4096, seq_len=2048, vocab=50257)


def make_sim(tp, dp, pp, nodes, gpn=None):
    n = tp * dp * pp
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=nodes, gpus_per_node=gpn or max(4, n // nodes)),
        job=JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp, micro_batches=2 * dp),
    )


def assert_matches_reference(sim, ctx):
    assert sim.iteration_time() == sim.iteration_time_reference(), ctx
    assert sim.profile_groups() == sim.profile_groups_reference(), ctx
    assert (
        sim.per_microbatch_times() == sim.per_microbatch_times_reference()
    ), ctx


def churn_step(sim, rng, nd, nodes):
    """One random mutation drawn from every dirt source the log models."""
    a = int(rng.integers(10))
    if a == 0:
        sim.state.devices[int(rng.integers(nd))].compute_speed = float(
            rng.uniform(0.3, 1.0)
        )
    elif a == 1 and nd > 1:
        x, y = rng.choice(nd, 2, replace=False)
        sim.state.degrade_link(int(x), int(y), float(rng.uniform(0.05, 1.0)))
    elif a == 2:
        sim.state.degrade_nic(int(rng.integers(nodes)), float(rng.uniform(0.2, 1.0)))
    elif a == 3:
        perm = list(sim.placement)
        i, j = rng.choice(nd, 2, replace=False)
        perm[i], perm[j] = perm[j], perm[i]
        sim.remap_groups(perm)
    elif a == 4:
        node = int(rng.integers(nodes))
        per = sim.cluster.gpus_per_node
        for d in range(node * per, min((node + 1) * per, nd)):
            sim.state.devices[d].host_speed = float(rng.uniform(0.5, 1.0))
    elif a == 5 and nd > 1:
        x, y = rng.choice(nd, 2, replace=False)
        sim.state.restore_link(int(x), int(y))
    elif a == 6:
        sim.state.restore_nic(int(rng.integers(nodes)))
    elif a == 7:
        sim.state.reset()
    elif a == 8:
        counts = [1] * sim.job.dp
        counts[int(rng.integers(sim.job.dp))] += (
            sim.job.micro_batches - sim.job.dp
        )
        sim.set_allocation(counts)
    # a == 9: no mutation — the memoized path must also stay correct
    return a


@pytest.mark.parametrize(
    "tp,dp,pp,nodes",
    [
        (2, 2, 4, 2), (1, 4, 2, 2), (4, 2, 1, 1), (1, 8, 1, 2),
        (2, 4, 2, 4),
        # pp - 1 >= 9 hops: numpy would sum a 1-D hop column pairwise while
        # the full pass reduces axis 0 sequentially — the incremental hop
        # update must accumulate in the full pass's order (ulp regression)
        (1, 2, 12, 3),
    ],
)
def test_churn_equivalence_randomized(tp, dp, pp, nodes):
    sim = make_sim(tp, dp, pp, nodes)
    nd = tp * dp * pp
    rng = np.random.default_rng(nd * 1000 + nodes)
    for step in range(250):
        a = churn_step(sim, rng, nd, nodes)
        assert_matches_reference(sim, (step, a))


def test_incremental_cache_equals_full_rebuild_after_churn():
    """The cached per-cell reductions equal a from-scratch rebuild bit for
    bit after arbitrary churn — the invariant every reader relies on."""
    sim = make_sim(2, 2, 2, 2)
    rng = np.random.default_rng(7)
    for step in range(150):
        churn_step(sim, rng, 8, 2)
        sim.iteration_time()
        fresh = sim._cells_rebuild(sim._layout())
        cached = sim._cells()
        for name in (
            "cell_speed", "tp_edge", "tp_bw", "dp_edge", "dp_bw",
            "hop_bw", "stage", "stage_max", "hop2",
        ):
            a, b = getattr(fresh, name), getattr(cached, name)
            if isinstance(a, np.ndarray):
                assert np.array_equal(a, b), (step, name)
            else:
                assert a == b or (a is None and b is None), (step, name)


def test_injector_diff_apply_matches_reset_reapply():
    """Diff-apply composes overlapping + ramping episodes to exactly the
    multipliers a from-scratch reset+reapply produces, at every tick."""
    rng = np.random.default_rng(0)
    spec = ClusterSpec(n_nodes=4, gpus_per_node=4)
    kinds = list(InjectionKind)
    injs = []
    for _ in range(30):
        k = kinds[int(rng.integers(4))]
        if k is InjectionKind.GPU_SLOW:
            tgt = (int(rng.integers(16)),)
        elif k in (InjectionKind.CPU_CONTENTION, InjectionKind.NIC_CONGESTION):
            tgt = (int(rng.integers(4)),)
        else:
            a, b = rng.choice(16, 2, replace=False)
            tgt = (int(a), int(b))
        injs.append(Injection(
            start=float(rng.uniform(0, 80)),
            duration=float(rng.uniform(5, 40)),
            kind=k, target=tgt,
            severity=float(rng.uniform(0.1, 0.8)),
            ramp=float(rng.choice([0.0, 10.0])),
        ))
    inc = FailSlowInjector(list(injs))
    st = ClusterState(spec)
    for t in np.linspace(0.0, 130.0, 131):
        inc.apply(st, float(t))
        ref_state = ClusterState(spec)
        FailSlowInjector(list(injs)).apply(ref_state, float(t))
        assert np.array_equal(st._compute, ref_state._compute), t
        assert np.array_equal(st._host, ref_state._host), t
        assert dict(st.link_mult) == dict(ref_state.link_mult), t
        assert dict(st.nic_mult) == dict(ref_state.nic_mult), t


def test_injector_diff_apply_falls_back_on_external_mutation():
    """Any mutation outside the injector voids the diff basis: the next
    apply resets (wiping the external write), exactly as before."""
    st = ClusterState(ClusterSpec(n_nodes=1, gpus_per_node=4))
    inj = FailSlowInjector([Injection(
        start=0.0, duration=100.0, kind=InjectionKind.GPU_SLOW,
        target=(0,), severity=0.5,
    )])
    inj.apply(st, 1.0)
    st.devices[2].compute_speed = 0.25  # external
    inj.apply(st, 2.0)
    assert st.devices[2].compute_speed == 1.0  # reset path wiped it
    assert st.devices[0].compute_speed == 0.5


def test_injector_epoch_tracks_schedule_changes():
    inj = FailSlowInjector()
    e0 = inj.epoch
    inj.add(Injection(start=0.0, duration=1.0, kind=InjectionKind.GPU_SLOW,
                      target=(0,), severity=0.5))
    assert inj.epoch > e0
    e1 = inj.epoch
    inj.injections = []  # the S4 clearing path reassigns wholesale
    assert inj.epoch > e1
    e2 = inj.epoch
    inj.extend([])
    assert inj.epoch > e2


def test_dirty_cursor_typed_sets():
    st = ClusterState(ClusterSpec(n_nodes=2, gpus_per_node=4))
    c0 = st.cursor()
    st.devices[3].compute_speed = 0.5
    st.degrade_link(0, 5, 0.4)
    st.degrade_nic(1, 0.7)
    ds = st.dirty_since(c0)
    assert ds.devices == {3}
    assert ds.links == {(0, 5)}
    assert ds.nics == {1}
    assert ds and not ds.full
    # a fresh cursor sees nothing; reset dirties only what was degraded
    c1 = st.cursor()
    assert not st.dirty_since(c1)
    st.reset()
    ds2 = st.dirty_since(c1)
    assert (ds2.devices, ds2.links, ds2.nics) == ({3}, {(0, 5)}, {1})
    # a pre-creation / overflowed cursor degrades to full-dirty
    assert st.dirty_since(-1).full
    st._bump()  # legacy whole-state invalidation stays conservative
    assert st.dirty_since(c1).full


def test_dirty_cursor_isolation_across_jobs_sharing_hardware():
    """Two jobs reading one hardware map each hold their own cursor: a
    fault on job A's devices leaves job B's cached reductions untouched
    (same object, no re-reduction), while both stay reference-exact."""
    cluster = ClusterSpec(n_nodes=4, gpus_per_node=4)
    sim_a = TrainingSimulator(
        cluster=cluster,
        job=JobSpec(model=MODEL, tp=2, dp=2, pp=2, micro_batches=4),
        placement=list(range(8)),
    )
    sim_b = TrainingSimulator(
        cluster=cluster,
        job=JobSpec(model=MODEL, tp=2, dp=2, pp=2, micro_batches=4),
        placement=list(range(8, 16)),
    )
    shared = ClusterState(cluster)
    sim_a.state = shared
    sim_b.state = shared
    assert sim_a.state_cursor() == sim_b.state_cursor()
    # a cursor from a *previous* state object must read as fully dirty
    assert sim_a.dirty_since((shared.uid - 1, 0)).full
    t_b0 = sim_b.iteration_time()
    sim_a.iteration_time()
    cells_b = sim_b._cells()
    # fault squarely inside job A's slice
    shared.devices[2].compute_speed = 0.4
    shared.degrade_link(0, 5, 0.3)
    assert sim_a.iteration_time() == sim_a.iteration_time_reference()
    assert sim_a.iteration_time() > sim_a.healthy_iteration_time()
    # B consumed the dirt but mapped it to zero cells: same cache object,
    # bit-identical content, unchanged result
    assert sim_b._cells() is cells_b
    assert sim_b.iteration_time() == t_b0
    assert sim_b.iteration_time() == sim_b.iteration_time_reference()
    # and a fault on B's slice does not disturb A's view
    t_a = sim_a.iteration_time()
    shared.devices[9].compute_speed = 0.5
    assert sim_b.iteration_time() == sim_b.iteration_time_reference()
    assert sim_b.iteration_time() != t_b0
    assert sim_a.iteration_time() == t_a
    assert sim_a.iteration_time() == sim_a.iteration_time_reference()


def test_shared_hardware_job_churn():
    """Jobs join and leave a shared hardware map mid-churn; every live
    job's incremental result stays bit-identical to its loop oracle."""
    cluster = ClusterSpec(n_nodes=4, gpus_per_node=4)
    shared = ClusterState(cluster)
    rng = np.random.default_rng(21)
    slices = [list(range(0, 8)), list(range(8, 16)), list(range(4, 12))]
    live: dict[int, TrainingSimulator] = {}
    for step in range(120):
        a = int(rng.integers(8))
        if a == 0 and len(live) < 2:
            free = [i for i in range(3) if i not in live
                    and not any(set(slices[i]) & set(slices[j]) for j in live)]
            if free:
                i = free[0]
                sim = TrainingSimulator(
                    cluster=cluster,
                    job=JobSpec(model=MODEL, tp=2, dp=2, pp=2, micro_batches=4),
                    placement=list(slices[i]),
                )
                sim.state = shared
                live[i] = sim
        elif a == 1 and live:
            del live[sorted(live)[0]]
        elif a == 2:
            shared.devices[int(rng.integers(16))].compute_speed = float(
                rng.uniform(0.3, 1.0)
            )
        elif a == 3:
            x, y = rng.choice(16, 2, replace=False)
            shared.degrade_link(int(x), int(y), float(rng.uniform(0.1, 1.0)))
        elif a == 4:
            shared.degrade_nic(int(rng.integers(4)), float(rng.uniform(0.3, 1.0)))
        elif a == 5:
            shared.reset()
        elif a == 6 and live:
            sim = live[sorted(live)[0]]
            perm = list(sim.placement)
            i, j = rng.choice(len(perm), 2, replace=False)
            perm[i], perm[j] = perm[j], perm[i]
            sim.remap_groups(perm)
        for key, sim in live.items():
            assert sim.iteration_time() == sim.iteration_time_reference(), (
                step, key,
            )


def test_mutation_log_overflow_degrades_to_full_rebuild():
    from repro.cluster import spec as spec_mod

    sim = make_sim(2, 2, 2, 2)
    sim.iteration_time()
    rng = np.random.default_rng(3)
    for i in range(spec_mod._LOG_CAP + 50):
        sim.state.devices[int(rng.integers(8))].compute_speed = float(
            rng.uniform(0.3, 1.0)
        )
    assert sim.state.dirty_since(0).full  # cursor fell off the log tail
    assert sim.iteration_time() == sim.iteration_time_reference()


def test_link_no_ring_traverses_is_free():
    """A degraded link that no communication ring uses changes nothing —
    and the incremental path knows it without re-reducing anything."""
    sim = make_sim(2, 2, 2, 2)
    t0 = sim.iteration_time()
    cells = sim._cells()
    # devices 0 and 7 share no ring adjacency in the canonical layout
    grid = sim._layout().grid
    a, b = int(grid[0, 0, 0]), int(grid[1, 1, 1])
    sim.state.degrade_link(a, b, 0.01)
    assert sim.iteration_time() == t0
    assert sim.iteration_time() == sim.iteration_time_reference()
    assert sim._cells() is cells


def test_event_scoped_beats_rebuild_op_count():
    """A single-device event must not trigger the O(devices) rebuild: the
    state's vectorized gathers are untouched on the incremental path."""
    sim = make_sim(2, 4, 2, 4)
    sim.iteration_time()
    calls = {"n": 0}
    orig = sim.state.effective_speeds

    def counting():
        calls["n"] += 1
        return orig()

    sim.state.effective_speeds = counting
    sim.state.devices[0].compute_speed = 0.5
    assert sim.iteration_time() == sim.iteration_time_reference()
    assert calls["n"] == 0  # full rebuild would have called it
