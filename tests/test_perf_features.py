"""Tests for the beyond-paper performance and control-plane features added
during the EXPERIMENTS §Perf hillclimb:

  * live-residual ski-rental (escalate only while mitigation is ineffective),
  * pipeline-aware S2 (offset = P-1),
  * targeted congestion swap from pinpointed links,
  * per-class link validation references,
  * detector re-validation (relief invisible after successful mitigation),
  * vocab padding (head/embedding stay model-sharded, pad columns masked),
  * FSDP serve param specs.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.core import microbatch as mb_lib, topology as topo_lib, validation
from repro.core.detector import FalconDetect
from repro.core.events import FailSlowEvent, RootCause, Strategy
from repro.core.planner import MitigationPlanner
from repro.models import model as model_lib
from repro.sharding import partition


# --------------------------------------------------------------- planner
def test_planner_live_residual_stops_escalation():
    """Once the measured iteration time returns to ~healthy (mitigation
    worked), the planner must stop accumulating impact (paper: escalate only
    while 'the current strategy proves ineffective')."""
    ev = FailSlowEvent(start_time=0, root_cause=RootCause.GPU_DEGRADATION,
                       t_healthy=1.0, t_slow=2.0)
    over = {Strategy.IGNORE: 0.0, Strategy.ADJUST_MICROBATCH: 3.0,
            Strategy.ADJUST_TOPOLOGY: 30.0, Strategy.CKPT_AND_RESTART: 1e9}
    p = MitigationPlanner(ev, over)
    assert p.update(current_time=2.0) == Strategy.IGNORE
    # Escalates to S2 while slow.
    got = [p.update(current_time=2.0) for _ in range(5)]
    assert Strategy.ADJUST_MICROBATCH in got
    # S2 worked: residual ~0 -> never escalates to S3.
    for _ in range(1000):
        assert p.update(current_time=1.01) is None


def test_planner_stale_delta_still_matches_algorithm1():
    """Without current_time the paper's literal Algorithm 1 is reproduced."""
    ev = FailSlowEvent(start_time=0, root_cause=RootCause.GPU_DEGRADATION,
                       t_healthy=1.0, t_slow=2.0)
    over = {Strategy.IGNORE: 0.0, Strategy.ADJUST_MICROBATCH: 10.0,
            Strategy.ADJUST_TOPOLOGY: 60.0, Strategy.CKPT_AND_RESTART: 1e9}
    p = MitigationPlanner(ev, over)
    hits = {}
    for i in range(1, 100):
        s = p.update()
        if s:
            hits[s] = i
    assert hits[Strategy.IGNORE] == 1
    assert hits[Strategy.ADJUST_MICROBATCH] == 11
    assert hits[Strategy.ADJUST_TOPOLOGY] == 61


# ----------------------------------------------------- pipeline-aware S2
@settings(deadline=None, max_examples=40)
@given(
    times=st.lists(st.floats(0.5, 3.0), min_size=2, max_size=5),
    pp=st.integers(1, 4),
)
def test_property_offset_allocation_optimal(times, pp):
    """Greedy with offset = P-1 minimizes max_i (m_i + P - 1) * t_i exactly
    (verified against brute force)."""
    d = len(times)
    total = 3 * d
    counts = mb_lib.solve_allocation(times, total, offset=pp - 1)
    got = max((m + pp - 1) * t for m, t in zip(counts, times))

    best = float("inf")
    for combo in itertools.product(range(1, total - d + 2), repeat=d):
        if sum(combo) != total:
            continue
        best = min(best, max((m + pp - 1) * t for m, t in zip(combo, times)))
    assert got == pytest.approx(best, rel=1e-9)


# --------------------------------------------------- targeted congestion swap
def test_targeted_swap_evacuates_congested_link():
    model = ModelSpec(layers=16, hidden=2048, seq_len=1024, vocab=32000)
    spec = ClusterSpec(n_nodes=4, gpus_per_node=4)
    job = JobSpec(model=model, tp=1, dp=4, pp=4, micro_batches=16)
    sim = TrainingSimulator(cluster=spec, job=job)
    a = sim.device_at(1, 2, 0)
    b = sim.device_at(1, 3, 0)
    sim.state.degrade_link(a, b, 0.1)
    t_cong = sim.iteration_time()

    topo, m = job.topology, job.model
    traffic = topo_lib.build_traffic_matrix(
        topo,
        comm_tp=m.comm_tp_bytes(job.tp, job.pp, job.micro_batches),
        comm_dp=m.comm_dp_bytes(job.tp, job.pp),
        comm_pp=m.comm_pp_bytes(job.micro_batches),
    )
    n = job.n_devices
    bw = np.full((n, n), np.inf)
    for i in range(n):
        for j in range(n):
            if i != j:
                bw[i, j] = sim.state.link_bw(sim.placement[i], sim.placement[j])
    slow_pos = [p for p, d in enumerate(sim.placement) if d in (a, b)]
    perm = topo_lib.plan_targeted_swap(traffic, bw, slow_pos)
    sim.apply_placement(perm)
    assert sim.iteration_time() < t_cong


# -------------------------------------------------- per-class link reference
def test_validation_reference_ignores_slower_link_classes():
    """RDMA links are ~8x slower than NVLink; without a per-class reference
    the median test flags every healthy inter-node link."""
    passes = [[(0, 1), (2, 3)], [(1, 2), (3, 0)]]
    healthy = {(0, 1): 1.0, (2, 3): 1.0, (1, 2): 8.0, (3, 0): 8.0}

    def measure(pair):
        t = healthy[tuple(sorted(pair))] if tuple(sorted(pair)) in healthy else healthy[pair]
        return t * (3.0 if set(pair) == {2, 3} else 1.0)  # (2,3) congested

    def reference(pair):
        key = tuple(sorted(pair))
        return healthy.get(key, healthy.get(pair))

    slow, _ = validation.validate_links(passes, measure, reference=reference)
    assert [set(s) for s in slow] == [{2, 3}]

    # Median-based (no reference) wrongly flags the healthy RDMA links too.
    slow_med, _ = validation.validate_links(passes, measure)
    assert {2, 3} in [set(s) for s in slow_med] or len(slow_med) != 1


# ------------------------------------------------ detector re-validation
def test_detector_revalidation_sees_relief_after_mitigation():
    """After S2 flattens the iteration-time signal, relief of the underlying
    fault is only visible to component re-validation."""
    model = ModelSpec(layers=16, hidden=4096, seq_len=1024, vocab=32000)
    spec = ClusterSpec(n_nodes=2, gpus_per_node=4)
    sim = TrainingSimulator(
        cluster=spec, job=JobSpec(model=model, tp=1, dp=8, pp=1, micro_batches=16)
    )
    det = FalconDetect(cluster=sim, verify_window=6, revalidate_every=5)
    rng = np.random.default_rng(0)
    now = 0.0
    # Healthy warmup.
    for _ in range(30):
        now += 1.0
        det.observe(1.0 * rng.normal(1, 0.005), now)
    # Fault: GPU 3 slow; detector pinpoints it.
    sim.state.devices[3].compute_speed = 0.6
    event = None
    for _ in range(20):
        now += 1.4
        ev = det.observe(1.4 * rng.normal(1, 0.005), now)
        event = ev or event
    assert event is not None and "gpu:3" in event.components
    # Mitigation flattens the signal back to ~1.0 while the fault persists:
    # the event must stay active.
    for _ in range(20):
        now += 1.02
        det.observe(1.02 * rng.normal(1, 0.005), now)
    assert det.active_event is not None
    # Fault clears; signal unchanged — only re-validation can notice.
    sim.state.devices[3].compute_speed = 1.0
    for _ in range(10):
        now += 1.02
        det.observe(1.02 * rng.normal(1, 0.005), now)
    assert det.active_event is None
    assert det.history and det.history[-1].resolved


# ------------------------------------------------------- vocab padding
def test_padded_vocab_multiple_of_128():
    for arch in ("granite-3-8b", "mamba2-2.7b", "yi-9b"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 128 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 128


def test_head_masks_padding_columns():
    cfg = get_config("granite-3-8b").smoke()
    # Force a padded vocab on the smoke config.
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab_size=500)  # padded -> 512
    assert cfg.padded_vocab == 512
    params = model_lib.init_params(cfg, 0)
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, _ = model_lib.forward(params, {"tokens": toks}, cfg)
    logits = np.asarray(logits, np.float32)
    assert logits.shape[-1] == 512
    assert (logits[..., 500:] < -1e8).all()
    assert np.isfinite(logits[..., :500]).all()
    # Loss is finite and the padded columns contribute nothing to logsumexp.
    loss, _ = model_lib.loss_fn(
        params, {"tokens": toks, "labels": toks}, cfg
    )
    assert np.isfinite(float(loss))


# ------------------------------------------------- benchmark smoke pass
@pytest.mark.slow
def test_benchmark_suite_smoke_pass():
    """`benchmarks.run --smoke` executes every registered benchmark at toy
    scale — perf entry points that never run, silently rot. Subprocess so the
    suite's JAX compilations stay out of this interpreter."""
    import os
    import subprocess
    import sys

    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    assert "ALL BENCHMARKS COMPLETED" in out.stdout


# ------------------------------------------------------- FSDP serve specs
def test_fsdp_specs_add_data_axis_to_large_params():
    """Subprocess (needs >1 host device): large params gain a DP axis,
    small ones stay replicated."""
    import os
    import subprocess
    import sys

    script = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4'\n"
        "import jax\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from repro.configs.base import get_config\n"
        "from repro.sharding import partition\n"
        "cfg = get_config('granite-3-8b')\n"
        "mesh = jax.make_mesh((2, 2), ('data', 'model'))\n"
        "specs = partition.fsdp_param_specs(cfg, mesh, min_dim=2048)\n"
        "flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))\n"
        "assert any('data' in str(s) for s in flat), flat[:5]\n"
        "assert all(isinstance(s, P) for s in flat)\n"
        "norm = specs['final_norm']\n"
        "assert all(a is None for s in jax.tree.leaves(norm, is_leaf=lambda x: isinstance(x, P)) for a in s)\n"
        "print('FSDP-SPECS-OK')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FSDP-SPECS-OK" in out.stdout
