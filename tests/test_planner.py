"""Tests for the ski-rental mitigation planner (paper §5.2, Algorithm 1)."""
import pytest

from repro.core.events import FailSlowEvent, RootCause, Strategy
from repro.core.planner import APPLICABLE, MitigationPlanner


def make_event(cause=RootCause.GPU_DEGRADATION, t_healthy=1.0, t_slow=2.0):
    return FailSlowEvent(
        start_time=0.0, root_cause=cause, t_healthy=t_healthy, t_slow=t_slow
    )


def test_ignore_applied_first():
    p = MitigationPlanner(make_event())
    # First degraded iteration: impact 1s > overhead(S1)=0 -> apply S1.
    assert p.update() == Strategy.IGNORE


def test_ski_rental_break_even_escalation():
    overheads = {
        Strategy.IGNORE: 0.0,
        Strategy.ADJUST_MICROBATCH: 10.0,
        Strategy.ADJUST_TOPOLOGY: 60.0,
        Strategy.CKPT_AND_RESTART: 600.0,
    }
    p = MitigationPlanner(make_event(t_healthy=1.0, t_slow=2.0), overheads)
    applied = []
    for _ in range(700):
        s = p.update()
        if s:
            applied.append((p._slow_iters, s))
    # Escalation exactly when accumulated impact (1 s/iter) crosses overhead.
    stages = dict((s, it) for it, s in applied)
    assert stages[Strategy.IGNORE] == 1
    assert stages[Strategy.ADJUST_MICROBATCH] == 11
    assert stages[Strategy.ADJUST_TOPOLOGY] == 61
    assert stages[Strategy.CKPT_AND_RESTART] == 601
    assert p.exhausted()


def test_comm_failslow_skips_s2():
    """Table 3: S2 has no effect on slow communication."""
    assert Strategy.ADJUST_MICROBATCH not in APPLICABLE[RootCause.NETWORK_CONGESTION]
    p = MitigationPlanner(make_event(cause=RootCause.NETWORK_CONGESTION))
    applied = []
    for _ in range(10000):
        s = p.update()
        if s:
            applied.append(s)
    assert Strategy.ADJUST_MICROBATCH not in applied
    assert applied == [
        Strategy.IGNORE,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ]


def test_short_event_never_escalates():
    """A transient blip resolves before the accumulated impact reaches the
    next overhead — planner must stay at S1 (the whole point of ski-rental)."""
    ev = make_event(t_healthy=1.0, t_slow=1.5)
    overheads = {
        Strategy.IGNORE: 0.0,
        Strategy.ADJUST_MICROBATCH: 5.0,
        Strategy.ADJUST_TOPOLOGY: 60.0,
        Strategy.CKPT_AND_RESTART: 1800.0,
    }
    p = MitigationPlanner(ev, overheads)
    applied = [s for s in (p.update() for _ in range(8)) if s]
    ev.end_time = 8.0
    assert p.update() is None
    assert applied == [Strategy.IGNORE]


def test_no_update_after_resolution():
    ev = make_event()
    p = MitigationPlanner(ev)
    p.update()
    ev.end_time = 1.0
    assert p.update() is None


def test_zero_severity_never_escalates_past_s1():
    p = MitigationPlanner(make_event(t_healthy=1.0, t_slow=1.0))
    applied = [s for s in (p.update() for _ in range(1000)) if s]
    assert applied == []  # impact is 0: not even S1 triggers
