"""Control-plane API tests.

* Strategy-registry dispatch is decision-for-decision equivalent to the old
  hand-wired ``FalconTrainer._apply_strategy`` ladder on the 64-GPU
  end-to-end scenario (same escalation sequence, same wall time).
* Cross-job flag dedupe: two registered jobs sharing a node, one injected
  fail-slow, one profiling+validation pinpoint, a diagnosis routed to both.
* The vectorized pinpoint validation sweep matches the scalar per-pair /
  per-group fallback path component for component.
* Trace replay, custom strategy registration, screening-path relief, and
  the Monitor's injectable clock.
"""
import numpy as np
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.cluster.traces import LabeledEpisode, generate_trace
from repro.controlplane import (
    ControlPlane,
    Diagnosis,
    MitigationResult,
    StrategyRegistry,
    TraceReplayAdapter,
    default_registry,
)
from repro.controlplane.strategies import (
    IgnoreStrategy,
    MicroBatchStrategy,
    MitigationContext,
    StrategyOutcome,
)
from repro.core import microbatch as mb_lib
from repro.core import topology as topo_lib
from repro.core.detector import FalconDetect
from repro.core.events import ChangePoint, RootCause, Strategy
from repro.core.monitor import Monitor
from repro.core.planner import MitigationPlanner

MODEL_13B = ModelSpec(layers=40, hidden=5120, seq_len=2048, vocab=50257)
MODEL_SMALL = ModelSpec(
    layers=32, hidden=8192, seq_len=2048, vocab=32000, micro_batch=2
)

OVERHEADS = {
    Strategy.IGNORE: 0.0,
    Strategy.ADJUST_MICROBATCH: 2.0,
    Strategy.ADJUST_TOPOLOGY: 10.0,
    Strategy.CKPT_AND_RESTART: 1800.0,
}


# ------------------------------------------------- 64-GPU e2e scenario
def make_64gpu():
    """The end_to_end benchmark's (16DP, 4PP) job + mixed fail-slow trace."""
    spec = ClusterSpec(n_nodes=8, gpus_per_node=8)
    job = JobSpec(model=MODEL_13B, tp=1, dp=16, pp=4, micro_batches=64)
    sim = TrainingSimulator(cluster=spec, job=job)
    t = sim.healthy_iteration_time()
    comp, comm = InjectionKind.GPU_SLOW, InjectionKind.LINK_CONGESTION
    mk = lambda s, d, kind, tgt, sev: Injection(  # noqa: E731
        start=s * t, duration=d * t, kind=kind, target=tgt, severity=sev
    )
    injections = [
        mk(25, 250, comp, (5,), 0.3),
        mk(150, 200, comp, (12,), 0.5),
        mk(420, 450, comm, (23, 24), 0.7),
        mk(500, 180, comp, (33,), 0.4),
    ]
    return sim, FailSlowInjector(injections)


def legacy_apply(strategy, event, sim, injector, wall):
    """The seed FalconTrainer's hand-wired strategy ladder, verbatim
    (simulator-side effects; the JAX-side params shuffle doesn't touch the
    modeled dynamics)."""
    if strategy is Strategy.IGNORE:
        return
    if strategy is Strategy.ADJUST_MICROBATCH:
        counts = mb_lib.solve_allocation(
            sim.per_microbatch_times(), sim.job.micro_batches,
            offset=sim.job.pp - 1,
        )
        sim.set_allocation(counts)
    elif strategy is Strategy.ADJUST_TOPOLOGY:
        before_placement = list(sim.placement)
        before_t = sim.iteration_time()
        job, topo = sim.job, sim.job.topology
        stragglers = [
            int(c.split(":")[1]) for c in event.components if c.startswith("gpu:")
        ]
        slow_links = [
            tuple(int(x) for x in c.split(":")[1].split("-"))
            for c in event.components
            if c.startswith("link:")
        ]
        if stragglers and not slow_links and topo.pp > 1:
            pos = [p for p, d in enumerate(sim.placement) if d in set(stragglers)]
            sim.apply_placement(topo_lib.consolidate_stragglers(pos, topo))
        else:
            m = job.model
            traffic = topo_lib.build_traffic_matrix(
                topo,
                comm_tp=m.comm_tp_bytes(job.tp, job.pp, job.micro_batches),
                comm_dp=m.comm_dp_bytes(job.tp, job.pp),
                comm_pp=m.comm_pp_bytes(job.micro_batches),
            )
            n = job.n_devices
            bw = np.full((n, n), np.inf)
            for i in range(n):
                for j in range(n):
                    if i != j:
                        bw[i, j] = sim.state.link_bw(
                            sim.placement[i], sim.placement[j]
                        )
            if slow_links:
                slow_pos = [
                    p for p, d in enumerate(sim.placement)
                    if any(d in pair for pair in slow_links)
                ]
                sim.apply_placement(
                    topo_lib.plan_targeted_swap(traffic, bw, slow_pos)
                )
            else:
                sim.apply_placement(topo_lib.plan_topology_adjustment(traffic, bw))
        if sim.iteration_time() > before_t * 0.999:
            sim.placement = before_placement
    elif strategy is Strategy.CKPT_AND_RESTART:
        sim.restart()
        if injector is not None:
            injector.injections = [
                i for i in injector.injections if not i.active(wall)
            ]


def legacy_drive(sim, injector, n_steps):
    """The seed trainer's detect/plan/mitigate loop (pre-control-plane)."""
    detector = FalconDetect(cluster=sim, verify_window=8)
    planner = None
    wall = 0.0
    applied = []
    for _ in range(n_steps):
        injector.apply(sim.state, wall)
        it = sim.iteration_time()
        wall += it
        had_active = detector.active_event is not None
        new_event = detector.observe(it, wall)
        if new_event is not None:
            planner = MitigationPlanner(new_event, dict(OVERHEADS))
        active = detector.active_event
        if active is None:
            if had_active:
                counts = mb_lib.solve_allocation(
                    sim.per_microbatch_times(), sim.job.micro_batches,
                    offset=sim.job.pp - 1,
                )
                sim.set_allocation(counts)
                applied.append("REBALANCE")
            planner = None
        elif planner is not None:
            s = planner.update(current_time=it)
            if s is not None:
                legacy_apply(s, active, sim, injector, wall)
                wall += OVERHEADS.get(s, 0.0)
                applied.append(s.name)
    return applied, wall


def controlplane_drive(sim, injector, n_steps):
    """The same scenario through the public ControlPlane API."""
    plane = ControlPlane()
    plane.register_job(
        "job", sim,
        detector=FalconDetect(cluster=sim, verify_window=8),
        overheads=dict(OVERHEADS), injector=injector,
    )
    wall = 0.0
    applied = []
    for _ in range(n_steps):
        injector.apply(sim.state, wall)
        it = sim.iteration_time()
        wall += it
        for ev in plane.observe("job", it, wall):
            if isinstance(ev, MitigationResult):
                if ev.kind == "relief":
                    applied.append("REBALANCE")
                else:
                    wall += ev.overhead
                    applied.append(ev.strategy.name)
    return applied, wall


def test_registry_dispatch_equivalent_to_legacy_ladder_64gpu():
    """Acceptance: the 64-GPU scenario produces the same strategy escalation
    sequence and wall time through ControlPlane as through the old
    hand-wired trainer path."""
    n_steps = 400
    sim_a, inj_a = make_64gpu()
    legacy_strats, legacy_wall = legacy_drive(sim_a, inj_a, n_steps)
    sim_b, inj_b = make_64gpu()
    plane_strats, plane_wall = controlplane_drive(sim_b, inj_b, n_steps)
    assert legacy_strats == plane_strats
    assert legacy_strats  # the scenario must actually exercise the ladder
    assert "ADJUST_MICROBATCH" in legacy_strats
    assert plane_wall == pytest.approx(legacy_wall, rel=1e-12)
    assert sim_b.allocation == sim_a.allocation
    assert sim_b.placement == sim_a.placement


# --------------------------------------------------- cross-job dedupe
class CountingSim(TrainingSimulator):
    """Counts pinpoint entries (profiling-phase calls)."""

    def __post_init__(self):
        super().__post_init__()
        self.profile_calls = 0

    def profile_groups(self):
        self.profile_calls += 1
        return super().profile_groups()


def make_shared_pair():
    """Two jobs scheduled on the same physical 8-GPU slice."""

    def mk():
        return CountingSim(
            cluster=ClusterSpec(n_nodes=2, gpus_per_node=4),
            job=JobSpec(model=MODEL_SMALL, tp=2, dp=4, pp=1, micro_batches=16),
        )

    return mk(), mk(), [f"hw{i}" for i in range(8)]


def test_cross_job_flag_dedupe_single_diagnosis_routed_to_both():
    """One shared-hardware fail-slow -> one pinpoint, a deduped diagnosis
    for the second job carrying the same (translated) components."""
    sim_a, sim_b, hw = make_shared_pair()
    plane = ControlPlane()
    plane.register_job("A", sim_a, hardware=hw)
    plane.register_job("B", sim_b, hardware=hw)
    rng = np.random.default_rng(0)
    wall = 0.0
    for t in range(120):
        if t == 60:  # the shared GPU hw1 degrades under both jobs
            sim_a.state.devices[1].compute_speed = 0.4
            sim_b.state.devices[1].compute_speed = 0.4
        ta = sim_a.iteration_time() * float(rng.normal(1, 0.003))
        tb = sim_b.iteration_time() * float(rng.normal(1, 0.003))
        wall += max(ta, tb)
        plane.tick({"A": ta, "B": tb}, wall)

    assert sim_a.profile_calls + sim_b.profile_calls == 1  # single pinpoint
    open_diags = [d for d in plane.diagnoses() if not d.resolved]
    assert sorted(d.job_id for d in open_diags) == ["A", "B"]
    for d in open_diags:
        assert d.event.root_cause is RootCause.GPU_DEGRADATION
        assert d.event.components == ["gpu:1"]
        assert d.components_global == ("gpu:hw1",)
    by_job = {d.job_id: d for d in open_diags}
    assert by_job["A"].deduped_from is None
    assert by_job["B"].deduped_from == "A"
    # Both jobs' planners escalate on their own copy of the diagnosis.
    assert plane.job("A").planner is not None
    assert plane.job("B").planner is not None


def test_dedupe_requires_shared_hardware():
    """Disjoint hardware maps: each job pinpoints for itself."""
    sim_a, sim_b, hw = make_shared_pair()
    plane = ControlPlane()
    plane.register_job("A", sim_a, hardware=[f"a{i}" for i in range(8)])
    plane.register_job("B", sim_b, hardware=[f"b{i}" for i in range(8)])
    rng = np.random.default_rng(1)
    wall = 0.0
    for t in range(120):
        if t == 60:
            sim_a.state.devices[1].compute_speed = 0.4
            sim_b.state.devices[1].compute_speed = 0.4
        ta = sim_a.iteration_time() * float(rng.normal(1, 0.003))
        tb = sim_b.iteration_time() * float(rng.normal(1, 0.003))
        wall += max(ta, tb)
        plane.tick({"A": ta, "B": tb}, wall)
    assert sim_a.profile_calls == 1
    assert sim_b.profile_calls == 1
    assert all(d.deduped_from is None for d in plane.diagnoses())


# ------------------------------------- screening-path relief + revalidate
def test_screening_path_closes_event_after_relief():
    sim, _, hw = make_shared_pair()
    plane = ControlPlane()
    plane.register_job("A", sim, hardware=hw)
    rng = np.random.default_rng(3)
    wall = 0.0
    for t in range(240):
        if t == 80:
            sim.state.devices[1].compute_speed = 0.4
        if t == 160:
            sim.state.devices[1].compute_speed = 1.0
        it = sim.iteration_time() * float(rng.normal(1, 0.003))
        wall += it
        plane.tick({"A": it}, wall)
    assert plane.job("A").detector.active_event is None
    diags = plane.diagnoses()
    assert any(not d.resolved for d in diags)  # onset was diagnosed
    assert any(d.resolved for d in diags)  # ...and later closed
    relief = [
        e for e in plane.events
        if isinstance(e, MitigationResult) and e.kind == "relief"
    ]
    assert relief and relief[-1].detail["allocation"] == [4, 4, 4, 4]


# ------------------------------------------------ vectorized pinpoint
class ScalarOnlyProxy:
    """Hides the batch validation methods: forces the per-pair fallback."""

    def __init__(self, sim):
        self._sim = sim

    def profile_groups(self):
        return self._sim.profile_groups()

    def group_ranks(self, group):
        return self._sim.group_ranks(group)

    def benchmark_compute(self, ranks):
        return self._sim.benchmark_compute(ranks)

    def measure_link(self, pair):
        return self._sim.measure_link(pair)

    def healthy_link_time(self, pair):
        return self._sim.healthy_link_time(pair)


def _random_failslow_sim(rng):
    tp = int(rng.choice([1, 2, 4]))
    dp = int(rng.choice([2, 4]))
    pp = int(rng.choice([1, 2]))
    n = tp * dp * pp
    spec = ClusterSpec(n_nodes=max(1, n // 4), gpus_per_node=4)
    if n > spec.n_devices:
        return None
    sim = TrainingSimulator(
        cluster=spec,
        job=JobSpec(model=MODEL_SMALL, tp=tp, dp=dp, pp=pp, micro_batches=4 * dp),
    )
    kind = rng.choice(["gpu", "link", "both", "none"])
    if kind in ("gpu", "both"):
        sim.state.devices[int(rng.integers(n))].compute_speed = float(
            rng.uniform(0.3, 0.6)
        )
    if kind in ("link", "both"):
        a, b = rng.choice(n, 2, replace=False)
        sim.state.degrade_link(int(a), int(b), float(rng.uniform(0.1, 0.4)))
    return sim


def test_vectorized_pinpoint_matches_scalar_fallback():
    """Batched benchmark_compute / measure_links sweeps flag exactly the
    components the scalar per-group path flags (order included)."""
    rng = np.random.default_rng(7)
    cp = ChangePoint(index=50, probability=1.0, mean_before=1.0, mean_after=1.5)
    tried = 0
    while tried < 25:
        sim = _random_failslow_sim(rng)
        if sim is None:
            continue
        tried += 1
        fast = FalconDetect(cluster=sim)._pinpoint(0.0, cp)
        slow = FalconDetect(cluster=ScalarOnlyProxy(sim))._pinpoint(0.0, cp)
        assert fast.components == slow.components
        assert fast.root_cause is slow.root_cause


def test_pinpoint_flags_injected_gpu_and_link():
    sim, _, _ = make_shared_pair()
    sim.state.devices[2].compute_speed = 0.5
    det = FalconDetect(cluster=sim)
    ev = det._pinpoint(
        0.0, ChangePoint(index=0, probability=1.0, mean_before=1.0, mean_after=1.4)
    )
    assert ev.root_cause is RootCause.GPU_DEGRADATION
    assert "gpu:2" in ev.components


# ------------------------------------------------ trace replay adapter
def test_trace_replay_adapter_through_control_plane():
    rng = np.random.default_rng(11)
    trace = generate_trace(
        rng, n_iters=300,
        episodes=[LabeledEpisode(onset=120, relief=260, severity=0.5)],
    )
    adapter = TraceReplayAdapter(trace)
    plane = ControlPlane()
    plane.register_job("trace", adapter)
    wall, onset_steps = 0.0, []
    while (t := adapter.next_observation()) is not None:
        wall += t
        for ev in plane.observe("trace", t, wall):
            if isinstance(ev, Diagnosis) and not ev.resolved:
                onset_steps.append(plane.job("trace").steps - 1)
    assert onset_steps, "episode missed"
    assert abs(onset_steps[0] - 120) <= 12
    # A scalar trace carries no component evidence: host-level root cause.
    diag = plane.diagnoses("trace")[0]
    assert diag.event.root_cause is RootCause.CPU_CONTENTION
    assert diag.event.components == []


# ------------------------------------------------ custom strategies
class HotSpareStrategy:
    """Beyond-paper example: swap the slow device for a hot spare."""

    key = "HOT_SPARE_SWAP"

    def __init__(self):
        self.swapped = []

    def handles(self, event):
        return event.root_cause is RootCause.GPU_DEGRADATION

    def apply(self, ctx):
        for comp in ctx.event.components:
            kind, _, ident = comp.partition(":")
            if kind == "gpu":
                dev = int(ident)
                ctx.adapter.state.devices[dev].compute_speed = 1.0
                self.swapped.append(dev)
        return StrategyOutcome(applied=bool(self.swapped))

    def relieve(self, ctx):
        return None


def test_custom_strategy_slots_into_escalation_ladder():
    """A new scenario is one registered class: the ski-rental ladder places
    it by overhead (here between S1 and S2), no trainer/planner edit."""
    sim, _, _ = make_shared_pair()
    spare = HotSpareStrategy()
    registry = (
        StrategyRegistry()
        .register(IgnoreStrategy())
        .register(spare, overhead=1.0)
        .register(MicroBatchStrategy())
    )
    plane = ControlPlane()
    plane.register_job(
        "A", sim, registry=registry,
        overheads={Strategy.IGNORE: 0.0, Strategy.ADJUST_MICROBATCH: 5.0},
    )
    rng = np.random.default_rng(5)
    wall, applied = 0.0, []
    for t in range(140):
        if t == 60:
            sim.state.devices[1].compute_speed = 0.4
        it = sim.iteration_time() * float(rng.normal(1, 0.003))
        wall += it
        for ev in plane.observe("A", it, wall):
            if isinstance(ev, MitigationResult) and ev.kind == "mitigate":
                wall += ev.overhead
                applied.append(ev.strategy)
    assert Strategy.IGNORE in applied
    assert "HOT_SPARE_SWAP" in applied
    assert spare.swapped == [1]
    # The hot spare fixed the fault, so S2 never needed to fire.
    assert Strategy.ADJUST_MICROBATCH not in applied
    assert sim.state.devices[1].compute_speed == 1.0


def test_default_registry_candidates_match_paper_table3():
    from repro.core.events import FailSlowEvent
    from repro.core.planner import APPLICABLE

    reg = default_registry()
    for cause, expected in APPLICABLE.items():
        ev = FailSlowEvent(start_time=0.0, root_cause=cause)
        assert tuple(reg.candidates(ev)) == expected


# ------------------------------------------------ monitor clock satellite
def test_monitor_uses_injected_clock():
    from repro.core.events import CommOp

    sim_clock = {"now": 100.0}
    mon = Monitor(clock=lambda: sim_clock["now"])
    mon.record(CommOp.ALL_REDUCE)
    sim_clock["now"] = 250.0
    mon.record(CommOp.ALL_GATHER)
    mon.record(CommOp.ALL_REDUCE, timestamp=7.5)  # explicit wins
    stamps = [e.timestamp for e in mon.events]
    assert stamps == [100.0, 250.0, 7.5]
