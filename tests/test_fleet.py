"""Fleet fast-path equivalence tests.

Pins the vectorized implementations to their scalar/loop references:

* ``BatchedBOCD`` change-point indices match scalar ``BOCD`` per column
  (uncapped mode is per-column exact; the capped shared frontier equals the
  scalar cap rule at B=1).
* The vectorized ``TrainingSimulator`` fast path matches the nested-loop
  reference to 1e-9 across randomized placements, allocations and injected
  slowdowns, and its memo invalidates on every mutation surface.
"""
import numpy as np
import pytest

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.core import bocd
from repro.core.detector import FalconDetect, FleetDetect
from repro.core.ringbuf import MatrixRingBuffer, RingBuffer

MODEL = ModelSpec(layers=24, hidden=4096, seq_len=2048, vocab=50257)


# --------------------------------------------------------- batched BOCD
def fleet_matrix(n_workers=24, n_ticks=400, seed=0):
    """Per-column step changes at varied onsets/levels/jumps."""
    x = np.empty((n_ticks, n_workers))
    for col in range(n_workers):
        r = np.random.default_rng(seed * 1000 + col)
        lvl = 1.0 + 0.5 * (col % 3)
        jump = 1.0 + 0.15 + 0.02 * (col % 7)
        cp = (100 + 7 * col) % (n_ticks // 2) + 50
        x[:, col] = np.concatenate([
            r.normal(lvl, 0.01 * lvl, cp),
            r.normal(lvl * jump, 0.01 * lvl * jump, n_ticks - cp),
        ])
    return x


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_indices_match_scalar_per_column(seed):
    x = fleet_matrix(seed=seed)
    batched = bocd.detect_change_points_batch(x)
    for col in range(x.shape[1]):
        assert batched[col] == bocd.detect_change_points(x[:, col]), col


def test_batched_posterior_matches_scalar_uncapped():
    x = fleet_matrix(n_workers=8, n_ticks=200)
    scale = bocd.noise_scale_batch(x)
    det = bocd.BatchedBOCD(8, mu0=x[0] / scale)
    scalars = [
        bocd.BOCD(mu0=float(x[0, c] / scale[c])) for c in range(8)
    ]
    for t in range(x.shape[0]):
        det.update(x[t] / scale)
        for c, s in enumerate(scalars):
            s.update(float(x[t, c] / scale[c]))
            live = np.isfinite(det._log_r[:, c])
            assert np.array_equal(det._rl[live], s._rl), (t, c)
            np.testing.assert_allclose(
                det._log_r[live, c], s._log_r, atol=1e-9
            )


def test_capped_batched_equals_capped_scalar_at_b1():
    """The shared truncation frontier degenerates to the scalar cap rule."""
    r = np.random.default_rng(3)
    x = np.concatenate([r.normal(1.0, 0.01, 250), r.normal(1.4, 0.014, 250)])
    s = bocd.BOCD(mu0=float(x[0]), max_hypotheses=32)
    b = bocd.BatchedBOCD(1, mu0=x[:1], max_hypotheses=32)
    for t in range(x.size):
        s.update(float(x[t]))
        b.update(x[t : t + 1])
        assert b.n_hypotheses <= 32
        assert np.array_equal(b._rl, s._rl), t
        np.testing.assert_allclose(b._log_r[:, 0], s._log_r, atol=1e-9)


def test_scalar_cap_bounds_hypotheses():
    det = bocd.BOCD(hazard=0.01, mu0=1.0, max_hypotheses=24)
    r = np.random.default_rng(1)
    for _ in range(800):
        det.update(float(r.normal(1.0, 0.01)))
        assert det._log_r.size <= 24
    # detection still works through the cap
    x = np.concatenate([r.normal(1.0, 0.01, 80), r.normal(1.5, 0.015, 80)])
    scale = bocd.noise_scale(x)
    det2 = bocd.BOCD(mu0=float(x[0] / scale), max_hypotheses=24)
    fired = []
    for i, xi in enumerate(x):
        det2.update(float(xi / scale))
        if i > 2 and det2.p_recent_change() > 0.9:
            fired.append(i - det2.map_runlength())
    assert any(abs(i - 80) <= 3 for i in fired)


def test_noise_scale_batch_matches_scalar():
    x = fleet_matrix(n_workers=6, n_ticks=100)
    batch = bocd.noise_scale_batch(x)
    for c in range(6):
        assert batch[c] == pytest.approx(bocd.noise_scale(x[:, c]), rel=0, abs=0)


# ----------------------------------------------------------- FleetDetect
def test_fleet_detect_flags_exactly_the_stragglers():
    n, t_total, onset = 256, 160, 100
    rng = np.random.default_rng(5)
    x = rng.normal(1.0, 0.01, (t_total, n))
    bad = sorted(rng.choice(n, 6, replace=False).tolist())
    x[onset:, bad] *= 1.35
    fd = FleetDetect(n_workers=n)
    hits = {}
    for t in range(t_total):
        for flag in fd.tick(x[t]):
            hits.setdefault(flag.worker, flag.change_point)
    assert sorted(hits) == bad
    for cp in hits.values():
        assert abs(cp.index - onset) <= 5
        assert cp.relative_change > 0.2


def test_fleet_detect_no_false_flags_on_healthy_fleet():
    rng = np.random.default_rng(11)
    fd = FleetDetect(n_workers=128)
    flags = [f for t in range(200) for f in fd.tick(rng.normal(1.0, 0.01, 128))]
    assert flags == []


def test_fleet_detect_flags_once_per_change():
    rng = np.random.default_rng(7)
    x = rng.normal(1.0, 0.01, (200, 32))
    x[80:, 3] *= 1.5
    fd = FleetDetect(n_workers=32)
    flags = [f for t in range(200) for f in fd.tick(x[t])]
    assert len([f for f in flags if f.worker == 3]) == 1


# ------------------------------------------------------------ ring buffer
def test_ring_buffer_absolute_indexing():
    rb = RingBuffer(4)
    for i in range(10):
        rb.append(float(i))
    assert len(rb) == 10
    assert rb.start == 6
    assert rb.view(6, 10).tolist() == [6.0, 7.0, 8.0, 9.0]
    assert rb.view(0, 8).tolist() == [6.0, 7.0]  # clamped to retained
    assert rb.last(2).tolist() == [8.0, 9.0]
    assert rb[7] == 7.0
    with pytest.raises(IndexError):
        rb[5]


def test_matrix_ring_buffer_columns():
    mb = MatrixRingBuffer(3, 2)
    for i in range(5):
        mb.append(np.array([i, 10 + i], dtype=float))
    assert mb.column(0, 2, 5).tolist() == [2.0, 3.0, 4.0]
    assert mb.column(1, 0, 5).tolist() == [12.0, 13.0, 14.0]
    assert mb.rows(3).shape == (2, 2)


def test_falcon_detect_bounded_history_still_detects():
    """Detection works far beyond the ring capacity (O(1) per observe)."""
    class _Stub:  # pinpoint sees no groups -> CPU_CONTENTION root cause
        def profile_groups(self):
            return {}
        def group_ranks(self, g):
            return []
        def benchmark_compute(self, ranks):
            return {}
        def measure_link(self, pair):
            return 0.0
    det = FalconDetect(cluster=_Stub(), history_cap=128)
    rng = np.random.default_rng(0)
    event = None
    for i in range(2000):
        t = 1.0 if i < 1500 else 1.6
        t *= float(rng.normal(1, 0.004))
        event = det.observe(t, float(i)) or event
    assert det._series.capacity == 128  # bounded storage
    assert event is not None
    assert event.t_slow > event.t_healthy * 1.4


# ------------------------------------------------- vectorized simulator
def random_sim(rng):
    tp = int(rng.choice([1, 2, 4]))
    pp = int(rng.choice([1, 2, 4]))
    dp = int(rng.choice([1, 2, 4, 8]))
    n = tp * dp * pp
    gpn = int(rng.choice([2, 4, 8]))
    nodes = max(1, (n + gpn - 1) // gpn)
    spec = ClusterSpec(n_nodes=nodes, gpus_per_node=gpn)
    if n > spec.n_devices:
        return None
    job = JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp, micro_batches=4 * dp)
    sim = TrainingSimulator(cluster=spec, job=job)
    sim.apply_placement(rng.permutation(n).tolist())
    if dp > 1:
        alloc = [4] * dp
        alloc[0] += 2
        alloc[1] -= 2
        sim.set_allocation(alloc)
    for _ in range(int(rng.integers(0, 4))):
        kind = rng.choice(["gpu", "host", "link", "nic"])
        if kind == "gpu":
            sim.state.devices[int(rng.integers(n))].compute_speed = float(
                rng.uniform(0.3, 0.9)
            )
        elif kind == "host":
            sim.state.devices[int(rng.integers(n))].host_speed = float(
                rng.uniform(0.5, 0.9)
            )
        elif kind == "link":
            a, b = rng.choice(spec.n_devices, 2, replace=False)
            sim.state.degrade_link(int(a), int(b), float(rng.uniform(0.05, 0.8)))
        else:
            sim.state.degrade_nic(int(rng.integers(nodes)), float(rng.uniform(0.2, 0.8)))
    return sim


def test_vectorized_simulator_matches_reference_randomized():
    rng = np.random.default_rng(42)
    tried = 0
    while tried < 40:
        sim = random_sim(rng)
        if sim is None:
            continue
        tried += 1
        fast, ref = sim.iteration_time(), sim.iteration_time_reference()
        assert fast == pytest.approx(ref, rel=1e-9, abs=0.0)
        assert sim.profile_groups() == sim.profile_groups_reference()
        assert sim.per_microbatch_times() == pytest.approx(
            sim.per_microbatch_times_reference(), rel=1e-9
        )


def make_sim(tp=2, dp=2, pp=2, nodes=2, gpn=4, micro_batches=8):
    job = JobSpec(model=MODEL, tp=tp, dp=dp, pp=pp, micro_batches=micro_batches)
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=nodes, gpus_per_node=gpn), job=job
    )


def test_memo_invalidates_on_every_mutation_surface():
    sim = make_sim()

    def check():
        assert sim.iteration_time() == pytest.approx(
            sim.iteration_time_reference(), rel=1e-12
        )

    check()
    sim.state.devices[0].compute_speed = 0.5
    check()
    sim.state.devices[1].host_speed = 0.7
    check()
    sim.state.degrade_link(0, 4, 0.2)
    check()
    sim.state.degrade_nic(1, 0.5)
    check()
    sim.state.restore_link(0, 4)
    check()
    sim.state.restore_nic(1)
    check()
    sim.state.reset()
    check()
    sim.set_allocation([6, 2])
    check()
    sim.apply_placement(list(reversed(range(sim.job.n_devices))))
    check()
    sim.placement = list(range(sim.job.n_devices))  # direct assignment
    check()
    sim.restart()
    check()


def test_memoized_healthy_steps_hit_cache():
    sim = make_sim()
    inj = FailSlowInjector([
        Injection(start=5.0, duration=10.0, kind=InjectionKind.GPU_SLOW,
                  target=(0,), severity=0.5),
    ])
    inj.apply(sim.state, 0.0)
    t0 = sim.iteration_time()
    v0 = sim.state.version
    inj.apply(sim.state, 1.0)  # same (empty) active set: no reset, no bump
    assert sim.state.version == v0
    assert sim.iteration_time() == t0
    inj.apply(sim.state, 6.0)  # episode starts: state changes
    assert sim.state.version != v0
    t1 = sim.iteration_time()
    assert t1 > t0
    v1 = sim.state.version
    inj.apply(sim.state, 7.0)  # steady episode: no re-apply
    assert sim.state.version == v1
    inj.apply(sim.state, 20.0)  # episode over: reset back to healthy
    assert sim.iteration_time() == pytest.approx(t0)


def test_external_mutation_between_applies_is_not_lost():
    """The injector's steady-state skip must notice third-party mutations."""
    sim = make_sim()
    inj = FailSlowInjector([
        Injection(start=0.0, duration=100.0, kind=InjectionKind.GPU_SLOW,
                  target=(0,), severity=0.5),
    ])
    inj.apply(sim.state, 1.0)
    t_ep = sim.iteration_time()
    sim.state.devices[0].compute_speed = 1.0  # external meddling
    inj.apply(sim.state, 2.0)  # version moved: full reset + re-apply
    assert sim.iteration_time() == pytest.approx(t_ep)


# ------------------------------------------- dynamic membership (churn)
def _posterior(batch, col):
    """One column's live (run_length, posterior) pairs, sorted."""
    live = np.isfinite(batch._log_r[:, col])
    return sorted(
        zip(batch._rl[live].tolist(), batch._log_r[live, col].tolist())
    )


def test_batched_take_columns_equals_fresh_run():
    """Sub-slicing mid-stream leaves each survivor's posterior exactly what
    a fresh (uncapped) recursion over the surviving columns would hold."""
    x = fleet_matrix(n_workers=10, n_ticks=200, seed=3)
    scale = bocd.noise_scale_batch(x)
    keep = [0, 2, 5, 9]
    full = bocd.BatchedBOCD(10, mu0=x[0] / scale)
    for t in range(120):
        full.update(x[t] / scale)
    full.take_columns(np.array(keep))
    fresh = bocd.BatchedBOCD(len(keep), mu0=x[0, keep] / scale[keep])
    for t in range(200):
        if t >= 120:
            full.update(x[t, keep] / scale[keep])
        fresh.update(x[t, keep] / scale[keep])
    for c in range(len(keep)):
        a, b = _posterior(full, c), _posterior(fresh, c)
        assert [rl for rl, _ in a] == [rl for rl, _ in b]
        assert np.allclose([p for _, p in a], [p for _, p in b])
    assert np.array_equal(full.map_runlength(), fresh.map_runlength())


def test_fleet_remove_worker_matches_fresh_detector():
    """Flags after a mid-stream leave match a fresh detector that never saw
    the departed stream (sub-slice equivalence at the FleetDetect level)."""
    n_t = 200
    rng = np.random.default_rng(21)
    x = np.asarray(rng.normal(1.0, 0.01, (n_t, 6)))
    x[150:, 4] *= 1.4  # onset after the leave, on a surviving stream
    keep = [0, 1, 3, 4, 5]
    a = FleetDetect(n_workers=6, max_hypotheses=None)
    b = FleetDetect(n_workers=5, max_hypotheses=None)
    flags_a, flags_b = [], []
    for t in range(n_t):
        if t == 100:
            a.remove_worker(2)
        row = x[t, keep]
        if t < 100:
            flags_a += a.tick(x[t])
        else:
            flags_a += [f for f in a.tick(row)]
        flags_b += b.tick(row)
    assert [(f.worker, f.change_point.index) for f in flags_a] == [
        (f.worker, f.change_point.index) for f in flags_b
    ]
    assert any(f.worker == 3 for f in flags_b)  # old column 4, shifted


def test_fleet_add_worker_warms_and_detects():
    """A stream joining mid-flight is screened after its own warmup and its
    fail-slow is flagged; established streams are unaffected."""
    rng = np.random.default_rng(9)
    fd = FleetDetect(n_workers=3)
    for t in range(60):
        fd.tick(rng.normal(1.0, 0.01, 3))
    w = fd.add_worker()
    assert (w, fd.n_workers, fd.n_cohorts) == (3, 4, 2)
    hits = {}
    for t in range(80):
        row = np.empty(4)
        row[:3] = rng.normal(1.0, 0.01, 3)
        row[3] = rng.normal(2.0 if t < 40 else 2.9, 0.02)
        for f in fd.tick(row):
            hits.setdefault(f.worker, t)
    assert list(hits) == [3]
    assert abs(hits[3] - 40) <= 4


def test_fleet_consolidate_matches_fresh_window_detector():
    """Re-warming cohorts into one frontier equals a fresh detector fed the
    common retained history window, flag for flag."""
    rng = np.random.default_rng(4)
    fd = FleetDetect(n_workers=3, max_cohorts=None)
    hist = []
    for t in range(40):
        row = rng.normal(1.0, 0.01, 3)
        hist.append(row)
        fd.tick(row)
    fd.add_worker()
    for t in range(30):
        row = np.empty(4)
        row[:3] = rng.normal(1.0, 0.01, 3)
        row[3] = rng.normal(1.5, 0.015)
        hist.append(row)
        fd.tick(row)
    assert fd.n_cohorts == 2
    fd.consolidate()
    assert fd.n_cohorts == 1
    # Fresh detector over the common window (the join tick onward).
    window = np.asarray([h for h in hist if len(h) == 4])
    fresh = FleetDetect(n_workers=4, max_cohorts=None)
    for row in window:
        fresh.tick(row)
    onset = 70
    flags_a, flags_b = [], []
    for t in range(40):
        row = np.empty(4)
        row[:3] = rng.normal(1.0, 0.01, 3)
        row[3] = rng.normal(1.5, 0.015)
        if t >= 10:
            row[1] *= 1.45
        flags_a += fd.tick(row)
        flags_b += fresh.tick(row)
    assert [f.worker for f in flags_a] == [f.worker for f in flags_b]
    # Absolute indices differ by the 40 pre-join ticks the fresh one skipped.
    assert [f.change_point.index - 40 for f in flags_a] == [
        f.change_point.index for f in flags_b
    ]


def test_fleet_drift_screen_catches_ramped_onset():
    """A gradual ramp (invisible to the run-length rule — each step is
    barely surprising) is flagged by the lagged drift screen."""
    rng = np.random.default_rng(0)
    n_t = 250
    prof = np.concatenate([
        np.zeros(100), np.linspace(0.0, 0.3, 40), np.full(n_t - 140, 0.3)
    ])
    fd = FleetDetect(n_workers=1)
    hits = []
    for t in range(n_t):
        hits += [
            (t, f.change_point.relative_change)
            for f in fd.tick(np.array([(1 + prof[t]) * rng.normal(1, 0.003)]))
        ]
    assert hits, "ramp missed"
    t0, rel = hits[0]
    assert 100 < t0 < 140  # confirmed during the ramp
    assert rel > 0.1


def test_long_horizon_screen_catches_subthreshold_creep():
    """A creep below threshold/drift_ref per 40 ticks is invisible to both
    BOCD and the lagged drift screen; the long-horizon EWMA baseline
    catches it (ROADMAP: e.g. a 10 %/hour ramp on a fleet-monitor tick)."""
    rng = np.random.default_rng(0)
    fd = FleetDetect(n_workers=4, ewma_min_age=32)
    flags = []
    for t in range(900):
        x = rng.normal(1.0, 0.004, 4)
        if t >= 100:  # worker 2: +0.05 %/tick, ~2 %/40 ticks — sub-threshold
            x[2] *= 1.0 + 0.0005 * (t - 100)
        flags += [(t, f.worker, f.change_point) for f in fd.tick(x)]
    mine = [f for f in flags if f[1] == 2]
    assert mine, "creep missed"
    t0, _, cp = mine[0]
    assert cp.relative_change > 0.09
    assert not [f for f in flags if f[1] != 2], "healthy workers flagged"
    # the confirmed drift re-estimates the stream's jitter scale
    assert np.isfinite(fd._scale[2])


def test_long_horizon_screen_stays_quiet_on_step_faults():
    """Step changes are BOCD's: the baseline re-anchors on the confirmed
    flag, so the same physical fault never double-fires through the
    long-horizon screen."""
    rng = np.random.default_rng(1)
    fd = FleetDetect(n_workers=2, ewma_min_age=32)
    flags = []
    for t in range(400):
        x = rng.normal(1.0, 0.004, 2)
        if t >= 120:
            x[1] *= 1.35
        flags += [(t, f.worker) for f in fd.tick(x)]
    hits = [t for t, w in flags if w == 1]
    assert hits and hits[0] <= 125  # BOCD got it promptly
    assert len(hits) <= 2  # no EWMA re-fire on the anchored level


def test_adaptive_knobs_retune_from_observed_change_rate():
    """adapt_every derives the hazard (and the shared frontier cap) from
    the observed confirmed-flag rate; a quiet fleet drifts toward the rare
    end, a churny one toward the frequent end, both within bounds."""
    rng = np.random.default_rng(2)
    quiet = FleetDetect(n_workers=8, adapt_every=50)
    for _ in range(200):
        quiet.tick(rng.normal(1.0, 0.004, 8))
    assert quiet.last_tuning is not None
    assert quiet.hazard < 1.0 / 100.0  # rarer than the prior
    assert quiet.hazard >= quiet.hazard_bounds[0]
    assert quiet.max_hypotheses >= 32
    for cohort in quiet._cohorts:  # propagated into the live batches
        assert cohort.batch.hazard == quiet.hazard
        assert cohort.batch.max_hypotheses == quiet.max_hypotheses

    churny = FleetDetect(n_workers=8, adapt_every=50, ewma_span=0)
    level = np.ones(8)
    for t in range(400):
        if t % 25 == 0:  # a real level shift somewhere, every 25 ticks
            level[int(rng.integers(8))] *= float(rng.choice([1.3, 1 / 1.3]))
        churny.tick(level * rng.normal(1.0, 0.004, 8))
    assert churny.last_tuning is not None
    assert churny.hazard > quiet.hazard
    assert churny.hazard <= churny.hazard_bounds[1]

    fixed = FleetDetect(n_workers=8)  # default: constants stay put
    for _ in range(200):
        fixed.tick(rng.normal(1.0, 0.004, 8))
    assert fixed.last_tuning is None and fixed.hazard == 1.0 / 100.0


def test_screen_tuning_event_in_typed_log():
    """The control plane mirrors adaptive re-tunes into the event log."""
    from repro.cluster.simulator import JobSpec, TrainingSimulator
    from repro.cluster.spec import ClusterSpec, ModelSpec
    from repro.controlplane import ControlPlane, ScreenTuning

    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=1, gpus_per_node=4),
        job=JobSpec(
            model=ModelSpec(layers=8, hidden=1024, seq_len=512, vocab=1000),
            tp=1, dp=4, pp=1, micro_batches=8,
        ),
    )
    plane = ControlPlane(fleet_kwargs={"adapt_every": 40})
    plane.register_job("j0", sim)
    rng = np.random.default_rng(3)
    t = sim.iteration_time()
    for k in range(100):
        plane.tick({"j0": t * float(rng.normal(1, 0.004))}, float(k))
    tunings = [e for e in plane.events if isinstance(e, ScreenTuning)]
    assert tunings, "no ScreenTuning emitted"
    assert tunings[0].job_id == "" and tunings[0].hazard > 0
    assert tunings[0].worker_ticks > 0
    # one event per distinct retune, not one per tick
    assert len(tunings) <= 100 // 40

    # default plane: no adaptive events, log shape unchanged
    plane2 = ControlPlane()
    plane2.register_job("j0", sim)
    for k in range(100):
        plane2.tick({"j0": t * float(rng.normal(1, 0.004))}, float(k))
    assert not [e for e in plane2.events if isinstance(e, ScreenTuning)]


# ------------------------------------------------- backend registries
# Satellite of the ScreeningBackend/ReductionBackend API redesign: every
# registry entry must be interchangeable within its documented tolerance
# (scalar fan-out is the per-column oracle; batched numpy is exact;
# Pallas carries the float32 kernel tolerance from docs/kernels.md).


def _screen_traces(b, t_max, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, (t_max, b))
    x[t_max // 2:, :: max(b // 3, 1)] += 6.0  # strong breaks, scaled units
    return x


@pytest.mark.parametrize("b", [1, 7, 64, 1000])
def test_screening_backends_equivalent_probabilities(b):
    """scalar / batched / pallas report the same change probabilities per
    stream (registry promise), at fleet sizes from one stream to 1k."""
    t_max = 16 if b == 1000 else 24
    x = _screen_traces(b, t_max, seed=b)
    dets = {
        name: bocd.SCREENING_BACKENDS[name].make(
            b, mu0=x[0], max_hypotheses=32
        )
        for name in ("scalar", "batched", "pallas")
    }
    for t in range(t_max):
        p = {name: det.update(x[t]) for name, det in dets.items()}
        np.testing.assert_allclose(   # numpy paths: same recursion exactly
            p["batched"], p["scalar"], rtol=1e-9, atol=1e-12
        )
        np.testing.assert_allclose(   # float32 kernel: documented drift
            p["pallas"], p["batched"], rtol=1e-4, atol=1e-4
        )
    np.testing.assert_array_equal(
        dets["batched"].map_runlength(), dets["scalar"].map_runlength()
    )
    np.testing.assert_allclose(
        dets["pallas"].p_recent_change(), dets["batched"].p_recent_change(),
        rtol=1e-4, atol=1e-4,
    )


def test_fleet_detect_backend_flag_parity():
    """FleetDetect raises identical flags whichever registry backend runs
    the screen — the end-to-end guarantee the CI kernels job smoke-tests."""
    b, t_max = 48, 60
    rng = np.random.default_rng(11)
    x = rng.normal(1.0, 0.01, (t_max, b))
    x[30:, [3, 17, 40]] *= 1.35
    flags = {}
    for name in ("scalar", "batched", "pallas"):
        fleet = FleetDetect(n_workers=b, backend=name)
        flags[name] = sorted(
            (t, f.worker) for t in range(t_max) for f in fleet.tick(x[t])
        )
    assert flags["batched"] == flags["scalar"]
    assert flags["pallas"] == flags["batched"]
    assert {w for _, w in flags["batched"]} == {3, 17, 40}


def test_screening_backend_registry_resolution():
    assert bocd.select_backend("batched").name == "batched"
    assert bocd.select_backend("numpy").name == "batched"  # alias
    auto = bocd.select_backend(None)
    assert auto.name == ("pallas" if bocd.pallas_is_compiled() else "batched")
    with pytest.raises(ValueError, match="unknown screening backend"):
        bocd.select_backend("fpga")
    # factory instances pass through; backend classes warn but still work
    fac = bocd.SCREENING_BACKENDS["scalar"]
    assert bocd.resolve_screening_backend(fac) is fac
    with pytest.deprecated_call():
        shim = bocd.resolve_screening_backend(bocd.BatchedBOCD)
    assert shim.name == "batched"


def _faulted_sim(n_devices=512, seed=0):
    tp, pp = 4, 4
    dp = n_devices // (tp * pp)
    model = ModelSpec(layers=16, hidden=2048, seq_len=1024, vocab=32000)
    job = JobSpec(model=model, tp=tp, dp=dp, pp=pp, micro_batches=2 * dp)
    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=n_devices // 8), job=job
    )
    rng = np.random.default_rng(seed)
    for d in rng.choice(n_devices, 5, replace=False):
        sim.state.devices[int(d)].compute_speed = 0.7
    sim.state.degrade_nic(int(rng.integers(n_devices // 8)), 0.5)
    return sim


def test_reduction_backends_equivalent():
    """Every ReductionBackend registry entry agrees with the reference
    nested-loop oracle on a faulted hybrid topology, within its own
    documented tolerance, across the whole read API."""
    from repro.cluster.simulator import REDUCTION_BACKENDS

    sim = _faulted_sim()
    want_t = sim.iteration_time_reference()
    want_pm = np.asarray(sim.per_microbatch_times_reference())
    want_pg = sim.profile_groups_reference()
    for name, cls in REDUCTION_BACKENDS.items():
        rb = cls()
        tol = max(rb.tolerance, 1e-12)
        got_t = float(rb.iteration_time(sim))
        np.testing.assert_allclose(got_t, want_t, rtol=tol, err_msg=name)
        got_pm = np.asarray(rb.per_microbatch_times(sim))
        np.testing.assert_allclose(got_pm, want_pm, rtol=tol, err_msg=name)
        got_pg = rb.profile_groups(sim)
        assert got_pg.keys() == want_pg.keys(), name
        for k in want_pg:
            np.testing.assert_allclose(
                got_pg[k], want_pg[k], rtol=tol, err_msg=f"{name}:{k}"
            )


def test_reduction_backend_resolution_and_sim_knob():
    from repro.cluster import simulator as S

    # the hot path stays inline for the defaults (no indirection object)
    assert S.resolve_reduction_backend(None) is None or \
        S.resolve_reduction_backend(None).name == "pallas"
    assert S.resolve_reduction_backend("vectorized") is None
    assert S.resolve_reduction_backend("numpy") is None
    assert S.resolve_reduction_backend("reference").name == "reference"
    with pytest.raises(ValueError, match="unknown reduction backend"):
        S.select_reduction_backend("abacus")
    with pytest.raises(TypeError):
        S.resolve_reduction_backend(42)

    # the TrainingSimulator knob swaps backends and stays consistent
    sim = _faulted_sim(seed=3)
    t_vec = sim.iteration_time()
    sim.reduction = "reference"
    t_ref = sim.iteration_time()
    np.testing.assert_allclose(t_vec, t_ref, rtol=1e-9)
    sim.reduction = "pallas"
    t_pal = sim.iteration_time()
    np.testing.assert_allclose(t_pal, t_ref, rtol=1e-4)
    # and the memo keeps tracking mutations across backend switches
    sim.state.devices[0].compute_speed = 0.4
    t_after = sim.iteration_time()
    assert t_after > t_pal
    np.testing.assert_allclose(
        t_after, sim.iteration_time_reference(), rtol=1e-4
    )


# ------------------------------------------- fused multi-cohort screen
def _churny_screen(seed, adapt, fused, ticks=420, n0=6):
    """Drive one FleetDetect through joins/leaves/step-faults; return the
    flag log plus the observable tuning state."""
    rng = np.random.default_rng(seed)
    fd = FleetDetect(
        n_workers=n0, adapt_every=adapt, backend="batched", fused=fused
    )
    level = np.ones(fd.n_workers)
    flags_log = []
    for t in range(ticks):
        if t in (120, 180):
            fd.add_worker()
            level = np.append(level, 1.0)
        if t == 260 and fd.n_workers > 4:
            fd.remove_worker(2)
            level = np.delete(level, 2)
        if t in (90, 150, 230, 300, 360):
            level[(t // 30) % fd.n_workers] *= 1.6
        if t == 330:
            level[0] *= 0.6
        x = level * (1.0 + 0.02 * rng.standard_normal(fd.n_workers))
        flags_log.append([
            (f.worker, f.change_point.index, f.change_point.probability,
             f.change_point.mean_before, f.change_point.mean_after)
            for f in fd.tick(x)
        ])
    return flags_log, fd._scale.copy(), fd._ewma.copy(), fd.hazard, \
        fd.max_hypotheses


@pytest.mark.parametrize("adapt", [0, 50])
@pytest.mark.parametrize("seed", [3, 7])
def test_fused_screen_bitwise_matches_per_cohort(adapt, seed):
    """The single-launch fused frontier is not approximately the per-cohort
    screen — it IS the per-cohort screen, bitwise, through membership churn
    and adaptive retunes (the campaign engine's forks rely on this)."""
    fl0, sc0, ew0, hz0, mh0 = _churny_screen(seed, adapt, fused=False)
    fl1, sc1, ew1, hz1, mh1 = _churny_screen(seed, adapt, fused=True)
    assert fl0 == fl1
    assert np.array_equal(sc0, sc1, equal_nan=True)
    assert np.array_equal(ew0, ew1, equal_nan=True)
    assert (hz0, mh0) == (hz1, mh1)


@pytest.mark.parametrize("fused", [False, True])
def test_fleet_snapshot_restore_tail_equivalence(fused):
    """A fresh FleetDetect restored from snapshot() continues bitwise
    identically to the instance that kept running."""
    def drive(fd, level, rng, t0, t1, out):
        for t in range(t0, t1):
            if t in (90, 150, 230):
                level[(t // 30) % fd.n_workers] *= 1.5
            x = level * (1.0 + 0.02 * rng.standard_normal(fd.n_workers))
            out.append([
                (f.worker, f.change_point.index) for f in fd.tick(x)
            ])

    rng = np.random.default_rng(9)
    fd = FleetDetect(
        n_workers=6, backend="batched", fused=fused, adapt_every=50
    )
    level = np.ones(6)
    pre: list = []
    drive(fd, level, rng, 0, 100, pre)
    snap = fd.snapshot()
    rng_state = rng.bit_generator.state
    level_snap = level.copy()
    cont_a: list = []
    drive(fd, level, rng, 100, 180, cont_a)

    fd2 = FleetDetect(
        n_workers=6, backend="batched", fused=fused, adapt_every=50
    )
    fd2.restore(snap)
    rng2 = np.random.default_rng(9)
    rng2.bit_generator.state = rng_state
    cont_b: list = []
    drive(fd2, level_snap, rng2, 100, 180, cont_b)
    assert cont_a == cont_b


def test_fleet_restore_rejects_fused_mismatch():
    fd = FleetDetect(n_workers=4, backend="batched", fused=True)
    fd.tick(np.ones(4))
    snap = fd.snapshot()
    other = FleetDetect(n_workers=4, backend="batched", fused=False)
    with pytest.raises(ValueError, match="fused"):
        other.restore(snap)


def test_watchdog_snapshot_roundtrip():
    from repro.core.detector import Watchdog

    wd = Watchdog()
    for i in range(10):
        wd.beat("j1", i * 1.0)
        wd.beat("j2", i * 1.7)
    snap = wd.snapshot()
    wd.beat("j1", 99.0)  # post-snapshot divergence must not leak back
    wd2 = Watchdog()
    wd2.restore(snap)
    assert wd2._last == {"j1": 9.0, "j2": 9 * 1.7}
    assert wd2._beats == {"j1": 10, "j2": 10}
    # the continued instance moved on; the restored one holds the snapshot
    assert wd._last["j1"] == 99.0 and wd._beats["j1"] == 11
