"""Expert-parallel shard_map MoE must match the plain GSPMD formulation.

Subprocess with 4 host devices: the same params/tokens are run through
``apply_moe`` (a) with no mesh (dense-host path) and (b) under a
(data=2, model=2) mesh where E % model == 0 engages the EP shard_map path.
With a generous capacity factor (no token drops — per-shard capacity is the
one intentional semantic difference), outputs must agree.
"""
import os

import pytest
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.models import moe
from repro.models.schema import init_tree

cfg = get_config("olmoe-1b-7b").smoke()
cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
assert cfg.num_experts % 2 == 0 and cfg.moe_shard == "experts"

schema = moe.moe_schema(cfg)
params = init_tree(schema, jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32).astype(cfg.activation_dtype)

# (a) dense-host path (no ambient mesh).
y_ref, aux_ref = jax.jit(lambda p, h: moe.apply_moe(p, h, cfg))(params, x)

# (b) expert-parallel path under the mesh, entered through the same
# version shim the product code uses.
from repro import compat

mesh = jax.make_mesh((2, 2), ("data", "model"))
with compat.set_mesh(mesh):
    y_ep, aux_ep = jax.jit(lambda p, h: moe.apply_moe(p, h, cfg))(params, x)

np.testing.assert_allclose(
    np.asarray(y_ref, np.float32), np.asarray(y_ep, np.float32),
    rtol=3e-2, atol=3e-2)
np.testing.assert_allclose(float(aux_ref), float(aux_ep), rtol=1e-2, atol=1e-2)

# (c) batch=1 (long_500k decode regime): EP must fall back to
# model-only manual axes and still agree.
x1 = x[:1, :1]
y1_ref, _ = jax.jit(lambda p, h: moe.apply_moe(p, h, cfg))(params, x1)
with compat.set_mesh(mesh):
    y1_ep, _ = jax.jit(lambda p, h: moe.apply_moe(p, h, cfg))(params, x1)
np.testing.assert_allclose(
    np.asarray(y1_ref, np.float32), np.asarray(y1_ep, np.float32),
    rtol=3e-2, atol=3e-2)
print("MOE-EP-OK")
"""


@pytest.mark.slow
def test_expert_parallel_moe_matches_dense_host():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MOE-EP-OK" in out.stdout
