"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the exact TPU kernel logic on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import blocked_attention
from repro.models.ssm import ssd_scan as ssd_jnp


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------------ attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,h,kvh,hd,blk",
    [
        (1, 128, 128, 4, 4, 64, 64),
        (2, 256, 256, 4, 2, 64, 128),
        (1, 64, 64, 8, 1, 32, 32),  # MQA, tiny blocks
        (1, 192, 192, 2, 2, 64, 64),  # non-power-of-two seq with padding
    ],
)
def test_flash_attention_matches_ref(b, sq, skv, h, kvh, hd, blk, dtype):
    q = rand(0, (b, sq, h, hd), dtype)
    k = rand(1, (b, skv, kvh, hd), dtype)
    v = rand(2, (b, skv, kvh, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=blk, block_k=blk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype],
    )


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    b, s, h, kvh, hd = 1, 128, 4, 2, 64
    q = rand(3, (b, s, h, hd), jnp.float32)
    k = rand(4, (b, s, kvh, hd), jnp.float32)
    v = rand(5, (b, s, kvh, hd), jnp.float32)
    got = ops.flash_attention(
        q, k, v, causal=True, window=window, block_q=32, block_k=32, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, hd = 1, 128, 2, 64
    q = rand(6, (b, s, h, hd), jnp.float32)
    k = rand(7, (b, s, h, hd), jnp.float32)
    v = rand(8, (b, s, h, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blocked_attention_jnp_matches_ref():
    """The model's jnp online-softmax path is itself validated vs the oracle."""
    b, s, h, kvh, hd = 2, 160, 4, 2, 32
    q = rand(9, (b, s, h, hd), jnp.float32)
    k = rand(10, (b, s, kvh, hd), jnp.float32)
    v = rand(11, (b, s, kvh, hd), jnp.float32)
    got = blocked_attention(q, k, v, causal=True, kv_block=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    got_w = blocked_attention(q, k, v, causal=True, window=48, kv_block=64)
    want_w = ref.attention_ref(q, k, v, causal=True, window=48)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ SSD
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 64, 2, 32, 1, 16, 16),
        (2, 128, 4, 64, 2, 32, 32),
        (1, 96, 2, 16, 1, 8, 32),  # 3 chunks
    ],
)
def test_ssd_kernel_matches_sequential_ref(b, s, h, p, g, n, chunk, dtype):
    x = rand(20, (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(21, (b, s, h), jnp.float32)) * 0.5
    a = -jnp.exp(rand(22, (h,), jnp.float32) * 0.2)
    bm = rand(23, (b, s, g, n), dtype)
    cm = rand(24, (b, s, g, n), dtype)
    y_k, st_k = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_r, st_r = ref.ssd_ref(x, dt, a, bm, cm)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_k, np.float32), np.asarray(st_r, np.float32), **tol)


def test_ssd_jnp_chunked_matches_sequential_ref():
    """The model's chunked jnp SSD is validated against the recurrence."""
    b, s, h, p, g, n = 2, 64, 4, 16, 1, 8
    x = rand(30, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(31, (b, s, h), jnp.float32)) * 0.5
    a = -jnp.exp(rand(32, (h,), jnp.float32) * 0.2)
    bm = rand(33, (b, s, g, n), jnp.float32)
    cm = rand(34, (b, s, g, n), jnp.float32)
    y_c, st_c = ssd_jnp(x, dt, a, bm, cm, chunk=16)
    y_r, st_r = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_initial_state_threading():
    """Decode consistency: chunked scan final state equals running the
    sequential reference — then one more decode step matches too."""
    from repro.models.ssm import decode_mamba  # noqa: F401  (smoke covered elsewhere)

    b, s, h, p, g, n = 1, 32, 2, 16, 1, 8
    x = rand(40, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(41, (b, s, h), jnp.float32))
    a = -jnp.exp(rand(42, (h,), jnp.float32) * 0.1)
    bm = rand(43, (b, s, g, n), jnp.float32)
    cm = rand(44, (b, s, g, n), jnp.float32)
    _, st1 = ssd_jnp(x, dt, a, bm, cm, chunk=8)
    _, st2 = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,skv,h,kvh,hd,blk,valid",
    [
        (2, 256, 4, 4, 64, 128, 256),
        (2, 512, 8, 2, 64, 128, 300),   # GQA, partial fill
        (1, 384, 8, 1, 32, 256, 100),   # MQA, non-pow2 cache w/ padding
        (3, 128, 4, 2, 64, 512, 1),     # one valid position
    ],
)
def test_flash_decode_matches_reference(b, skv, h, kvh, hd, blk, valid, dtype):
    q = rand(1, (b, h, hd), dtype)
    k = rand(2, (b, skv, kvh, hd), dtype)
    v = rand(3, (b, skv, kvh, hd), dtype)
    got = ops.flash_decode(q, k, v, jnp.int32(valid), block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(valid))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_decode_per_sequence_lengths():
    b, skv, h, kvh, hd = 4, 256, 4, 2, 64
    q = rand(4, (b, h, hd), jnp.float32)
    k = rand(5, (b, skv, kvh, hd), jnp.float32)
    v = rand(6, (b, skv, kvh, hd), jnp.float32)
    lens = jnp.asarray([1, 17, 128, 256], jnp.int32)
    got = ops.flash_decode(q, k, v, lens, block_k=128, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_decode_step_kernel_path_matches_jnp():
    """Full serve decode step with use_kernel=True (flash-decode in interpret
    mode) must match the pure-jnp decode path, incl. sliding window."""
    import numpy as np
    from repro.configs.base import get_config
    from repro.models import model as model_lib, transformer

    for arch, window in (("granite-3-8b", 0), ("mistral-nemo-12b", 0)):
        cfg = get_config(arch).smoke()
        B, S = 2, 32
        params = model_lib.init_params(cfg, 0)
        caches = transformer.init_caches(cfg, B, S)
        tok = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 1)),
            jnp.int32,
        )
        pos = jnp.asarray(9, jnp.int32)
        ref_logits, _ = jax.jit(
            lambda p, t, c, q: model_lib.decode_step(p, t, c, q, cfg, window=window)
        )(params, tok, caches, pos)
        ker_logits, _ = jax.jit(
            lambda p, t, c, q: model_lib.decode_step(
                p, t, c, q, cfg, window=window, use_kernel=True
            )
        )(params, tok, caches, pos)
        np.testing.assert_allclose(
            np.asarray(ref_logits, np.float32),
            np.asarray(ker_logits, np.float32),
            rtol=2e-2, atol=2e-2,
        )
