"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the exact TPU kernel logic on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import blocked_attention
from repro.models.ssm import ssd_scan as ssd_jnp


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------------ attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,h,kvh,hd,blk",
    [
        (1, 128, 128, 4, 4, 64, 64),
        (2, 256, 256, 4, 2, 64, 128),
        (1, 64, 64, 8, 1, 32, 32),  # MQA, tiny blocks
        (1, 192, 192, 2, 2, 64, 64),  # non-power-of-two seq with padding
    ],
)
def test_flash_attention_matches_ref(b, sq, skv, h, kvh, hd, blk, dtype):
    q = rand(0, (b, sq, h, hd), dtype)
    k = rand(1, (b, skv, kvh, hd), dtype)
    v = rand(2, (b, skv, kvh, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=blk, block_k=blk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype],
    )


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    b, s, h, kvh, hd = 1, 128, 4, 2, 64
    q = rand(3, (b, s, h, hd), jnp.float32)
    k = rand(4, (b, s, kvh, hd), jnp.float32)
    v = rand(5, (b, s, kvh, hd), jnp.float32)
    got = ops.flash_attention(
        q, k, v, causal=True, window=window, block_q=32, block_k=32, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, hd = 1, 128, 2, 64
    q = rand(6, (b, s, h, hd), jnp.float32)
    k = rand(7, (b, s, h, hd), jnp.float32)
    v = rand(8, (b, s, h, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blocked_attention_jnp_matches_ref():
    """The model's jnp online-softmax path is itself validated vs the oracle."""
    b, s, h, kvh, hd = 2, 160, 4, 2, 32
    q = rand(9, (b, s, h, hd), jnp.float32)
    k = rand(10, (b, s, kvh, hd), jnp.float32)
    v = rand(11, (b, s, kvh, hd), jnp.float32)
    got = blocked_attention(q, k, v, causal=True, kv_block=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    got_w = blocked_attention(q, k, v, causal=True, window=48, kv_block=64)
    want_w = ref.attention_ref(q, k, v, causal=True, window=48)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ SSD
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 64, 2, 32, 1, 16, 16),
        (2, 128, 4, 64, 2, 32, 32),
        (1, 96, 2, 16, 1, 8, 32),  # 3 chunks
    ],
)
def test_ssd_kernel_matches_sequential_ref(b, s, h, p, g, n, chunk, dtype):
    x = rand(20, (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(21, (b, s, h), jnp.float32)) * 0.5
    a = -jnp.exp(rand(22, (h,), jnp.float32) * 0.2)
    bm = rand(23, (b, s, g, n), dtype)
    cm = rand(24, (b, s, g, n), dtype)
    y_k, st_k = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_r, st_r = ref.ssd_ref(x, dt, a, bm, cm)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_k, np.float32), np.asarray(st_r, np.float32), **tol)


def test_ssd_jnp_chunked_matches_sequential_ref():
    """The model's chunked jnp SSD is validated against the recurrence."""
    b, s, h, p, g, n = 2, 64, 4, 16, 1, 8
    x = rand(30, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(31, (b, s, h), jnp.float32)) * 0.5
    a = -jnp.exp(rand(32, (h,), jnp.float32) * 0.2)
    bm = rand(33, (b, s, g, n), jnp.float32)
    cm = rand(34, (b, s, g, n), jnp.float32)
    y_c, st_c = ssd_jnp(x, dt, a, bm, cm, chunk=16)
    y_r, st_r = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_initial_state_threading():
    """Decode consistency: chunked scan final state equals running the
    sequential reference — then one more decode step matches too."""
    from repro.models.ssm import decode_mamba  # noqa: F401  (smoke covered elsewhere)

    b, s, h, p, g, n = 1, 32, 2, 16, 1, 8
    x = rand(40, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(41, (b, s, h), jnp.float32))
    a = -jnp.exp(rand(42, (h,), jnp.float32) * 0.1)
    bm = rand(43, (b, s, g, n), jnp.float32)
    cm = rand(44, (b, s, g, n), jnp.float32)
    _, st1 = ssd_jnp(x, dt, a, bm, cm, chunk=8)
    _, st2 = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,skv,h,kvh,hd,blk,valid",
    [
        (2, 256, 4, 4, 64, 128, 256),
        (2, 512, 8, 2, 64, 128, 300),   # GQA, partial fill
        (1, 384, 8, 1, 32, 256, 100),   # MQA, non-pow2 cache w/ padding
        (3, 128, 4, 2, 64, 512, 1),     # one valid position
    ],
)
def test_flash_decode_matches_reference(b, skv, h, kvh, hd, blk, valid, dtype):
    q = rand(1, (b, h, hd), dtype)
    k = rand(2, (b, skv, kvh, hd), dtype)
    v = rand(3, (b, skv, kvh, hd), dtype)
    got = ops.flash_decode(q, k, v, jnp.int32(valid), block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(valid))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_decode_per_sequence_lengths():
    b, skv, h, kvh, hd = 4, 256, 4, 2, 64
    q = rand(4, (b, h, hd), jnp.float32)
    k = rand(5, (b, skv, kvh, hd), jnp.float32)
    v = rand(6, (b, skv, kvh, hd), jnp.float32)
    lens = jnp.asarray([1, 17, 128, 256], jnp.int32)
    got = ops.flash_decode(q, k, v, lens, block_k=128, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_decode_step_kernel_path_matches_jnp():
    """Full serve decode step with use_kernel=True (flash-decode in interpret
    mode) must match the pure-jnp decode path, incl. sliding window."""
    import numpy as np
    from repro.configs.base import get_config
    from repro.models import model as model_lib, transformer

    for arch, window in (("granite-3-8b", 0), ("mistral-nemo-12b", 0)):
        cfg = get_config(arch).smoke()
        B, S = 2, 32
        params = model_lib.init_params(cfg, 0)
        caches = transformer.init_caches(cfg, B, S)
        tok = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 1)),
            jnp.int32,
        )
        pos = jnp.asarray(9, jnp.int32)
        ref_logits, _ = jax.jit(
            lambda p, t, c, q: model_lib.decode_step(p, t, c, q, cfg, window=window)
        )(params, tok, caches, pos)
        ker_logits, _ = jax.jit(
            lambda p, t, c, q: model_lib.decode_step(
                p, t, c, q, cfg, window=window, use_kernel=True
            )
        )(params, tok, caches, pos)
        np.testing.assert_allclose(
            np.asarray(ref_logits, np.float32),
            np.asarray(ker_logits, np.float32),
            rtol=2e-2, atol=2e-2,
        )


# ----------------------------------------------- BOCD screening kernel
# Tolerance policy (docs/kernels.md): the Pallas kernel must match the
# same math as a plain traced-jnp function *bit for bit* in interpret
# mode (same ops, same order); the float32 kernel state is allowed
# <=1e-4 relative drift vs the float64 numpy oracle.
from repro.core import bocd  # noqa: E402
from repro.kernels import bocd_step as bk  # noqa: E402
from repro.kernels import cell_reduce as ck  # noqa: E402


def _bocd_state(k, b, seed=0, dtype=jnp.float32):
    det = bk.PallasBOCD(b, max_hypotheses=k, dtype=dtype, interpret=True)
    return det


@pytest.mark.parametrize("b", [1, 7, 64])
def test_bocd_step_kernel_bitmatches_traced_reference(b):
    """pallas_call(interpret) vs the identical math traced without
    pallas_call: zero tolerance, every state array, several steps."""
    k = 16
    det = _bocd_state(k, b)
    state_r = (det._log_r, det._mu, det._beta, det._kappa, det._alpha,
               det._rl)
    rng = np.random.default_rng(0)
    x = rng.normal(1.0, 0.05, (12, b))
    x[8:] += 0.5  # a change, so growth/truncation/recycling all fire
    for t in range(12):
        xs = jnp.asarray(x[t], det.dtype)
        out_k = bk.bocd_step(xs, *state_r, det._mu0, det.hazard,
                             interpret=True)
        out_r = bk.bocd_step_reference(xs, *state_r, det._mu0, det.hazard)
        for a, bref in zip(out_k, out_r, strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bref))
        state_r = out_r[:6]


def test_bocd_step_kernel_nan_isolation():
    """NaN (censored) observations poison only their own column: clean
    columns stay finite and match a NaN-free run. The victim-slot choice
    is shared across columns (module docstring), so the isolation
    guarantee is tolerance-level, not bit-level."""
    k, b = 16, 5
    full = _bocd_state(k, b, seed=1)
    clean = _bocd_state(k, b - 1, seed=1)
    rng = np.random.default_rng(1)
    x = rng.normal(1.0, 0.05, (10, b))
    x[3:, -1] = np.nan  # censor the last column mid-stream
    p_full = [full.update(x[t]) for t in range(10)]
    p_clean = [clean.update(x[t, :-1]) for t in range(10)]
    for pf, pc in zip(p_full, p_clean, strict=True):
        assert np.isfinite(pf[:-1]).all()
        np.testing.assert_allclose(pf[:-1], pc, rtol=1e-3, atol=1e-3)
    assert np.isnan(p_full[-1][-1])  # the censored column is marked
    # Posterior statistics on the clean columns stay usable: finite,
    # in-range probabilities and valid run lengths. (The shared victim
    # slot means their exact values legitimately shift a little, so no
    # tight equality here — FleetDetect re-verifies flags exactly.)
    prc = full.p_recent_change()[:-1]
    assert np.isfinite(prc).all() and ((prc >= 0) & (prc <= 1)).all()
    assert (full.map_runlength()[:-1] >= 0).all()


@pytest.mark.parametrize("b", [1, 7, 64])
def test_pallas_bocd_matches_float64_numpy_oracle(b):
    """Float32 fixed-slot frontier vs the float64 BatchedBOCD oracle,
    while the frontier is not truncating (documented <=1e-4 drift)."""
    t_max, k = 24, 32
    rng = np.random.default_rng(2)
    x = rng.normal(1.0, 0.05, (t_max, b))
    x[16:] *= 1.3
    pal = bk.PallasBOCD(b, mu0=x[0], max_hypotheses=k, interpret=True)
    ora = bocd.BatchedBOCD(b, mu0=x[0], max_hypotheses=k)
    for t in range(t_max):
        p_pal = pal.update(x[t])
        p_ora = ora.update(x[t])
        np.testing.assert_allclose(p_pal, p_ora, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        pal.p_recent_change(), ora.p_recent_change(), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(pal.map_runlength(), ora.map_runlength())


@pytest.mark.parametrize("k", [2, 4])
def test_pallas_bocd_frontier_truncation_edges(k):
    """Tightest legal slot caps: the frontier recycles its victim slot
    every tick and the posterior stays a valid distribution throughout."""
    b, t_max = 7, 30
    rng = np.random.default_rng(3)
    x = rng.normal(1.0, 0.05, (t_max, b))
    x[20:] *= 1.4
    det = bk.PallasBOCD(b, mu0=x[0], max_hypotheses=k, interpret=True)
    p_hist = []
    for t in range(t_max):
        p0 = det.update(x[t])
        p_hist.append(p0)
        assert np.all((p0 >= 0.0) & (p0 <= 1.0))
        lr = np.asarray(det._log_r, np.float64)
        assert lr.shape[0] == k  # the cap held
        mass = np.exp(lr[np.isfinite(lr).any(axis=1)]).sum(axis=0)
        np.testing.assert_allclose(mass, 1.0, rtol=1e-3)
    # the break still registers through the tight cap: the change-point
    # mass right after the fault exceeds anything the quiet period produced
    p = np.asarray(p_hist)
    assert p[20:23].max() > 2.0 * p[5:20].max()


def test_pallas_bocd_take_columns_equals_fresh_slice():
    b = 10
    rng = np.random.default_rng(4)
    x = rng.normal(1.0, 0.05, (15, b))
    full = bk.PallasBOCD(b, mu0=x[0], interpret=True)
    keep = np.asarray([0, 3, 7])
    sub = bk.PallasBOCD(keep.size, mu0=x[0, keep], interpret=True)
    for t in range(15):
        full.update(x[t])
        sub.update(x[t, keep])
    full.take_columns(keep)
    np.testing.assert_array_equal(
        np.asarray(full._log_r), np.asarray(sub._log_r)
    )
    np.testing.assert_array_equal(
        full.p_recent_change(), sub.p_recent_change()
    )


# ---------------------------------------------- simulator cell reduce
def _reduce_inputs(pp, tp, dp, seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        cell_speed=jnp.asarray(rng.uniform(0.5, 1.0, (pp, dp)),
                               jnp.float32),
        tp_edge=jnp.asarray(rng.uniform(5.0, 40.0, (pp, dp, tp)),
                            jnp.float32),
        dp_edge=jnp.asarray(rng.uniform(5.0, 40.0, (pp, dp, tp)),
                            jnp.float32),
        hop_bw=jnp.asarray(rng.uniform(5.0, 40.0, (pp - 1, dp)),
                           jnp.float32),
        alloc_off=jnp.asarray(rng.uniform(1.0, 3.0, (dp,)), jnp.float32),
    )


@pytest.mark.parametrize("pp,tp,dp", [(2, 2, 2), (4, 8, 4), (8, 8, 16)])
def test_cell_reduce_kernel_bitmatches_traced_reference(pp, tp, dp):
    ins = _reduce_inputs(pp, tp, dp, seed=pp)
    scalars = dict(c_flops=3.0, c_speed=1.1, c_tp=0.4, pp_vol=0.2,
                   c_dp=0.9)
    out_k = ck.cell_reduce(**ins, **scalars, interpret=True)
    out_r = ck.cell_reduce_reference(**ins, **scalars)
    for a, b in zip(out_k, out_r, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cell_reduce_matches_float64_formula():
    """Float32 fused tree vs the float64 numpy reduction formulas."""
    pp, tp, dp = 4, 4, 8
    ins = _reduce_inputs(pp, tp, dp, seed=9)
    c_flops, c_speed, c_tp, pp_vol, c_dp = 3.0, 1.1, 0.4, 0.2, 0.9
    t, stage_max, tp_bw, dp_bw = ck.cell_reduce(
        **ins, c_flops=c_flops, c_speed=c_speed, c_tp=c_tp,
        pp_vol=pp_vol, c_dp=c_dp, interpret=True,
    )
    cs = np.asarray(ins["cell_speed"], np.float64)
    te = np.asarray(ins["tp_edge"], np.float64)
    de = np.asarray(ins["dp_edge"], np.float64)
    hb = np.asarray(ins["hop_bw"], np.float64)
    ao = np.asarray(ins["alloc_off"], np.float64)
    tp_bw64 = te.min(axis=2)                       # (pp, dp)
    stage = c_flops / (c_speed * cs) + c_tp / tp_bw64
    stage_max64 = stage.max(axis=0)                # (dp,)
    dp_bw64 = de.min(axis=1)                       # (pp, tp)
    pipe = ao * stage_max64 + 2.0 * (pp_vol / hb).sum(axis=0)
    want_t = pipe.max() + c_dp / dp_bw64.min()
    np.testing.assert_allclose(float(t[0, 0]), want_t, rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(tp_bw, np.float64), tp_bw64, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(dp_bw, np.float64), dp_bw64, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(stage_max, np.float64)[0], stage_max64, rtol=1e-4
    )
