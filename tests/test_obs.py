"""Observability layer: tracer invariants, decomposition correctness,
metrics registry semantics, sidecar byte-determinism, and the contract
that tracing never changes behavior.

The expensive pieces (campaign runs) are shared through module-scoped
fixtures; everything here is tier-1.
"""
import json
import math

import pytest

from repro.cluster.spec import ClusterSpec, ClusterState
from repro.cluster.simulator import JobSpec, ModelSpec, TrainingSimulator
from repro.controlplane.events import (
    Diagnosis,
    Observation,
    event_log_records,
    event_record,
)
from repro.obs import (
    COMPONENTS,
    MetricsRegistry,
    SpanTracer,
    TraceError,
    decompose,
)
from repro.obs import recorder as obs_recorder
from repro.obs.dashboard import render_dashboard
from repro.scenarios.campaign import build_campaign, run_campaign
from repro.scenarios.scoring import run_and_score


# ------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def hang_campaign():
    spec = build_campaign("collective_hang", n_jobs=2, seed=0)
    tracer = SpanTracer()
    run = run_campaign(spec, "falcon", tracer=tracer)
    return spec, run


@pytest.fixture(scope="module")
def scored_obs():
    return run_and_score("single_gpu_throttle", n_jobs=1, seed=0, obs=True)


def _sim(tp=2, dp=2, pp=2, nodes=2, gpn=4):
    return TrainingSimulator(
        cluster=ClusterSpec(n_nodes=nodes, gpus_per_node=gpn),
        job=JobSpec(
            model=ModelSpec(layers=8, hidden=1024, seq_len=512, vocab=32000),
            tp=tp, dp=dp, pp=pp, micro_batches=8,
        ),
    )


# ------------------------------------------------------------ SpanTracer
def test_tracer_nesting_and_chrome_export():
    tr = SpanTracer()
    tr.begin(("j0", "t"), "outer", 0.0)
    tr.begin(("j0", "t"), "inner", 1.0)
    tr.end(("j0", "t"), 2.0)
    tr.end(("j0", "t"), 3.0, args={"k": 1})
    tr.instant(("j0", "t"), "mark", 1.5)
    doc = tr.to_chrome()
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    # Inner closed first, fully contained in outer.
    by_name = {e["name"]: e for e in spans}
    assert by_name["inner"]["ts"] == 1_000_000
    assert by_name["inner"]["dur"] == 1_000_000
    assert by_name["outer"]["ts"] == 0
    assert by_name["outer"]["dur"] == 3_000_000
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert (
        by_name["inner"]["ts"] + by_name["inner"]["dur"]
        <= by_name["outer"]["ts"] + by_name["outer"]["dur"]
    )


def test_tracer_end_without_begin_raises():
    tr = SpanTracer()
    with pytest.raises(TraceError):
        tr.end(("j0", "t"), 1.0)


def test_tracer_name_mismatch_raises():
    tr = SpanTracer()
    tr.begin(("j0", "t"), "a", 0.0)
    with pytest.raises(TraceError):
        tr.end(("j0", "t"), 1.0, name="b")


def test_tracer_export_with_open_span_raises_until_closed():
    tr = SpanTracer()
    tr.begin(("j0", "t"), "open", 0.0)
    with pytest.raises(TraceError):
        tr.to_chrome()
    tr.close_all(5.0)
    spans = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
    assert spans[0]["dur"] == 5_000_000


def test_tracer_json_deterministic_and_metadata_first():
    def build():
        tr = SpanTracer()
        tr.span(("b", "y"), "s2", 1.0, 2.0)
        tr.span(("a", "x"), "s1", 0.0, 1.0)
        tr.counter(("a", "c"), "v", 0.5, 3.14159265)
        return tr

    assert build().to_json() == build().to_json()
    doc = build().to_chrome()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert evs[: len(meta)] == meta  # metadata events lead
    # Distinct processes get distinct pids, deterministically.
    pids = {e["args"]["name"]: e["pid"] for e in meta
            if e["name"] == "process_name"}
    assert len(set(pids.values())) == len(pids)


# ------------------------------------------------- collective breakdown
def test_decompose_parts_sum_to_iteration_time():
    sim = _sim()
    bd = decompose(sim)
    assert math.isclose(
        sum(bd.parts().values()), sim.iteration_time(), rel_tol=1e-9
    )
    assert math.isclose(bd.total_s, sim.iteration_time(), rel_tol=1e-9)
    assert bd.bottleneck in COMPONENTS
    assert 0.0 < bd.share <= 1.0


@pytest.mark.parametrize(
    "edge,collective",
    [((0, 2), "dp_allreduce"), ((0, 1), "tp_allreduce"), ((0, 4), "pp_p2p")],
)
def test_decompose_degraded_link_shifts_bottleneck_and_names_edge(
    edge, collective
):
    # In the tp2/dp2/pp2 layout on 2x4 GPUs, (0,1) is a TP ring edge,
    # (0,2) a DP ring edge, and (0,4) the stage-0 -> stage-1 PP hop.
    sim = _sim()
    healthy = decompose(sim)
    assert healthy.bottleneck == "compute"
    state = ClusterState(sim.cluster)
    state.degrade_link(*edge, 0.01)  # 100x slower link
    sim.state = state
    degraded = sim.collective_breakdown()
    assert degraded.bottleneck == collective
    assert degraded.edge == f"link:{edge[0]}-{edge[1]}"
    part = degraded.parts()[collective]
    assert part > healthy.parts()[collective] * 10


def test_timing_decomposition_matches_profile_groups():
    sim = _sim()
    td = sim.timing_decomposition()
    prof = sim.profile_groups()
    for s in range(2):
        for d in range(2):
            assert td["tp_allreduce_s"][s][d] == prof[f"tp:s{s}d{d}"]
    for s in range(2):
        for k in range(2):
            assert td["dp_allreduce_s"][s][k] == prof[f"dp:s{s}t{k}"]


# --------------------------------------------------- metrics registry
def test_metrics_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    reg.counter("hits", job="j0").inc()
    reg.counter("hits", job="j0").inc(2.0)
    reg.gauge("level").set(0.25)
    h = reg.histogram("lat_s", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(100.0)
    snap = reg.snapshot()
    assert snap["counters"] == [
        {"name": "hits", "labels": {"job": "j0"}, "value": 3.0}
    ]
    assert snap["gauges"][0]["value"] == 0.25
    hist = snap["histograms"][0]
    assert hist["count"] == 3
    assert hist["buckets"] == {"1": 1, "10": 2, "+Inf": 3}
    assert hist["sum"] == 105.5


def test_metrics_kind_collision_and_negative_inc_raise():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("y").inc(-1.0)


# ------------------------------------------- control-plane integration
def test_hang_diagnosis_breakdown_names_injected_ring_edge(hang_campaign):
    spec, run = hang_campaign
    # Ground truth: the preset's collective_hang episode and its edge.
    hang_inj = next(
        inj for inj in spec.schedule if inj.kind.value == "collective_hang"
    )
    a, b = hang_inj.target
    placed = next(
        p for p in spec.jobs
        if a in p.devices and b in p.devices
    )
    la, lb = sorted((p := list(placed.devices)).index(a) for a in (a, b))
    onsets = [
        e for e in run.events
        if isinstance(e, Diagnosis) and not e.resolved
        and e.job_id == placed.job_id
    ]
    assert onsets, "hang never diagnosed"
    diag = next(e for e in onsets if getattr(e.event, "hang", False))
    bd = diag.breakdown
    assert bd is not None
    assert bd.bottleneck == "dp_allreduce"
    assert bd.edge == f"link:{la}-{lb}"
    # The transient field must not leak into the serialized record.
    assert "breakdown" not in event_record(diag)


def test_tracing_does_not_change_behavior(hang_campaign):
    spec, traced = hang_campaign
    plain = run_campaign(spec, "falcon")
    assert event_log_records(traced.events) == event_log_records(plain.events)
    assert {
        j: (o.iters_done, o.end_time) for j, o in traced.outcomes.items()
    } == {
        j: (o.iters_done, o.end_time) for j, o in plain.outcomes.items()
    }


def test_trace_covers_pipeline_and_is_deterministic(hang_campaign):
    spec, run = hang_campaign
    names = {
        e["name"] for e in run.tracer.to_chrome()["traceEvents"]
        if e["ph"] == "X"
    }
    for expected in ("tick", "job", "silence", "deadline"):
        assert expected in names, f"missing {expected} spans"
    assert any(n.startswith("fault:") for n in names)
    assert any(n.startswith("inject:") for n in names)
    assert any(n.startswith("dispatch:") for n in names)
    tr2 = SpanTracer()
    run_campaign(spec, "falcon", tracer=tr2)
    assert run.tracer.to_json() == tr2.to_json()


def test_event_log_records_observation_stride():
    events = [
        Observation(job_id="j0", time=float(i), iter_time=1.0, step=i)
        for i in range(10)
    ]
    assert event_log_records(events) == []
    kept = event_log_records(events, observation_stride=3)
    assert [r["step"] for r in kept] == [0, 3, 6, 9]


# ------------------------------------------------- recorder + dashboard
def test_sidecars_byte_deterministic_and_report_unchanged(
    scored_obs, tmp_path
):
    spec, runs, report = scored_obs
    a = tmp_path / "a"
    b = tmp_path / "b"
    paths_a = obs_recorder.write_sidecars(spec, runs, report, out_dir=str(a))
    spec2, runs2, report2 = run_and_score(
        "single_gpu_throttle", n_jobs=1, seed=0, obs=True
    )
    paths_b = obs_recorder.write_sidecars(
        spec2, runs2, report2, out_dir=str(b)
    )
    assert report == report2
    for kind in ("trace", "metrics"):
        assert (
            open(paths_a[kind]).read() == open(paths_b[kind]).read()
        ), f"{kind} sidecar not byte-deterministic"
    # Observability must not perturb the scored report itself.
    _, _, plain = run_and_score("single_gpu_throttle", n_jobs=1, seed=0)
    assert report == plain


def test_recorder_metric_catalog(scored_obs):
    spec, runs, report = scored_obs
    snap = obs_recorder.record_campaign(spec, runs, report).snapshot()
    counters = {c["name"] for c in snap["counters"]}
    gauges = {g["name"] for g in snap["gauges"]}
    hists = {h["name"] for h in snap["histograms"]}
    assert {"events_total", "diagnoses_total"} <= counters
    assert {"wasted_gpu_seconds", "slowdown_mitigated_pct"} <= gauges
    assert "detection_latency_s" in hists
    assert "fault_duration_s" in hists


def test_dashboard_renders_deterministically(scored_obs):
    _, runs, report = scored_obs
    html = render_dashboard(report)
    assert html == render_dashboard(report)
    assert html.count("<svg") == 3
    for jid in (r["job_id"] for r in report["jobs"]):
        assert jid in html
