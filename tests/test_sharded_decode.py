"""Correctness of the sequence-sharded KV-cache decode (EXPERIMENTS §Perf
iteration 1): under a (data=2, model=2) mesh with the cache sequence dim
sharded over the model axis, decode logits must match the single-device
reference bit-for-bit (GSPMD inserts the partial-softmax collectives; the
math is unchanged).

Runs in a subprocess because the host device count must be fixed before JAX
initializes.
"""
import os

import pytest
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.models import model as model_lib, transformer
from repro.serve.serve_step import make_decode_step
from repro.sharding import partition

cfg = get_config("granite-3-8b").smoke()   # GQA kv < model axis
B, S = 4, 32
params = model_lib.init_params(cfg, 0)
caches = transformer.init_caches(cfg, B, S)
tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
pos = jnp.asarray(7, jnp.int32)
step = make_decode_step(cfg, S)

ref_logits, ref_caches = jax.jit(step)(params, tok, caches, pos)

mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh:
    nshard = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
    in_sh = (
        nshard(partition.param_specs(cfg, mesh)),
        NamedSharding(mesh, partition.decode_token_specs(cfg, mesh, B)),
        nshard(partition.cache_specs(cfg, mesh, B, seq_shard=True)),
        NamedSharding(mesh, P()),
    )
    out_logits, out_caches = jax.jit(step, in_shardings=in_sh)(params, tok, caches, pos)

np.testing.assert_allclose(
    np.asarray(ref_logits, np.float32), np.asarray(out_logits, np.float32),
    rtol=2e-2, atol=2e-2)
for a, b in zip(jax.tree.leaves(ref_caches), jax.tree.leaves(out_caches)):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)
print("SHARDED-DECODE-OK")
"""


@pytest.mark.slow
def test_seq_sharded_cache_decode_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-DECODE-OK" in out.stdout
