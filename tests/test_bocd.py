"""Tests for BOCD change-point detection + verification (paper §4.2)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bocd
from repro.core.detector import (
    detect_slow_iterations,
    detect_slow_iterations_sliding_window,
    verify_change_points,
)


def trace(segments, noise=0.01, seed=0):
    """Piecewise-constant iteration-time trace [(level, length), ...]."""
    rng = np.random.default_rng(seed)
    parts = [rng.normal(level, noise * level, size=n) for level, n in segments]
    return np.concatenate(parts)


def test_detects_single_step_change():
    x = trace([(1.0, 50), (1.5, 50)])
    cps = bocd.detect_change_points(x)
    assert any(abs(c - 50) <= 3 for c in cps), cps


def test_no_change_points_on_stationary_series():
    x = trace([(1.0, 200)])
    cps = detect_slow_iterations(x)
    assert cps == []


def test_verification_rejects_small_jitter():
    # 5 % step: BOCD may fire, verification must reject (<10 % rule).
    x = trace([(1.0, 60), (1.05, 60)], noise=0.002)
    verified = detect_slow_iterations(x)
    assert verified == []


def test_bocd_plus_v_full_pipeline_onset_and_relief():
    x = trace([(1.0, 60), (1.6, 60), (1.0, 60)])
    verified = detect_slow_iterations(x)
    onsets = [c for c in verified if c.relative_change > 0]
    reliefs = [c for c in verified if c.relative_change < 0]
    assert any(abs(c.index - 60) <= 3 for c in onsets)
    assert any(abs(c.index - 120) <= 3 for c in reliefs)


def test_linear_time_truncation():
    det = bocd.BOCD(hazard=0.01)
    rng = np.random.default_rng(1)
    for _ in range(500):
        det.update(float(rng.normal(1.0, 0.01)))
    # Run-length mass must stay truncated (linear-time requirement R2).
    assert det._log_r.size < 400


@settings(max_examples=15, deadline=None)
@given(
    level_jump=st.floats(min_value=0.2, max_value=2.0),
    seg=st.integers(min_value=30, max_value=80),
    seed=st.integers(min_value=0, max_value=100),
)
def test_property_detects_large_changes(level_jump, seg, seed):
    """Any >=20 % step change in a clean series is found within 5 steps."""
    x = trace([(1.0, seg), (1.0 + level_jump, seg)], noise=0.005, seed=seed)
    cps = detect_slow_iterations(x)
    assert any(abs(c.index - seg) <= 5 and c.relative_change > 0 for c in cps)


def test_verify_change_points_window_math():
    x = np.array([1.0] * 10 + [2.0] * 10)
    cps = verify_change_points(x, [10])
    assert len(cps) == 1
    assert cps[0].mean_before == 1.0
    assert cps[0].mean_after == 2.0
    assert cps[0].relative_change == 1.0


def test_verification_cuts_bocd_false_positives():
    """Table 4/5 trade-off: raw BOCD has high FPR on jittery-but-healthy
    traces (occasional transient spikes), BOCD+V filters them out, and both
    catch a genuine step change."""
    rng = np.random.default_rng(7)
    healthy = rng.normal(1.0, 0.01, 150)
    healthy[40] = 1.25  # transient single-iteration spikes (GC pause etc.)
    healthy[90] = 0.8
    raw_fp = bocd.detect_change_points(healthy)
    verified_fp = detect_slow_iterations(healthy)
    assert len(raw_fp) >= 1  # raw BOCD reacts to spikes
    assert verified_fp == []  # verification rejects them

    real = np.concatenate([rng.normal(1.0, 0.01, 80), rng.normal(1.3, 0.013, 80)])
    assert any(abs(c.index - 80) <= 5 for c in detect_slow_iterations(real))


def test_run_length_hypotheses_stay_bounded():
    """The truncation step keeps the per-update cost O(1) — the paper's
    'linear time' requirement (R2) would otherwise degrade to O(n^2)."""
    import numpy as np
    from repro.core.bocd import BOCD

    rng = np.random.default_rng(0)
    det = BOCD(hazard=1 / 100.0, mu0=1.0)
    sizes = []
    for i in range(3000):
        x = 1.0 + 0.01 * rng.standard_normal()
        if 1500 <= i < 1800:
            x *= 1.4
        det.update(x)
        sizes.append(det._log_r.size)
    # Hypothesis count must not grow with t.
    assert max(sizes[2000:]) <= max(sizes[500:1000]) + 50
    assert max(sizes) < 2000
