"""Tests for the S2 micro-batch allocation solver (paper §5.3, Eq. 1)."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import microbatch as mb


def brute_force(times, total):
    """Exact optimum by enumeration (small instances only)."""
    d = len(times)
    best = float("inf")
    for combo in itertools.product(range(1, total - d + 2), repeat=d):
        if sum(combo) != total:
            continue
        best = min(best, max(m * t for m, t in zip(combo, times)))
    return best


def test_uniform_groups_split_evenly():
    counts = mb.solve_allocation([1.0, 1.0, 1.0, 1.0], 16)
    assert counts == [4, 4, 4, 4]


def test_slow_group_gets_fewer():
    # One group 2x slower: it should get about half the micro-batches.
    counts = mb.solve_allocation([1.0, 1.0, 1.0, 2.0], 16)
    assert sum(counts) == 16
    assert counts[3] < min(counts[:3])
    assert mb.makespan(counts, [1.0, 1.0, 1.0, 2.0]) <= 6.0


def test_validation_errors():
    with pytest.raises(ValueError):
        mb.solve_allocation([], 4)
    with pytest.raises(ValueError):
        mb.solve_allocation([1.0, -1.0], 4)
    with pytest.raises(ValueError):
        mb.solve_allocation([1.0, 1.0, 1.0], 2)


@settings(max_examples=60, deadline=None)
@given(
    times=st.lists(
        st.floats(min_value=0.1, max_value=5.0), min_size=2, max_size=4
    ),
    extra=st.integers(min_value=0, max_value=8),
)
def test_property_greedy_is_optimal(times, extra):
    """Greedy allocation matches the brute-force optimum (Eq. 1)."""
    total = len(times) + extra
    counts = mb.solve_allocation(times, total)
    assert sum(counts) == total
    assert all(m >= 1 for m in counts)
    got = mb.makespan(counts, times)
    want = brute_force(times, total)
    assert got <= want * (1 + 1e-9)


@settings(max_examples=30, deadline=None)
@given(
    times=st.lists(
        st.floats(min_value=0.05, max_value=10.0), min_size=2, max_size=16
    ),
)
def test_property_never_worse_than_even_split(times):
    total = 4 * len(times)
    counts, balanced, even = mb.speedup(times, total)
    assert balanced <= even * (1 + 1e-9)


def test_gradient_weights_sum_to_one():
    w = mb.gradient_weights([3, 5, 4, 4])
    np.testing.assert_allclose(w.sum(), 1.0)
    np.testing.assert_allclose(w, np.array([3, 5, 4, 4]) / 16)


def test_paper_fig13_style_scenario():
    """8 DP groups, one severely degraded GPU (3x slower): S2 recovers most
    of the slowdown, mirroring the up-to-82.9 % reduction in Fig. 13."""
    times = [1.0] * 7 + [3.0]
    total = 32
    counts, balanced, even = mb.speedup(times, total)
    slowdown_before = even / 4.0 - 1.0  # healthy makespan would be 4.0
    slowdown_after = balanced / 4.0 - 1.0
    reduction = 1.0 - slowdown_after / slowdown_before
    assert reduction > 0.5
