"""Top-level language model: embed -> blocks -> head, plus loss and decode.

Input conventions per modality (the VLM/audio carve-out):
  * text:          batch["tokens"] (B, S) int32
  * vision_embeds: batch["embeds"] (B, S, D) + batch["positions"] (3, B, S)
  * audio_codes:   batch["tokens"] (B, S, K) int32 (K EnCodec codebooks)
Training batches additionally carry batch["labels"] (same layout as tokens).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, transformer
from repro.models.schema import (
    ParamDef,
    Schema,
    axes_tree,
    init_tree,
    shape_tree,
)

AUX_LOSS_COEF = 0.01


def model_schema(cfg: ArchConfig) -> Schema:
    return {
        "embed": layers.embed_schema(cfg),
        "blocks": transformer.blocks_schema(cfg),
        "final_norm": layers.rmsnorm_schema(cfg.d_model),
        "head": layers.head_schema(cfg),
    }


def init_params(cfg: ArchConfig, seed: int = 0) -> dict:
    return init_tree(model_schema(cfg), jax.random.key(seed))


def param_shapes(cfg: ArchConfig) -> dict:
    return shape_tree(model_schema(cfg))


def param_axes(cfg: ArchConfig) -> dict:
    return axes_tree(model_schema(cfg))


def _embed_inputs(params: dict, batch: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.modality == "vision_embeds":
        return batch["embeds"].astype(cfg.activation_dtype)
    return layers.apply_embed(params["embed"], batch["tokens"], cfg)


def _positions(batch: dict, cfg: ArchConfig, seq_len: int) -> jax.Array | None:
    if cfg.pos_encoding == "none":
        return None
    if cfg.pos_encoding == "mrope":
        return batch["positions"]
    bsz = (
        batch["embeds"].shape[0]
        if cfg.modality == "vision_embeds"
        else batch["tokens"].shape[0]
    )
    return jnp.broadcast_to(jnp.arange(seq_len)[None, :], (bsz, seq_len))


def forward(
    params: dict,
    batch: dict,
    cfg: ArchConfig,
    *,
    window: int = 0,
    use_kernel: bool = False,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full forward pass. Returns (logits, aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    positions = _positions(batch, cfg, x.shape[1])
    x, aux = transformer.apply_blocks(
        params["blocks"], x, cfg, positions,
        window=window, use_kernel=use_kernel, remat=remat,
    )
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return layers.apply_head(params["head"], x, cfg), aux


def loss_fn(
    params: dict,
    batch: dict,
    cfg: ArchConfig,
    *,
    window: int = 0,
    use_kernel: bool = False,
    remat: bool = True,
) -> tuple[jax.Array, dict]:
    """Mean next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux = forward(
        params, batch, cfg, window=window, use_kernel=use_kernel, remat=remat
    )
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - ll)
    loss = ce + AUX_LOSS_COEF * aux
    return loss, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------- decode
def decode_step(
    params: dict,
    tokens: jax.Array,
    caches: dict,
    pos: jax.Array,
    cfg: ArchConfig,
    *,
    window: int = 0,
    use_kernel: bool = False,
) -> tuple[jax.Array, dict]:
    """Generate logits for ONE new token given the cache state.

    tokens: (B, 1) int32 (or (B, 1, K) audio / (B, 1, D) vision embeds).
    Returns (logits (B, 1, V[, K]), new caches).
    """
    if cfg.modality == "vision_embeds":
        x = tokens.astype(cfg.activation_dtype)  # already embeddings
    else:
        x = layers.apply_embed(params["embed"], tokens, cfg)
    x, new_caches = transformer.decode_blocks(
        params["blocks"], x, caches, pos, cfg, window=window,
        use_kernel=use_kernel,
    )
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return layers.apply_head(params["head"], x, cfg), new_caches
