"""Parameter schema: one source of truth for shapes, init AND sharding.

Every layer module contributes ``{name: ParamDef}`` entries; ``init_tree``
materializes arrays and ``spec_tree`` produces the matching PartitionSpec
pytree, so parameter layout and distribution can never drift apart.

Logical sharding axes used in specs (resolved against the mesh later by
``repro.sharding.partition.resolve_specs``):
  * "model"  — tensor/expert-parallel axis; sharded only if divisible,
  * None     — replicated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    #: logical partition axes, one per dim (None or "model")
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "bfloat16"

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict[str, "ParamDef | dict"]


def init_leaf(defn: ParamDef, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(defn.dtype)
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, dt)
    if defn.init == "ones":
        return jnp.ones(defn.shape, dt)
    return (defn.scale * jax.random.normal(key, defn.shape, jnp.float32)).astype(dt)


def init_tree(schema: Schema, key: jax.Array, _path: str = "") -> dict:
    """Materialize a parameter pytree from a schema (deterministic per path)."""
    out: dict = {}
    for name, sub in sorted(schema.items()):
        path = f"{_path}/{name}"
        if isinstance(sub, dict):
            out[name] = init_tree(sub, key, path)
        else:
            leaf_key = jax.random.fold_in(key, _stable_hash(path))
            out[name] = init_leaf(sub, leaf_key)
    return out


def shape_tree(schema: Schema) -> dict:
    """ShapeDtypeStruct pytree (for eval_shape-free dry-runs)."""
    out: dict = {}
    for name, sub in schema.items():
        if isinstance(sub, dict):
            out[name] = shape_tree(sub)
        else:
            out[name] = jax.ShapeDtypeStruct(sub.shape, jnp.dtype(sub.dtype))
    return out


def axes_tree(schema: Schema) -> dict:
    """Logical-axes pytree matching the parameter pytree structure."""
    out: dict = {}
    for name, sub in schema.items():
        if isinstance(sub, dict):
            out[name] = axes_tree(sub)
        else:
            out[name] = sub.axes
    return out


def stack(schema: Schema, n: int) -> Schema:
    """Prefix every leaf with a stacking dim (scan-over-periods layout)."""
    out: Schema = {}
    for name, sub in schema.items():
        if isinstance(sub, dict):
            out[name] = stack(sub, n)
        else:
            out[name] = ParamDef(
                shape=(n, *sub.shape),
                axes=(None, *sub.axes),
                init=sub.init,
                scale=sub.scale,
                dtype=sub.dtype,
            )
    return out


def count_params(schema: Schema) -> int:
    total = 0
    for sub in schema.values():
        if isinstance(sub, dict):
            total += count_params(sub)
        else:
            total += math.prod(sub.shape)
    return total


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 31)
    return h
