"""Pure-JAX model zoo: dense / MoE / SSM / hybrid decoder backbones."""
