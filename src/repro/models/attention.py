"""GQA attention: blocked (flash-style) training path, cached decode path.

The training/prefill path is a pure-jnp *blocked online-softmax* attention
(`lax.scan` over KV blocks) so the full (S x S) score matrix is never
materialized — this is what the multi-pod dry-run lowers. The Pallas TPU
kernel in ``repro.kernels.flash_attention`` implements the same blocking for
real hardware and is validated against ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.schema import ParamDef, Schema

NEG_INF = -1e30


def attn_schema(cfg: ArchConfig) -> Schema:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    return {
        "norm": layers.rmsnorm_schema(d),
        "wq": ParamDef((d, h * hd), (None, "model")),
        "wk": ParamDef((d, kv * hd), (None, "model")),
        "wv": ParamDef((d, kv * hd), (None, "model")),
        "wo": ParamDef((h * hd, d), ("model", None)),
    }


# ------------------------------------------------------------------ core
def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV blocks.

    q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd) with H a multiple of KVH.
    ``window`` > 0 restricts attention to the last ``window`` keys
    (sliding-window). ``q_offset`` is the absolute position of q[0]
    (for decode/prefill continuation).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = hd**-0.5

    nblk = -(-skv // kv_block)
    pad = nblk * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # (B, KVH, rep, Sq, hd) grouped query layout.
    qg = q.reshape(b, sq, kvh, rep, hd).transpose(0, 2, 3, 1, 4) * scale
    kb = k.reshape(b, nblk, kv_block, kvh, hd) if pad == 0 else k.reshape(
        b, nblk, kv_block, kvh, hd
    )
    vb = v.reshape(b, nblk, kv_block, kvh, hd)
    kb = kb.transpose(1, 0, 3, 2, 4)  # (nblk, B, KVH, blk, hd)
    vb = vb.transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, blk_idx = blk
        # scores: (B, KVH, rep, Sq, blk)
        s = jnp.einsum(
            "bgrsd,bgkd->bgrsk", qg.astype(jnp.float32), kblk.astype(jnp.float32)
        )
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)
        mask = jnp.ones((sq, kv_block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < skv)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrsk,bgkd->bgrsd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def _apply_positions(
    q: jax.Array, k: jax.Array, positions: jax.Array | None, cfg: ArchConfig
) -> tuple[jax.Array, jax.Array]:
    if cfg.pos_encoding == "rope":
        assert positions is not None
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_encoding == "mrope":
        assert positions is not None and positions.shape[0] == 3
        q = layers.apply_mrope(q, positions, cfg.rope_theta)
        k = layers.apply_mrope(k, positions, cfg.rope_theta)
    return q, k


def apply_attention(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array | None,
    *,
    window: int = 0,
    use_kernel: bool = False,
) -> jax.Array:
    """Training/prefill self-attention. x: (B, S, D)."""
    b, s, _ = x.shape
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    hn = layers.rmsnorm(x, params["norm"], cfg.norm_eps)
    q = (hn @ params["wq"]).reshape(b, s, h, hd)
    k = (hn @ params["wk"]).reshape(b, s, kv, hd)
    v = (hn @ params["wv"]).reshape(b, s, kv, hd)
    q, k = _apply_positions(q, k, positions, cfg)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        out = kernel_ops.flash_attention(q, k, v, causal=True, window=window)
    else:
        out = blocked_attention(q, k, v, causal=True, window=window)
    return out.reshape(b, s, h * hd) @ params["wo"]


# ----------------------------------------------------------------- decode
def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    shape = (batch, max_len, kv, hd)
    dt = cfg.activation_dtype
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def kv_cache_shape(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    shape = (batch, max_len, kv, hd)
    dt = cfg.activation_dtype
    return {
        "k": jax.ShapeDtypeStruct(shape, dt),
        "v": jax.ShapeDtypeStruct(shape, dt),
    }


def decode_attention(
    params: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg: ArchConfig,
    positions_full: jax.Array | None = None,
    *,
    window: int = 0,
    use_kernel: bool = False,
) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, S_max, KVH, hd);
    pos: scalar int32 — current position. Returns (out, new_cache).

    With ``window`` > 0, only the trailing ``window`` cache entries are
    attended (sliding-window decode — the sub-quadratic long_500k path for
    full-attention architectures). ``use_kernel`` routes the cache read
    through the Pallas flash-decode kernel (TPU target; interpret on CPU).
    """
    b, _, _ = x.shape
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    s_max = cache["k"].shape[1]
    hn = layers.rmsnorm(x, params["norm"], cfg.norm_eps)
    q = (hn @ params["wq"]).reshape(b, 1, h, hd)
    k_new = (hn @ params["wk"]).reshape(b, 1, kv, hd)
    v_new = (hn @ params["wv"]).reshape(b, 1, kv, hd)

    if cfg.pos_encoding == "rope":
        pos_arr = jnp.full((b, 1), pos, jnp.int32)
        q = layers.apply_rope(q, pos_arr, cfg.rope_theta)
        k_new = layers.apply_rope(k_new, pos_arr, cfg.rope_theta)
    elif cfg.pos_encoding == "mrope":
        pos_arr = jnp.full((3, b, 1), pos, jnp.int32)
        q = layers.apply_mrope(q, pos_arr, cfg.rope_theta)
        k_new = layers.apply_mrope(k_new, pos_arr, cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)

    if window and window < s_max:
        # Slide: attend to the `window` keys ending at pos (static size).
        start = jnp.maximum(pos - window + 1, 0)
        k_att = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        k_pos = start + jnp.arange(window)
        valid = k_pos <= pos
    else:
        k_att, v_att = k_cache, v_cache
        k_pos = jnp.arange(s_max)
        valid = k_pos <= pos

    if use_kernel:
        from repro.kernels import ops as kernel_ops

        # valid positions form a prefix of k_att in both branches:
        # full cache -> pos+1; sliding window -> pos+1-start.
        valid_len = jnp.sum(valid).astype(jnp.int32)
        out = kernel_ops.flash_decode(
            q.reshape(b, h, hd), k_att, v_att, valid_len
        )
        out = out.reshape(b, 1, h * hd).astype(x.dtype)
        return out @ params["wo"], {"k": k_cache, "v": v_cache}

    rep = h // kv
    qg = q.reshape(b, kv, rep, hd).astype(jnp.float32) * hd**-0.5
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k_att.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v_att.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    return out @ params["wo"], {"k": k_cache, "v": v_cache}
