"""Shared layers: RMSNorm, SwiGLU MLP, RoPE / M-RoPE, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.schema import ParamDef, Schema


# --------------------------------------------------------------- RMSNorm
def rmsnorm_schema(d: int) -> ParamDef:
    return ParamDef((d,), (None,), init="ones")


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    norm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (norm * weight.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------- SwiGLU MLP
def mlp_schema(cfg: ArchConfig, d_ff: int | None = None) -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "norm": rmsnorm_schema(d),
        "wi_gate": ParamDef((d, f), (None, "model")),
        "wi_up": ParamDef((d, f), (None, "model")),
        "wo": ParamDef((f, d), ("model", None)),
    }


def apply_mlp(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = rmsnorm(x, params["norm"], cfg.norm_eps)
    gate = h @ params["wi_gate"]
    up = h @ params["wi_up"]
    return (jax.nn.silu(gate) * up) @ params["wo"]


# ------------------------------------------------------------- RoPE(s)
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: broadcastable (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Qwen2-VL M-RoPE: split the hd/2 rotary pairs into (t, h, w) sections
    with the 16/24/24-style 1:1.5:1.5 proportion."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Multimodal RoPE. positions3: (3, ..., S) = (temporal, height, width)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    secs = mrope_sections(hd)
    parts = []
    start = 0
    for i, sec in enumerate(secs):
        pos = positions3[i]
        parts.append(pos[..., None].astype(jnp.float32) * freqs[start : start + sec])
        start += sec
    angles = jnp.concatenate(parts, axis=-1)[..., None, :]  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------- embeddings
def embed_schema(cfg: ArchConfig) -> Schema:
    v, d = cfg.padded_vocab, cfg.d_model
    if cfg.modality == "audio_codes":
        return {"tok": ParamDef((cfg.num_codebooks, v, d), (None, "model", None))}
    return {"tok": ParamDef((v, d), ("model", None))}


def apply_embed(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.modality == "audio_codes":
        # tokens: (B, S, K) -> sum of the K per-codebook embeddings
        # (MusicGen's delay-pattern interleave is the data stub's job).
        out = sum(
            jnp.take(params["tok"][k], tokens[..., k], axis=0)
            for k in range(cfg.num_codebooks)
        )
        return out.astype(cfg.activation_dtype)
    return jnp.take(params["tok"], tokens, axis=0).astype(cfg.activation_dtype)


def head_schema(cfg: ArchConfig) -> Schema:
    v, d = cfg.padded_vocab, cfg.d_model
    if cfg.modality == "audio_codes":
        return {"w": ParamDef((cfg.num_codebooks, d, v), (None, None, "model"))}
    return {"w": ParamDef((d, v), (None, "model"))}


def apply_head(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Returns logits over the padded vocab: (B,S,Vp) or (B,S,K,Vp).

    Padding columns are masked to a large negative so softmax/argmax/logsumexp
    never select them; the width stays ``padded_vocab`` so the model-axis
    sharding survives through the loss.
    """
    if cfg.modality == "audio_codes":
        logits = jnp.einsum("bsd,kdv->bskv", x, params["w"])
    else:
        logits = x @ params["w"]
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return logits
