"""Mixture-of-Experts with sort-based token dispatch (dropping, capacity C).

FLOP-exact formulation: tokens are sorted by routed expert, packed into an
(E, C, D) capacity buffer, processed by per-expert SwiGLU FFNs, and combined
back with router gates — so HLO FLOPs reflect *active* experts only (the
dense all-experts einsum would inflate the roofline by E/k).

Sharding modes (resolved against the model axis):
  * ``experts``: expert-parallel — the E dim of expert weights and of the
    capacity buffer is sharded; dispatch/combine induce all-to-all traffic.
  * ``ff``: tensor-parallel experts — the per-expert FF dim is sharded
    (used when E does not divide the axis, e.g. 60 experts on 16 devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.schema import ParamDef, Schema


def moe_schema(cfg: ArchConfig) -> Schema:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.padded_experts
    if cfg.moe_shard == "experts":
        ax: tuple = ("model", None, None)
    else:  # "ff": shard the per-expert hidden dim
        ax = (None, None, "model")
    out: Schema = {
        "norm": layers.rmsnorm_schema(d),
        "router": ParamDef((d, e), (None, None)),
        "wi_gate": ParamDef((e, d, f), ax),
        "wi_up": ParamDef((e, d, f), ax),
        "wo": ParamDef((e, f, d), (ax[0], ax[2], None)),
    }
    if cfg.num_shared_experts:
        fs = cfg.shared_d_ff * cfg.num_shared_experts
        out["shared_wi_gate"] = ParamDef((d, fs), (None, "model"))
        out["shared_wi_up"] = ParamDef((d, fs), (None, "model"))
        out["shared_wo"] = ParamDef((fs, d), ("model", None))
    return out


def route(
    logits: jax.Array, top_k: int, n_real: int | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (gates (T,k), expert_idx (T,k), aux_loss).

    ``n_real``: number of real experts when the expert dim is padded —
    dummy columns are masked so they are never routed to."""
    if n_real is not None and n_real < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < n_real
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    e = logits.shape[-1]
    pe = probs.mean(axis=0)  # (E,)
    fe = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = e * jnp.sum(fe * pe)
    return gates, idx, aux


def _moe_core(
    params: dict,
    xf: jax.Array,
    cfg: ArchConfig,
    e_offset,
    e_local: int,
) -> tuple[jax.Array, jax.Array]:
    """Route + sort-dispatch + per-expert SwiGLU for experts
    [e_offset, e_offset + e_local). Returns the *partial* combined output
    (T, D) f32 (contributions of those experts only) and the aux loss.

    With (e_offset=0, e_local=E) this is the full dense-host computation;
    the expert-parallel path calls it per model-axis shard so dispatch and
    combine stay device-local (the cross-shard reduction is one psum of the
    activation-sized partial output — see apply_moe).
    """
    t, d = xf.shape
    k, e = cfg.top_k, cfg.padded_experts
    # Capacity is sized for the REAL expert count: tokens only ever route to
    # real experts, so padded columns get none.
    cap = int(t * k / cfg.num_experts * cfg.capacity_factor) + 1

    gates, idx, aux = route(xf @ params["router"], k, n_real=cfg.num_experts)

    # ---- sort-based dispatch into the (e_local, C, D) capacity buffer ---
    flat_e = idx.reshape(-1)  # (T*k,)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    token_of = order // k
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(t * k) - starts[sorted_e]
    local_e = sorted_e - e_offset
    keep = (slot < cap) & (local_e >= 0) & (local_e < e_local)
    slot_c = jnp.where(keep, slot, 0)
    local_c = jnp.where(keep, local_e, 0)

    buf = jnp.zeros((e_local, cap, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[token_of], 0.0)
    buf = buf.at[local_c, slot_c].add(contrib)

    # ---- per-expert SwiGLU ---------------------------------------------
    gate_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    act = jax.nn.silu(gate_h) * up_h
    out_buf = jnp.einsum("ecf,efd->ecd", act, params["wo"])

    # ---- combine ---------------------------------------------------------
    y_sorted = out_buf[local_c, slot_c] * jnp.where(keep, flat_g[order], 0.0)[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[token_of].add(y_sorted.astype(jnp.float32))
    return y, aux


def _ep_axes(cfg: ArchConfig):
    """(batch_axes, model_axis_size) when the expert-parallel shard_map path
    applies under the ambient mesh, else None.

    Expert parallelism needs E % model == 0; the GSPMD fallback handles the
    rest. On meshless hosts (CPU smoke tests) the mesh is empty -> None.
    Older jax (no top-level ``jax.shard_map``) miscompiles this manual
    pattern in the SPMD partitioner — fall back to GSPMD there too.
    """
    if not compat.HAS_MODERN_SHARD_MAP:
        return None
    mesh = compat.ambient_mesh()
    if mesh is None:
        return None
    names = getattr(mesh, "axis_names", ()) or ()
    if "model" not in names:
        return None
    tp = mesh.shape["model"]
    if tp <= 1 or cfg.moe_shard != "experts" or cfg.padded_experts % tp:
        return None
    ba = tuple(a for a in ("pod", "data") if a in names)
    return mesh, ba, tp


def apply_moe(params: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    Under a mesh with a model axis dividing E (and ``moe_shard="experts"``),
    dispatch/combine run *shard-locally* inside a shard_map: each model rank
    builds the capacity buffer for its own experts from its own tokens, and
    the only cross-shard communication is one activation-sized psum of the
    partial outputs over the model axis — the same collective the dense TP
    MLP already pays — instead of GSPMD's replicated-scatter all-reduces
    (EXPERIMENTS §Perf, jamba/olmoe iterations). Otherwise falls back to the
    plain GSPMD formulation.
    """
    b, s, d = x.shape
    hn = layers.rmsnorm(x, params["norm"], cfg.norm_eps)

    ep = _ep_axes(cfg)
    if ep is None:
        xf = hn.reshape(b * s, d)
        y, aux = _moe_core(params, xf, cfg, 0, cfg.padded_experts)
        y = y.astype(x.dtype)
        if cfg.num_shared_experts:
            shg = jax.nn.silu(xf @ params["shared_wi_gate"]) * (
                xf @ params["shared_wi_up"]
            )
            y = y + (shg @ params["shared_wo"]).astype(x.dtype)
        return y.reshape(b, s, d), aux

    mesh, ba, tp = ep
    e_local = cfg.padded_experts // tp
    from jax.sharding import PartitionSpec as P

    dsize = 1
    for a in ba:
        dsize *= mesh.shape[a]
    if b % dsize:
        # Batch doesn't divide the DP axes (long_500k decode has B=1): go
        # manual over the model axis only; tokens are replicated across DP.
        ba = ()
    bspec = P(ba if ba else None, None, None)
    wspec = {
        "norm": jax.tree.map(lambda _: P(), params["norm"]),
        "router": P(),
        "wi_gate": P("model", None, None),
        "wi_up": P("model", None, None),
        "wo": P("model", None, None),
    }
    if cfg.num_shared_experts:
        wspec["shared_wi_gate"] = P(None, "model")
        wspec["shared_wi_up"] = P(None, "model")
        wspec["shared_wo"] = P("model", None)

    def ep_body(p, h):
        bl, sl, _ = h.shape
        xf = h.reshape(bl * sl, d)
        r = jax.lax.axis_index("model")
        y, aux = _moe_core(p, xf, cfg, r * e_local, e_local)
        if cfg.num_shared_experts:
            # Shared experts are column/row tensor-parallel over the same
            # axis; their row-parallel partial rides the same psum.
            shg = jax.nn.silu(xf @ p["shared_wi_gate"]) * (xf @ p["shared_wi_up"])
            y = y + (shg @ p["shared_wo"]).astype(jnp.float32)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, ("model", *ba))  # identical across manual ranks
        return y.astype(h.dtype).reshape(bl, sl, d), aux

    manual = frozenset(("model", *ba))
    shmapped = compat.shard_map_compat(
        ep_body,
        mesh=mesh,
        in_specs=(wspec, bspec),
        out_specs=(bspec, P()),
        axis_names=manual,
    )
    y, aux = shmapped(
        {k: params[k] for k in wspec}, hn
    )
    return y, aux
