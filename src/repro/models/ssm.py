"""Mamba2 SSD (state-space duality) layer — chunked dual form.

TPU adaptation (DESIGN.md): the selective scan is evaluated in the SSD
*dual* form — per-chunk matmuls (MXU-friendly) plus a short inter-chunk
recurrence via `lax.scan` — instead of the element-wise CUDA scan of the
original. The Pallas kernel in ``repro.kernels.ssd_scan`` implements the
same chunking with explicit VMEM tiles; this module is the pure-jnp path
(also the oracle the kernel is validated against).

Shapes follow the Mamba2 paper: H heads of dim P, state size N, G groups
for B/C (shared across H//G heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.schema import ParamDef, Schema


def mamba_schema(cfg: ArchConfig) -> Schema:
    d = cfg.d_model
    inner, h = cfg.ssm_inner, cfg.ssm_heads
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width
    return {
        "norm": layers.rmsnorm_schema(d),
        "w_z": ParamDef((d, inner), (None, "model")),
        "w_x": ParamDef((d, inner), (None, "model")),
        "w_bc": ParamDef((d, 2 * g * n), (None, None)),
        "w_dt": ParamDef((d, h), (None, "model")),
        "dt_bias": ParamDef((h,), ("model",), init="zeros"),
        "a_log": ParamDef((h,), ("model",), init="zeros"),
        "d_skip": ParamDef((h,), ("model",), init="ones"),
        "conv_x": ParamDef((w, inner), (None, "model"), scale=0.1),
        "conv_bc": ParamDef((w, 2 * g * n), (None, None), scale=0.1),
        "out_norm": ParamDef((inner,), ("model",), init="ones"),
        "w_out": ParamDef((inner, d), ("model", None)),
    }


def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    out = sum(xp[:, i : i + s, :] * w[i] for i in range(width))
    return out


def ssd_scan(
    x: jax.Array,  # (B, S, H, P) — dt-scaled inputs NOT yet applied
    dt: jax.Array,  # (B, S, H) — softplus'd step sizes
    a: jax.Array,  # (H,) — negative decay rates (-exp(a_log))
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)

    da = dtc * a  # (B, nc, Q, H), negative
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative log-decay

    # ---- intra-chunk (dual/attention-like form) -------------------------
    # L[q, k] = exp(cum[q] - cum[k]) for q >= k else 0.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", cc, bc)  # (B,nc,Q,K,G)
    scores = jnp.repeat(scores, rep, axis=-1)  # G -> H
    m = scores * l_mat * dtc[:, :, None, :, :]  # dt applied at source step k
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xc)

    # ---- chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    xbar = xc * (dtc * decay_to_end)[..., None]  # (B,nc,Q,H,P)
    b_h = jnp.repeat(bc, rep, axis=3)  # (B,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqhp->bchpn", b_h, xbar)

    # ---- inter-chunk recurrence -----------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), x.dtype)
    )

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev  # emit the state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        step,
        s0.astype(jnp.float32),
        (states.swapaxes(0, 1).astype(jnp.float32), chunk_decay.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)  # (B,nc,H,P,N)

    c_h = jnp.repeat(cc, rep, axis=3)  # (B,nc,Q,H,N)
    decay_from_start = jnp.exp(cum)  # (B,nc,Q,H)
    y_inter = (
        jnp.einsum("bcqhn,bchpn->bcqhp", c_h, prev_states.astype(x.dtype))
        * decay_from_start[..., None]
    )

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state.astype(x.dtype)


def apply_mamba(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    use_kernel: bool = False,
) -> jax.Array:
    """Training/prefill Mamba2 block. x: (B, S, D)."""
    bsz, s, _ = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state

    hn = layers.rmsnorm(x, params["norm"], cfg.norm_eps)
    z = hn @ params["w_z"]
    xin = hn @ params["w_x"]
    bc = hn @ params["w_bc"]
    dt = jax.nn.softplus(hn @ params["w_dt"] + params["dt_bias"])

    xin = jax.nn.silu(causal_conv(xin, params["conv_x"]))
    bc = jax.nn.silu(causal_conv(bc, params["conv_bc"]))
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, s, h, p)
    b_mat = b_mat.reshape(bsz, s, g, n)
    c_mat = c_mat.reshape(bsz, s, g, n)

    if use_kernel:
        from repro.kernels import ops as kernel_ops

        y, _ = kernel_ops.ssd_scan(xh, dt, a, b_mat, c_mat, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_scan(xh, dt, a, b_mat, c_mat, chunk=cfg.ssm_chunk)
    y = y + params["d_skip"][:, None] * xh  # per-head skip
    y = y.reshape(bsz, s, h * p)
    y = layers.rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    return y @ params["w_out"]


# ----------------------------------------------------------------- decode
def init_ssm_cache(cfg: ArchConfig, batch: int) -> dict:
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width
    dt = cfg.activation_dtype
    return {
        "state": jnp.zeros((batch, h, p, n), dt),
        "conv_x": jnp.zeros((batch, w - 1, cfg.ssm_inner), dt),
        "conv_bc": jnp.zeros((batch, w - 1, 2 * g * n), dt),
    }


def ssm_cache_shape(cfg: ArchConfig, batch: int) -> dict:
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width
    dt = cfg.activation_dtype
    return {
        "state": jax.ShapeDtypeStruct((batch, h, p, n), dt),
        "conv_x": jax.ShapeDtypeStruct((batch, w - 1, cfg.ssm_inner), dt),
        "conv_bc": jax.ShapeDtypeStruct((batch, w - 1, 2 * g * n), dt),
    }


def decode_mamba(
    params: dict, x: jax.Array, cache: dict, cfg: ArchConfig
) -> tuple[jax.Array, dict]:
    """One-token Mamba2 step. x: (B, 1, D)."""
    bsz = x.shape[0]
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    rep = h // g

    hn = layers.rmsnorm(x, params["norm"], cfg.norm_eps)
    z = hn @ params["w_z"]  # (B,1,inner)
    xin = hn @ params["w_x"]
    bc = hn @ params["w_bc"]
    dt = jax.nn.softplus(hn @ params["w_dt"] + params["dt_bias"])  # (B,1,H)

    # Rolling conv caches.
    xin_hist = jnp.concatenate([cache["conv_x"], xin], axis=1)  # (B,W,inner)
    bc_hist = jnp.concatenate([cache["conv_bc"], bc], axis=1)
    xin = jax.nn.silu(jnp.einsum("bwc,wc->bc", xin_hist, params["conv_x"]))[:, None]
    bc_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", bc_hist, params["conv_bc"]))[:, None]
    b_mat, c_mat = jnp.split(bc_c, 2, axis=-1)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, h, p)
    b_h = jnp.repeat(b_mat.reshape(bsz, g, n), rep, axis=1)  # (B,H,N)
    c_h = jnp.repeat(c_mat.reshape(bsz, g, n), rep, axis=1)
    dt1 = dt[:, 0, :]  # (B,H)

    decay = jnp.exp(dt1 * a)  # (B,H)
    state = cache["state"].astype(jnp.float32)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt1, xh.astype(jnp.float32), b_h.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_h.astype(jnp.float32), state)
    y = y + params["d_skip"][:, None].astype(jnp.float32) * xh
    y = y.reshape(bsz, 1, h * p).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    new_cache = {
        "state": state.astype(cache["state"].dtype),
        "conv_x": xin_hist[:, 1:],
        "conv_bc": bc_hist[:, 1:],
    }
    return y @ params["w_out"], new_cache
