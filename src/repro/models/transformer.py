"""Decoder stack assembly: scan over repeating periods of sub-layers.

Heterogeneous architectures (jamba's 1-attention:7-mamba interleave with
alternating MoE) are expressed as a *period* — a fixed tuple of sub-layers —
and the full stack is `lax.scan` over ``n_periods`` with parameters stacked
on a leading axis. This keeps the lowered HLO small (one period body) even
for 80-layer models, which matters for 512-device dry-run compile times.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SubLayer
from repro.models import attention, layers, moe, ssm
from repro.models.schema import Schema, stack


def period_schema(cfg: ArchConfig) -> Schema:
    out: Schema = {}
    for j, sub in enumerate(cfg.period):
        entry: Schema = {}
        if sub.mixer == "attn":
            entry["attn"] = attention.attn_schema(cfg)
        else:
            entry["mamba"] = ssm.mamba_schema(cfg)
        if sub.mlp == "mlp":
            entry["mlp"] = layers.mlp_schema(cfg)
        elif sub.mlp == "moe":
            entry["moe"] = moe.moe_schema(cfg)
        out[f"sub{j}"] = entry
    return out


def blocks_schema(cfg: ArchConfig) -> Schema:
    return stack(period_schema(cfg), cfg.n_periods)


def _apply_sublayer(
    x: jax.Array,
    p: dict,
    sub: SubLayer,
    cfg: ArchConfig,
    positions: jax.Array | None,
    window: int,
    use_kernel: bool,
) -> tuple[jax.Array, jax.Array]:
    """Residual sub-layer application. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if sub.mixer == "attn":
        x = x + attention.apply_attention(
            p["attn"], x, cfg, positions, window=window, use_kernel=use_kernel
        )
    else:
        x = x + ssm.apply_mamba(p["mamba"], x, cfg, use_kernel=use_kernel)
    if sub.mlp == "mlp":
        x = x + layers.apply_mlp(p["mlp"], x, cfg)
    elif sub.mlp == "moe":
        y, aux = moe.apply_moe(p["moe"], x, cfg)
        x = x + y
    return x, aux


def apply_blocks(
    blocks: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array | None,
    *,
    window: int = 0,
    use_kernel: bool = False,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the full stack. Returns (hidden (B,S,D), total aux loss)."""

    def period_body(carry, period_params):
        h, aux_sum = carry
        for j, sub in enumerate(cfg.period):
            h, aux = _apply_sublayer(
                h, period_params[f"sub{j}"], sub, cfg, positions, window, use_kernel
            )
            aux_sum = aux_sum + aux
        return (h, aux_sum), None

    if remat:
        # Save matmul outputs across the remat boundary (they're what the
        # backward pass actually needs); recompute only the cheap
        # elementwise/norm chains. Full-recompute remat costs ~25-30 % extra
        # FLOPs, and train_4k peaks far below HBM — memory is the cheaper
        # currency here (EXPERIMENTS §Perf, granite-3-8b iteration 1).
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    else:
        body = period_body
    (x, aux_total), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux_total


# ----------------------------------------------------------------- decode
def init_caches(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    out: dict = {}
    for j, sub in enumerate(cfg.period):
        if sub.mixer == "attn":
            c = attention.init_kv_cache(cfg, batch, max_len)
        else:
            c = ssm.init_ssm_cache(cfg, batch)
        out[f"sub{j}"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (cfg.n_periods, *leaf.shape)), c
        )
    return out


def grow_caches(caches: dict, cfg: ArchConfig, max_len: int) -> dict:
    """Pad prefill-produced KV caches out to the serving context length.

    Prefill returns caches sized to the prompt; decode writes into a fixed
    ``max_len`` buffer indexed by ``pos``. SSM caches are O(1) in context
    length and pass through unchanged.
    """
    out: dict = {}
    for j, sub in enumerate(cfg.period):
        key = f"sub{j}"
        c = caches[key]
        if sub.mixer == "attn":
            pad = max_len - c["k"].shape[2]  # (periods, B, S, kv, hd)
            widths = [(0, 0), (0, 0), (0, max(pad, 0)), (0, 0), (0, 0)]
            c = {
                "k": jnp.pad(c["k"], widths),
                "v": jnp.pad(c["v"], widths),
            }
        out[key] = c
    return out


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    out: dict = {}
    for j, sub in enumerate(cfg.period):
        if sub.mixer == "attn":
            c = attention.kv_cache_shape(cfg, batch, max_len)
        else:
            c = ssm.ssm_cache_shape(cfg, batch)
        out[f"sub{j}"] = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(
                (cfg.n_periods, *leaf.shape), leaf.dtype
            ),
            c,
        )
    return out


def decode_blocks(
    blocks: dict,
    x: jax.Array,
    caches: dict,
    pos: jax.Array,
    cfg: ArchConfig,
    *,
    window: int = 0,
    use_kernel: bool = False,
) -> tuple[jax.Array, dict]:
    """One-token decode through the stack. Returns (hidden, new caches)."""

    def period_body(h, scanned):
        period_params, cache = scanned
        new_cache = {}
        for j, sub in enumerate(cfg.period):
            key = f"sub{j}"
            if sub.mixer == "attn":
                dh, nc = attention.decode_attention(
                    period_params[key]["attn"], h, cache[key], pos, cfg,
                    window=window, use_kernel=use_kernel,
                )
            else:
                dh, nc = ssm.decode_mamba(
                    period_params[key]["mamba"], h, cache[key], cfg
                )
            h = h + dh
            new_cache[key] = nc
            if sub.mlp == "mlp":
                h = h + layers.apply_mlp(period_params[key]["mlp"], h, cfg)
            elif sub.mlp == "moe":
                y, _ = moe.apply_moe(period_params[key]["moe"], h, cfg)
                h = h + y
        return h, new_cache

    x, new_caches = jax.lax.scan(period_body, x, (blocks, caches))
    return x, new_caches
