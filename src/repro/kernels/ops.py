"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid step, which validates the exact TPU program
logic; on a real TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal=True, window=0, block_q=128, block_k=128, interpret=None
):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk=128, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd_scan(x, dt, a, b_mat, c_mat, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k, v, valid_len, *, block_k=512, interpret=None):
    from repro.kernels import flash_decode as _fd

    if interpret is None:
        interpret = _interpret_default()
    return _fd.flash_decode(
        q, k, v, valid_len, block_k=block_k, interpret=interpret
    )
