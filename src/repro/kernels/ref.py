"""Pure-jnp oracles for the Pallas kernels (naive, obviously-correct)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Materialized-softmax GQA attention (the slow, trusted reference)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) * hd**-0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= rows - cols < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, H, hd) one token
    k: jax.Array,  # (B, Skv, KVH, hd)
    v: jax.Array,
    valid_len,  # () or (B,) int32
) -> jax.Array:
    """Single-token GQA attention over a masked cache (trusted reference)."""
    b, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) * hd**-0.5
    s = jnp.einsum("bhd,bkhd->bhk", qf, kf)
    lens = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    mask = jnp.arange(skv)[None, None, :] < lens[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vf)
    return out.astype(q.dtype)


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,) negative
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential state-space recurrence (the definitionally-correct form):

        S_t = exp(dt_t * a) * S_{t-1} + dt_t * x_t b_tᵀ
        y_t = C_t · S_t
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    ch = jnp.repeat(c_mat, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * af)  # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt
        )
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(
        step,
        s0,
        (
            xf.swapaxes(0, 1),
            dtf.swapaxes(0, 1),
            bh.swapaxes(0, 1),
            ch.swapaxes(0, 1),
        ),
    )
    y = ys.swapaxes(0, 1)  # (B,S,H,P)
    return y.astype(x.dtype), final.astype(x.dtype)
