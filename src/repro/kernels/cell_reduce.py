"""Fused simulator reduction kernel (Pallas) — one launch per evaluation.

:class:`repro.cluster.simulator.TrainingSimulator`'s vectorized fast path
derives an iteration time from the cached per-cell measurements with ~10
separate numpy reductions (TP ring minima, stage-time formula, per-column
stage maxima, DP ring minima, activation-hop sums, pipeline max, DP
all-reduce bottleneck). This module fuses that whole reduction tree —
per-cell ring-min, stage-max, hop-path sum and the final critical-path
max — into a single Pallas kernel launch, so on a compiled backend the
entire evaluation runs out of VMEM with no HBM round-trips between passes.

It backs the ``pallas`` entry of the simulator's ``ReductionBackend``
registry (see docs/kernels.md). Inputs are the simulator's *measured*
arrays (cell speed minima and raw ring/hop edge bandwidths — incremental
event-scoped maintenance stays on the numpy side); the kernel owns every
reduction after measurement. ``cell_reduce`` (the Pallas launch) and
``cell_reduce_reference`` (the same traced math without ``pallas_call``)
share one function, so interpret-mode kernel output is bit-identical to
the reference by construction; versus the float64 numpy oracle the float32
kernel carries the documented ~1e-5 relative tolerance.

The kernel requires a full hybrid shape (tp > 1, dp > 1, pp > 1);
degenerate axes stay on the numpy path (the ``PallasReduction`` backend
falls back automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent members are fine on the interpret path.
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover - non-TPU pallas builds
    pltpu = None
    _VMEM = _SMEM = None


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _fused_reduce(cell_speed, tp_edge, dp_edge, hop_bw, alloc_off,
                  c_flops, c_speed, c_tp, pp_vol, c_dp):
    """The simulator's full post-measurement reduction tree (pure jnp).

    Shapes: ``cell_speed`` (pp, dp); ``tp_edge``/``dp_edge`` (pp, dp, tp);
    ``hop_bw`` (pp - 1, dp); ``alloc_off`` (1, dp) — ``allocation + pp - 1``
    as floats. Scalars are 0-d arrays (the factored formula constants of
    ``_Cells``). Returns ``(t, stage_max, tp_bw, dp_bw)`` with ``t`` (1, 1)
    the iteration time, ``stage_max`` (1, dp), ``tp_bw`` (pp, dp) and
    ``dp_bw`` (pp, tp) the per-group bottlenecks ``profile_groups`` needs.
    """
    tp_bw = jnp.min(tp_edge, axis=2)                      # TP ring minima
    stage = c_flops / (c_speed * cell_speed) + c_tp / tp_bw
    stage_max = jnp.max(stage, axis=0, keepdims=True)     # per-DP-group
    dp_bw = jnp.min(dp_edge, axis=1)                      # DP ring minima
    hop2 = 2.0 * jnp.sum(pp_vol / hop_bw, axis=0, keepdims=True)
    pipe = alloc_off * stage_max + hop2                   # 1F1B + hops
    t = jnp.max(pipe) + c_dp / jnp.min(dp_bw)             # + DP all-reduce
    return t.reshape(1, 1), stage_max, tp_bw, dp_bw


def _reduce_kernel(
    params_ref, cell_speed_ref, tp_edge_ref, dp_edge_ref, hop_bw_ref,
    alloc_ref, t_out, stage_max_out, tp_bw_out, dp_bw_out,
):
    p = params_ref
    outs = _fused_reduce(
        cell_speed_ref[:], tp_edge_ref[:], dp_edge_ref[:], hop_bw_ref[:],
        alloc_ref[:],
        c_flops=p[0, 0], c_speed=p[0, 1], c_tp=p[0, 2],
        pp_vol=p[0, 3], c_dp=p[0, 4],
    )
    for ref, val in zip(
        (t_out, stage_max_out, tp_bw_out, dp_bw_out), outs
    ):
        ref[:] = val


@functools.partial(jax.jit, static_argnames=("interpret",))
def cell_reduce(
    cell_speed, tp_edge, dp_edge, hop_bw, alloc_off,
    c_flops, c_speed, c_tp, pp_vol, c_dp, *, interpret=None,
):
    """The full reduction tree as a single ``pallas_call`` launch.

    Array shapes/dtypes as in :func:`_fused_reduce` (``alloc_off`` may be
    1-D; constants may be python floats — traced, so allocation changes
    don't recompile). Returns ``(t, stage_max, tp_bw, dp_bw)``.
    """
    if interpret is None:
        interpret = _interpret_default()
    dt = cell_speed.dtype
    pp, dp, tp = tp_edge.shape
    alloc_off = alloc_off.astype(dt).reshape(1, dp)
    params = jnp.stack([
        jnp.asarray(c_flops, dt), jnp.asarray(c_speed, dt),
        jnp.asarray(c_tp, dt), jnp.asarray(pp_vol, dt),
        jnp.asarray(c_dp, dt), jnp.zeros((), dt),
    ]).reshape(1, 6)
    vec = pl.BlockSpec(memory_space=_VMEM) if _VMEM is not None \
        else pl.BlockSpec()
    smem = pl.BlockSpec(memory_space=_SMEM) if _SMEM is not None \
        else pl.BlockSpec()
    return pl.pallas_call(
        _reduce_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), dt),      # iteration time
            jax.ShapeDtypeStruct((1, dp), dt),     # stage_max
            jax.ShapeDtypeStruct((pp, dp), dt),    # tp_bw
            jax.ShapeDtypeStruct((pp, tp), dt),    # dp_bw
        ),
        in_specs=[smem] + [vec] * 5,
        out_specs=(vec,) * 4,
        interpret=interpret,
    )(params, cell_speed, tp_edge, dp_edge, hop_bw, alloc_off)


@jax.jit
def cell_reduce_reference(
    cell_speed, tp_edge, dp_edge, hop_bw, alloc_off,
    c_flops, c_speed, c_tp, pp_vol, c_dp,
):
    """The kernel's math as a plain traced function (no ``pallas_call``) —
    the bit-match oracle for interpret-mode parity tests."""
    dt = cell_speed.dtype
    pp, dp, tp = tp_edge.shape
    alloc_off = alloc_off.astype(dt).reshape(1, dp)
    return _fused_reduce(
        cell_speed, tp_edge, dp_edge, hop_bw, alloc_off,
        jnp.asarray(c_flops, dt), jnp.asarray(c_speed, dt),
        jnp.asarray(c_tp, dt), jnp.asarray(pp_vol, dt),
        jnp.asarray(c_dp, dt),
    )
