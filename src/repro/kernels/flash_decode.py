"""Pallas TPU flash-decode: one query token against a long KV cache.

Decode attention is HBM-bound — the whole cache streams through VMEM once
per generated token — so the kernel's job is (a) to keep that streaming at
full HBM bandwidth with MXU-aligned (block_k × hd) tiles and (b) to split
the cache into parallel chunks whose partial softmaxes are combined with the
log-sum-exp trick (the same math the seq-sharded cache layout relies on
across devices; here applied within one device).

Grid: (batch, kv_heads, Skv/block_k). The innermost (KV) dimension is
sequential on TPU, so the (rep, hd) accumulator — all GQA query heads of
one KV head — lives in VMEM scratch across KV steps. ``valid_len`` masks
positions beyond the current decode position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, block_k: int, rep: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    valid = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (rep, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (rep, bk)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,  # (B, H, hd) — ONE query token per sequence
    k: jax.Array,  # (B, Skv, KVH, hd) cache
    v: jax.Array,
    valid_len: jax.Array,  # () or (B,) int32: positions < valid_len attend
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = hd**-0.5
    block_k = min(block_k, skv)

    qt = q.reshape(b, kvh, rep, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, KVH, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    pad_k = (-skv) % block_k
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = kt.shape[2] // block_k

    lens = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))

    kernel = functools.partial(
        _kernel, scale=scale, block_k=block_k, rep=rep
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1,),
                         index_map=lambda bi, hi, ki: (bi,)),
            pl.BlockSpec((1, 1, rep, hd), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(b, h, hd)
