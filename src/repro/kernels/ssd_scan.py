"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Tiling: grid = (batch, heads, n_chunks); the innermost (chunk) grid axis is
sequential on TPU, so the running inter-chunk state (P x N, f32) lives in a
VMEM scratch buffer that persists across chunk steps — the recurrence never
round-trips to HBM. Per step, the kernel evaluates the SSD *dual* form for
one (batch, head, chunk) tile: three MXU matmuls (C·Bᵀ masked-decay score,
intra-chunk output, inter-chunk output) plus the rank-1 state update.

Chunk length Q and head dim P default to 128 to match the MXU; state dim N
is the model's (16 or 128 here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (1, 1, Q, P)
    dt_ref,  # (1, 1, Q)
    a_ref,  # (1,)
    b_ref,  # (1, 1, Q, N)
    c_ref,  # (1, 1, Q, N)
    y_ref,  # (1, 1, Q, P)
    state_out_ref,  # (1, 1, P, N)
    state_scr,  # VMEM (P, N) f32
    *, chunk: int,
):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0].astype(jnp.float32)  # scalar (negative)
    b = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)  # (Q, N)

    da = dt * a  # (Q,)
    cum = jnp.cumsum(da)  # (Q,)

    # Intra-chunk dual form: y_intra = ((C Bᵀ) ⊙ L ⊙ dt_k) x
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(rows >= cols, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    m = scores * l_mat * dt[None, :]
    y = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # Inter-chunk: y += diag(exp(cum)) C S_prev
    s_prev = state_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, s_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update: S = S * exp(cum[-1]) + Σ_q exp(cum[-1]-cum_q) dt_q x_q b_qᵀ
    w = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    xw = x * w[:, None]  # (Q, P)
    state_scr[...] = s_prev * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_scr[...].astype(state_out_ref.dtype)


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,)
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pallas SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk

    xt = x.transpose(0, 2, 1, 3)  # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)  # (B, H, S)
    bt = b_mat.transpose(0, 2, 1, 3)  # (B, G, S, N)
    ct = c_mat.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, chunk=chunk)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec(
                (1, 1, chunk, n), lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0)
            ),
            pl.BlockSpec(
                (1, 1, chunk, n), lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a, bt, ct)
    return y.transpose(0, 2, 1, 3), final_state
