"""Pallas TPU flash attention (blocked online-softmax), GQA + sliding window.

Tiling: grid = (batch, q_heads, Sq/block_q, Skv/block_k); the innermost
(KV) grid dimension is sequential on TPU, so the online-softmax accumulators
(m, l, acc) live in VMEM scratch and persist across KV steps. Q/K/V tiles
are staged HBM->VMEM by BlockSpec; block sizes default to 128 to align with
the MXU (128x128) and the f32 VREG lane layout.

Causal + sliding-window masking is applied per tile with 2D iota; fully
masked tiles are skipped via ``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int,
    block_q: int, block_k: int, seq_kv: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Tile-level skip: causal => no work if the whole tile is above the
    # diagonal; sliding window => no work if the tile is entirely outside.
    run = jnp.bool_(True)
    if causal:
        run &= q_start + block_q - 1 >= k_start
    if window:
        run &= q_start < k_start + block_k + window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < seq_kv
        if causal:
            mask &= rows >= cols
        if window:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = hd**-0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)

    qt = q.transpose(0, 2, 1, 3)  # (B, H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, KVH, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_kv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    if pad_q:
        out = out[:, :, :sq, :]
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hd)
