"""Fused BatchedBOCD step kernel (Pallas) — one launch per fleet tick.

The numpy :class:`repro.core.bocd.BatchedBOCD` advances the run-length
posterior of B streams with ~15 separate (K, B) array passes per tick
(predictive, normalize, Normal-Gamma update, truncate, frontier kill,
renormalize). This module fuses the whole step — predict / update /
truncate, including the shared ``max_hypotheses`` frontier as an in-kernel
threshold + victim-selection pass — into a single Pallas kernel launch
over the entire (K, B) state, so on a compiled backend every pass runs out
of VMEM with no HBM round-trips between them.

Fixed-slot frontier
-------------------
``BatchedBOCD`` stores a *growing* list of hypothesis rows and compacts /
kills rows per tick; a kernel needs static shapes. The slot model used
here is provably step-equivalent: keep exactly ``K = max_hypotheses`` rows
("slots"), and each tick overwrite the **victim** slot — the row with the
lowest shared strength ``max_b log_r[k, b]``, ties broken on smallest run
length, then smallest slot index — with the new ``r = 0`` hypothesis.
Fully-dead rows (all columns ``-inf``, the state BatchedBOCD compacts
away) have strength ``-inf`` and are recycled first, so below the cap no
live hypothesis is ever evicted; at the cap the evicted row is exactly the
one BatchedBOCD's stable argsort kills (its rows are rl-ascending, so
"smallest index" == "smallest run length"). The one intended difference:
the kernel renormalizes every column after the kill, where BatchedBOCD
renormalizes only affected columns — a ``log(1) ~ 0`` shift that moves
untouched columns by at most a few ulp (see docs/kernels.md for the
tolerance policy; NaN inputs additionally perturb victim choice, which the
numpy path leaves to argsort's NaN ordering).

``bocd_step`` (the Pallas launch) and ``bocd_step_reference`` (the same
traced math without ``pallas_call``) share one step function, so
interpret-mode kernel output is bit-identical to the reference by
construction — the parity tests assert exact equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent members are fine on the interpret path.
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover - non-TPU pallas builds
    pltpu = None
    _VMEM = _SMEM = None

from repro.core.bocd import DEFAULT_CP_THRESHOLD, _logsumexp_cols

#: default frontier when the caller passes ``max_hypotheses=None`` — the
#: fixed-slot kernel needs *some* static K (uncapped growth is a
#: numpy-backend feature; 64 comfortably covers the fleet screen's caps).
DEFAULT_SLOTS = 64


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _fused_step(
    x, log_r, mu, beta, kappa, alpha, rl, tconst, mu0,
    log_h, log_1mh, log_trunc, kappa0, alpha0, beta0, cp_const,
):
    """One fused BOCD step over (K, B) fixed-slot state (pure jnp).

    Shapes: ``x``/``mu0`` (1, B); ``log_r``/``mu``/``beta`` (K, B);
    ``kappa``/``alpha``/``tconst`` (K, 1); ``rl`` (K, 1) int32. Scalars are
    0-d arrays. Returns the updated state tuple plus ``p0`` (1, B) =
    Pr(r_t = 0) per stream.
    """
    dt = log_r.dtype
    k_slots = log_r.shape[0]
    # Growth: Student-t posterior predictive per slot (gammaln terms are
    # precomputed outside the kernel into tconst — Mosaic has no lgamma).
    df = 2.0 * alpha
    scale2 = beta * ((kappa + 1.0) / (alpha * kappa))
    z2 = (x - mu) ** 2 / scale2 / df
    logpred = tconst - 0.5 * jnp.log(jnp.pi * df * scale2)
    logpred -= 0.5 * (df + 1.0) * jnp.log1p(z2)
    growth = logpred + log_r + log_1mh  # dead (-inf) slots stay dead
    # Change-point row: x scored under the fresh-segment prior.
    df0 = 2.0 * alpha0
    s20 = beta0 * (kappa0 + 1.0) / (alpha0 * kappa0)
    z20 = (x - mu0) ** 2 / s20 / df0
    cp = cp_const - 0.5 * jnp.log(jnp.pi * df0 * s20)
    cp -= 0.5 * (df0 + 1.0) * jnp.log1p(z20)
    cp = cp + log_h
    # Normalize over the K + 1 conceptual rows (K grown slots + cp row).
    m = jnp.maximum(jnp.max(growth, axis=0, keepdims=True), cp)
    shift = jnp.where(jnp.isfinite(m), m, jnp.zeros((), dt))
    tot = jnp.sum(jnp.exp(growth - shift), axis=0, keepdims=True)
    tot += jnp.exp(cp - shift)
    lse = jnp.log(tot) + shift
    growth = growth - lse
    cp = cp - lse
    # Per-column mass truncation (the cp row is exempt, like numpy).
    neg_inf = jnp.asarray(-jnp.inf, dt)
    growth = jnp.where(growth <= log_trunc, neg_inf, growth)
    # Victim slot = lowest shared strength, ties -> smallest run length,
    # then smallest slot index. NaN strengths (NaN observations) are
    # treated as +inf so a poisoned column never hijacks the frontier.
    strength = jnp.max(growth, axis=1, keepdims=True)
    key = jnp.where(jnp.isnan(strength), jnp.asarray(jnp.inf, dt), strength)
    smin = jnp.min(key)
    rl_f = rl.astype(dt)
    tie = key == smin
    rmin = jnp.min(jnp.where(tie, rl_f, jnp.asarray(jnp.inf, dt)))
    victim = tie & (rl_f == rmin)
    rows = jax.lax.broadcasted_iota(jnp.int32, (k_slots, 1), 0)
    first = jnp.min(jnp.where(victim, rows, k_slots))
    victim = rows == first  # (K, 1) one-hot
    # Normal-Gamma update: survivors advance their posterior; the victim
    # slot restarts from the prior and absorbs x as its first observation.
    kap = jnp.where(victim, kappa0, kappa)
    alp = jnp.where(victim, alpha0, alpha)
    mu_b = jnp.where(victim, mu0, mu)
    beta_b = jnp.where(victim, beta0, beta)
    denom = kap + 1.0
    beta_out = beta_b + 0.5 * kap * (x - mu_b) ** 2 / denom
    mu_out = (kap * mu_b + x) / denom
    alpha_out = alp + 0.5
    rl_out = jnp.where(victim, 0, rl + 1)
    log_r_new = jnp.where(victim, cp, growth)
    # Renormalize (all columns — see module docstring re: tolerance).
    m2 = jnp.max(log_r_new, axis=0, keepdims=True)
    shift2 = jnp.where(jnp.isfinite(m2), m2, jnp.zeros((), dt))
    lse2 = jnp.log(jnp.sum(jnp.exp(log_r_new - shift2), axis=0,
                           keepdims=True)) + shift2
    log_r_out = log_r_new - lse2
    p0 = jnp.sum(jnp.where(victim, jnp.exp(log_r_out), jnp.zeros((), dt)),
                 axis=0, keepdims=True)
    return log_r_out, mu_out, beta_out, denom, alpha_out, rl_out, p0


def _step_kernel(
    params_ref, x_ref, log_r_ref, mu_ref, beta_ref, kappa_ref, alpha_ref,
    rl_ref, tconst_ref, mu0_ref,
    log_r_out, mu_out, beta_out, kappa_out, alpha_out, rl_out, p0_out,
):
    p = params_ref
    outs = _fused_step(
        x_ref[:], log_r_ref[:], mu_ref[:], beta_ref[:], kappa_ref[:],
        alpha_ref[:], rl_ref[:], tconst_ref[:], mu0_ref[:],
        log_h=p[0, 0], log_1mh=p[0, 1], log_trunc=p[0, 2],
        kappa0=p[0, 3], alpha0=p[0, 4], beta0=p[0, 5], cp_const=p[0, 6],
    )
    for ref, val in zip(
        (log_r_out, mu_out, beta_out, kappa_out, alpha_out, rl_out, p0_out),
        outs,
    ):
        ref[:] = val


def _prep(x, log_r, alpha, mu0, hazard, alpha0, truncation):
    """Shared launch prologue: scalar params + the gammaln constants the
    kernel can't compute (Mosaic has no lgamma)."""
    dt = log_r.dtype
    gammaln = jax.scipy.special.gammaln
    df = 2.0 * alpha.astype(dt)
    tconst = gammaln((df + 1.0) / 2.0) - gammaln(df / 2.0)
    a0 = jnp.asarray(alpha0, dt)
    cp_const = gammaln((2.0 * a0 + 1.0) / 2.0) - gammaln(a0)
    hz = jnp.asarray(hazard, dt)
    log_h = jnp.log(hz)
    log_1mh = jnp.log1p(-hz)
    log_trunc = jnp.log(jnp.asarray(truncation, dt))
    x = x.astype(dt).reshape(1, -1)
    mu0 = mu0.astype(dt).reshape(1, -1)
    return x, mu0, tconst, log_h, log_1mh, log_trunc, cp_const


@functools.partial(jax.jit, static_argnames=("interpret",))
def bocd_step(
    x, log_r, mu, beta, kappa, alpha, rl, mu0,
    hazard, kappa0=1.0, alpha0=1.0, beta0=1.0, truncation=1e-6,
    *, interpret=None,
):
    """One fused step as a single ``pallas_call`` launch.

    State dtypes/shapes as in :func:`_fused_step`; ``hazard`` may be a
    traced scalar (retunes don't recompile). Returns
    ``(log_r, mu, beta, kappa, alpha, rl, p0)``.
    """
    if interpret is None:
        interpret = _interpret_default()
    dt = log_r.dtype
    k_slots, b = log_r.shape
    x, mu0, tconst, log_h, log_1mh, log_trunc, cp_const = _prep(
        x, log_r, alpha, mu0, hazard, alpha0, truncation
    )
    params = jnp.stack([
        log_h, log_1mh, log_trunc,
        jnp.asarray(kappa0, dt), jnp.asarray(alpha0, dt),
        jnp.asarray(beta0, dt), cp_const, jnp.zeros((), dt),
    ]).reshape(1, 8)
    vec = pl.BlockSpec(memory_space=_VMEM) if _VMEM is not None \
        else pl.BlockSpec()
    smem = pl.BlockSpec(memory_space=_SMEM) if _SMEM is not None \
        else pl.BlockSpec()
    return pl.pallas_call(
        _step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k_slots, b), dt),   # log_r
            jax.ShapeDtypeStruct((k_slots, b), dt),   # mu
            jax.ShapeDtypeStruct((k_slots, b), dt),   # beta
            jax.ShapeDtypeStruct((k_slots, 1), dt),   # kappa
            jax.ShapeDtypeStruct((k_slots, 1), dt),   # alpha
            jax.ShapeDtypeStruct((k_slots, 1), jnp.int32),  # rl
            jax.ShapeDtypeStruct((1, b), dt),          # p0
        ),
        in_specs=[smem] + [vec] * 9,
        out_specs=(vec,) * 7,
        interpret=interpret,
    )(params, x, log_r, mu, beta, kappa, alpha, rl, tconst, mu0)


@jax.jit
def bocd_step_reference(
    x, log_r, mu, beta, kappa, alpha, rl, mu0,
    hazard, kappa0=1.0, alpha0=1.0, beta0=1.0, truncation=1e-6,
):
    """The kernel's math as a plain traced function (no ``pallas_call``) —
    the bit-match oracle for interpret-mode parity tests."""
    dt = log_r.dtype
    x, mu0, tconst, log_h, log_1mh, log_trunc, cp_const = _prep(
        x, log_r, alpha, mu0, hazard, alpha0, truncation
    )
    return _fused_step(
        x, log_r, mu, beta, kappa, alpha, rl, tconst, mu0,
        log_h, log_1mh, log_trunc,
        jnp.asarray(kappa0, dt), jnp.asarray(alpha0, dt),
        jnp.asarray(beta0, dt), cp_const,
    )


class PallasBOCD:
    """Fixed-slot batched BOCD screening backend driven by the fused kernel.

    Drop-in for :class:`repro.core.bocd.BatchedBOCD` behind the
    ``ScreeningBackend`` interface (``update`` / ``p_recent_change`` /
    ``map_runlength`` / ``take_columns`` / ``retune``). State lives as jax
    arrays and advances one kernel launch per tick; posterior statistics
    are read back to numpy on demand.

    ``dtype`` defaults to float32 (the accelerator-native width — see
    docs/kernels.md for the documented tolerance vs the float64 numpy
    oracle); pass ``jnp.float64`` with jax x64 enabled for tight-parity
    testing. ``interpret`` defaults to auto (True on CPU jax). The whole
    (K, B) state must fit in VMEM on a compiled backend: at the default 32
    slots and float32 that bounds B at roughly 30k streams per instance —
    shard wider fleets across instances (cohorts already do).
    """

    def __init__(
        self,
        n_series: int,
        hazard: float = 1.0 / 100.0,
        mu0: float | np.ndarray = 0.0,
        kappa0: float = 1.0,
        alpha0: float = 1.0,
        beta0: float = 1.0,
        cp_threshold: float = DEFAULT_CP_THRESHOLD,
        truncation: float = 1e-6,
        max_hypotheses: int | None = 32,
        *,
        dtype=jnp.float32,
        interpret: bool | None = None,
    ) -> None:
        b = int(n_series)
        k = DEFAULT_SLOTS if max_hypotheses is None else int(max_hypotheses)
        if k < 2:
            raise ValueError("PallasBOCD needs at least 2 hypothesis slots")
        self.n_series = b
        self.hazard = float(hazard)
        self.kappa0 = float(kappa0)
        self.alpha0 = float(alpha0)
        self.beta0 = float(beta0)
        self.cp_threshold = float(cp_threshold)
        self.truncation = float(truncation)
        self.max_hypotheses = k
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        mu0 = np.broadcast_to(np.asarray(mu0, dtype=np.float64), (b,))
        self._mu0 = jnp.asarray(mu0, self.dtype)
        # Slot 0 holds the prior hypothesis; slots 1..K-1 start dead
        # (-inf mass) and are recycled as the frontier fills.
        log_r = np.full((k, b), -np.inf)
        log_r[0] = 0.0
        self._log_r = jnp.asarray(log_r, self.dtype)
        self._mu = jnp.broadcast_to(self._mu0[None, :], (k, b)).astype(
            self.dtype
        )
        self._beta = jnp.full((k, b), beta0, self.dtype)
        self._kappa = jnp.full((k, 1), kappa0, self.dtype)
        self._alpha = jnp.full((k, 1), alpha0, self.dtype)
        self._rl = jnp.zeros((k, 1), jnp.int32)
        self._t = 0

    # -- ScreeningBackend interface ------------------------------------
    @property
    def n_hypotheses(self) -> int:
        return int(np.isfinite(np.asarray(self._log_r)).any(axis=1).sum())

    def update(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.n_series,):
            raise ValueError(f"expected shape ({self.n_series},), got {x.shape}")
        (self._log_r, self._mu, self._beta, self._kappa, self._alpha,
         self._rl, p0) = bocd_step(
            jnp.asarray(x, self.dtype), self._log_r, self._mu, self._beta,
            self._kappa, self._alpha, self._rl, self._mu0,
            self.hazard, self.kappa0, self.alpha0, self.beta0,
            self.truncation, interpret=self.interpret,
        )
        self._t += 1
        return np.asarray(p0[0], dtype=np.float64)

    def p_recent_change(self, window: int = 2) -> np.ndarray:
        lr = np.asarray(self._log_r, dtype=np.float64)
        recent = np.asarray(self._rl)[:, 0] <= window
        if not recent.any():
            return np.zeros(self.n_series)
        return np.exp(_logsumexp_cols(lr[recent]))

    def map_runlength(self) -> np.ndarray:
        lr = np.asarray(self._log_r)
        rl = np.asarray(self._rl)[:, 0].astype(np.int64)
        return rl[np.argmax(lr, axis=0)]

    def take_columns(self, idx: np.ndarray) -> None:
        idx = jnp.asarray(np.asarray(idx, dtype=np.int64))
        self.n_series = int(idx.size)
        self._mu0 = self._mu0[idx]
        self._log_r = self._log_r[:, idx]
        self._mu = self._mu[:, idx]
        self._beta = self._beta[:, idx]

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        if hazard is not None:
            self.hazard = float(hazard)
        if max_hypotheses is None or max_hypotheses == self.max_hypotheses:
            return
        # Resize the slot frontier: keep the strongest rows (ties to the
        # smallest run length / slot, like the per-tick victim rule), pad
        # with dead slots when growing.
        k_new = int(max_hypotheses)
        lr = np.asarray(self._log_r, dtype=np.float64)
        k, b = lr.shape
        if k_new < k:
            strength = np.where(
                np.isnan(lr).any(axis=1), -np.inf, np.max(lr, axis=1)
            )
            rl = np.asarray(self._rl)[:, 0]
            order = np.lexsort((np.arange(k), -rl, -strength))
            keep = np.sort(order[:k_new])
            sel = jnp.asarray(keep)
            self._log_r = self._log_r[sel]
            self._mu = self._mu[sel]
            self._beta = self._beta[sel]
            self._kappa = self._kappa[sel]
            self._alpha = self._alpha[sel]
            self._rl = self._rl[sel]
        elif k_new > k:
            pad = k_new - k
            self._log_r = jnp.concatenate(
                [self._log_r, jnp.full((pad, b), -jnp.inf, self.dtype)]
            )
            self._mu = jnp.concatenate(
                [self._mu, jnp.zeros((pad, b), self.dtype)]
            )
            self._beta = jnp.concatenate(
                [self._beta, jnp.full((pad, b), self.beta0, self.dtype)]
            )
            self._kappa = jnp.concatenate(
                [self._kappa, jnp.full((pad, 1), self.kappa0, self.dtype)]
            )
            self._alpha = jnp.concatenate(
                [self._alpha, jnp.full((pad, 1), self.alpha0, self.dtype)]
            )
            self._rl = jnp.concatenate(
                [self._rl, jnp.zeros((pad, 1), jnp.int32)]
            )
        self.max_hypotheses = k_new
