"""Checkpointing: in-memory (the paper's fast 'M' variant used by topology
adjustment) and disk ('D' baseline, used by S4 checkpoint-and-restart).

Pytrees are flattened to path-keyed arrays; disk format is a single .npz.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bfloat16: store the raw bits (restore casts back
            # using the target pytree's leaf dtype).
            arr = arr.view(np.uint16)
            key += "::bf16"
        flat[key] = arr
    return flat


@dataclass
class CheckpointManager:
    directory: str

    _memory: dict | None = field(init=False, default=None)
    last_save_time: float = field(init=False, default=0.0)
    last_restore_time: float = field(init=False, default=0.0)

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)

    # ---- memory (paper's M: dump params into host memory, swap via RDMA)
    def save_memory(self, tree) -> float:
        t0 = time.monotonic()
        self._memory = jax.tree.map(lambda x: np.asarray(x), tree)
        self.last_save_time = time.monotonic() - t0
        return self.last_save_time

    def restore_memory(self, like=None) -> dict:
        assert self._memory is not None, "no in-memory checkpoint"
        t0 = time.monotonic()
        out = jax.tree.map(jnp.asarray, self._memory)
        self.last_restore_time = time.monotonic() - t0
        return out

    # ---- disk (baseline D)
    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save_disk(self, tree, step: int) -> float:
        t0 = time.monotonic()
        np.savez(self.path(step), **_flatten(tree))
        self.last_save_time = time.monotonic() - t0
        return self.last_save_time

    def restore_disk(self, like, step: int) -> dict:
        t0 = time.monotonic()
        with np.load(self.path(step)) as data:
            flat = dict(data)
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        out_leaves = []
        for path, leaf in leaves_with_path:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            if key + "::bf16" in flat:
                raw = flat[key + "::bf16"].view(jnp.bfloat16)
                arr = jnp.asarray(raw).astype(leaf.dtype)
            else:
                arr = jnp.asarray(flat[key]).astype(leaf.dtype)
            out_leaves.append(arr)
        self.last_restore_time = time.monotonic() - t0
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    def latest_step(self) -> int | None:
        steps = [
            int(f[5:13])
            for f in os.listdir(self.directory)
            if f.startswith("ckpt_") and f.endswith(".npz")
        ]
        return max(steps) if steps else None
