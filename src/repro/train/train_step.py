"""Micro-batched training steps.

Two variants:

* ``make_train_step`` — fixed even micro-batching: ``lax.scan`` over the
  slot axis accumulating gradients, then AdamW. This is what the multi-pod
  dry-run lowers (the roofline baseline).

* ``make_adaptive_train_step`` — the FALCON S2-integrated step: a
  ``jax.shard_map`` manual over the DP axes (model axis left auto for
  GSPMD) runs a ``lax.while_loop`` whose trip count is each DP group's
  *own* micro-batch allocation ``m_i``, so slow groups genuinely execute
  fewer micro-batches inside one SPMD program. Gradients are combined with
  the paper's weighted aggregation: sum of per-micro-batch gradients psum'd
  over DP and divided by the global micro-batch count. Model-axis
  collectives stay consistent because every member of a model group shares
  the same DP index, hence the same trip count.

Batch layout: ``(slots, global_microbatch, S, ...)`` — see data/pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.sharding import partition


def _microbatch_loss(params, mb, cfg: ArchConfig, use_kernel: bool):
    return model_lib.loss_fn(params, mb, cfg, use_kernel=use_kernel)


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    use_kernel: bool = False,
) -> Callable:
    """Even micro-batching: scan over all slots."""

    def train_step(params, opt_state, batch):
        slots = jax.tree.leaves(batch)[0].shape[0]

        def body(carry, i):
            gsum, lsum = carry
            mb = _take_slot(batch, i, cfg)
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: _microbatch_loss(p, mb, cfg, use_kernel), has_aux=True
            )(params)
            return (jax.tree.map(jnp.add, gsum, grads), lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), jnp.arange(slots))
        grads = jax.tree.map(lambda g: g / slots, gsum)
        params, opt_state = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": lsum / slots}

    return train_step


def _take_slot(batch: dict, i, cfg: ArchConfig) -> dict:
    out = {}
    for k, v in batch.items():
        if k == "positions":  # (3, B, S) — shared across slots
            out[k] = v
        else:
            out[k] = jax.lax.dynamic_index_in_dim(v, i, axis=0, keepdims=False)
    return out


def make_adaptive_train_step(
    cfg: ArchConfig,
    opt_cfg: adamw.AdamWConfig,
    mesh: Mesh,
    *,
    use_kernel: bool = False,
) -> Callable:
    """FALCON S2 step: per-DP-group dynamic trip counts + weighted grads."""
    ba = partition.batch_axes(mesh)

    def grad_fn(params, batch, counts):
        m = counts[0]  # local DP group's allocation

        def cond(carry):
            return carry[0] < m

        def body(carry):
            i, gsum, lsum = carry
            mb = _take_slot(batch, i, cfg)
            (loss, _), grads = jax.value_and_grad(
                lambda p: _microbatch_loss(p, mb, cfg, use_kernel), has_aux=True
            )(params)
            return (i + 1, jax.tree.map(jnp.add, gsum, grads), lsum + loss)

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        _, gsum, lsum = jax.lax.while_loop(
            cond, body, (jnp.int32(0), g0, jnp.float32(0))
        )
        # Weighted gradient aggregation (paper §5.3 / ref [5]): each group
        # contributes its gradient *sum*; dividing by the global micro-batch
        # count gives weights m_i / M.
        gsum = jax.lax.psum(gsum, ba)
        total = jax.lax.psum(m, ba).astype(jnp.float32)
        grads = jax.tree.map(lambda g: g / total, gsum)
        loss = jax.lax.psum(lsum, ba) / total
        return grads, loss

    def batch_in_specs(batch_spec_tree):
        # shard_map is manual only over the DP axes: drop other axis names.
        keep = set(ba)

        def strip(spec: P) -> P:
            out = []
            for s in spec:
                if s is None:
                    out.append(None)
                elif isinstance(s, tuple):
                    t = tuple(a for a in s if a in keep)
                    out.append(t if t else None)
                else:
                    out.append(s if s in keep else None)
            return P(*out)

        return jax.tree.map(strip, batch_spec_tree, is_leaf=lambda x: isinstance(x, P))

    bspecs = batch_in_specs(partition.train_batch_specs(cfg, mesh))
    param_specs0 = jax.tree.map(
        lambda _: P(), model_lib.param_shapes(cfg)
    )  # params replicated over DP axes (model axis stays auto)

    sharded_grad = compat.shard_map_compat(
        grad_fn,
        mesh=mesh,
        in_specs=(param_specs0, bspecs, P(ba)),
        out_specs=(param_specs0, P()),
        axis_names=frozenset(ba),
    )

    def train_step(params, opt_state, batch, counts):
        grads, loss = sharded_grad(params, batch, counts)
        params, opt_state = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step
