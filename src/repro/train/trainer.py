"""Training loop with FALCON integrated as a first-class runtime feature.

The trainer executes *real* JAX training steps (params genuinely update) and
feeds FALCON an iteration-time signal. On real hardware that signal is the
measured step time; on this CPU container, fail-slows are modeled by an
attached :class:`TrainingSimulator` + :class:`FailSlowInjector` (the same
cluster performance model used in the paper-reproduction benchmarks), so
detection and mitigation operate on honest dynamics while the numerics stay
real. DESIGN.md §2 documents this split.

Mitigation wiring:
  * S1 ignore            -> bookkeeping only.
  * S2 micro-batch       -> ``core.microbatch.solve_allocation`` from the
    profiled per-group speeds; applied to the adaptive train step's trip
    counts AND to the simulator.
  * S3 topology          -> ``core.topology.plan_topology_adjustment`` /
    ``consolidate_stragglers``; applied to the simulator placement; the
    runtime analogue (mesh device permutation + state re-put) is exposed as
    ``remap_mesh`` for multi-device runs.
  * S4 ckpt-and-restart  -> in-memory checkpoint restore + simulator restart,
    charging the measured restore overhead.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.injector import FailSlowInjector
from repro.cluster.simulator import TrainingSimulator
from repro.configs.base import ArchConfig
from repro.core import microbatch as mb_lib
from repro.core import topology as topo_lib
from repro.core.detector import FalconDetect
from repro.core.events import CommOp, RootCause, Strategy
from repro.core.monitor import Monitor
from repro.core.planner import DEFAULT_OVERHEADS, MitigationPlanner
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import train_step as ts_lib
from repro.train.checkpoint import CheckpointManager


@dataclass
class StepRecord:
    step: int
    loss: float
    iter_time: float
    wall_time: float
    strategy: str | None = None


@dataclass
class FalconTrainer:
    cfg: ArchConfig
    data: DataConfig
    opt_cfg: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    #: cluster performance model supplying iteration times (+ fail-slows)
    perf_model: TrainingSimulator | None = None
    injector: FailSlowInjector | None = None
    falcon_enabled: bool = True
    overheads: dict = field(default_factory=lambda: dict(DEFAULT_OVERHEADS))
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0

    params: dict = field(init=False)
    opt_state: adamw.AdamWState = field(init=False)
    monitor: Monitor = field(init=False)
    detector: FalconDetect | None = field(init=False, default=None)
    planner: MitigationPlanner | None = field(init=False, default=None)
    history: list[StepRecord] = field(init=False, default_factory=list)
    allocation: list[int] = field(init=False)
    _wall: float = field(init=False, default=0.0)

    def __post_init__(self) -> None:
        self.params = model_lib.init_params(self.cfg, self.seed)
        self.opt_state = adamw.init(self.params)
        self.monitor = Monitor()
        self.ckpt = CheckpointManager(self.ckpt_dir)
        self.allocation = [self.data.slots] * self.data.dp_groups
        if self.perf_model is not None:
            self.detector = FalconDetect(cluster=self.perf_model, verify_window=8)
        self._step_fn = jax.jit(
            ts_lib.make_train_step(self.cfg, self.opt_cfg)
        )

    # ------------------------------------------------------------------
    def _observed_iter_time(self, measured: float, now: float) -> float:
        if self.perf_model is None:
            return measured
        if self.injector is not None:
            self.injector.apply(self.perf_model.state, now)
        return self.perf_model.iteration_time()

    def _apply_strategy(self, strategy: Strategy, event) -> None:
        sim = self.perf_model
        if strategy is Strategy.IGNORE or sim is None:
            return
        if strategy is Strategy.ADJUST_MICROBATCH:
            times = sim.per_microbatch_times()
            counts = mb_lib.solve_allocation(
                times, sim.job.micro_batches, offset=sim.job.pp - 1
            )
            sim.set_allocation(counts)
            if len(counts) == self.data.dp_groups:
                self.allocation = list(counts)
        elif strategy is Strategy.ADJUST_TOPOLOGY:
            self._adjust_topology(event)
        elif strategy is Strategy.CKPT_AND_RESTART:
            # In-memory checkpoint restore (fast path, Fig. 19 'M').
            self.ckpt.save_memory(self.params)
            self.params = self.ckpt.restore_memory()
            sim.restart()
            if self.injector is not None:
                # Restart lands on healthy nodes: clear active injections.
                self.injector.injections = [
                    i for i in self.injector.injections if not i.active(self._wall)
                ]
            self.allocation = [self.data.slots] * self.data.dp_groups

    def _rebalance(self) -> None:
        """Post-relief: recompute the micro-batch split from the (now
        healthy) profile so a skewed S2 allocation doesn't outlive the
        fail-slow it compensated for."""
        sim = self.perf_model
        if sim is None:
            return
        counts = mb_lib.solve_allocation(
            sim.per_microbatch_times(), sim.job.micro_batches,
            offset=sim.job.pp - 1,
        )
        sim.set_allocation(counts)
        if len(counts) == self.data.dp_groups:
            self.allocation = list(counts)

    def _adjust_topology(self, event) -> None:
        """Apply a placement adjustment, keeping it only if the modeled
        iteration time improves — mitigation effects are re-measured before
        being committed (a blind consolidation can re-expose a congested
        link the previous targeted swap had evacuated)."""
        sim = self.perf_model
        before_placement = list(sim.placement)
        before_t = sim.iteration_time()
        self._plan_and_apply_topology(event)
        if sim.iteration_time() > before_t * 0.999:
            sim.placement = before_placement  # revert: no improvement

    def _plan_and_apply_topology(self, event) -> None:
        sim = self.perf_model
        job, topo = sim.job, sim.job.topology
        stragglers = [
            int(c.split(":")[1]) for c in event.components if c.startswith("gpu:")
        ]
        slow_links = [
            tuple(int(x) for x in c.split(":")[1].split("-"))
            for c in event.components
            if c.startswith("link:")
        ]
        if stragglers and not slow_links and topo.pp > 1:
            # Straggler consolidation (Fig. 11): pack the positions hosting
            # slow devices into the fewest PP stages.
            pos = [p for p, d in enumerate(sim.placement) if d in set(stragglers)]
            perm = topo_lib.consolidate_stragglers(pos, topo)
            sim.apply_placement(perm)
            return
        m = job.model
        traffic = topo_lib.build_traffic_matrix(
            topo,
            comm_tp=m.comm_tp_bytes(job.tp, job.pp, job.micro_batches),
            comm_dp=m.comm_dp_bytes(job.tp, job.pp),
            comm_pp=m.comm_pp_bytes(job.micro_batches),
        )
        n = job.n_devices
        bw = np.full((n, n), np.inf)
        for i in range(n):
            for j in range(n):
                if i != j:
                    bw[i, j] = sim.state.link_bw(sim.placement[i], sim.placement[j])
        if slow_links:
            # Targeted congestion swap (Fig. 10): FALCON pinpointed the slow
            # physical links; move their endpoints' traffic elsewhere.
            slow_pos = [
                p for p, d in enumerate(sim.placement)
                if any(d in pair for pair in slow_links)
            ]
            perm = topo_lib.plan_targeted_swap(traffic, bw, slow_pos)
        else:
            perm = topo_lib.plan_topology_adjustment(traffic, bw)
        sim.apply_placement(perm)

    # ------------------------------------------------------------------
    def run(self, num_steps: int) -> list[StepRecord]:
        for step in range(num_steps):
            batch = jax.tree.map(
                jnp.asarray, make_batch(self.cfg, self.data, step)
            )
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            measured = time.monotonic() - t0

            iter_time = self._observed_iter_time(measured, self._wall)
            self._wall += iter_time
            for ev in (
                self.perf_model.emit_events(self._wall - iter_time, iter_time)
                if self.perf_model
                else []
            ):
                self.monitor.extend([ev])

            strategy_applied: str | None = None
            if self.falcon_enabled and self.detector is not None:
                had_active = self.detector.active_event is not None
                new_event = self.detector.observe(iter_time, self._wall)
                if new_event is not None:
                    self.planner = MitigationPlanner(new_event, dict(self.overheads))
                active = self.detector.active_event
                if active is None:
                    if had_active:
                        # Relief: re-balance micro-batches for the recovered
                        # cluster (S2 with a healthy profile = even split).
                        self._rebalance()
                        strategy_applied = "REBALANCE"
                    self.planner = None
                elif self.planner is not None:
                    s = self.planner.update(current_time=iter_time)
                    if s is not None:
                        self._apply_strategy(s, active)
                        self._wall += self.overheads.get(s, 0.0)
                        strategy_applied = s.name

            self.history.append(
                StepRecord(
                    step=step,
                    loss=loss,
                    iter_time=iter_time,
                    wall_time=self._wall,
                    strategy=strategy_applied,
                )
            )
        return self.history


# ---------------------------------------------------------------- S3 util
def remap_mesh(mesh, perm: list[int]):
    """Runtime analogue of the paper's node swap: rebuild the mesh with a
    permuted device order (state must be re-`device_put` by the caller)."""
    import numpy as _np
    from jax.sharding import Mesh

    devs = _np.asarray(mesh.devices).reshape(-1)[_np.asarray(perm)]
    return Mesh(devs.reshape(mesh.devices.shape), mesh.axis_names)
