"""Training loop with FALCON integrated as a first-class runtime feature.

The trainer executes *real* JAX training steps (params genuinely update) and
feeds FALCON an iteration-time signal. On real hardware that signal is the
measured step time; on this CPU container, fail-slows are modeled by an
attached :class:`TrainingSimulator` + :class:`FailSlowInjector` (the same
cluster performance model used in the paper-reproduction benchmarks), so
detection and mitigation operate on honest dynamics while the numerics stay
real. DESIGN.md §2 documents this split.

Detection and mitigation run through the control plane
(:mod:`repro.controlplane`): the trainer registers its performance model as
a job and drives :meth:`ControlPlane.observe` once per step; strategy
dispatch goes through the job's
:class:`~repro.controlplane.strategies.StrategyRegistry` (S1 ignore /
S2 micro-batch / S3 topology / S4 ckpt-restart — each one pluggable class).
The trainer's only mitigation role is mirroring results into its JAX-side
state: S2 allocations into the adaptive train step's trip counts, S4 into
an in-memory checkpoint restore; the runtime analogue of S3 (mesh device
permutation + state re-put) is exposed as :func:`remap_mesh` for
multi-device runs. ``FalconTrainer._apply_strategy`` remains as a thin
deprecation shim over the registry.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.cluster.injector import FailSlowInjector
from repro.cluster.simulator import TrainingSimulator
from repro.configs.base import ArchConfig
from repro.controlplane import ControlPlane, MitigationResult
from repro.controlplane.strategies import MitigationContext
from repro.core.detector import FalconDetect
from repro.core.events import Strategy, strategy_label
from repro.core.monitor import Monitor
from repro.core.planner import DEFAULT_OVERHEADS
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import train_step as ts_lib
from repro.train.checkpoint import CheckpointManager


@dataclass
class StepRecord:
    step: int
    loss: float
    iter_time: float
    wall_time: float
    strategy: str | None = None


@dataclass
class FalconTrainer:
    cfg: ArchConfig
    data: DataConfig
    opt_cfg: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    #: cluster performance model supplying iteration times (+ fail-slows)
    perf_model: TrainingSimulator | None = None
    injector: FailSlowInjector | None = None
    falcon_enabled: bool = True
    overheads: dict = field(default_factory=lambda: dict(DEFAULT_OVERHEADS))
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0

    params: dict = field(init=False)
    opt_state: adamw.AdamWState = field(init=False)
    monitor: Monitor = field(init=False)
    control: ControlPlane | None = field(init=False, default=None)
    detector: FalconDetect | None = field(init=False, default=None)
    history: list[StepRecord] = field(init=False, default_factory=list)
    allocation: list[int] = field(init=False)
    _wall: float = field(init=False, default=0.0)

    def __post_init__(self) -> None:
        self.params = model_lib.init_params(self.cfg, self.seed)
        self.opt_state = adamw.init(self.params)
        # The monitor logs on the trainer's simulated wall clock, so comm
        # events and control-plane events share one timebase.
        self.monitor = Monitor(clock=lambda: self._wall)
        self.ckpt = CheckpointManager(self.ckpt_dir)
        self.allocation = [self.data.slots] * self.data.dp_groups
        if self.perf_model is not None:
            self.control = ControlPlane()
            self._job = self.control.register_job(
                "train",
                self.perf_model,
                detector=FalconDetect(cluster=self.perf_model, verify_window=8),
                overheads=dict(self.overheads),
                injector=self.injector,
            )
            self.detector = self._job.detector
        self._step_fn = jax.jit(
            ts_lib.make_train_step(self.cfg, self.opt_cfg)
        )

    @property
    def planner(self):
        """The active event's mitigation planner (None when healthy)."""
        return self._job.planner if self.control is not None else None

    # ------------------------------------------------------------------
    def _observed_iter_time(self, measured: float, now: float) -> float:
        if self.perf_model is None:
            return measured
        if self.injector is not None:
            self.injector.apply(self.perf_model.state, now)
        return self.perf_model.iteration_time()

    def _apply_strategy(self, strategy: Strategy, event) -> None:
        """Deprecated: dispatch through the control-plane strategy registry
        (kept as a shim for pre-control-plane callers)."""
        warnings.warn(
            "FalconTrainer._apply_strategy is deprecated; strategies are "
            "dispatched through repro.controlplane.StrategyRegistry",
            DeprecationWarning,
            stacklevel=2,
        )
        if self.control is None:
            return
        outcome = self._job.registry.dispatch(
            strategy,
            MitigationContext(
                adapter=self.perf_model, event=event, now=self._wall,
                job_id="train", injector=self.injector,
            ),
        )
        self._mirror_result(
            MitigationResult(
                job_id="train", time=self._wall, strategy=strategy,
                applied=outcome.applied, detail=outcome.detail,
            )
        )

    def _mirror_result(self, ev: MitigationResult) -> None:
        """Reflect a strategy's modeled effects into the JAX-side state."""
        counts = ev.detail.get("allocation")
        if counts is not None and len(counts) == self.data.dp_groups:
            self.allocation = list(counts)
        if ev.strategy is Strategy.CKPT_AND_RESTART and ev.applied:
            # In-memory checkpoint restore (fast path, Fig. 19 'M'); the
            # modeled side (simulator restart + injection relief) already
            # ran inside CkptRestartStrategy.
            self.ckpt.save_memory(self.params)
            self.params = self.ckpt.restore_memory()
            self.allocation = [self.data.slots] * self.data.dp_groups

    # ------------------------------------------------------------------
    def run(self, num_steps: int) -> list[StepRecord]:
        for step in range(num_steps):
            batch = jax.tree.map(
                jnp.asarray, make_batch(self.cfg, self.data, step)
            )
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            measured = time.monotonic() - t0

            iter_time = self._observed_iter_time(measured, self._wall)
            self._wall += iter_time
            for ev in (
                self.perf_model.emit_events(self._wall - iter_time, iter_time)
                if self.perf_model
                else []
            ):
                self.monitor.extend([ev])

            strategy_applied: str | None = None
            if self.falcon_enabled and self.control is not None:
                for ev in self.control.observe("train", iter_time, self._wall):
                    if not isinstance(ev, MitigationResult):
                        continue
                    if ev.kind == "relief":
                        # Relief: re-balance micro-batches for the recovered
                        # cluster (S2 with a healthy profile = even split).
                        self._mirror_result(ev)
                        strategy_applied = "REBALANCE"
                    else:
                        self._mirror_result(ev)
                        self._wall += ev.overhead
                        strategy_applied = strategy_label(ev.strategy)

            self.history.append(
                StepRecord(
                    step=step,
                    loss=loss,
                    iter_time=iter_time,
                    wall_time=self._wall,
                    strategy=strategy_applied,
                )
            )
        return self.history


# ---------------------------------------------------------------- S3 util
def remap_mesh(mesh, perm: list[int]):
    """Runtime analogue of the paper's node swap: rebuild the mesh with a
    permuted device order (state must be re-`device_put` by the caller)."""
    import numpy as _np
    from jax.sharding import Mesh

    devs = _np.asarray(mesh.devices).reshape(-1)[_np.asarray(perm)]
    return Mesh(devs.reshape(mesh.devices.shape), mesh.axis_names)
