"""Training runtime: microbatched train step, trainer loop, checkpointing."""
