"""Serving runtime: prefill and single-token decode steps."""
