"""Serve steps: prefill (fill caches, return last-token logits) and decode
(one new token against a seq_len cache) — the shapes the decode dry-runs
lower.

Sliding-window policy: architectures with ``long_context == "sliding"`` use
their configured window for the long_500k decode (sub-quadratic per-token
cost AND bounded attention reads); SSM/hybrid archs run natively.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers, model as model_lib, ssm, transformer


def serve_window(cfg: ArchConfig, seq_len: int) -> int:
    """The attention window used when serving at this context length."""
    if cfg.long_context == "sliding" and cfg.sliding_window and seq_len > 65536:
        return cfg.sliding_window
    return 0


def make_decode_step(
    cfg: ArchConfig, seq_len: int, *, use_kernel: bool = False
) -> Callable:
    window = serve_window(cfg, seq_len)

    def decode_step(params, tokens, caches, pos):
        return model_lib.decode_step(
            params, tokens, caches, pos, cfg, window=window,
            use_kernel=use_kernel,
        )

    return decode_step


# ------------------------------------------------------------------ prefill
def make_prefill_step(cfg: ArchConfig, seq_len: int) -> Callable:
    """Forward over the prompt, returning (last-token logits, filled caches)."""
    window = serve_window(cfg, seq_len)

    def prefill(params, batch):
        x = (
            batch["embeds"].astype(cfg.activation_dtype)
            if cfg.modality == "vision_embeds"
            else layers.apply_embed(params["embed"], batch["tokens"], cfg)
        )
        positions = model_lib._positions(batch, cfg, x.shape[1])

        def period_body(carry, period_params):
            h = carry
            cache_out = {}
            for j, sub in enumerate(cfg.period):
                key = f"sub{j}"
                p = period_params[key]
                if sub.mixer == "attn":
                    dh, c = _prefill_attention(p["attn"], h, cfg, positions, window)
                else:
                    dh, c = _prefill_mamba(p["mamba"], h, cfg)
                h = h + dh
                cache_out[key] = c
                if sub.mlp == "mlp":
                    h = h + layers.apply_mlp(p["mlp"], h, cfg)
                elif sub.mlp == "moe":
                    from repro.models import moe

                    y, _ = moe.apply_moe(p["moe"], h, cfg)
                    h = h + y
            return h, cache_out

        h, caches = jax.lax.scan(period_body, x, params["blocks"])
        h = layers.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = layers.apply_head(params["head"], h[:, -1:], cfg)
        return logits, caches

    return prefill


def _prefill_attention(p, x, cfg, positions, window):
    b, s, _ = x.shape
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    hn = layers.rmsnorm(x, p["norm"], cfg.norm_eps)
    q = (hn @ p["wq"]).reshape(b, s, h, hd)
    k = (hn @ p["wk"]).reshape(b, s, kv, hd)
    v = (hn @ p["wv"]).reshape(b, s, kv, hd)
    q, k = attention._apply_positions(q, k, positions, cfg)
    out = attention.blocked_attention(q, k, v, causal=True, window=window)
    return out.reshape(b, s, h * hd) @ p["wo"], {"k": k, "v": v}


def _prefill_mamba(p, x, cfg):
    b, s, _ = x.shape
    h, pd = cfg.ssm_heads, cfg.ssm_head_dim
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width

    hn = layers.rmsnorm(x, p["norm"], cfg.norm_eps)
    z = hn @ p["w_z"]
    xin_raw = hn @ p["w_x"]
    bc_raw = hn @ p["w_bc"]
    dt = jax.nn.softplus(hn @ p["w_dt"] + p["dt_bias"])

    xin = jax.nn.silu(ssm.causal_conv(xin_raw, p["conv_x"]))
    bc = jax.nn.silu(ssm.causal_conv(bc_raw, p["conv_bc"]))
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, h, pd)
    y, final_state = ssm.ssd_scan(
        xh,
        dt,
        a,
        b_mat.reshape(b, s, g, n),
        c_mat.reshape(b, s, g, n),
        chunk=cfg.ssm_chunk,
    )
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(b, s, h * pd)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    cache = {
        "state": final_state,
        "conv_x": xin_raw[:, -(w - 1) :],
        "conv_bc": bc_raw[:, -(w - 1) :],
    }
    return y @ p["w_out"], cache
