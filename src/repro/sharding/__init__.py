"""Sharding rules: logical parameter axes -> mesh PartitionSpecs."""
