"""Resolve logical parameter/activation axes against a concrete mesh.

Safety rule: a dimension is sharded on a mesh axis only when its size is
divisible by that axis — otherwise it is replicated (the Megatron-standard
fallback, e.g. KV projections with kv_heads < TP degree). This keeps every
(architecture x mesh) combination lowerable without per-arch exceptions.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_axis_size(mesh: Mesh, axis: str | tuple[str, ...]) -> int:
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 1
    size = 1
    for a in axis:
        size *= mesh.shape[a] if a in mesh.shape else 1
    return size


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel mesh axes: ("pod", "data") when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def resolve_leaf_spec(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    mesh: Mesh,
) -> P:
    out: list = []
    for dim, ax in zip(shape, axes, strict=True):
        if ax is None:
            out.append(None)
        else:
            size = mesh_axis_size(mesh, ax)
            out.append(ax if size > 1 and dim % size == 0 else None)
    return P(*out)


def schema_specs(schema: dict, mesh: Mesh) -> dict:
    """Pytree of PartitionSpec resolved from a parameter Schema."""
    out: dict = {}
    for name, sub in schema.items():
        if isinstance(sub, dict):
            out[name] = schema_specs(sub, mesh)
        else:
            out[name] = resolve_leaf_spec(sub.shape, sub.axes, mesh)
    return out


def param_specs(cfg: ArchConfig, mesh: Mesh) -> dict:
    from repro.models.model import model_schema

    return schema_specs(model_schema(cfg), mesh)


def fsdp_param_specs(cfg: ArchConfig, mesh: Mesh, min_dim: int = 2048) -> dict:
    """Param specs with additional FSDP-style sharding over the DP axes.

    For models whose model-axis shard alone exceeds HBM (jamba-1.5's 398B:
    49.75 GB per device at 16-way TP), each large parameter also shards one
    unsharded dimension over ("pod","data"); XLA all-gathers the weights at
    use, and the per-period `lax.scan` keeps only one period's gathered
    weights live. Small tensors (norms, biases, dims < ``min_dim``) stay
    replicated — gathering them wouldn't pay for the latency.
    """
    from repro.models.model import model_schema

    ba = batch_axes(mesh)
    dsize = mesh_axis_size(mesh, ba)

    def widen(schema: dict) -> dict:
        out: dict = {}
        for name, sub in schema.items():
            if isinstance(sub, dict):
                out[name] = widen(sub)
                continue
            spec = list(resolve_leaf_spec(sub.shape, sub.axes, mesh))
            # Pick the largest still-unsharded dim divisible by the DP size.
            # 1-D params (norm scales, biases) stay replicated: kilobytes of
            # residency saved would not pay for a per-use gather.
            cands = [
                (dim, i)
                for i, (dim, s) in enumerate(zip(sub.shape, spec))
                if len(sub.shape) >= 2
                and s is None and dim % dsize == 0 and dim >= min_dim
            ]
            if cands:
                _, i = max(cands)
                spec[i] = ba if len(ba) > 1 else ba[0]
            out[name] = P(*spec)
        return out

    return widen(model_schema(cfg))


def named(specs: dict, mesh: Mesh) -> dict:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------- batch specs
def train_batch_specs(cfg: ArchConfig, mesh: Mesh) -> dict:
    """Specs for the (slots, global_mb, S[, ...]) training batch layout.

    dim 1 (the per-slot global micro-batch of sequences) is sharded across
    the DP axes; everything else is replicated.
    """
    ba = batch_axes(mesh)
    if cfg.modality == "vision_embeds":
        return {
            "embeds": P(None, ba, None, None),
            "positions": P(None, ba, None),  # (3, B, S)
            "labels": P(None, ba, None),
        }
    if cfg.modality == "audio_codes":
        return {
            "tokens": P(None, ba, None, None),
            "labels": P(None, ba, None, None),
        }
    return {"tokens": P(None, ba, None), "labels": P(None, ba, None)}


def serve_batch_specs(cfg: ArchConfig, mesh: Mesh, batch: int) -> dict:
    """Specs for a (B, S[, ...]) prefill/decode request batch; if B doesn't
    divide the DP axes (long_500k has B=1) the batch dim is replicated and
    the *sequence* gets the sharding (sequence-parallel serving)."""
    ba = batch_axes(mesh)
    dp = mesh_axis_size(mesh, ba)
    bdim = ba if batch % dp == 0 else None
    sdim = None if bdim is not None else ba
    if cfg.modality == "vision_embeds":
        return {
            "embeds": P(bdim, sdim, None),
            "positions": P(None, bdim, sdim),
        }
    if cfg.modality == "audio_codes":
        return {"tokens": P(bdim, sdim, None)}
    return {"tokens": P(bdim, sdim)}


def decode_token_specs(cfg: ArchConfig, mesh: Mesh, batch: int) -> P:
    """Spec for the (B, 1[, ...]) decode token: batch over DP if divisible,
    otherwise fully replicated (the cache carries the sharding instead)."""
    ba = batch_axes(mesh)
    dp = mesh_axis_size(mesh, ba)
    bdim = ba if batch % dp == 0 else None
    if cfg.modality == "vision_embeds":
        return P(bdim, None, None)
    if cfg.modality == "audio_codes":
        return P(bdim, None, None)
    return P(bdim, None)


def cache_specs(
    cfg: ArchConfig, mesh: Mesh, batch: int, *, seq_shard: bool = True
) -> dict:
    """Specs for the decode caches (leading n_periods stack dim).

    Attention KV caches: (L, B, S, KV, hd) — batch over DP axes when it
    divides, otherwise the *sequence* dim is sharded (the long_500k
    flash-decode layout); KV heads over the model axis when divisible.

    ``seq_shard`` (beyond-paper, EXPERIMENTS §Perf iteration 1): when the KV
    heads do NOT divide the model axis (GQA kv=1/4/8 under 16-way TP), the
    baseline replicates the whole cache across the model axis — 16x the HBM.
    Instead we shard the cache *sequence* over the model axis (flash-decode:
    each shard attends to its slice, partial softmax combined by GSPMD).
    SSM caches: (L, B, H, P, N) — heads over model.
    """
    from repro.models import transformer

    ba = batch_axes(mesh)
    dp = mesh_axis_size(mesh, ba)
    tp = mesh_axis_size(mesh, "model")
    bdim = ba if batch % dp == 0 else None
    sdim = None if bdim is not None else ba

    out: dict = {}
    for j, sub in enumerate(cfg.period):
        if sub.mixer == "attn":
            kvdim = "model" if cfg.num_kv_heads % tp == 0 and tp > 1 else None
            kv_sdim = sdim
            if seq_shard and kvdim is None and tp > 1:
                # Fold the model axis onto the cache sequence dim.
                kv_sdim = (
                    (*sdim, "model") if isinstance(sdim, tuple)
                    else ((sdim, "model") if sdim else "model")
                )
            spec = {
                "k": P(None, bdim, kv_sdim, kvdim, None),
                "v": P(None, bdim, kv_sdim, kvdim, None),
            }
        else:
            hdim = "model" if cfg.ssm_heads % tp == 0 and tp > 1 else None
            spec = {
                "state": P(None, bdim, hdim, None, None),
                "conv_x": P(None, bdim, None, "model" if cfg.ssm_inner % tp == 0 and tp > 1 else None),
                "conv_bc": P(None, bdim, None, None),
            }
        out[f"sub{j}"] = spec
    return out


def logits_spec(cfg: ArchConfig, mesh: Mesh, batch: int) -> P:
    ba = batch_axes(mesh)
    dp = mesh_axis_size(mesh, ba)
    tp = mesh_axis_size(mesh, "model")
    vdim = "model" if cfg.padded_vocab % tp == 0 and tp > 1 else None
    bdim = ba if batch % dp == 0 else None
    if cfg.modality == "audio_codes":
        return P(bdim, None, None, vdim)
    return P(bdim, None, vdim)
