"""Deterministic synthetic data pipeline."""
