"""Deterministic synthetic token pipeline.

Produces per-step batches in the framework's slot layout
``(slots, global_microbatch, S, ...)``: slot ``m`` column ``i`` holds the
m-th micro-batch assigned to DP group ``i``. With FALCON S2 active, groups
process only their first ``m_i`` slots (dynamic trip counts), so the loader
simply keeps every slot filled. Data is a fixed-seed PRNG stream — bitwise
deterministic across restarts (checkpoint resume replays the same batches)
and host-shardable by (step, slot, group).

The token stream is a structured integer process (random walk over the
vocab with local repetition) rather than iid noise, so cross-entropy
actually *decreases* during the example runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int  # sequences per iteration
    slots: int = 8  # micro-batch slots per DP group
    dp_groups: int = 1
    seed: int = 1234

    @property
    def mb_sequences(self) -> int:
        """Sequences per micro-batch per DP group."""
        per_group = self.global_batch // self.dp_groups
        assert per_group % self.slots == 0 or per_group >= self.slots, (
            f"global batch {self.global_batch} too small for "
            f"{self.dp_groups} groups x {self.slots} slots"
        )
        return max(1, per_group // self.slots)


def _tokens(rng: np.random.Generator, shape: tuple[int, ...], vocab: int) -> np.ndarray:
    """Structured stream: a lazy random walk with repetition."""
    flat = rng.integers(0, vocab, size=shape)
    # Repeat the previous token with p=0.5 along the last axis -> learnable.
    rep = rng.random(shape) < 0.5
    out = flat.copy()
    for t in range(1, shape[-1]):
        out[..., t] = np.where(rep[..., t], out[..., t - 1], out[..., t])
    return out.astype(np.int32)


def make_batch(cfg: ArchConfig, data: DataConfig, step: int) -> dict:
    """Training batch for one step (numpy, host-side)."""
    rng = np.random.default_rng(np.random.SeedSequence([data.seed, step]))
    slots = data.slots
    gmb = data.dp_groups * data.mb_sequences  # sequences per slot row
    s = data.seq_len
    if cfg.modality == "vision_embeds":
        embeds = rng.normal(0, 1, size=(slots, gmb, s, cfg.d_model)).astype(np.float32)
        labels = _tokens(rng, (slots, gmb, s), cfg.vocab_size)
        positions = np.broadcast_to(np.arange(s, dtype=np.int32), (3, gmb, s)).copy()
        return {"embeds": embeds, "positions": positions, "labels": labels}
    if cfg.modality == "audio_codes":
        k = cfg.num_codebooks
        toks = _tokens(rng, (slots, gmb, s * k), cfg.vocab_size).reshape(slots, gmb, s, k)
        labels = np.roll(toks, -1, axis=2)
        return {"tokens": toks, "labels": labels}
    toks = _tokens(rng, (slots, gmb, s + 1), cfg.vocab_size)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
