"""Batched serving driver with FALCON latency monitoring.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --requests 8 --prompt-len 32 --gen 16 [--inject gpu:1:0.5:5:200]

Serves a batch of requests through the real prefill + decode path (smoke
configs on CPU; the full configs are exercised via the dry-run). FALCON's
detector watches the per-token decode latency exactly as it watches training
iteration time — serving is iterative too, so the same ACF/BOCD stack
applies; mitigation for serving is placement adjustment (S3) or re-schedule
(S4), surfaced here as detection reports.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.cluster.injector import FailSlowInjector
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.core.detector import FalconDetect
from repro.launch.train import parse_injection
from repro.models import model as model_lib, transformer
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--inject", action="append", default=[])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    b, s0, total = args.requests, args.prompt_len, args.prompt_len + args.gen
    print(f"serving {b} requests x ({s0} prompt + {args.gen} new) on {cfg.name}")

    params = model_lib.init_params(cfg, args.seed)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s0)), jnp.int32)
    if cfg.modality == "audio_codes":
        prompt = prompt[..., None].repeat(cfg.num_codebooks, -1)

    # Performance model for latency signal + optional fail-slow injection.
    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=1, gpus_per_node=8),
        job=JobSpec(
            model=ModelSpec(layers=cfg.num_layers, hidden=max(cfg.d_model, 1024),
                            seq_len=total, vocab=cfg.vocab_size),
            tp=2, dp=4, pp=1, micro_batches=8,
        ),
    )
    injector = FailSlowInjector([parse_injection(t) for t in args.inject])
    detector = FalconDetect(cluster=sim, verify_window=6)

    prefill = jax.jit(make_prefill_step(cfg, s0))
    decode = jax.jit(make_decode_step(cfg, total, use_kernel=args.use_kernel))

    t0 = time.monotonic()
    logits, caches = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0
    caches = transformer.grow_caches(caches, cfg, total)

    tok = jnp.argmax(logits[:, -1], axis=-1).reshape(b, 1).astype(jnp.int32)
    if cfg.modality == "audio_codes":
        tok = tok[..., 0:1] if tok.ndim == 3 else tok[..., None].repeat(cfg.num_codebooks, -1)
    pos = jnp.asarray(s0, jnp.int32)
    out_tokens = []
    wall = 0.0
    for step in range(args.gen):
        t1 = time.monotonic()
        logits, caches = decode(params, tok, caches, pos)
        jax.block_until_ready(logits)
        measured = time.monotonic() - t1
        injector.apply(sim.state, wall)
        latency = sim.iteration_time() if injector.injections else measured
        wall += latency
        ev = detector.observe(latency, wall)
        if ev is not None:
            print(f"  token {step}: FALCON flags {ev.root_cause.value} "
                  f"on {ev.components} ({ev.t_healthy:.3f}s -> {ev.t_slow:.3f}s)")
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        if cfg.modality == "audio_codes":
            tok = nxt.reshape(b, 1, cfg.num_codebooks).astype(jnp.int32)
            out_tokens.append(np.asarray(nxt)[..., 0])
        else:
            tok = nxt.reshape(b, 1).astype(jnp.int32)
            out_tokens.append(np.asarray(nxt))
        pos = pos + 1

    gen = np.stack(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"prefill: {t_prefill:.2f}s   decode: {args.gen} tokens/seq, "
          f"{b * args.gen / max(wall, 1e-9):.1f} tok/s (modeled)"
          if injector.injections else
          f"prefill: {t_prefill:.2f}s   decode throughput "
          f"{b * args.gen / max(wall, 1e-9):.1f} tok/s")
    print(f"sample continuation: {gen[0].tolist()}")


if __name__ == "__main__":
    main()
