"""What-if driver — counterfactual replay, attribution, knob tuning.

    # attribution of a committed campaign report (writes the sidecar
    # <report>.attribution.json next to it):
    PYTHONPATH=src python -m repro.launch.whatif \
        --report results/campaigns/mixed_fleet-j8-s0.json --leave-one-out

    # ad-hoc counterfactuals: drop episodes / suppress / force decisions
    ... --preset mixed_fleet --jobs 8 --seed 0 --drop 6 8 \
        --suppress j1:S2P:460 --force j1:CKPT_AND_RESTART:500

    # planner knob auto-tuning (mean objective over N seeds); exits
    # non-zero if the measured gain is negative (the CI gate):
    ... --preset single_gpu_throttle --jobs 1 --tune breakeven_scale \
        --tune-seeds 3

    # "explain this PR": per-cause attribution delta vs a committed
    # baseline report (the CI artifact):
    ... --explain results/campaigns/mixed_fleet-j8-s0.json

Decision specs are ``job:strategy:time`` with the strategy in
:func:`~repro.core.events.strategy_label` form (``ADJUST_MICROBATCH``,
``S2P``, ...). All artifacts serialize deterministically (sorted keys,
fixed rounding, no timestamps) — the attribution sidecar is byte-stable
and diffable in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.whatif import (
    DecisionRef,
    Variant,
    WhatIfEngine,
    leave_one_out,
    shapley,
    tune,
    write_tuning,
)
from repro.whatif.tuning import RESULTS_DIR as WHATIF_DIR


def _fmt(v) -> str:
    return "-" if v is None else (f"{v:.3f}" if isinstance(v, float) else str(v))


def parse_decision(spec: str) -> DecisionRef:
    try:
        job, strategy, time_s = spec.split(":")
        return DecisionRef(job_id=job, strategy=strategy, time=float(time_s))
    except ValueError:
        raise SystemExit(
            f"bad decision spec {spec!r}: expected job:strategy:time, "
            "e.g. j1:S2P:460"
        )


def sidecar_path(report_path: str) -> str:
    base = report_path[:-5] if report_path.endswith(".json") else report_path
    return base + ".attribution.json"


def _write_json(payload: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def attribution_table(att: dict) -> str:
    t = att["totals"]
    lines = [
        f"fleet slowdown {t['gap_s']:.1f} s, mitigated {t['mitigated_s']:.1f} s "
        f"({_fmt(t['mitigated_pct'])} %)",
        "",
        f"{'cause':<22}{'slowdown_s':>11}{'mitigated_s':>12}{'mitig%':>8}"
        f"{'episodes':>9}",
    ]
    for cause, row in att["per_cause"].items():
        lines.append(
            f"{cause:<22}{row['slowdown_s']:>11.1f}{row['mitigated_s']:>12.1f}"
            f"{_fmt(row['mitigated_pct']):>8}{len(row['episodes']):>9}"
        )
    lines.append(
        f"{'(interaction residual)':<22}{att['per_cause_residual_s']:>11.1f}"
        f"{att['per_cause_mitigated_residual_s']:>12.1f}"
    )
    if "per_decision" in att:
        lines += [
            "",
            f"{'job':<5}{'strategy':<20}{'t(s)':>8}  {'cause':<22}{'value_s':>9}",
        ]
        for d in att["per_decision"]:
            lines.append(
                f"{d['job_id']:<5}{d['strategy']:<20}{d['time_s']:>8.0f}  "
                f"{d['cause']:<22}{d['value_s']:>9.1f}"
            )
        lines.append(
            f"decision values sum {att['per_decision_total_s']:.1f} s vs "
            f"total mitigated {t['mitigated_s']:.1f} s "
            f"(residual {att['per_decision_residual_s']:.1f} s)"
        )
    return "\n".join(lines)


def explain(engine: WhatIfEngine, att: dict, baseline_path: str) -> dict:
    """Per-cause attribution delta vs a committed baseline report."""
    with open(baseline_path) as f:
        base_report = json.load(f)
    base_side = sidecar_path(baseline_path)
    if os.path.exists(base_side):
        with open(base_side) as f:
            base_causes = json.load(f)["per_cause"]
        source = "attribution sidecar"
    else:
        base_causes = base_report["mitigation"].get("per_cause", {})
        source = "report per-cause estimate"
    rows = {}
    causes = sorted(set(att["per_cause"]) | set(base_causes))
    for cause in causes:
        cur = att["per_cause"].get(cause, {})
        base = base_causes.get(cause, {})
        rows[cause] = {
            "mitigated_s": cur.get("mitigated_s"),
            "baseline_mitigated_s": base.get("mitigated_s"),
            "delta_s": (
                round(cur.get("mitigated_s", 0.0)
                      - base.get("mitigated_s", 0.0), 3)
            ),
            "mitigated_pct": cur.get("mitigated_pct"),
            "baseline_mitigated_pct": base.get("mitigated_pct"),
        }
    base_pct = base_report["mitigation"].get("slowdown_mitigated_pct")
    cur_pct = att["totals"]["mitigated_pct"]
    return {
        "campaign": {
            "preset": engine.spec.preset.name,
            "n_jobs": len(engine.spec.jobs),
            "seed": engine.spec.seed,
        },
        "baseline": {"path": baseline_path, "source": source},
        "slowdown_mitigated_pct": round(cur_pct, 3) if cur_pct is not None else None,
        "baseline_slowdown_mitigated_pct": base_pct,
        "delta_pct_points": (
            round(cur_pct - base_pct, 3)
            if cur_pct is not None and base_pct is not None else None
        ),
        "per_cause": rows,
    }


def explain_table(exp: dict) -> str:
    lines = [
        f"explain vs {exp['baseline']['path']} ({exp['baseline']['source']})",
        f"slowdown mitigated: {_fmt(exp['slowdown_mitigated_pct'])} % now vs "
        f"{_fmt(exp['baseline_slowdown_mitigated_pct'])} % baseline "
        f"({_fmt(exp['delta_pct_points'])} points)",
        "",
        f"{'cause':<22}{'mitig_s':>9}{'base_s':>9}{'delta_s':>9}",
    ]
    for cause, r in exp["per_cause"].items():
        lines.append(
            f"{cause:<22}{_fmt(r['mitigated_s']):>9}"
            f"{_fmt(r['baseline_mitigated_s']):>9}{_fmt(r['delta_s']):>9}"
        )
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    src = ap.add_argument_group("campaign identity")
    src.add_argument("--report", default=None,
                     help="committed campaign report to replay (verified)")
    src.add_argument("--preset", default=None)
    src.add_argument("--jobs", type=int, default=None)
    src.add_argument("--seed", type=int, default=0)
    src.add_argument("--ticks", type=int, default=None)

    act = ap.add_argument_group("actions")
    act.add_argument("--leave-one-out", action="store_true",
                     help="per-cause/per-decision LOO attribution + sidecar")
    act.add_argument("--no-decisions", action="store_true",
                     help="skip the per-decision pass (causes only)")
    act.add_argument("--shapley", type=int, default=0, metavar="PERMS",
                     help="add sampled-permutation Shapley episode values")
    act.add_argument("--drop", type=int, nargs="*", default=None,
                     metavar="GID", help="replay without these episode ids")
    act.add_argument("--suppress", nargs="*", default=None,
                     metavar="JOB:STRAT:T", help="replay suppressing these")
    act.add_argument("--force", nargs="*", default=None,
                     metavar="JOB:STRAT:T", help="replay forcing these")
    act.add_argument("--tune", nargs="*", default=None, metavar="KNOB",
                     help="auto-tune planner knobs (default: breakeven_scale "
                          "prediction_margin)")
    act.add_argument("--tune-seeds", type=int, default=3)
    act.add_argument("--tune-iters", type=int, default=8)
    act.add_argument("--explain", default=None, metavar="BASELINE",
                     help="attribution delta vs a committed baseline report")

    ap.add_argument("--out", default=None,
                    help="override the artifact path/dir")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    report = None
    if args.report:
        with open(args.report) as f:
            report = json.load(f)
        engine = WhatIfEngine.from_report(report)
    elif args.preset:
        engine = WhatIfEngine.from_preset(
            args.preset, n_jobs=args.jobs, seed=args.seed,
            max_ticks=args.ticks,
        )
    else:
        ap.error("need --report or --preset")

    did_something = False

    # ---- ad-hoc counterfactual replay
    if args.drop is not None or args.suppress is not None or args.force is not None:
        did_something = True
        variant = Variant(
            drop_episodes=frozenset(args.drop or ()),
            suppress=tuple(parse_decision(s) for s in (args.suppress or ())),
            force=tuple(parse_decision(s) for s in (args.force or ())),
        )
        faults = engine.run_variant("faults", variant)
        falcon = engine.run_variant("falcon", variant)
        base = engine.totals()
        cur = engine.totals(faults=faults, falcon=falcon)
        print(
            f"counterfactual: drop={sorted(variant.drop_episodes)} "
            f"suppress={[d.key() for d in variant.suppress]} "
            f"force={[d.key() for d in variant.force]}"
        )
        print(
            f"  gap       {base['gap_s']:>9.1f} s -> {cur['gap_s']:>9.1f} s"
        )
        print(
            f"  mitigated {base['mitigated_s']:>9.1f} s -> "
            f"{cur['mitigated_s']:>9.1f} s"
        )
        print(
            f"  mitigated% {_fmt(base['mitigated_pct'])} -> "
            f"{_fmt(cur['mitigated_pct'])}"
        )

    # ---- attribution
    att = None
    if args.leave_one_out or args.explain:
        did_something = True
        att = leave_one_out(engine, per_decision=not args.no_decisions)
        if args.shapley > 0:
            att["shapley"] = shapley(engine, permutations=args.shapley)
        att["replay_stats"] = dict(sorted(engine.stats.items()))

    if args.leave_one_out:
        if args.report:
            out_path = args.out or sidecar_path(args.report)
        else:
            c = engine.spec
            out_path = args.out or os.path.join(
                "results", "campaigns",
                f"{c.preset.name}-j{len(c.jobs)}-s{c.seed}.attribution.json",
            )
        _write_json(att, out_path)
        if not args.quiet:
            print(attribution_table(att))
        print(f"\nattribution: {out_path}")

    # ---- explain-this-PR artifact
    if args.explain:
        exp = explain(engine, att, args.explain)
        c = exp["campaign"]
        out_path = args.out or os.path.join(
            WHATIF_DIR,
            f"explain-{c['preset']}-j{c['n_jobs']}-s{c['seed']}.json",
        )
        _write_json(exp, out_path)
        if not args.quiet:
            print(explain_table(exp))
        print(f"\nexplain artifact: {out_path}")

    # ---- knob auto-tuning
    if args.tune is not None:
        did_something = True
        knob_names = tuple(args.tune) or (
            "breakeven_scale", "prediction_margin"
        )
        preset = engine.spec.preset.name
        n_jobs = len(engine.spec.jobs)
        engines = [engine]
        for s in range(args.tune_seeds):
            if s == engine.spec.seed:
                continue
            engines.append(
                WhatIfEngine.from_preset(
                    preset, n_jobs=n_jobs, seed=s, max_ticks=args.ticks
                )
            )
        engines = engines[: max(args.tune_seeds, 1)]
        result = tune(engines, knob_names=knob_names, iters=args.tune_iters)
        path = write_tuning(result) if args.out is None else _write_json(
            result, args.out
        )
        print(
            f"tuned {list(knob_names)} over {len(engines)} seeds: "
            f"{result['objective_default_pct']} % -> "
            f"{result['objective_tuned_pct']} % "
            f"(gain {result['gain_pct_points']:+.3f} points)"
        )
        print(f"tuning artifact: {path}")
        if result["gain_pct_points"] < 0:
            print("TUNE FAIL: negative measured gain")
            return 2

    if not did_something:
        ap.error(
            "nothing to do: pass --leave-one-out, --drop/--suppress/--force, "
            "--tune, or --explain"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
