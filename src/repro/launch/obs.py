"""Dashboard renderer CLI — static HTML/SVG off a serialized campaign report.

    PYTHONPATH=src python -m repro.launch.obs \
        --report results/campaigns/mixed_fleet-j8-s0.json \
        [--metrics results/campaigns/mixed_fleet-j8-s0.metrics.json] \
        [--out results/campaigns/mixed_fleet-j8-s0.html]

Reads the scored report (and optionally the metrics sidecar) and writes a
standalone deterministic HTML page: per-job timeline lanes against the
injected ground truth, a host x time heat map of injected-vs-detected
faults, and the detect -> diagnose -> mitigate -> resolve funnel. The
output is a pure function of its inputs — identical files in, identical
bytes out — so dashboards can be committed and diffed like reports.

With no ``--metrics`` flag, the sidecar is picked up automatically when it
sits next to the report (``<base>.metrics.json``); ``--out`` defaults to
``<base>.html``.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.obs.dashboard import render_dashboard


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", required=True,
                    help="scored campaign report JSON")
    ap.add_argument("--metrics", default=None,
                    help="metrics sidecar (default: <base>.metrics.json "
                         "next to the report, when present)")
    ap.add_argument("--out", default=None,
                    help="output HTML path (default: <base>.html)")
    args = ap.parse_args()

    with open(args.report) as f:
        report = json.load(f)
    base, _ = os.path.splitext(args.report)
    metrics_path = args.metrics or f"{base}.metrics.json"
    metrics = None
    if os.path.exists(metrics_path):
        with open(metrics_path) as f:
            metrics = json.load(f)
    out = args.out or f"{base}.html"
    html = render_dashboard(report, metrics)
    with open(out, "w") as f:
        f.write(html)
    print(f"dashboard: {out}")


if __name__ == "__main__":
    main()
