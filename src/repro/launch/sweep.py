"""Seed-sweep driver — paper-style evaluation tables with regression gate.

    PYTHONPATH=src python -m repro.launch.sweep --preset mixed_fleet \
        --jobs 8 --seeds 5 [--workers 4] [--ticks N] [--out results/sweeps] \
        [--gate results/sweeps/<baseline>.json] [--write-baseline]

Runs a scenario preset over N seeds, aggregates the paper metrics
(precision, recall, detection latency, %-slowdown-mitigated, %-JCT delay)
into mean +/- 95 % CI, writes the table to ``results/sweeps/`` and prints
it. One seed is an anecdote; the sweep is the evaluation number a detector
or planner change must defend.

Seeds are independent campaigns, so ``--workers N`` fans them out over N
processes; the default stays serial (one process, deterministic resource
use) and the table is byte-identical either way — each seed's report is a
pure function of (preset, jobs, seed, ticks), whichever process runs it.

``--gate`` turns the sweep into a CI regression gate: the aggregate is
compared against a committed baseline JSON and the process exits non-zero
when the gated metric (default ``slowdown_mitigated_pct``) drops more than
the baseline's ``max_drop_pct_points`` below its recorded mean.
``--write-baseline`` records the current aggregate as that baseline.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

from repro.scenarios import run_and_score

RESULTS_DIR = os.path.join("results", "sweeps")

#: metrics aggregated across seeds: (name, where to find it in a report)
METRICS = (
    ("precision", ("detection", "overall", "precision")),
    ("recall", ("detection", "overall", "recall")),
    ("latency_mean_s", ("detection", "overall", "latency_mean_s")),
    ("slowdown_mitigated_pct", ("mitigation", "slowdown_mitigated_pct")),
    ("slowdown_mitigated_ckpt_pct",
     ("mitigation", "slowdown_mitigated_ckpt_pct")),
    ("avg_jct_delay_pct", ("mitigation", "avg_jct_delay_pct")),
    # Robustness (hang/executor) metrics: None for presets without hangs,
    # so they aggregate only where they apply.
    ("hang_detection_rate",
     ("robustness", "watchdog", "hang_detection_rate")),
    ("median_time_to_abort_s",
     ("robustness", "watchdog", "median_time_to_abort_s")),
)

#: the gate schema the committed baseline must carry (pinned by
#: tests/test_ci_gate.py so the CI workflow itself is under tier-1)
GATE_SCHEMA_KEYS = ("preset", "jobs", "seeds", "metrics", "gate")


def _dig(report: dict, path: tuple[str, ...]):
    node = report
    for key in path:
        node = node[key]
    return node


def _stats(vals: list[float]) -> dict:
    """Mean and 95 % CI (normal approximation) of one metric's samples."""
    if not vals:
        return {"mean": None, "ci95": None, "n": 0}
    mean = sum(vals) / len(vals)
    if len(vals) > 1:
        var = sum((v - mean) ** 2 for v in vals) / (len(vals) - 1)
        ci = 1.96 * math.sqrt(var / len(vals))
    else:
        ci = 0.0
    return {
        "mean": round(mean, 4),
        "ci95": round(ci, 4),
        "n": len(vals),
        "values": [round(v, 4) for v in vals],
    }


def aggregate(per_seed: list[dict]) -> dict:
    """Mean and 95 % CI per metric across seeds."""
    return {
        name: _stats([
            v for v in (_dig(r, path) for r in per_seed) if v is not None
        ])
        for name, path in METRICS
    }


def _per_cause_of(report: dict) -> dict[str, float | None]:
    """cause -> %-mitigated estimate from one report (may be empty for
    old-format reports that predate the per_cause section)."""
    table = report.get("mitigation", {}).get("per_cause", {})
    return {c: row.get("mitigated_pct") for c, row in table.items()}


def aggregate_per_cause(per_seed: list[dict]) -> dict[str, dict]:
    """Across-seed stats of the per-cause %-mitigated columns.

    Causes vary by seed (a seed may draw no NIC episode), so each cause
    aggregates over the seeds where it occurred — ``n`` says how many.
    Attribution deltas across seeds are only meaningful with this split:
    the scalar mean hides a regression that costs 10 points on
    ``network_congestion`` but is washed out by GPU-heavy seeds.
    """
    causes = sorted({c for r in per_seed for c in _per_cause_of(r)})
    return {
        c: _stats([
            v for v in (_per_cause_of(r).get(c) for r in per_seed)
            if v is not None
        ])
        for c in causes
    }


def _score_one(task: tuple) -> dict:
    """One seed's report (module-level so worker processes can pickle it)."""
    preset, n_jobs, seed, max_ticks = task
    _, _, report = run_and_score(
        preset, n_jobs=n_jobs, seed=seed, max_ticks=max_ticks
    )
    return report


def run_sweep(
    preset: str,
    n_jobs: int | None = None,
    seeds: int = 3,
    max_ticks: int | None = None,
    workers: int = 1,
) -> dict:
    """Run ``seeds`` campaigns (seed 0..N-1) and aggregate the metrics.

    ``workers > 1`` runs the seeds in a process pool; ``map`` keeps seed
    order, and each report is deterministic in its inputs, so the sweep
    dict — and the written table — is byte-identical to the serial run.
    """
    tasks = [(preset, n_jobs, seed, max_ticks) for seed in range(seeds)]
    if workers > 1 and seeds > 1:
        import multiprocessing as mp

        with mp.get_context("spawn").Pool(min(workers, seeds)) as pool:
            per_seed = pool.map(_score_one, tasks)
    else:
        per_seed = [_score_one(t) for t in tasks]
    jobs = per_seed[0]["campaign"]["n_jobs"]
    return {
        "preset": preset,
        "jobs": jobs,
        "seeds": seeds,
        "max_ticks": max_ticks,
        "metrics": aggregate(per_seed),
        "per_cause_mitigated_pct": aggregate_per_cause(per_seed),
        "per_seed": [
            {
                "seed": r["campaign"]["seed"],
                **{
                    name: _dig(r, path)
                    for name, path in METRICS
                },
                "per_cause_mitigated_pct": _per_cause_of(r),
            }
            for r in per_seed
        ],
    }


def check_gate(sweep: dict, baseline: dict) -> tuple[bool, str]:
    """Apply a committed baseline's regression gate to a fresh sweep.

    Returns (passed, human-readable verdict). The gate only guards the
    downside: improvements update the baseline via ``--write-baseline``.
    """
    gate = baseline["gate"]
    metric = gate.get("metric", "slowdown_mitigated_pct")
    max_drop = float(gate.get("max_drop_pct_points", 2.0))
    base_mean = baseline["metrics"][metric]["mean"]
    cur_mean = sweep["metrics"][metric]["mean"]
    if base_mean is None or cur_mean is None:
        return False, f"gate metric {metric!r} missing from sweep or baseline"
    drop = base_mean - cur_mean
    verdict = (
        f"{metric}: baseline {base_mean:.2f}, current {cur_mean:.2f} "
        f"(drop {drop:+.2f}, allowed {max_drop:.2f})"
    )
    return drop <= max_drop, verdict


def sweep_table(sweep: dict) -> str:
    lines = [
        f"sweep  {sweep['preset']} jobs={sweep['jobs']} "
        f"seeds={sweep['seeds']}",
        "",
        f"{'metric':<28}{'mean':>10}{'ci95':>9}{'n':>4}",
    ]
    for name, _ in METRICS:
        m = sweep["metrics"][name]
        mean = "-" if m["mean"] is None else f"{m['mean']:.3f}"
        ci = "-" if m["ci95"] is None else f"{m['ci95']:.3f}"
        lines.append(f"{name:<28}{mean:>10}{ci:>9}{m['n']:>4}")
    per_cause = sweep.get("per_cause_mitigated_pct", {})
    for cause, m in sorted(per_cause.items()):
        mean = "-" if m["mean"] is None else f"{m['mean']:.3f}"
        ci = "-" if m["ci95"] is None else f"{m['ci95']:.3f}"
        lines.append(
            f"{'mitigated% ' + cause:<28}{mean:>10}{ci:>9}{m['n']:>4}"
        )
    lines += ["", f"{'seed':<6}" + "".join(
        f"{name[:14]:>16}" for name, _ in METRICS
    )]
    for row in sweep["per_seed"]:
        lines.append(
            f"{row['seed']:<6}" + "".join(
                f"{'-' if row[name] is None else round(row[name], 3):>16}"
                for name, _ in METRICS
            )
        )
    return "\n".join(lines)


def write_sweep(sweep: dict, out_dir: str = RESULTS_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir,
        f"{sweep['preset']}-j{sweep['jobs']}-seeds{sweep['seeds']}.json",
    )
    with open(path, "w") as f:
        json.dump(sweep, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def write_baseline(
    sweep: dict,
    path: str,
    max_drop: float = 2.0,
    metric: str = "slowdown_mitigated_pct",
) -> None:
    baseline = {
        "preset": sweep["preset"],
        "jobs": sweep["jobs"],
        "seeds": sweep["seeds"],
        "metrics": sweep["metrics"],
        "gate": {
            "metric": metric,
            "max_drop_pct_points": max_drop,
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(baseline, f, indent=1, sort_keys=True)
        f.write("\n")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="mixed_fleet")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=1,
                    help="process fan-out across seeds (default: serial)")
    ap.add_argument("--ticks", type=int, default=None,
                    help="override the preset's horizon")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--gate", default=None,
                    help="baseline JSON to gate against (CI mode)")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="record this sweep as the gate baseline at PATH")
    ap.add_argument("--max-drop", type=float, default=2.0,
                    help="allowed %%-mitigated drop when writing a baseline")
    ap.add_argument("--gate-metric", default="slowdown_mitigated_pct",
                    help="metric a written baseline gates on")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    sweep = run_sweep(
        args.preset, n_jobs=args.jobs, seeds=args.seeds,
        max_ticks=args.ticks, workers=args.workers,
    )
    path = write_sweep(sweep, args.out)
    if not args.quiet:
        print(sweep_table(sweep))
    print(f"\nsweep: {path}")

    if args.write_baseline:
        write_baseline(
            sweep, args.write_baseline, args.max_drop,
            metric=args.gate_metric,
        )
        print(f"baseline: {args.write_baseline}")
    if args.gate:
        with open(args.gate) as f:
            baseline = json.load(f)
        missing = [k for k in GATE_SCHEMA_KEYS if k not in baseline]
        if missing:
            print(f"GATE ERROR: baseline missing keys {missing}")
            return 2
        passed, verdict = check_gate(sweep, baseline)
        print(("GATE PASS: " if passed else "GATE FAIL: ") + verdict)
        return 0 if passed else 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
