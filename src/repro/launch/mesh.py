"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the pod axis is pure data parallelism
(gradient all-reduce crosses the inter-pod links; the model axis stays
intra-pod, mirroring the paper's "TP stays intra-node" placement rule).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices are available."""
    return jax.make_mesh((data, model), ("data", "model"))
