"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with ShapeDtypeStruct inputs (no allocation), and extract the
memory / cost / collective statistics the roofline analysis consumes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun

The XLA_FLAGS line below MUST run before any other jax-touching import.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs.base import INPUT_SHAPES, ArchConfig, get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.models.schema import shape_tree  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.serve import serve_step as serve_lib  # noqa: E402
from repro.sharding import partition  # noqa: E402
from repro.train import train_step as ts_lib  # noqa: E402

SLOTS = 8

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n=]*=?\s*\(?([a-z0-9]+)\[([0-9,]*)\]"
)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
}


def input_specs(cfg: ArchConfig, shape_name: str, dp_groups: int) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    info = INPUT_SHAPES[shape_name]
    s, gb, kind = info["seq_len"], info["global_batch"], info["kind"]
    i32 = jnp.int32
    act = cfg.activation_dtype

    if kind == "train":
        mb_seqs = max(1, gb // (dp_groups * SLOTS))
        gmb = dp_groups * mb_seqs
        if cfg.modality == "vision_embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((SLOTS, gmb, s, cfg.d_model), act),
                "positions": jax.ShapeDtypeStruct((3, gmb, s), i32),
                "labels": jax.ShapeDtypeStruct((SLOTS, gmb, s), i32),
            }
        if cfg.modality == "audio_codes":
            return {
                "tokens": jax.ShapeDtypeStruct((SLOTS, gmb, s, cfg.num_codebooks), i32),
                "labels": jax.ShapeDtypeStruct((SLOTS, gmb, s, cfg.num_codebooks), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((SLOTS, gmb, s), i32),
            "labels": jax.ShapeDtypeStruct((SLOTS, gmb, s), i32),
        }

    if kind == "prefill":
        if cfg.modality == "vision_embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((gb, s, cfg.d_model), act),
                "positions": jax.ShapeDtypeStruct((3, gb, s), i32),
            }
        if cfg.modality == "audio_codes":
            return {"tokens": jax.ShapeDtypeStruct((gb, s, cfg.num_codebooks), i32)}
        return {"tokens": jax.ShapeDtypeStruct((gb, s), i32)}

    # decode: one new token + caches of length s.
    if cfg.modality == "vision_embeds":
        tok = jax.ShapeDtypeStruct((gb, 1, cfg.d_model), act)
    elif cfg.modality == "audio_codes":
        tok = jax.ShapeDtypeStruct((gb, 1, cfg.num_codebooks), i32)
    else:
        tok = jax.ShapeDtypeStruct((gb, 1), i32)
    return {
        "tokens": tok,
        "caches": transformer.cache_shapes(cfg, gb, s),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-operand sizes of collective ops in the (post-SPMD) HLO."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        op, dtype, dims = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0.0) + n * _DTYPE_BYTES[dtype]
    return out


#: set False (--baseline-sharding) to reproduce the pre-optimization
#: replicated-KV-cache baseline recorded in EXPERIMENTS.md §Perf.
SEQ_SHARD_CACHES = True


#: serve-time FSDP threshold: if the model-axis param shard alone exceeds
#: this, weights are additionally sharded over the DP axes (gathered at use).
FSDP_SERVE_BYTES = 12 * 2**30


def lower_one(cfg: ArchConfig, shape_name: str, mesh) -> tuple:
    """Build the jitted step + abstract args for one combination."""
    kind = INPUT_SHAPES[shape_name]["kind"]
    dp = partition.mesh_axis_size(mesh, partition.batch_axes(mesh))
    pspecs = partition.param_specs(cfg, mesh)
    if SEQ_SHARD_CACHES:
        tp = partition.mesh_axis_size(mesh, "model")
        resident = cfg.total_params() * 2 / max(tp, 1)
        if resident > FSDP_SERVE_BYTES:
            # Serve: weights gathered per period. Train: full FSDP — params,
            # grads and (via zero1) moments shard over the DP axes too;
            # jamba-398B's 72 GiB/dev train footprint is infeasible otherwise.
            pspecs = partition.fsdp_param_specs(cfg, mesh)
    pshapes = model_lib.param_shapes(cfg)
    nshard = lambda t: jax.tree.map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )

    if kind == "train":
        opt_cfg = adamw.AdamWConfig()
        step = ts_lib.make_train_step(cfg, opt_cfg)
        batch = input_specs(cfg, shape_name, dp)
        ospecs = adamw.opt_state_specs(pspecs, pshapes, mesh)
        opt_shapes = adamw.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes
            ),
            nu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes
            ),
        )
        in_shardings = (
            nshard(pspecs),
            nshard(ospecs),
            nshard(partition.train_batch_specs(cfg, mesh)),
        )
        jitted = jax.jit(step, in_shardings=in_shardings)
        args = (pshapes, opt_shapes, batch)
    elif kind == "prefill":
        gb = INPUT_SHAPES[shape_name]["global_batch"]
        step = serve_lib.make_prefill_step(cfg, INPUT_SHAPES[shape_name]["seq_len"])
        batch = input_specs(cfg, shape_name, dp)
        in_shardings = (
            nshard(pspecs),
            nshard(partition.serve_batch_specs(cfg, mesh, gb)),
        )
        jitted = jax.jit(step, in_shardings=in_shardings)
        args = (pshapes, batch)
    else:  # decode
        gb = INPUT_SHAPES[shape_name]["global_batch"]
        s = INPUT_SHAPES[shape_name]["seq_len"]
        step = serve_lib.make_decode_step(cfg, s)
        spec = input_specs(cfg, shape_name, dp)
        in_shardings = (
            nshard(pspecs),
            NamedSharding(mesh, partition.decode_token_specs(cfg, mesh, gb)),
            nshard(partition.cache_specs(cfg, mesh, gb, seq_shard=SEQ_SHARD_CACHES)),
            NamedSharding(mesh, P()),
        )
        # Donate the KV caches: the functional cache update would otherwise
        # hold old + new cache simultaneously (§Perf iteration 2).
        jitted = jax.jit(step, in_shardings=in_shardings, donate_argnums=(2,))
        args = (pshapes, spec["tokens"], spec["caches"], spec["pos"])
    return jitted, args


def dryrun(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    # jax.set_mesh (not the bare `with mesh:`) so the abstract mesh is
    # visible at trace time — the expert-parallel MoE path reads it. On
    # older jax the compat shim enters the Mesh context instead, which is
    # what compat.ambient_mesh() reads there.
    with compat.set_mesh(mesh):
        jitted, args = lower_one(cfg, shape_name, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    ap.add_argument(
        "--baseline-sharding", action="store_true",
        help="disable beyond-paper sharding optimizations (EXPERIMENTS §Perf)",
    )
    args = ap.parse_args()
    if args.baseline_sharding:
        global SEQ_SHARD_CACHES
        SEQ_SHARD_CACHES = False

    archs = list_archs() if args.all else [args.arch]
    archs = [a for a in archs if a and a != "falcon-demo-100m"]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                try:
                    res = dryrun(arch, shape_name, mp)
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
                    failures.append(tag)
                    continue
                print(
                    f"OK {tag}: flops={res['flops']:.3e} "
                    f"peak/dev={res['bytes_per_device']['peak']/2**30:.2f}GiB "
                    f"compile={res['compile_s']}s"
                )
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch}__{shape_name}__{res['mesh'].replace('x','_')}.json"
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(res, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:\n" + "\n".join(failures))
        sys.exit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
