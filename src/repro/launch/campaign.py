"""Scenario-campaign driver.

    PYTHONPATH=src python -m repro.launch.campaign --preset mixed_fleet \
        --jobs 8 --seed 0 [--ticks N] [--out results/campaigns] \
        [--obs] [--obs-stride N] [--list-presets] [--quiet]

Builds the campaign (heterogeneous jobs packed on a shared hardware map,
characterization-driven fault schedule), runs it under all four mitigation
modes (healthy / faults / ckpt / falcon), scores the paper metrics from the
typed event log, writes the machine-readable report, and prints a summary.

``--obs`` additionally writes the observability sidecars next to the
report: ``<base>.trace.json`` (the falcon run's simulated-clock span
trace, loadable in Perfetto / ``chrome://tracing``) and
``<base>.metrics.json`` (the metric-catalog snapshot). ``--obs-stride N``
keeps every Nth per-job Observation in the report's event log (sampled
iteration-time lanes; default 0 = none, the byte-stable historical form).
Render dashboards from the report with ``python -m repro.launch.obs``.

``--screening-backend`` / ``--reduction-backend`` override the fleet
screen's and the simulators' compute backends (registry names, see
docs/kernels.md); the committed reports pin the deterministic defaults.

The four modes execute on the shared-prefix
:class:`~repro.scenarios.engine.CampaignEngine` (byte-identical to four
independent runs — see docs/scenarios.md); ``--fresh`` forces the
independent executions, the belt-and-braces path the CI ``reuse`` job
diffs the engine against.
"""
from __future__ import annotations

import argparse

from repro.scenarios import get_preset, list_presets, run_and_score, write_report
from repro.scenarios.scoring import RESULTS_DIR


def _fmt(v) -> str:
    return "-" if v is None else (f"{v:.4g}" if isinstance(v, float) else str(v))


def summarize(report: dict) -> str:
    c = report["campaign"]
    det = report["detection"]
    mit = report["mitigation"]
    lines = [
        f"campaign   {c['preset']} seed={c['seed']} jobs={c['n_jobs']} "
        f"fleet={c['n_nodes']}x{c['gpus_per_node']} "
        f"ticks={c['max_ticks']}@{c['tick_seconds']}s "
        f"injections={c['n_injections']}",
        "",
        f"{'cause':<22}{'precision':>10}{'recall':>8}{'episodes':>9}"
        f"{'diags':>6}{'lat_mean_s':>11}{'lat_p90_s':>10}",
    ]
    rows = {"overall": det["overall"], **det["per_cause"]}
    for name, b in rows.items():
        lines.append(
            f"{name:<22}{_fmt(b['precision']):>10}{_fmt(b['recall']):>8}"
            f"{b['episodes']:>9}{b['diagnoses']:>6}"
            f"{_fmt(b['latency_mean_s']):>11}{_fmt(b['latency_p90_s']):>10}"
        )
    lines += [
        "",
        f"slowdown mitigated   {_fmt(mit['slowdown_mitigated_pct'])} % "
        f"(ckpt-restart baseline {_fmt(mit['slowdown_mitigated_ckpt_pct'])} %, "
        f"paper {mit['paper_slowdown_mitigated_pct']} %)",
        f"avg JCT delay        {_fmt(mit['avg_jct_delay_pct'])} % "
        f"(paper {mit['paper_avg_jct_delay_pct']} %)",
        "",
        f"{'job':<5}{'arch':<18}{'parallel':<14}{'join':>5}{'steps':>7}"
        f"{'jct_falcon':>11}{'delay%':>8}{'mitig%':>8}  mitigations",
    ]
    for j in report["jobs"]:
        lines.append(
            f"{j['job_id']:<5}{j['arch']:<18}{j['parallelism']:<14}"
            f"{j['join_tick']:>5}{j['steps']:>7}"
            f"{j['jct_s']['falcon']:>11}{_fmt(j['jct_delay_pct']):>8}"
            f"{_fmt(j['slowdown_mitigated_pct']):>8}  "
            + (",".join(f"{k}x{v}" for k, v in j["mitigations"].items()) or "-")
        )
    joins = sum(1 for m in report["membership"] if m["action"] == "join")
    leaves = sum(1 for m in report["membership"] if m["action"] == "leave")
    lines.append(
        f"\nmembership churn: {joins} joins, {leaves} leaves; "
        f"events: {report['falcon_event_counts']}"
    )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="mixed_fleet")
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ticks", type=int, default=None,
                    help="override the preset's horizon")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--obs", action="store_true",
                    help="write trace/metrics sidecars next to the report")
    ap.add_argument("--obs-stride", type=int, default=0,
                    help="keep every Nth per-job Observation in the event "
                         "log (0 = none)")
    ap.add_argument("--screening-backend", default=None,
                    help="fleet-screen backend (scalar/batched/pallas/auto; "
                         "default: the control plane's auto selection)")
    ap.add_argument("--reduction-backend", default=None,
                    help="simulator reduction backend (reference/vectorized/"
                         "pallas/auto; default: the simulator's auto "
                         "selection)")
    ap.add_argument("--fresh", action="store_true",
                    help="bypass the shared-prefix engine and run the four "
                         "modes independently")
    ap.add_argument("--list-presets", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    if args.list_presets:
        for name in list_presets():
            print(f"{name:<28}{get_preset(name).description}")
        return

    spec, runs, report = run_and_score(
        args.preset, n_jobs=args.jobs, seed=args.seed, max_ticks=args.ticks,
        obs=args.obs, observation_stride=args.obs_stride,
        screening_backend=args.screening_backend,
        reduction_backend=args.reduction_backend,
        fresh=args.fresh,
    )
    path = write_report(report, args.out)
    if not args.quiet:
        print(summarize(report))
    print(f"\nreport: {path}")
    if args.obs:
        from repro.obs.recorder import write_sidecars

        for kind, p in sorted(write_sidecars(
            spec, runs, report, out_dir=args.out
        ).items()):
            print(f"{kind}: {p}")


if __name__ == "__main__":
    main()
