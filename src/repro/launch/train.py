"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch falcon-demo-100m \
        --steps 50 --seq-len 256 --global-batch 32 [--no-falcon] \
        [--inject gpu:3:0.5:100:600] [--smoke] [--events]

``--inject kind:target:severity:start:duration`` adds a fail-slow to the
attached cluster performance model (kind: gpu|cpu|link|nic). Detection and
mitigation run through :mod:`repro.controlplane`; ``--events`` dumps the
control plane's typed event log after the run as JSON lines through the
same :func:`~repro.controlplane.event_log_records` serializer the
campaign reports use (Observations elided; ``--events-stride N`` samples
every Nth per-job Observation into the dump).
"""
from __future__ import annotations

import argparse
import json

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.controlplane import event_log_records
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import FalconTrainer

KIND = {
    "gpu": InjectionKind.GPU_SLOW,
    "cpu": InjectionKind.CPU_CONTENTION,
    "link": InjectionKind.LINK_CONGESTION,
    "nic": InjectionKind.NIC_CONGESTION,
}


def parse_injection(text: str) -> Injection:
    kind, target, severity, start, duration = text.split(":")
    tgt = tuple(int(x) for x in target.split("-"))
    return Injection(
        start=float(start),
        duration=float(duration),
        kind=KIND[kind],
        target=tgt,
        severity=float(severity),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-demo-100m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--dp-groups", type=int, default=4)
    ap.add_argument("--no-falcon", action="store_true")
    ap.add_argument("--inject", action="append", default=[])
    ap.add_argument("--sim-nodes", type=int, default=2)
    ap.add_argument(
        "--events", action="store_true",
        help="dump the control plane's typed event log after the run "
             "(JSON lines, the campaign-report serialization)",
    )
    ap.add_argument(
        "--events-stride", type=int, default=0,
        help="with --events, keep every Nth per-job Observation (0 = none)",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    data = DataConfig(
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        slots=args.slots,
        dp_groups=args.dp_groups,
    )

    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=args.sim_nodes, gpus_per_node=4),
        job=JobSpec(
            model=ModelSpec(
                layers=cfg.num_layers,
                hidden=max(cfg.d_model, 1024),
                seq_len=args.seq_len,
                vocab=cfg.vocab_size,
            ),
            tp=2,
            dp=args.dp_groups,
            pp=1,
            micro_batches=args.slots * args.dp_groups,
        ),
    )
    injector = FailSlowInjector([parse_injection(t) for t in args.inject])

    trainer = FalconTrainer(
        cfg=cfg,
        data=data,
        opt_cfg=AdamWConfig(total_steps=args.steps),
        perf_model=sim,
        injector=injector,
        falcon_enabled=not args.no_falcon,
    )
    history = trainer.run(args.steps)
    print("step,loss,iter_time,wall_time,strategy")
    for r in history:
        print(f"{r.step},{r.loss:.4f},{r.iter_time:.3f},{r.wall_time:.1f},{r.strategy or ''}")
    healthy = min(r.iter_time for r in history)
    mean = sum(r.iter_time for r in history) / len(history)
    print(f"# mean iter {mean:.3f}s vs healthy {healthy:.3f}s "
          f"(slowdown {mean / healthy:.2f}x)")
    if args.events and trainer.control is not None:
        print("# control-plane events:")
        for rec in event_log_records(
            trainer.control.events, observation_stride=args.events_stride
        ):
            print(json.dumps(rec, sort_keys=True))


if __name__ == "__main__":
    main()
