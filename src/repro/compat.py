"""Cross-version jax API shims.

The container's jax (0.4.x) predates several APIs this codebase targets:
top-level ``jax.shard_map`` (``axis_names``/``check_vma``), ``jax.set_mesh``
and ``jax.sharding.get_abstract_mesh``. These helpers bridge both worlds so
the model/train code stays written against the modern surface.
"""
from __future__ import annotations

import jax

HAS_MODERN_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names):
    """``jax.shard_map`` manual over ``axis_names`` across jax versions.

    0.4.x spells the manual axes as the complement of ``auto`` on
    ``jax.experimental.shard_map.shard_map`` and replication checking as
    ``check_rep``.
    """
    if HAS_MODERN_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        auto=auto, check_rep=False,
    )


def ambient_mesh():
    """The mesh the caller entered, or None on meshless hosts.

    Newer jax: ``jax.sharding.get_abstract_mesh()``. Older jax: the pxla
    thread-resources physical mesh set by ``with mesh:``.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def set_mesh(mesh):
    """``jax.set_mesh`` where available; the Mesh context manager otherwise."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh
