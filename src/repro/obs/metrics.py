"""Deterministic metrics registry — counters, gauges, histograms.

Prometheus-flavored naming (``name{label="value",...}``) over plain
Python state: metrics are keyed by ``(name, sorted labels)``, histograms
use fixed cumulative buckets, and :meth:`MetricsRegistry.snapshot`
emits everything sorted with fixed float rounding — so a registry fed
from a deterministic event stream serializes byte-identically
(``<name>.metrics.json``, gated in CI next to the trace sidecar).

The metric *catalog* the campaign recorder feeds — detection latency,
diagnosis counts, time-to-mitigate, executor retries/quarantines, wasted
GPU seconds — lives in :mod:`repro.obs.recorder`; this module is the
mechanism and is dependency-free.
"""
from __future__ import annotations

import json

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: default histogram bucket upper bounds, in seconds (latency-shaped:
#: sub-tick through multi-hour), cumulative le-style with +Inf implied
DEFAULT_BUCKETS = (
    0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0, 600.0,
    1800.0, 3600.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically-increasing total (float increments allowed: some
    totals are seconds, not event counts)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount


class Gauge:
    """A value that is simply *set* (last write wins)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Fixed-bucket cumulative histogram (le semantics, +Inf implied)."""

    __slots__ = ("buckets", "counts", "count", "sum")

    def __init__(self, buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> None:
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError("histogram buckets must be sorted ascending")
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> dict[str, int]:
        out: dict[str, int] = {}
        acc = 0
        for le, n in zip(self.buckets, self.counts):
            acc += n
            out[f"{le:g}"] = acc
        out["+Inf"] = acc + self.counts[-1]
        return out


class MetricsRegistry:
    """Get-or-create metric store keyed by (name, labels)."""

    def __init__(self) -> None:
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}
        self._kinds: dict[str, str] = {}  # name -> kind (no cross-kind reuse)

    def _key(self, kind: str, name: str, labels: dict) -> tuple:
        prior = self._kinds.setdefault(name, kind)
        if prior != kind:
            raise ValueError(
                f"metric {name!r} already registered as a {prior}"
            )
        return (name, _label_key(labels))

    def counter(self, name: str, **labels) -> Counter:
        key = self._key("counter", name, labels)
        return self._counters.setdefault(key, Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        key = self._key("gauge", name, labels)
        return self._gauges.setdefault(key, Gauge())

    def histogram(
        self, name: str, buckets: tuple[float, ...] = DEFAULT_BUCKETS,
        **labels,
    ) -> Histogram:
        key = self._key("histogram", name, labels)
        return self._histograms.setdefault(key, Histogram(buckets))

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """Everything, sorted and rounded — the serialization contract."""

        def rows(store, render):
            return [
                {"name": name, "labels": dict(labels), **render(m)}
                for (name, labels), m in sorted(store.items())
            ]

        return {
            "counters": rows(
                self._counters, lambda m: {"value": round(m.value, 6)}
            ),
            "gauges": rows(
                self._gauges, lambda m: {"value": round(m.value, 6)}
            ),
            "histograms": rows(
                self._histograms,
                lambda m: {
                    "buckets": m.cumulative(),
                    "count": m.count,
                    "sum": round(m.sum, 6),
                },
            ),
        }

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1, sort_keys=True) + "\n"

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path
