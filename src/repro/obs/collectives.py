"""Per-collective timing decomposition of a simulated training iteration.

The simulator's iteration-time formula (docs/simulator.md) is a max/sum
over per-cell reductions; this module re-reads those cached reductions
(:class:`~repro.cluster.simulator._Cells`) and splits the *critical path*
into its four constituents:

* **compute** — the critical DP column's slowest stage, compute part,
  times the pipeline multiplier ``m_d + P - 1``;
* **tp_allreduce** — the same stage's TP ring all-reduce, same multiplier;
* **pp_p2p** — the critical column's activation-hop round trips;
* **dp_allreduce** — the gradient all-reduce of the slowest DP ring.

That turns "job J is slow" into "the DP all-reduce of ring ``dp:s0t0``
over ring edge ``link:0-4`` is the bottleneck" — the CCL-D-style
stream-level attribution ROADMAP item 5a left open. The control plane
attaches a :class:`CollectiveBreakdown` to every onset Diagnosis (see
``docs/observability.md`` for the decomposition contract), so a
``COLLECTIVE_HANG`` or link fault is pinned to the specific collective and
ring edge, not just the job.

This module is a leaf: it imports nothing from the cluster or control
plane layers (the simulator imports *it*), and reads the simulator
duck-typed through its cached-cell surface.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

#: decomposition component names, in reporting order
COMPONENTS = ("compute", "tp_allreduce", "pp_p2p", "dp_allreduce")


@dataclass(frozen=True)
class CollectiveBreakdown:
    """One iteration's critical-path time split, with the bottleneck named.

    ``bottleneck`` is the largest of the four components; ``group`` its
    profiling-group key in the simulator's naming scheme (``tp:s{s}d{d}``,
    ``dp:s{s}t{k}``, ``pp:d{d}``) and ``edge`` the slowest constituent —
    a ring edge ``link:a-b`` (local device ranks, the same ids the
    detector's component validation emits) or, for a compute bottleneck,
    the slowest device ``gpu:r``. ``share`` is the bottleneck's fraction
    of ``total_s``.
    """

    compute_s: float
    tp_allreduce_s: float
    pp_p2p_s: float
    dp_allreduce_s: float
    total_s: float
    bottleneck: str
    group: str
    edge: str
    share: float

    def parts(self) -> dict[str, float]:
        return {
            "compute": self.compute_s,
            "tp_allreduce": self.tp_allreduce_s,
            "pp_p2p": self.pp_p2p_s,
            "dp_allreduce": self.dp_allreduce_s,
        }

    def summary(self) -> dict:
        """Compact rounded view for trace span args / metric labels."""
        return {
            "bottleneck": self.bottleneck,
            "group": self.group,
            "edge": self.edge,
            "share": round(self.share, 4),
            "total_s": round(self.total_s, 6),
        }


def _link(a: int, b: int) -> str:
    lo, hi = sorted((int(a), int(b)))
    return f"link:{lo}-{hi}"


def decompose(sim) -> CollectiveBreakdown:
    """Critical-path decomposition of ``sim``'s current iteration time.

    Reads the cached per-cell reductions (no extra state traversal: the
    call after an ``iteration_time()`` costs O(cells) argmax/argmin work)
    and names the bottleneck collective, its profiling group, and the
    slowest ring edge / device inside it.
    """
    job = sim.job
    c = sim._cells()
    lay = sim._layout()
    grid = lay.grid
    # Critical DP column: the argmax of the pipeline formula, exactly as
    # iteration_time() evaluates it.
    pipe = sim._alloc_off() * c.stage_max
    if c.hop_bw is not None:
        pipe = pipe + c.hop2
    d = int(np.argmax(pipe))
    s = int(np.argmax(c.stage[:, d]))
    n = float(sim._alloc_off()[d])

    compute_s = n * float(c.c_flops / (c.c_speed * c.cell_speed[s, d]))
    tp_s = (
        n * float(c.c_tp / c.tp_bw[s, d]) if c.tp_bw is not None else 0.0
    )
    pp_s = float(c.hop2[d]) if c.hop_bw is not None else 0.0
    dp_s = float(c.c_dp / c.dp_bw.min()) if c.dp_bw is not None else 0.0
    total = float(sim.iteration_time())

    parts = {
        "compute": compute_s,
        "tp_allreduce": tp_s,
        "pp_p2p": pp_s,
        "dp_allreduce": dp_s,
    }
    # First-wins on exact ties: dict order is the fixed COMPONENTS order,
    # so the pick is deterministic.
    bottleneck = max(parts.items(), key=lambda kv: kv[1])[0]

    if bottleneck == "dp_allreduce":
        flat = int(np.argmin(c.dp_bw))
        s2, k2 = divmod(flat, job.tp)
        d2 = int(np.argmin(c.dp_edge[s2, :, k2]))
        group = f"dp:s{s2}t{k2}"
        edge = _link(grid[s2, d2, k2], grid[s2, (d2 + 1) % job.dp, k2])
    elif bottleneck == "tp_allreduce":
        k2 = int(np.argmin(c.tp_edge[s, d]))
        group = f"tp:s{s}d{d}"
        edge = _link(grid[s, d, k2], grid[s, d, (k2 + 1) % job.tp])
    elif bottleneck == "pp_p2p":
        hs = int(np.argmin(c.hop_bw[:, d]))
        group = f"pp:d{d}"
        edge = _link(grid[hs, d, 0], grid[hs + 1, d, 0])
    else:  # compute
        row = grid[s, d]
        speeds = sim.state._compute[row] * sim.state._host[row]
        group = f"tp:s{s}d{d}"
        edge = f"gpu:{int(row[int(np.argmin(speeds))])}"

    return CollectiveBreakdown(
        compute_s=compute_s,
        tp_allreduce_s=tp_s,
        pp_p2p_s=pp_s,
        dp_allreduce_s=dp_s,
        total_s=total,
        bottleneck=bottleneck,
        group=group,
        edge=edge,
        share=parts[bottleneck] / total if total > 0 else 0.0,
    )


def timing_decomposition(sim) -> dict[str, list]:
    """Every cell's time split, as nested lists (the per-cell contract).

    * ``compute_s[s][d]`` / ``tp_allreduce_s[s][d]`` — one micro-batch's
      compute / TP-ring time of TP cell (stage ``s``, dp rank ``d``);
    * ``pp_p2p_s[h][d]`` — the round-trip activation hop between stages
      ``h`` and ``h+1`` of DP column ``d`` (empty when ``pp == 1``);
    * ``dp_allreduce_s[s][k]`` — the full gradient all-reduce of DP ring
      (stage ``s``, tp rank ``k``) (empty when ``dp == 1``).

    ``dp_allreduce_s`` matches ``profile_groups()``'s ``dp:*`` entries and
    ``tp_allreduce_s`` its ``tp:*`` entries bit for bit (same cached
    arrays, same arithmetic) — the equivalence the decomposition tests pin.
    """
    c = sim._cells()
    compute = c.c_flops / (c.c_speed * c.cell_speed)
    out: dict[str, list] = {
        "compute_s": compute.tolist(),
        "tp_allreduce_s": (
            (c.c_tp / c.tp_bw).tolist()
            if c.tp_bw is not None else np.zeros_like(compute).tolist()
        ),
        "pp_p2p_s": (
            (2.0 * c.pp_vol / c.hop_bw).tolist()
            if c.hop_bw is not None else []
        ),
        "dp_allreduce_s": (
            (c.c_dp / c.dp_bw).tolist() if c.dp_bw is not None else []
        ),
    }
    return out
