"""Campaign recorder — feed a :class:`MetricsRegistry` from a scored run.

Sits *above* the control plane and scenarios layers (import it
explicitly: ``from repro.obs import recorder`` — it is deliberately not
re-exported from :mod:`repro.obs`). Two entry points:

* :func:`record_campaign` — walk the falcon run's typed event pipeline
  plus the scored report and populate the full metric catalog
  (docs/observability.md): event/diagnosis/mitigation counters, executor
  retry/quarantine totals, detection-latency and time-to-mitigate
  histograms, wasted-GPU-seconds and headline gauges.
* :func:`write_sidecars` — persist the observability sidecars next to a
  campaign report: ``<preset>-j<n>-s<seed>.trace.json`` (the falcon
  run's span trace, Chrome/Perfetto format) and ``....metrics.json``
  (the registry snapshot). Both are byte-deterministic for identical
  (preset, jobs, seed) inputs — gated in CI like the report itself.
"""
from __future__ import annotations

import os

from repro.controlplane.events import (
    Diagnosis,
    MitigationResult,
    WatchdogAlarm,
)
from repro.core.events import strategy_label
from repro.obs.metrics import MetricsRegistry

__all__ = ["record_campaign", "write_sidecars"]


def record_campaign(spec, runs, report) -> MetricsRegistry:
    """Populate a registry from a campaign's runs + scored report.

    ``spec``/``runs``/``report`` are :func:`repro.scenarios.scoring
    .run_and_score` outputs. Everything recorded is a pure function of
    them, so two runs of the same campaign snapshot byte-identically.
    """
    reg = MetricsRegistry()
    falcon = runs["falcon"]

    # ------------------------------------------------ event-stream walk
    #: job_id -> time of its latest un-mitigated onset diagnosis (the
    #: time-to-mitigate clock; cleared by the first applied dispatch)
    onset_at: dict[str, tuple[float, str]] = {}
    for ev in falcon.events:
        reg.counter("events_total", type=type(ev).__name__).inc()
        if isinstance(ev, Diagnosis):
            if ev.resolved:
                continue
            cause = ev.event.root_cause.value
            reg.counter("diagnoses_total", cause=cause, job=ev.job_id).inc()
            if ev.deduped_from is not None:
                reg.counter("diagnoses_deduped_total").inc()
            if ev.job_id not in onset_at:
                onset_at[ev.job_id] = (ev.time, cause)
            bd = ev.breakdown
            if bd is not None:
                reg.counter(
                    "diagnosis_bottleneck_total", collective=bd.bottleneck
                ).inc()
        elif isinstance(ev, WatchdogAlarm):
            reg.counter("watchdog_alarms_total", job=ev.job_id).inc()
            reg.histogram("watchdog_silence_s").observe(ev.silence_s)
        elif isinstance(ev, MitigationResult):
            if ev.kind == "relief":
                reg.counter("relief_total").inc()
                continue
            if ev.kind == "suppressed":
                reg.counter("suppressed_total").inc()
                continue
            if ev.kind == "error":
                reg.counter("executor_errors_total").inc()
                continue
            label = strategy_label(ev.strategy) if ev.strategy else "none"
            reg.counter(
                "mitigation_attempts_total", strategy=label, status=ev.status
            ).inc()
            if ev.attempt > 1:
                reg.counter("executor_retries_total").inc()
            if ev.detail.get("quarantined") and ev.status == "rolled_back":
                reg.counter("executor_quarantines_total").inc()
            if ev.overhead:
                reg.counter(
                    "mitigation_overhead_s_total", job=ev.job_id
                ).inc(ev.overhead)
            if ev.applied:
                reg.counter("mitigations_applied_total", strategy=label).inc()
                pending = onset_at.pop(ev.job_id, None)
                if pending is not None:
                    t0, cause = pending
                    reg.histogram(
                        "time_to_mitigate_s", cause=cause
                    ).observe(max(ev.time - t0, 0.0))

    # ------------------------------------------------ scored-report walk
    for row in report["episodes"]:
        causes = row["causes"]
        cause = causes[0] if len(causes) == 1 else "mixed"
        if row["detected"] and row["latency_s"] is not None:
            reg.histogram(
                "detection_latency_s", cause=cause
            ).observe(row["latency_s"])
        else:
            reg.counter("missed_episodes_total", cause=cause).inc()
    for row in report["injections"]:
        reg.histogram(
            "fault_duration_s", kind=row["kind"]
        ).observe(row["duration_s"])
    for row in report["robustness"]["watchdog"]["hangs"]:
        if row["time_to_abort_s"] is not None:
            reg.histogram("time_to_abort_s").observe(row["time_to_abort_s"])

    mit = report["mitigation"]
    if mit["slowdown_mitigated_pct"] is not None:
        reg.gauge("slowdown_mitigated_pct", mode="falcon").set(
            mit["slowdown_mitigated_pct"]
        )
    if mit["slowdown_mitigated_ckpt_pct"] is not None:
        reg.gauge("slowdown_mitigated_pct", mode="ckpt").set(
            mit["slowdown_mitigated_ckpt_pct"]
        )
    if mit["avg_jct_delay_pct"] is not None:
        reg.gauge("avg_jct_delay_pct").set(mit["avg_jct_delay_pct"])
    for mode, wasted in report["robustness"]["wasted_gpu_time_s"].items():
        reg.gauge("wasted_gpu_seconds", mode=mode).set(wasted)
    rate = report["robustness"]["watchdog"]["hang_detection_rate"]
    if rate is not None:
        reg.gauge("hang_detection_rate").set(rate)
    for row in report["jobs"]:
        reg.gauge("jct_delay_pct", job=row["job_id"]).set(
            row["jct_delay_pct"]
        )
    return reg


def write_sidecars(spec, runs, report, out_dir=None) -> dict[str, str]:
    """Write the trace/metrics sidecars next to a campaign report.

    Returns ``{"trace": path, "metrics": path}`` (the trace entry is
    omitted when the falcon run carried no tracer). The base name matches
    :func:`repro.scenarios.scoring.write_report`, so
    ``<base>.json`` / ``<base>.trace.json`` / ``<base>.metrics.json``
    sit side by side.
    """
    from repro.scenarios.scoring import RESULTS_DIR

    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    c = report["campaign"]
    base = os.path.join(
        out_dir, f"{c['preset']}-j{c['n_jobs']}-s{c['seed']}"
    )
    paths: dict[str, str] = {}
    tracer = getattr(runs.get("falcon"), "tracer", None)
    if tracer is not None:
        paths["trace"] = f"{base}.trace.json"
        tracer.write(paths["trace"])
    reg = record_campaign(spec, runs, report)
    paths["metrics"] = f"{base}.metrics.json"
    reg.write(paths["metrics"])
    return paths
