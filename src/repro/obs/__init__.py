"""Fleet observability layer — tracing, decomposition, metrics, dashboards.

Four pieces (docs/observability.md):

* :mod:`repro.obs.tracer` — :class:`SpanTracer`, a simulated-clock span
  recorder exported as Chrome trace-event JSON (``<name>.trace.json``,
  Perfetto-loadable, byte-deterministic). Thread one through
  :class:`~repro.controlplane.ControlPlane` (``tracer=``) and
  :func:`~repro.scenarios.campaign.run_campaign` to see tick cadence,
  watchdog silence windows, executor attempt/retry cycles, and per-job
  fault episodes as nested spans.
* :mod:`repro.obs.collectives` — :class:`CollectiveBreakdown` +
  :func:`decompose`: an iteration's critical path split into
  compute / TP-allreduce / PP-p2p / DP-allreduce with the bottleneck
  collective, profiling group and ring edge named. Attached to every
  onset Diagnosis by the control plane.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry`
  (counters/gauges/histograms), snapshotted to ``<name>.metrics.json``.
* :mod:`repro.obs.recorder` / :mod:`repro.obs.dashboard` — feed the
  registry from a campaign's typed event pipeline, and render static
  deterministic HTML/SVG dashboards off the serialized event log
  (``python -m repro.launch.obs``). These two sit *above* the control
  plane and scenarios layers, so they are imported explicitly
  (``from repro.obs import recorder``), not re-exported here — this
  package ``__init__`` must stay a leaf (the cluster simulator imports
  :mod:`repro.obs.collectives`).
"""
from repro.obs.collectives import (  # noqa: F401
    COMPONENTS,
    CollectiveBreakdown,
    decompose,
    timing_decomposition,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.tracer import SpanTracer, TraceError  # noqa: F401
