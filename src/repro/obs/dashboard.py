"""Static dashboard renderer — deterministic HTML/SVG off a campaign report.

``render_dashboard(report)`` is a pure function of the scored report dict
(plus an optional metrics snapshot): no timestamps, no randomness, stable
iteration order, fixed rounding — the same report renders byte-identical
HTML, so a committed dashboard is diffable like any other artifact. The
CLI wrapper is ``python -m repro.launch.obs``.

Three visuals, each an inline SVG:

* **Per-job timeline lanes** — one lane per job from join to completion
  (or the horizon), ground-truth fault windows as colored bands under it,
  onset diagnoses as triangles, applied mitigations as vertical ticks.
  The vertical offset between a band's left edge and its triangle IS the
  detection latency, visible without tooling.
* **Host x time heat map** — every injected episode drawn on its node
  row(s), green when some job's diagnosis traced back to it, red when it
  went undetected (the miss map).
* **Funnel** — detect (flags + watchdog alarms) -> diagnose (onsets) ->
  mitigate (applied dispatches) -> resolve (relief diagnoses), the
  pipeline's attrition at a glance.

Like :mod:`repro.obs.recorder` this sits above the scenarios layer and is
imported explicitly, not via ``repro.obs``.
"""
from __future__ import annotations

__all__ = ["render_dashboard"]

#: fixed per-cause palette (fault kinds map through their cause bucket)
_COLORS = {
    "gpu_degradation": "#e6a23c",
    "network_congestion": "#7b68ee",
    "cpu_contention": "#4baea0",
    "unknown": "#9aa0a6",
    "mixed": "#9aa0a6",
}
_KIND_COLOR = {
    "gpu_slow": "#e6a23c",
    "gpu_hang": "#d9534f",
    "cpu_contention": "#4baea0",
    "nic_congestion": "#5bc0de",
    "link_congestion": "#7b68ee",
    "link_flap": "#b07cc6",
    "collective_hang": "#d9534f",
}
_LANE_H = 26
_PAD_L = 70
_PAD_R = 20


def _esc(s) -> str:
    return (
        str(s).replace("&", "&amp;").replace("<", "&lt;").replace(">", "&gt;")
        .replace('"', "&quot;")
    )


def _f(v: float) -> str:
    """Fixed-precision SVG coordinate (determinism: no float repr drift)."""
    return f"{v:.2f}".rstrip("0").rstrip(".")


class _Svg:
    def __init__(self, width: float, height: float) -> None:
        self.w, self.h = width, height
        self.parts: list[str] = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{_f(width)}" '
            f'height="{_f(height)}" viewBox="0 0 {_f(width)} {_f(height)}" '
            'font-family="sans-serif" font-size="11">'
        ]

    def rect(self, x, y, w, h, fill, opacity=None, title=None) -> None:
        o = f' fill-opacity="{opacity}"' if opacity is not None else ""
        t = f"<title>{_esc(title)}</title>" if title else ""
        self.parts.append(
            f'<rect x="{_f(x)}" y="{_f(y)}" width="{_f(max(w, 1.0))}" '
            f'height="{_f(h)}" fill="{fill}"{o}>{t}</rect>'
            if t else
            f'<rect x="{_f(x)}" y="{_f(y)}" width="{_f(max(w, 1.0))}" '
            f'height="{_f(h)}" fill="{fill}"{o}/>'
        )

    def line(self, x1, y1, x2, y2, stroke, width=1.0) -> None:
        self.parts.append(
            f'<line x1="{_f(x1)}" y1="{_f(y1)}" x2="{_f(x2)}" y2="{_f(y2)}" '
            f'stroke="{stroke}" stroke-width="{_f(width)}"/>'
        )

    def text(self, x, y, s, anchor="start", fill="#333") -> None:
        self.parts.append(
            f'<text x="{_f(x)}" y="{_f(y)}" text-anchor="{anchor}" '
            f'fill="{fill}">{_esc(s)}</text>'
        )

    def tri(self, x, y, size, fill, title=None) -> None:
        pts = (
            f"{_f(x)},{_f(y - size)} {_f(x - size * 0.7)},{_f(y)} "
            f"{_f(x + size * 0.7)},{_f(y)}"
        )
        t = f"<title>{_esc(title)}</title>" if title else ""
        self.parts.append(f'<polygon points="{pts}" fill="{fill}">{t}</polygon>'
                          if t else f'<polygon points="{pts}" fill="{fill}"/>')

    def render(self) -> str:
        return "".join(self.parts) + "</svg>"


def _time_axis(svg: _Svg, x0, x1, y, horizon: float) -> None:
    svg.line(x0, y, x1, y, "#bbb")
    n_ticks = 6
    for i in range(n_ticks + 1):
        t = horizon * i / n_ticks
        x = x0 + (x1 - x0) * i / n_ticks
        svg.line(x, y, x, y + 4, "#bbb")
        svg.text(x, y + 16, f"{int(round(t))}s", anchor="middle", fill="#777")


def _timeline_svg(report: dict, width: float = 960.0) -> str:
    c = report["campaign"]
    horizon = c["max_ticks"] * c["tick_seconds"]
    jobs = report["jobs"]
    h = len(jobs) * _LANE_H + 40
    svg = _Svg(width, h)
    x0, x1 = _PAD_L, width - _PAD_R

    def sx(t: float) -> float:
        return x0 + (x1 - x0) * min(max(t, 0.0), horizon) / horizon

    diags_by_job: dict[str, list[dict]] = {}
    for d in report["diagnoses"]:
        diags_by_job.setdefault(d["job_id"], []).append(d)
    mits_by_job: dict[str, list[dict]] = {}
    resolved_by_job: dict[str, list[float]] = {}
    for rec in report["event_log"]:
        if (
            rec["type"] == "MitigationResult"
            and rec.get("kind") == "mitigate" and rec.get("applied")
        ):
            mits_by_job.setdefault(rec["job_id"], []).append(rec)
        elif rec["type"] == "Diagnosis" and rec.get("resolved"):
            resolved_by_job.setdefault(rec["job_id"], []).append(rec["time"])

    dt = c["tick_seconds"]
    for i, row in enumerate(jobs):
        y = 10 + i * _LANE_H
        jid = row["job_id"]
        join = row["join_tick"] * dt
        jct = row["jct_s"].get("falcon")
        end = join + jct if jct is not None else horizon
        svg.text(x0 - 8, y + 14, jid, anchor="end")
        # lifetime lane
        svg.rect(
            sx(join), y + 6, sx(end) - sx(join), 10, "#dfe7f0",
            title=f"{jid}: {_f(join)}s - {_f(end)}s",
        )
        # ground-truth fault bands
        for ep in row["ground_truth_ticks"]:
            a = ep["onset"] * dt
            b = horizon if ep["relief"] is None else ep["relief"] * dt
            svg.rect(
                sx(a), y + 17, sx(b) - sx(a), 5, "#d9534f", opacity="0.55",
                title=f"injected: {_f(a)}s - {_f(b)}s "
                      f"(severity {ep['severity']})",
            )
        # onset diagnoses
        for d in diags_by_job.get(jid, []):
            color = _COLORS.get(d["cause"], "#9aa0a6")
            svg.tri(
                sx(d["time_s"]), y + 6, 5, color,
                title=f"diagnosed {d['cause']} @ {_f(d['time_s'])}s "
                      f"({', '.join(d['components']) or 'no components'})",
            )
        # applied mitigations
        for m in mits_by_job.get(jid, []):
            svg.line(sx(m["time"]), y + 4, sx(m["time"]), y + 18, "#2c7a2c", 2)
        for t in resolved_by_job.get(jid, []):
            svg.line(sx(t), y + 4, sx(t), y + 18, "#888", 1)
    _time_axis(svg, x0, x1, 10 + len(jobs) * _LANE_H + 4, horizon)
    return svg.render()


def _heatmap_svg(report: dict, width: float = 960.0) -> str:
    c = report["campaign"]
    horizon = c["max_ticks"] * c["tick_seconds"]
    n_nodes = c["n_nodes"]
    gpn = c["gpus_per_node"]
    h = n_nodes * _LANE_H + 40
    svg = _Svg(width, h)
    x0, x1 = _PAD_L, width - _PAD_R

    def sx(t: float) -> float:
        return x0 + (x1 - x0) * min(max(t, 0.0), horizon) / horizon

    node_kinds = ("cpu_contention", "nic_congestion")
    for n in range(n_nodes):
        y = 10 + n * _LANE_H
        svg.text(x0 - 8, y + 14, f"n{n}", anchor="end")
        svg.rect(sx(0), y + 4, x1 - x0, _LANE_H - 8, "#f4f6f8")
    for inj in report["injections"]:
        if inj["kind"] in node_kinds:
            nodes = list(inj["target"])
        else:
            nodes = sorted({d // gpn for d in inj["target"]})
        detected = bool(inj["detected_by"])
        fill = "#3c9a5f" if detected else "#d9534f"
        a, b = inj["start_s"], inj["start_s"] + inj["duration_s"]
        for n in nodes:
            if not 0 <= n < n_nodes:
                continue
            y = 10 + n * _LANE_H
            svg.rect(
                sx(a), y + 4, sx(min(b, horizon)) - sx(a), _LANE_H - 8,
                fill, opacity="0.75",
                title=f"#{inj['id']} {inj['kind']} target={inj['target']} "
                      f"{_f(a)}s +{_f(inj['duration_s'])}s "
                      f"severity={inj['severity']} "
                      + ("detected by " + ",".join(inj["detected_by"])
                         if detected else "UNDETECTED"),
            )
    _time_axis(svg, x0, x1, 10 + n_nodes * _LANE_H + 4, horizon)
    return svg.render()


def _funnel_svg(report: dict, width: float = 480.0) -> str:
    counts = report["falcon_event_counts"]
    onsets = len(report["diagnoses"])
    resolved = sum(
        1 for r in report["event_log"]
        if r["type"] == "Diagnosis" and r.get("resolved")
    )
    applied = sum(
        1 for r in report["event_log"]
        if r["type"] == "MitigationResult"
        and r.get("kind") == "mitigate" and r.get("applied")
    )
    stages = [
        ("detect", counts.get("Flag", 0) + counts.get("WatchdogAlarm", 0)),
        ("diagnose", onsets),
        ("mitigate", applied),
        ("resolve", resolved),
    ]
    top = max((v for _, v in stages), default=0) or 1
    h = len(stages) * 34 + 10
    svg = _Svg(width, h)
    for i, (name, v) in enumerate(stages):
        y = 8 + i * 34
        w = (width - 200) * v / top
        svg.text(90, y + 15, name, anchor="end")
        svg.rect(100, y, w, 22, "#4878a8", title=f"{name}: {v}")
        svg.text(104 + w, y + 15, str(v))
    return svg.render()


def _metrics_table(metrics: dict) -> str:
    rows = []
    for g in metrics.get("gauges", []):
        labels = ",".join(f"{k}={v}" for k, v in sorted(g["labels"].items()))
        rows.append(
            f"<tr><td>{_esc(g['name'])}"
            + (f"{{{_esc(labels)}}}" if labels else "")
            + f"</td><td>{g['value']}</td></tr>"
        )
    if not rows:
        return ""
    return (
        "<h2>Headline gauges</h2><table><tr><th>metric</th><th>value</th>"
        "</tr>" + "".join(rows) + "</table>"
    )


def render_dashboard(report: dict, metrics: dict | None = None) -> str:
    """Render a scored campaign report into one standalone HTML page."""
    c = report["campaign"]
    mit = report["mitigation"]
    det = report["detection"]["overall"]
    headline = (
        f"slowdown mitigated {mit['slowdown_mitigated_pct']}% "
        f"(ckpt baseline {mit['slowdown_mitigated_ckpt_pct']}%), "
        f"precision {det['precision']}, recall {det['recall']}"
    )
    parts = [
        "<!DOCTYPE html><html><head><meta charset='utf-8'>",
        f"<title>{_esc(c['preset'])} campaign dashboard</title>",
        "<style>body{font-family:sans-serif;margin:24px;color:#222}"
        "h1{font-size:20px}h2{font-size:15px;margin-top:28px}"
        "table{border-collapse:collapse;font-size:12px}"
        "td,th{border:1px solid #ccc;padding:3px 8px;text-align:left}"
        ".legend{font-size:12px;color:#555;margin:4px 0 12px}"
        "</style></head><body>",
        f"<h1>{_esc(c['preset'])} — j{c['n_jobs']} s{c['seed']} "
        f"({c['n_nodes']} nodes x {c['gpus_per_node']} GPUs)</h1>",
        f"<p>{_esc(c['description'])}</p>",
        f"<p><b>{_esc(headline)}</b></p>",
        "<h2>Per-job timelines (falcon run)</h2>",
        "<div class='legend'>lane = job lifetime; red band = injected "
        "fault window (ground truth); triangle = onset diagnosis; green "
        "tick = applied mitigation; grey tick = relief</div>",
        _timeline_svg(report),
        "<h2>Host x time — injected vs detected</h2>",
        "<div class='legend'>green = episode traced back by some job's "
        "diagnosis; red = undetected</div>",
        _heatmap_svg(report),
        "<h2>Pipeline funnel</h2>",
        _funnel_svg(report),
    ]
    if metrics is not None:
        parts.append(_metrics_table(metrics))
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"
