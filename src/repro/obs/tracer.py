"""Simulated-clock span tracer with Chrome trace-event export.

:class:`SpanTracer` records *what the control plane did and when* on the
campaign's simulated clock: nested spans (``begin``/``end`` or the direct
``span``), instants, and counter samples, each on a named track. Tracks
are ``(process, thread)`` string pairs — the exporter assigns stable
pid/tid numbers in first-use order, so identical runs produce identical
traces byte for byte (the determinism CI gates on the sidecars).

Export is the Chrome trace-event JSON format (``{"traceEvents": [...]}``,
phases ``X``/``i``/``C``/``M``) — drop ``<name>.trace.json`` into
Perfetto or ``chrome://tracing`` to browse a campaign's control-plane
timeline: tick cadence, watchdog silence windows, executor attempt/retry
cycles, per-job fault episodes.

Timestamps are simulated seconds; the exporter converts to integer
microseconds. Nothing here reads a wall clock, so tracing never perturbs
the traced run.
"""
from __future__ import annotations

import json

__all__ = ["SpanTracer", "TraceError"]


class TraceError(RuntimeError):
    """Span nesting violation (end without begin, name mismatch)."""


def _round(v):
    return round(float(v), 6) if isinstance(v, float) else v


def _clean_args(args: dict) -> dict:
    return {
        str(k): (
            _round(v) if not isinstance(v, (list, tuple))
            else [_round(x) for x in v]
        )
        for k, v in args.items()
    }


class SpanTracer:
    """Deterministic span/instant/counter recorder on a simulated clock."""

    __slots__ = ("_events", "_stacks", "counter_stride")

    def __init__(self, counter_stride: int = 10) -> None:
        #: finished events: ("X"|"i"|"C", track, name, ts, dur, args)
        self._events: list[tuple] = []
        #: per-track stack of open spans: [(name, ts_begin, args), ...]
        self._stacks: dict[tuple[str, str], list] = {}
        #: sampling stride for per-step counter feeds (the plane emits an
        #: iteration-time counter point every ``counter_stride`` steps)
        self.counter_stride = max(int(counter_stride), 1)

    # ------------------------------------------------------------ record
    def begin(
        self, track: tuple[str, str], name: str, ts: float,
        args: dict | None = None,
    ) -> None:
        """Open a span; spans on one track must nest (stack discipline)."""
        self._stacks.setdefault(track, []).append((name, float(ts), args))

    def end(
        self, track: tuple[str, str], ts: float,
        name: str | None = None, args: dict | None = None,
    ) -> None:
        """Close the innermost open span on ``track``."""
        stack = self._stacks.get(track)
        if not stack:
            raise TraceError(f"end with no open span on track {track!r}")
        open_name, ts0, open_args = stack.pop()
        if name is not None and name != open_name:
            stack.append((open_name, ts0, open_args))
            raise TraceError(
                f"end({name!r}) does not match open span {open_name!r} "
                f"on track {track!r}"
            )
        merged = dict(open_args or {})
        if args:
            merged.update(args)
        self._events.append(
            ("X", track, open_name, ts0, max(float(ts) - ts0, 0.0), merged)
        )

    def span(
        self, track: tuple[str, str], name: str,
        ts_start: float, ts_end: float, args: dict | None = None,
    ) -> None:
        """Record a complete span directly (no stack interaction)."""
        self._events.append((
            "X", track, name, float(ts_start),
            max(float(ts_end) - float(ts_start), 0.0), dict(args or {}),
        ))

    def instant(
        self, track: tuple[str, str], name: str, ts: float,
        args: dict | None = None,
    ) -> None:
        self._events.append(("i", track, name, float(ts), 0.0, dict(args or {})))

    def counter(
        self, track: tuple[str, str], name: str, ts: float, value: float,
    ) -> None:
        self._events.append(
            ("C", track, name, float(ts), 0.0, {name: float(value)})
        )

    # -------------------------------------------------------- inspection
    def open_spans(self) -> dict[tuple[str, str], list[str]]:
        """Names of currently-open spans per track, outermost first."""
        return {
            track: [name for name, _, _ in stack]
            for track, stack in self._stacks.items() if stack
        }

    def close_track(self, track: tuple[str, str], ts: float) -> int:
        """Close every open span on one track (innermost out); returns
        how many were closed."""
        n = 0
        while self._stacks.get(track):
            self.end(track, ts)
            n += 1
        return n

    def close_all(self, ts: float) -> int:
        """Close every open span everywhere — the campaign's horizon
        censoring: a fault span still open when the run ends is truncated
        at the horizon rather than dropped."""
        n = 0
        for track in sorted(self._stacks):
            n += self.close_track(track, ts)
        return n

    def __len__(self) -> int:
        return len(self._events)

    # ------------------------------------------------------------ export
    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event dict (Perfetto-loadable).

        pid/tid assignment follows first use, and metadata naming events
        lead the stream — identical recording orders therefore serialize
        byte-identically.
        """
        if any(stack for stack in self._stacks.values()):
            raise TraceError(
                f"open spans at export: {self.open_spans()!r} "
                "(call close_all(horizon) first)"
            )
        pids: dict[str, int] = {}
        tids: dict[tuple[str, str], int] = {}
        for _, track, *_ in self._events:
            proc, thread = track
            if proc not in pids:
                pids[proc] = len(pids) + 1
            if track not in tids:
                tids[track] = (
                    sum(1 for t in tids if t[0] == proc) + 1
                )
        meta: list[dict] = []
        for proc, pid in pids.items():
            meta.append({
                "ph": "M", "pid": pid, "tid": 0, "ts": 0,
                "name": "process_name", "args": {"name": proc},
            })
        for (proc, thread), tid in tids.items():
            meta.append({
                "ph": "M", "pid": pids[proc], "tid": tid, "ts": 0,
                "name": "thread_name", "args": {"name": thread},
            })
        events: list[dict] = []
        for ph, track, name, ts, dur, args in self._events:
            rec: dict = {
                "ph": ph,
                "pid": pids[track[0]],
                "tid": tids[track],
                "ts": int(round(ts * 1e6)),
                "name": name,
            }
            if ph == "X":
                rec["dur"] = int(round(dur * 1e6))
            if ph == "i":
                rec["s"] = "t"  # thread-scoped instant
            if args:
                rec["args"] = _clean_args(args)
            events.append(rec)
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.to_chrome(), indent=1, sort_keys=True) + "\n"

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path
