"""From-scratch optimizers (no optax in this environment)."""
