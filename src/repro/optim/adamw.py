"""AdamW + global-norm clipping + cosine schedule, pure JAX.

Moments are fp32 and — beyond the paper — ZeRO-1-style sharded over the DP
axes where a parameter dimension divides them (see ``zero1_specs``), which
cuts per-device optimizer memory by ~|DP| for the large 2D-sharded weights.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(params: dict) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: dict) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(
    cfg: AdamWConfig, grads: dict, state: AdamWState, params: dict
) -> tuple[dict, AdamWState]:
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_vec + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    new_p, new_mu, new_nu = [], [], []
    for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu, strict=True):
        a, b, c = upd(p, g, m, n)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        AdamWState(
            step=step,
            mu=jax.tree.unflatten(treedef, new_mu),
            nu=jax.tree.unflatten(treedef, new_nu),
        ),
    )


# ------------------------------------------------------------- sharding
def zero1_specs(param_spec_tree: dict, shapes: dict, mesh: Mesh) -> dict:
    """Moment specs: like the parameter spec, plus ZeRO-1 sharding of the
    first still-replicated dimension over the DP axes when divisible."""
    from repro.sharding.partition import batch_axes, mesh_axis_size

    ba = batch_axes(mesh)
    dp = mesh_axis_size(mesh, ba)

    def one(spec: P, shape) -> P:
        if dp <= 1:
            return spec
        dims = list(spec) + [None] * (len(shape.shape) - len(spec))
        # FSDP-sharded params already use the DP axes — a mesh axis can only
        # appear once per spec, and the moments inherit that sharding anyway.
        used = {a for s in dims if s for a in (s if isinstance(s, tuple) else (s,))}
        if used & set(ba):
            return P(*dims)
        for i, (d, s) in enumerate(zip(shape.shape, dims, strict=True)):
            if s is None and d % dp == 0 and d >= dp:
                dims[i] = ba
                return P(*dims)
        return spec

    return jax.tree.map(
        one, param_spec_tree, shapes, is_leaf=lambda x: isinstance(x, P)
    )


def opt_state_specs(param_spec_tree: dict, shapes: dict, mesh: Mesh) -> AdamWState:
    moment = zero1_specs(param_spec_tree, shapes, mesh)
    return AdamWState(step=P(), mu=moment, nu=moment)
