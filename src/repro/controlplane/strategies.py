"""Pluggable mitigation strategies (paper §5, Table 3) + registry.

Each strategy is one class implementing :class:`MitigationStrategy`; the
:class:`StrategyRegistry` is an ordered table the
:class:`~repro.core.planner.MitigationPlanner` escalates through (cheapest
applicable first, the paper's ski-rental rule). The four built-ins port the
ladder that used to be hand-wired in ``FalconTrainer._apply_strategy``:

* :class:`IgnoreStrategy`          — S1, bookkeeping only.
* :class:`MicroBatchStrategy`      — S2, ``core.microbatch.solve_allocation``
  over the profiled per-group speeds.
* :class:`TopologyStrategy`        — S3, targeted congestion swap /
  straggler consolidation / QAP local search from ``core.topology``, with
  the measure-before-commit revert.
* :class:`CkptRestartStrategy`     — S4, restart onto healthy devices.

Two *placement-aware* rungs extend the ladder beyond the paper
(:func:`placement_registry`; Malleus-style group malleability, see
:mod:`repro.core.placement` and docs/mitigation.md):

* :class:`PlacementMicroBatchStrategy` — ``S2P``: when a host-scoped fault
  hits every DP group equally (node-spanning groups leave S2 no skew),
  re-shape the groups so the slow host concentrates in as few of them as
  possible, then re-solve the micro-batch split over the restored skew.
* :class:`PlacementTopologyStrategy`   — ``S3P``: when congestion hits a
  re-shaped layout whose DP rings now cross the congested fabric, restore
  the canonical stage-contiguous placement to internalize ring traffic.

Both measure the modeled iteration time before committing and revert when
the re-shape does not pay (a concentrated layout sends DP rings across
the inter-node fabric — whether that trade wins depends on severity).

A new scenario (e.g. swapping in a hot spare) is one more class registered
with its overhead — no trainer or planner edit; see docs/control_plane.md
for a worked example.
"""
from __future__ import annotations

from collections.abc import Callable, Collection
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from repro.cluster.injector import HANG_KINDS, InjectionKind
from repro.core import microbatch as mb_lib
from repro.core import topology as topo_lib
from repro.core.duration import DurationModel
from repro.core.events import FailSlowEvent, RootCause, Strategy, StrategyKey
from repro.core.placement import PlacementPlanner, slow_devices_for
from repro.core.planner import DEFAULT_OVERHEADS, MitigationPlanner, PlannerKnobs

#: default wall-clock overheads of the placement rungs: a group re-shape
#: exchanges optimizer/parameter shards between the swapped ranks —
#: heavier than an S2 re-split, comparable to an S3 placement swap.
#: ABORT_REFORM (collective abort + group re-form) sits above them: it
#: tears down and rebuilds the communicator state, but stays far below a
#: full checkpoint-restart.
PLACEMENT_OVERHEADS: dict[StrategyKey, float] = {
    "S2P": 8.0, "S3P": 12.0, "ABORT_REFORM": 20.0,
}


@dataclass
class MitigationContext:
    """Everything a strategy may touch when it fires.

    ``now`` is the job clock at dispatch time (before the strategy's own
    overhead is charged). ``injector`` is the job's fail-slow injector when
    one drives the modeled cluster — S4 clears injections that a restart
    onto healthy hardware escapes.
    """

    adapter: object
    event: FailSlowEvent
    now: float = 0.0
    job_id: str = ""
    injector: object | None = None


@dataclass(frozen=True)
class StrategyOutcome:
    """What a dispatch did: ``applied`` + payload for the caller's runtime."""

    applied: bool
    detail: dict = field(default_factory=dict)


@runtime_checkable
class MitigationStrategy(Protocol):
    """One mitigation mechanism, registered under a :data:`StrategyKey`."""

    key: StrategyKey

    def handles(self, event: FailSlowEvent) -> bool:
        """Whether this strategy can act on the event's root cause."""
        ...

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        """Perform the mitigation against ``ctx.adapter``."""
        ...

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        """Undo skew after the fail-slow resolves (None = nothing to do)."""
        ...


# ------------------------------------------------------------------ S1
@dataclass
class IgnoreStrategy:
    """S1 — tolerate the slowdown; zero overhead, always applicable."""

    key: StrategyKey = Strategy.IGNORE

    def handles(self, event: FailSlowEvent) -> bool:
        return True

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        return StrategyOutcome(applied=True)

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        return None


# ------------------------------------------------------------------ S2
@dataclass
class MicroBatchStrategy:
    """S2 — redistribute micro-batches by profiled per-group speed."""

    key: StrategyKey = Strategy.ADJUST_MICROBATCH

    def handles(self, event: FailSlowEvent) -> bool:
        if getattr(event, "hang", False):
            return False  # re-splitting batches cannot unstick a hang
        # Table 3: "No Effect" on slow communication.
        return event.root_cause is not RootCause.NETWORK_CONGESTION

    def _solve(self, sim) -> list[int] | None:
        if not hasattr(sim, "per_microbatch_times"):
            return None
        return mb_lib.solve_allocation(
            sim.per_microbatch_times(), sim.job.micro_batches,
            offset=sim.job.pp - 1,
        )

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        counts = self._solve(ctx.adapter)
        if counts is None:
            return StrategyOutcome(applied=False)
        ctx.adapter.set_allocation(counts)
        return StrategyOutcome(applied=True, detail={"allocation": counts})

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        """Post-relief rebalance: recompute the split from the (now healthy)
        profile so a skewed allocation doesn't outlive the fail-slow it
        compensated for."""
        counts = self._solve(ctx.adapter)
        if counts is None:
            return None
        ctx.adapter.set_allocation(counts)
        return StrategyOutcome(applied=True, detail={"allocation": counts})


# ------------------------------------------------------------------ S3
@dataclass
class TopologyStrategy:
    """S3 — placement adjustment, kept only if modeled time improves.

    A blind consolidation can re-expose a congested link the previous
    targeted swap had evacuated, so mitigation effects are re-measured
    before being committed.
    """

    key: StrategyKey = Strategy.ADJUST_TOPOLOGY
    #: forwarded to the QAP local search (None = library default)
    max_rounds: int | None = None

    def handles(self, event: FailSlowEvent) -> bool:
        # A placement swap routes traffic around a *slow* component; a hung
        # collective blocks every member regardless of where it sits.
        return not getattr(event, "hang", False)

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        if not hasattr(sim, "apply_placement"):
            return StrategyOutcome(applied=False)
        before_placement = list(sim.placement)
        before_t = sim.iteration_time()
        self._plan_and_apply(sim, ctx.event)
        if sim.iteration_time() > before_t * 0.999:
            sim.placement = before_placement  # revert: no improvement
            return StrategyOutcome(applied=True, detail={"reverted": True})
        return StrategyOutcome(
            applied=True, detail={"reverted": False, "placement": list(sim.placement)}
        )

    def _plan_and_apply(self, sim, event: FailSlowEvent) -> None:
        job, topo = sim.job, sim.job.topology
        stragglers = [
            int(c.split(":")[1]) for c in event.components if c.startswith("gpu:")
        ]
        slow_links = [
            tuple(int(x) for x in c.split(":")[1].split("-"))
            for c in event.components
            if c.startswith("link:")
        ]
        if stragglers and not slow_links and topo.pp > 1:
            # Straggler consolidation (Fig. 11): pack the positions hosting
            # slow devices into the fewest PP stages.
            pos = [p for p, d in enumerate(sim.placement) if d in set(stragglers)]
            perm = topo_lib.consolidate_stragglers(pos, topo)
            sim.apply_placement(perm)
            return
        m = job.model
        traffic = topo_lib.build_traffic_matrix(
            topo,
            comm_tp=m.comm_tp_bytes(job.tp, job.pp, job.micro_batches),
            comm_dp=m.comm_dp_bytes(job.tp, job.pp),
            comm_pp=m.comm_pp_bytes(job.micro_batches),
        )
        n = job.n_devices
        bw = np.full((n, n), np.inf)
        for i in range(n):
            for j in range(n):
                if i != j:
                    bw[i, j] = sim.state.link_bw(sim.placement[i], sim.placement[j])
        if slow_links:
            # Targeted congestion swap (Fig. 10): FALCON pinpointed the slow
            # physical links; move their endpoints' traffic elsewhere.
            slow_pos = [
                p for p, d in enumerate(sim.placement)
                if any(d in pair for pair in slow_links)
            ]
            perm = topo_lib.plan_targeted_swap(traffic, bw, slow_pos)
        elif self.max_rounds is not None:
            perm = topo_lib.plan_topology_adjustment(
                traffic, bw, max_rounds=self.max_rounds
            )
        else:
            perm = topo_lib.plan_topology_adjustment(traffic, bw)
        sim.apply_placement(perm)

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        return None  # placement stays; it is optimal for the healthy state too


# ----------------------------------------------------------------- S2P
def _remap_surface(sim) -> bool:
    return all(
        hasattr(sim, a)
        for a in ("remap_groups", "per_microbatch_times", "set_allocation")
    )


def _solve_alloc(sim) -> list[int]:
    return mb_lib.solve_allocation(
        sim.per_microbatch_times(), sim.job.micro_batches,
        offset=sim.job.pp - 1,
    )


@dataclass
class PlacementMicroBatchStrategy:
    """S2P — re-shape DP groups around a host fault, then re-split batches.

    The remap is committed only if the modeled iteration time beats the
    best S2-alone split on the *current* placement: concentration trades
    intra-node DP rings for inter-node ones, a trade that wins for severe
    faults and loses for weak ones (measured, not assumed).
    """

    key: StrategyKey = "S2P"
    planner: PlacementPlanner = field(default_factory=PlacementPlanner)

    def handles(self, event: FailSlowEvent) -> bool:
        # Compute-side faults with located components: host-scoped (node:)
        # or device-scoped (gpu:) — S2P, like S2, cannot fix slow comm.
        if event.root_cause is RootCause.NETWORK_CONGESTION:
            return False
        if getattr(event, "hang", False):
            return False  # a re-shape cannot unstick a hang either
        return any(
            c.partition(":")[0] in ("node", "gpu") for c in event.components
        )

    #: a concentration must beat the S2-alone split by this factor to be
    #: committed (hysteresis: marginal remaps are not worth carrying into
    #: whatever fault comes next); restoring the canonical layout only
    #: needs to not lose
    commit_factor: float = 0.97

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        if not _remap_surface(sim):
            return StrategyOutcome(applied=False)
        node_of = getattr(sim, "node_of_rank", None)
        slow = slow_devices_for(ctx.event, sim.job.n_devices, node_of)
        remap = self.planner.plan(
            tp=sim.job.tp, dp=sim.job.dp, pp=sim.job.pp,
            placement=sim.placement, slow=slow, node_of=node_of,
        )
        # Candidate shapes for the *current* diagnosis: concentrate around
        # it, or fall back to the canonical layout (un-doing a previous
        # concentration whose fault has moved on — compound events replace
        # the diagnosis without a relief, so S2P must re-shape both ways).
        shapes: list[tuple[str, list[int], float]] = []
        if remap is not None:
            shapes.append(
                ("concentrated", list(remap.placement), self.commit_factor)
            )
        canonical = sorted(sim.placement)
        if canonical != list(sim.placement):
            shapes.append(("canonical", canonical, 0.999))
        if not shapes:
            return StrategyOutcome(applied=False, detail={"no_remap": True})
        saved_place = list(sim.placement)
        base_alloc = _solve_alloc(sim)
        sim.set_allocation(base_alloc)
        best_t = sim.iteration_time()
        best: tuple[str, list[int], list[int]] | None = None
        for name, place, factor in shapes:
            sim.remap_groups(place)
            alloc = _solve_alloc(sim)
            sim.set_allocation(alloc)
            t = sim.iteration_time()
            if t < best_t * factor:
                best_t, best = t, (name, place, alloc)
            sim.remap_groups(saved_place)
        if best is None:
            # No shape beats the S2-alone split on the current placement.
            sim.set_allocation(base_alloc)
            return StrategyOutcome(applied=True, detail={"reverted": True})
        name, place, alloc = best
        sim.remap_groups(place)
        sim.set_allocation(alloc)
        detail: dict = {"reverted": False, "shape": name, "allocation": alloc}
        if name == "concentrated" and remap is not None:
            detail["slow_groups"] = list(remap.slow_groups)
        return StrategyOutcome(applied=True, detail=detail)

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        """A concentrated layout is *not* optimal for a healthy cluster
        (its DP rings cross nodes): after relief, restore the canonical
        placement when that measures faster."""
        sim = ctx.adapter
        if not _remap_surface(sim):
            return None
        canonical = sorted(sim.placement)
        if canonical == list(sim.placement):
            return None
        saved_place = list(sim.placement)
        sim.set_allocation(_solve_alloc(sim))
        base_t = sim.iteration_time()
        sim.remap_groups(canonical)
        sim.set_allocation(_solve_alloc(sim))
        if sim.iteration_time() >= base_t * 0.999:
            sim.remap_groups(saved_place)
            sim.set_allocation(_solve_alloc(sim))
            return None
        return StrategyOutcome(applied=True, detail={"restored": True})


# ----------------------------------------------------------------- S3P
@dataclass
class PlacementTopologyStrategy:
    """S3P — internalize ring traffic away from congested inter-node fabric.

    The compound-fault counterpart of S2P: a NIC congests *while* a
    re-shaped (concentrated) layout has DP rings crossing that NIC. The
    canonical stage-contiguous placement sends only the light PP
    activations across nodes; restore it when the model says it wins.
    """

    key: StrategyKey = "S3P"

    def handles(self, event: FailSlowEvent) -> bool:
        if getattr(event, "hang", False):
            return False  # hangs take the abort/re-form path, not a re-shape
        if event.root_cause not in (
            RootCause.NETWORK_CONGESTION, RootCause.UNKNOWN
        ):
            return False
        return any(
            c.partition(":")[0] in ("nic", "link") for c in event.components
        )

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        if not _remap_surface(sim):
            return StrategyOutcome(applied=False)
        canonical = sorted(sim.placement)
        if canonical == list(sim.placement):
            return StrategyOutcome(applied=False, detail={"no_remap": True})
        saved_place = list(sim.placement)
        # Fair comparison: re-solve the split on the current placement too
        # (its allocation may be stale for the new fault state) before
        # measuring it against the canonical restore.
        base_alloc = _solve_alloc(sim)
        sim.set_allocation(base_alloc)
        base_t = sim.iteration_time()
        sim.remap_groups(canonical)
        sim.set_allocation(_solve_alloc(sim))
        if sim.iteration_time() >= base_t * 0.999:
            sim.remap_groups(saved_place)
            sim.set_allocation(base_alloc)
            return StrategyOutcome(applied=True, detail={"reverted": True})
        return StrategyOutcome(applied=True, detail={"reverted": False})

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        return None  # canonical placement is optimal for the healthy state


# ------------------------------------------------------------------ S4
@dataclass
class CkptRestartStrategy:
    """S4 — checkpoint-and-restart onto healthy devices (last resort)."""

    key: StrategyKey = Strategy.CKPT_AND_RESTART

    def handles(self, event: FailSlowEvent) -> bool:
        return True

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        if not hasattr(sim, "restart"):
            return StrategyOutcome(applied=False)
        sim.restart()
        if ctx.injector is not None:
            # Restart lands on healthy nodes: clear active injections.
            ctx.injector.injections = [
                i for i in ctx.injector.injections if not i.active(ctx.now)
            ]
        return StrategyOutcome(applied=True, detail={"restarted": True})

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        return None


# --------------------------------------------------------- ABORT_REFORM
@dataclass
class AbortReformStrategy:
    """Abort a stalled collective and re-form the communication group.

    The hang-specific rung (CCL-D's abort-and-reform, arXiv 2605.04478): a
    ``COLLECTIVE_HANG`` is *software* state — a collective stuck on a link
    — so aborting the operation and rebuilding the group on the same
    devices clears it. Modeled as dropping the active collective-hang
    injections (the stuck operation is gone) and re-forming the groups to
    the canonical stage-contiguous placement with a fresh micro-batch
    split. A ``GPU_HANG`` is *hardware* — abort cannot revive the device —
    and when the adapter exposes no injector/remap surface there is
    nothing to abort either; both fall back to the S4 restart-onto-healthy
    semantics (the "re-form is impossible" escape hatch).
    """

    key: StrategyKey = "ABORT_REFORM"

    def handles(self, event: FailSlowEvent) -> bool:
        return bool(getattr(event, "hang", False))

    @staticmethod
    def _active_hangs(ctx: MitigationContext) -> list:
        inj = ctx.injector
        if inj is None or not hasattr(inj, "injections"):
            return []
        return [
            i for i in inj.injections
            if getattr(i, "kind", None) in HANG_KINDS and i.active(ctx.now)
        ]

    def apply(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        hangs = self._active_hangs(ctx)
        coll = [
            i for i in hangs if i.kind is InjectionKind.COLLECTIVE_HANG
        ]
        hard = [i for i in hangs if i.kind is InjectionKind.GPU_HANG]
        if not coll or hard or not _remap_surface(sim):
            return self._fallback_s4(ctx)
        inj = ctx.injector
        drop = {id(i) for i in coll}
        # Wholesale reassignment: bumps the injector epoch so schedule
        # cursors re-apply (the same contract S4 relies on).
        inj.injections = [i for i in inj.injections if id(i) not in drop]
        canonical = sorted(sim.placement)
        if canonical != list(sim.placement):
            sim.remap_groups(canonical)
        sim.set_allocation(_solve_alloc(sim))
        return StrategyOutcome(
            applied=True,
            detail={"aborted": len(coll), "reformed": True,
                    "scopes": sorted({i.scope for i in coll if i.scope})},
        )

    def _fallback_s4(self, ctx: MitigationContext) -> StrategyOutcome:
        sim = ctx.adapter
        if not hasattr(sim, "restart"):
            return StrategyOutcome(applied=False, detail={"fallback": "none"})
        sim.restart()
        if ctx.injector is not None and hasattr(ctx.injector, "injections"):
            ctx.injector.injections = [
                i for i in ctx.injector.injections if not i.active(ctx.now)
            ]
        return StrategyOutcome(applied=True, detail={"fallback": "S4"})

    def relieve(self, ctx: MitigationContext) -> StrategyOutcome | None:
        return None


# ------------------------------------------------------------- registry
class StrategyRegistry:
    """Ordered strategy table + planner factory.

    Registration order is the tie-break for equal overheads (the planner's
    sort is stable), so registering S1..S4 in order reproduces the paper's
    ladder exactly; custom strategies slot in wherever their overhead puts
    them.
    """

    def __init__(self) -> None:
        self._table: dict[StrategyKey, MitigationStrategy] = {}
        self._overheads: dict[StrategyKey, float] = {}

    # -- population ----------------------------------------------------
    def register(
        self, strategy: MitigationStrategy, overhead: float | None = None
    ) -> "StrategyRegistry":
        key = strategy.key
        self._table[key] = strategy
        if overhead is not None:
            self._overheads[key] = overhead
        elif key in DEFAULT_OVERHEADS:
            self._overheads.setdefault(key, DEFAULT_OVERHEADS[key])
        elif key in PLACEMENT_OVERHEADS:
            self._overheads.setdefault(key, PLACEMENT_OVERHEADS[key])
        else:
            raise ValueError(f"strategy {key!r} needs an explicit overhead")
        return self

    def __contains__(self, key: StrategyKey) -> bool:
        return key in self._table

    def keys(self) -> list[StrategyKey]:
        return list(self._table)

    def overheads(self, overrides: dict | None = None) -> dict[StrategyKey, float]:
        out = dict(self._overheads)
        if overrides:
            out.update(overrides)
        return out

    # -- planner + dispatch ---------------------------------------------
    def candidates(self, event: FailSlowEvent) -> list[StrategyKey]:
        return [k for k, s in self._table.items() if s.handles(event)]

    def make_planner(
        self,
        event: FailSlowEvent,
        overheads: dict | None = None,
        estimator: DurationModel | None = None,
        work_remaining: Callable[[], float] | None = None,
        incident_gap: Callable[[], float] | None = None,
        exclude: Collection[StrategyKey] | None = None,
        knobs: PlannerKnobs | None = None,
        trace: list | None = None,
    ) -> MitigationPlanner:
        cands = self.candidates(event)
        if exclude:
            cands = [k for k in cands if k not in set(exclude)]
        return MitigationPlanner(
            event,
            self.overheads(overheads),
            candidates=cands,
            estimator=estimator,
            work_remaining=work_remaining,
            incident_gap=incident_gap,
            knobs=knobs,
            trace=trace,
        )

    def dispatch(self, key: StrategyKey, ctx: MitigationContext) -> StrategyOutcome:
        return self._table[key].apply(ctx)

    def relieve(self, ctx: MitigationContext) -> list[tuple[StrategyKey, StrategyOutcome]]:
        out = []
        for key, strat in self._table.items():
            try:
                res = strat.relieve(ctx)
            except Exception as exc:  # one bad relieve must not stop the rest
                res = StrategyOutcome(
                    applied=False,
                    detail={"error": f"{type(exc).__name__}: {exc}"},
                )
            if res is not None:
                out.append((key, res))
        return out


def default_registry(max_rounds: int | None = None) -> StrategyRegistry:
    """The paper's S1-S4 ladder as a registry."""
    reg = StrategyRegistry()
    reg.register(IgnoreStrategy())
    reg.register(MicroBatchStrategy())
    reg.register(TopologyStrategy(max_rounds=max_rounds))
    reg.register(CkptRestartStrategy())
    return reg


def placement_registry(max_rounds: int | None = None) -> StrategyRegistry:
    """The S1-S4 ladder extended with the placement rungs (S2P/S3P) and
    the hang rung (ABORT_REFORM).

    Escalation order follows the overheads: S1, S2, S2P, S3, S3P,
    ABORT_REFORM, S4 — the cheap paper rungs get first claim, the
    re-shapes fire when the skewless/congested cases leave them
    ineffective, and the abort rung (which only handles hang events, for
    which the slowdown rungs all decline) fires before the checkpoint
    sledgehammer.
    """
    reg = StrategyRegistry()
    reg.register(IgnoreStrategy())
    reg.register(MicroBatchStrategy())
    reg.register(PlacementMicroBatchStrategy())
    reg.register(TopologyStrategy(max_rounds=max_rounds))
    reg.register(PlacementTopologyStrategy())
    reg.register(AbortReformStrategy())
    reg.register(CkptRestartStrategy())
    return reg
