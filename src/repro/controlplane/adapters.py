"""Cluster adapters — how the control plane talks to a system under test.

:class:`ClusterAdapter` widens the detection-only
:class:`repro.core.detector.ClusterInterface` into the *full* control-plane
contract: observation (iteration times), validation (benchmarks + link
sweeps, with the batched variants the vectorized pinpoint path uses), and
mitigation hooks (allocation / placement / restart).

The plane itself only *requires* the five ClusterInterface methods; it
probes everything beyond them with ``getattr`` and degrades feature by
feature (batched validation falls back to per-pair scalars, strategies
without their hooks report ``applied=False``). Two in-repo sources:

* :class:`repro.cluster.simulator.TrainingSimulator` — the paper's cluster
  performance model; implements the full ClusterAdapter surface.
* :class:`TraceReplayAdapter` (here) — the *minimal* surface: it replays a
  labeled iteration-time trace from :mod:`repro.cluster.traces`, so
  detection runs for real while validation finds no slow component (root
  cause CPU_CONTENTION, the paper's "uniform slowdown, healthy GPUs and
  links" case) and mitigation strategies no-op. It is how recorded
  production traces are driven through the same ControlPlane as live jobs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from repro.cluster.spec import DirtySet
from repro.cluster.traces import LabeledTrace
from repro.core.detector import ClusterInterface


@runtime_checkable
class ClusterAdapter(ClusterInterface, Protocol):
    """The *full* control-plane contract (detection + observation +
    mitigation). Sources that cannot provide a method simply omit it and
    implement only :class:`ClusterInterface` — see the module docstring for
    the degradation rules (``isinstance`` against this protocol therefore
    checks for the complete surface, not the minimum)."""

    # -- observation ---------------------------------------------------
    def iteration_time(self) -> float:
        """Current modeled/measured iteration time of the job."""
        ...

    # -- event-scoped invalidation (per-job dirty cursors) --------------
    def state_cursor(self) -> object:
        """Opaque cursor into the adapter's hardware mutation log. Each
        control-plane reader (job, dashboard, candidate evaluator) holds
        its own cursor, so consuming one reader's dirt never invalidates
        another's view — the contract documented in docs/simulator.md.
        Cursors carry the backing state's identity: one taken before the
        adapter's state was replaced wholesale reads as everything-dirty."""
        ...

    def dirty_since(self, cursor: object) -> DirtySet:
        """Typed set of hardware components mutated since ``cursor``
        (job-local device ranks, link pairs, NIC nodes). Adapters without
        a mutation log simply omit the surface and callers fall back to
        treating every poll as fully dirty."""
        ...

    # -- batched validation (vectorized pinpoint fast path) ------------
    def measure_links(self, pairs: np.ndarray) -> np.ndarray:
        """P2P transfer times for an (k, 2) array of device pairs."""
        ...

    def healthy_link_times(self, pairs: np.ndarray) -> np.ndarray:
        """Expected healthy times for an (k, 2) array of device pairs."""
        ...

    # -- mitigation hooks ----------------------------------------------
    def per_microbatch_times(self) -> list[float]:
        """Per-DP-group per-micro-batch time (S2 solver input)."""
        ...

    def set_allocation(self, counts: list[int]) -> None:
        """Apply a micro-batch allocation (S2)."""
        ...

    def apply_placement(self, perm: list[int]) -> None:
        """Compose a logical->physical permutation onto placement (S3)."""
        ...

    def remap_groups(self, placement: list[int]) -> None:
        """Re-shape communication groups to an explicit device placement
        (S2P/S3P — the placement-aware mitigation rungs)."""
        ...

    def restart(self) -> None:
        """Checkpoint-and-restart onto healthy devices (S4)."""
        ...


@dataclass
class TraceReplayAdapter:
    """Replay a :class:`~repro.cluster.traces.LabeledTrace` as a job.

    ``next_observation()`` advances the replay cursor and returns the next
    iteration time (``None`` at end of trace); the ClusterInterface surface
    reports a healthy, group-less cluster so pinpointing classifies every
    confirmed fail-slow as a host-level (CPU_CONTENTION) incident — a
    recorded scalar trace carries no per-component evidence.
    """

    trace: LabeledTrace
    cursor: int = field(init=False, default=0)

    # -- observation ---------------------------------------------------
    def __len__(self) -> int:
        return int(self.trace.times.size)

    def next_observation(self) -> float | None:
        if self.cursor >= self.trace.times.size:
            return None
        t = float(self.trace.times[self.cursor])
        self.cursor += 1
        return t

    def iteration_time(self) -> float:
        i = min(max(self.cursor - 1, 0), self.trace.times.size - 1)
        return float(self.trace.times[i])

    # -- ClusterInterface (no component evidence in a scalar trace) ----
    def profile_groups(self) -> dict[str, float]:
        return {}

    def group_ranks(self, group: str) -> list[int]:
        return []

    def benchmark_compute(self, ranks: list[int]) -> dict[int, float]:
        return {}

    def measure_link(self, pair: tuple[int, int]) -> float:
        return 0.0

    def healthy_link_time(self, pair: tuple[int, int]) -> float:
        return 0.0
