"""ControlPlane — the unified monitor/detect/pinpoint/plan/mitigate loop.

One :class:`ControlPlane` owns any number of registered jobs and drives the
FALCON pipeline (paper §4-§5) for each of them through typed events
(:mod:`repro.controlplane.events`). Two ingestion paths:

* :meth:`ControlPlane.observe` — exact per-job path: the job's
  :class:`~repro.core.detector.FalconDetect` runs its own BOCD + verification
  on every sample. This is what :class:`repro.train.trainer.FalconTrainer`
  drives; it reproduces the pre-control-plane trainer behavior decision for
  decision (equivalence-tested on the 64-GPU end-to-end scenario).
* :meth:`ControlPlane.tick` — fleet path: one
  :class:`~repro.core.detector.FleetDetect` screens every registered job's
  stream per tick (shared batched-BOCD frontier, flat per-tick cost) and
  routes confirmed :class:`~repro.core.detector.FleetFlag`s into that job's
  ``FalconDetect`` pinpointing. Jobs sharing hardware (the ``hardware``
  registration map) dedupe diagnoses: the first flagged job runs profiling +
  validation, later flags whose hardware overlaps an active diagnosis adopt
  its translated root cause instead of re-validating.

Mitigation is planned by the per-event ski-rental
:class:`~repro.core.planner.MitigationPlanner` and dispatched through the
job's :class:`~repro.controlplane.strategies.StrategyRegistry`, so new
strategies plug in without touching this orchestrator.
"""
from __future__ import annotations

from collections import deque
from collections.abc import Callable, Mapping, Sequence
from dataclasses import dataclass, field

import numpy as np

from repro.core.detector import FalconDetect, FleetDetect, Watchdog
from repro.core.duration import DurationModel
from repro.core.events import (
    ChangePoint,
    FailSlowEvent,
    Strategy,
    StrategyKey,
    strategy_label,
)
from repro.core.planner import MitigationPlanner, PlannerKnobs
from repro.controlplane.events import (
    ControlEvent,
    Diagnosis,
    Flag,
    Membership,
    MitigationAction,
    MitigationResult,
    Observation,
    ScreenTuning,
    WatchdogAlarm,
)
from repro.controlplane.strategies import (
    MitigationContext,
    StrategyRegistry,
    default_registry,
)


@dataclass(frozen=True)
class ExecutorPolicy:
    """Knobs of the fault-tolerant mitigation executor (docs/control_plane.md).

    Every strategy dispatch runs under this policy: up to ``max_attempts``
    tries, each against a fresh pre-action snapshot; a failed attempt is
    rolled back and retried after an exponential backoff
    (``backoff_base_s * 2**(attempt-1)``, charged to the job's clock); a
    timed-out attempt additionally charges ``timeout_s``. After
    ``quarantine_after`` consecutive failed attempts with no intervening
    success, the strategy is quarantined for this (job, root cause) and
    future ladders escalate past it.
    """

    max_attempts: int = 3
    backoff_base_s: float = 2.0
    timeout_s: float = 30.0
    quarantine_after: int = 3


@dataclass
class JobHandle:
    """One registered job: adapter + detector + strategy table + planner."""

    job_id: str
    adapter: object
    detector: FalconDetect
    registry: StrategyRegistry
    #: per-job overrides merged over the registry's default overheads
    overheads: dict = field(default_factory=dict)
    injector: object | None = None
    #: local device rank -> global hardware id (cross-job dedupe identity);
    #: None opts the job out of device-level dedupe
    hardware: tuple[str, ...] | None = None
    #: local node index -> global host id: the dedupe identity for
    #: node-scoped components (``node:`` host faults, ``nic:`` ports), which
    #: co-located jobs share even when their device sets are disjoint
    hosts: tuple[str, ...] | None = None
    #: seconds of wall clock one tick() sample stands for (fleet monitors
    #: scrape on a fixed cadence); None = one sample == one iteration, the
    #: per-iteration ``observe`` semantics
    sample_period: float | None = None
    #: remaining useful work of the job in wall-clock seconds — caps the
    #: benefit any mitigation can still deliver (the predictive ski-rental
    #: horizon is min(fault remaining, job remaining)); None = unbounded
    work_remaining: Callable[[], float] | None = None
    planner: MitigationPlanner | None = None
    steps: int = field(default=0)
    #: wall clock of this job's last checkpoint-restart (None = never)
    _last_restart: float | None = field(default=None, repr=False)
    #: set when a restart's bought healthy window did not even cover its
    #: own overhead — restarts cannot win in this fault environment, so
    #: S4 is withheld from later ladders for this job
    _s4_burned: bool = field(default=False, repr=False)
    #: this job's column in the fleet screen (None until the fleet exists)
    _fleet_col: int | None = field(default=None, repr=False)
    _ticks_active: int = field(default=0)
    #: global hardware id -> local rank (built once; hardware is immutable)
    _hw_inverse: dict[str, int] | None = field(default=None, repr=False)
    _host_inverse: dict[str, int] | None = field(default=None, repr=False)
    #: last delivered iteration-time sample and its job clock (the
    #: watchdog's flat-imputation source while the stream is silent)
    _last_sample: float = field(default=0.0, repr=False)
    _last_seen: float | None = field(default=None, repr=False)
    #: a watchdog alarm fired and has not yet been cleared by a heartbeat
    _alarmed: bool = field(default=False, repr=False)
    #: (root_cause, strategy) pairs the executor quarantined for this job
    _quarantined: set = field(default_factory=set, repr=False)
    #: (root_cause, strategy) -> consecutive failed dispatch attempts
    _fail_streaks: dict = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if self.hardware is not None:
            self._hw_inverse = {h: r for r, h in enumerate(self.hardware)}
        if self.hosts is not None:
            self._host_inverse = {h: n for n, h in enumerate(self.hosts)}

    def effective_overheads(self) -> dict:
        return self.registry.overheads(self.overheads)


class ControlPlane:
    """Multi-job FALCON orchestrator over typed control-plane events."""

    def __init__(
        self,
        fleet_kwargs: dict | None = None,
        max_events: int = 65536,
        duration_model: DurationModel | None = None,
        executor_policy: ExecutorPolicy | None = None,
        executor_faults: Callable | None = None,
        watchdog: Watchdog | None = None,
        decision_hook: object | None = None,
        planner_knobs: PlannerKnobs | None = None,
        planner_trace: list | None = None,
        tracer: object | None = None,
        screening_backend: object | None = None,
    ) -> None:
        self._jobs: dict[str, JobHandle] = {}
        self._fleet: FleetDetect | None = None
        self._fleet_kwargs = dict(fleet_kwargs or {})
        #: screening backend for the fleet screen: a registry name
        #: ("scalar"/"batched"/"pallas"/"auto") or a
        #: :class:`repro.core.bocd.ScreeningBackendFactory` instance —
        #: forwarded to :class:`FleetDetect`; None keeps FleetDetect's
        #: own default ("auto") or whatever ``fleet_kwargs`` says.
        if screening_backend is not None:
            self._fleet_kwargs["backend"] = screening_backend
        #: fault-tolerant executor knobs (retry/backoff/quarantine)
        self.executor_policy = executor_policy or ExecutorPolicy()
        #: injectable executor fault model: (job_id, strategy, attempt, now)
        #: -> None | "fail" | "timeout" — lets campaigns make mitigations
        #: themselves flaky (scenario engine's ExecutorFaultModel)
        self.executor_faults = executor_faults
        #: heartbeat watchdog over every registered job's sample stream
        self.watchdog = watchdog or Watchdog()
        #: counterfactual decision intercept (repro.whatif replay contract):
        #: any object implementing a subset of
        #:   allow(job_id, strategy, now) -> bool       (False = suppress)
        #:   allow_relief(job_id, now) -> bool          (False = no relief)
        #:   forced(job_id, now) -> list[StrategyKey]   (dispatch these now)
        #: A suppressed decision emits a kind="suppressed" MitigationResult
        #: and neither touches the adapter nor consumes executor-fault
        #: randomness, so suppressing every decision replays the unmitigated
        #: run bit-exactly. None = every decision passes through.
        self.decision_hook = decision_hook
        #: planner knob bundle applied to every planner this plane builds
        #: (the what-if auto-tuner's injection point); None = defaults
        self.planner_knobs = planner_knobs
        #: shared sink threaded into every planner this plane builds: each
        #: break-even consult appends its knob-independent inputs and the
        #: decision taken (:func:`repro.core.planner.threshold_value`), so
        #: the campaign engine can re-score alternative knob bundles
        #: against the recorded decision sequence without re-running.
        #: None (the default) records nothing.
        self.planner_trace = planner_trace
        #: observability span tracer (:class:`repro.obs.SpanTracer`) on the
        #: caller's simulated clock: tick spans, watchdog silence/deadline
        #: spans, executor attempt/retry/rollback cycles, per-job fault
        #: episodes. None (the default) keeps the tick hot path allocation-
        #: free — every trace call site is guarded, never stubbed.
        self.tracer = tracer
        self._trace_prev: float | None = None
        #: last ScreenTuning payload mirrored into the event log
        self._last_tuning: dict | None = None
        #: fleet-shared fault-duration survival curves: every job's
        #: resolved diagnoses sharpen every other job's ski-rental
        #: break-even; None keeps the paper's fixed-horizon rule
        self.duration_model = duration_model
        #: accumulated job-seconds watched and fresh incidents seen — their
        #: ratio is the observed mean time between incidents per job, the
        #: healthy window any mitigation can actually buy (caps the
        #: predictive break-even's benefit under fail-slow storms)
        self._watched_s: float = 0.0
        self._fresh_onsets: int = 0
        #: job_id -> latest unresolved Diagnosis (the cross-job dedupe table)
        self._active_diag: dict[str, Diagnosis] = {}
        #: event log in emission order, bounded like the Monitor's comm log
        #: (a fleet ticking forever must not grow memory without bound);
        #: oldest events rotate out of ``events`` / ``diagnoses()`` first
        self.events: deque[ControlEvent] = deque(maxlen=max_events)

    # -- registry of jobs ----------------------------------------------
    def register_job(
        self,
        job_id: str,
        adapter,
        *,
        detector: FalconDetect | None = None,
        registry: StrategyRegistry | None = None,
        overheads: dict | None = None,
        injector=None,
        hardware: Sequence[str] | None = None,
        hosts: Sequence[str] | None = None,
        sample_period: float | None = None,
        work_remaining: Callable[[], float] | None = None,
        now: float = 0.0,
    ) -> JobHandle:
        """Register a job — before the first tick or at any point after.

        A job joining mid-flight is added to the fleet screen as a warming
        stream (:meth:`FleetDetect.add_worker`): established jobs' screening
        state is untouched, and the newcomer starts being screened once it
        has ``warmup`` samples.
        """
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already registered")
        job = JobHandle(
            job_id=job_id,
            adapter=adapter,
            detector=detector or FalconDetect(cluster=adapter),
            registry=registry or default_registry(),
            overheads=dict(overheads or {}),
            injector=injector,
            hardware=tuple(hardware) if hardware is not None else None,
            hosts=tuple(hosts) if hosts is not None else None,
            sample_period=sample_period,
            work_remaining=work_remaining,
        )
        self._jobs[job_id] = job
        if self._fleet is not None:
            job._fleet_col = self._fleet.add_worker()
        self.events.append(Membership(job_id=job_id, time=now, action="join"))
        return job

    def remove_job(self, job_id: str, now: float = 0.0) -> JobHandle:
        """Deregister a job (completion or eviction).

        Its column is sub-sliced out of the fleet screen
        (:meth:`FleetDetect.remove_worker`), its open diagnosis leaves the
        dedupe table, and a leave :class:`Membership` event is logged. The
        returned handle still carries the detector history for post-hoc
        scoring.
        """
        if job_id not in self._jobs:
            raise KeyError(f"job {job_id!r} not registered")
        job = self._jobs.pop(job_id)
        self._active_diag.pop(job_id, None)
        self.watchdog.forget(job_id)
        if self.tracer is not None:
            # A job leaving with an open fault episode censors the span at
            # departure time; its other tracks hold no open spans.
            self.tracer.close_track((job_id, "faults"), now)
        col = job._fleet_col
        if self._fleet is not None and col is not None:
            self._fleet.remove_worker(col)
            for other in self._jobs.values():
                if other._fleet_col is not None and other._fleet_col > col:
                    other._fleet_col -= 1
        self.events.append(Membership(job_id=job_id, time=now, action="leave"))
        return job

    @property
    def jobs(self) -> list[JobHandle]:
        return list(self._jobs.values())

    def job(self, job_id: str) -> JobHandle:
        return self._jobs[job_id]

    # -- state capture (campaign fork/restore contract) -----------------
    #: JobHandle fields a pre-intervention snapshot carries. Everything
    #: else on a handle is either immutable registration data the adopting
    #: caller re-supplies (adapter, registry, hardware, ...) or
    #: intervention state that is still pristine on the shared prefix.
    _JOB_SNAP_FIELDS = (
        "steps", "_fleet_col", "_ticks_active", "_last_sample",
        "_last_seen", "_alarmed",
    )

    def snapshot(self) -> dict:
        """Pre-intervention plane state as private copies.

        Supports the campaign engine's shared-prefix fork
        (``scenarios/engine.py``): valid only while no intervention state
        is live — no active diagnoses, planners, restarts, quarantines or
        executor fail streaks. On that prefix the plane never touches job
        adapters, detectors or injectors, so a fork reproduces the plane
        bit-exactly from fresh instances of those plus the scalars
        captured here (:meth:`adopt_job` + :meth:`restore`).
        """
        for job in self._jobs.values():
            if (
                job.planner is not None
                or job.detector.active_event is not None
                or job._last_restart is not None
                or job._s4_burned
                or job._quarantined
                or job._fail_streaks
            ):
                raise ValueError(
                    f"job {job.job_id!r} carries intervention state; "
                    "snapshot() supports only the pre-divergence prefix"
                )
        if self._active_diag:
            raise ValueError(
                "active diagnoses present; snapshot() supports only the "
                "pre-divergence prefix"
            )
        return {
            "jobs": {
                job_id: {f: getattr(job, f) for f in self._JOB_SNAP_FIELDS}
                for job_id, job in self._jobs.items()
            },
            "fleet": (
                self._fleet.snapshot() if self._fleet is not None else None
            ),
            "watchdog": self.watchdog.snapshot(),
            "watched_s": self._watched_s,
            "fresh_onsets": self._fresh_onsets,
            # _last_tuning mirrors fleet.last_tuning by identity between
            # ticks; restore re-links to the restored fleet's dict so the
            # ``tuning is not self._last_tuning`` emission check holds.
            "last_tuning_mirrored": self._last_tuning is not None,
            "n_events": len(self.events),
        }

    def adopt_job(
        self,
        job_id: str,
        adapter,
        *,
        state: dict,
        detector: FalconDetect | None = None,
        registry: StrategyRegistry | None = None,
        overheads: dict | None = None,
        injector=None,
        hardware: Sequence[str] | None = None,
        hosts: Sequence[str] | None = None,
        sample_period: float | None = None,
        work_remaining: Callable[[], float] | None = None,
    ) -> JobHandle:
        """Re-attach a job mid-flight from snapshot state.

        Like :meth:`register_job` but emits no :class:`Membership` event
        and touches no fleet column bookkeeping — the join already
        happened on the shared leg being forked; ``state`` is this job's
        entry from :meth:`snapshot`'s ``jobs`` map. Adopt jobs in their
        original registration order, then call :meth:`restore`.
        """
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already registered")
        job = JobHandle(
            job_id=job_id,
            adapter=adapter,
            detector=detector or FalconDetect(cluster=adapter),
            registry=registry or default_registry(),
            overheads=dict(overheads or {}),
            injector=injector,
            hardware=tuple(hardware) if hardware is not None else None,
            hosts=tuple(hosts) if hosts is not None else None,
            sample_period=sample_period,
            work_remaining=work_remaining,
        )
        for f, v in state.items():
            setattr(job, f, v)
        self._jobs[job_id] = job
        return job

    def restore(self, snap: dict, *, events: Sequence = ()) -> None:
        """Install a :meth:`snapshot` into this plane (fork completion).

        Every job in the snapshot must already be adopted
        (:meth:`adopt_job`). The fleet screen is rebuilt from this
        plane's own ``fleet_kwargs`` and restored from the snapshot —
        callers forking into different screening semantics (the engine's
        ckpt branch strips adaptive-retune state) adjust the restored
        fleet afterwards. ``events`` becomes the plane's event log
        (the shared leg's prefix, possibly filtered).
        """
        if set(snap["jobs"]) != set(self._jobs):
            raise ValueError(
                "adopted jobs do not match snapshot: "
                f"{sorted(self._jobs)} vs {sorted(snap['jobs'])}"
            )
        for job_id, st in snap["jobs"].items():
            job = self._jobs[job_id]
            for f, v in st.items():
                setattr(job, f, v)
        if snap["fleet"] is not None:
            fleet = FleetDetect(
                n_workers=len(self._jobs), **self._fleet_kwargs
            )
            fleet.restore(snap["fleet"])
            self._fleet = fleet
        else:
            self._fleet = None
        self.watchdog.restore(snap["watchdog"])
        self._watched_s = snap["watched_s"]
        self._fresh_onsets = snap["fresh_onsets"]
        self._last_tuning = (
            self._fleet.last_tuning
            if snap["last_tuning_mirrored"] and self._fleet is not None
            else None
        )
        self._trace_prev = None
        self.events = deque(events, maxlen=self.events.maxlen)

    # -- exact per-job path --------------------------------------------
    def observe(
        self, job_id: str, iter_time: float, now: float
    ) -> list[ControlEvent]:
        """Feed one iteration time through the full per-job pipeline.

        Returns the events emitted for this sample; the caller charges any
        :class:`MitigationResult.overhead` to the job's wall clock.
        """
        job = self._jobs[job_id]
        out: list[ControlEvent] = [
            Observation(
                job_id=job_id, time=now, iter_time=iter_time, step=job.steps
            )
        ]
        job.steps += 1
        self._watched_s += max(iter_time, 0.0)
        self.watchdog.beat(job_id, now)
        job._last_sample = iter_time
        job._last_seen = now
        job._alarmed = False
        had_active = job.detector.active_event is not None
        new_event = job.detector.observe(iter_time, now)
        out += self._after_detection(job, new_event, had_active, iter_time, now)
        self.events += out
        return out

    # -- fleet screening path ------------------------------------------
    def tick(
        self, times: Mapping[str, float] | Sequence[float] | np.ndarray,
        now: float,
    ) -> list[ControlEvent]:
        """Advance every registered job one tick through the fleet screen.

        ``times`` is one iteration time per job — a mapping keyed by job id,
        or a sequence in registration order. A mapping may *omit* jobs: a
        stalled job's current iteration never completes, so its monitor has
        nothing to report. Silent jobs get no Observation; their fleet-
        screen column is imputed flat (the last delivered sample — exactly
        the shape BOCD cannot flag) and the heartbeat watchdog takes over:
        once the silence exceeds the stream's jitter-calibrated deadline a
        :class:`WatchdogAlarm` fires and a synthesized change-point runs
        the normal pinpoint path, yielding a hang-flagged Diagnosis and a
        hang mitigation ladder.
        """
        jobs = list(self._jobs.values())
        tr = self.tracer
        if tr is not None:
            # The tick span covers the sampling interval it processes:
            # [previous tick, now] on the fleet track.
            prev = self._trace_prev
            tr.begin(
                ("fleet", "controlplane"), "tick",
                prev if prev is not None and prev < now else now,
            )
            self._trace_prev = now
        if self._fleet is None:
            self._fleet = FleetDetect(n_workers=len(jobs), **self._fleet_kwargs)
            for col, job in enumerate(jobs):
                job._fleet_col = col
        by_col = {j._fleet_col: j for j in jobs}
        if isinstance(times, Mapping):
            per_job = {
                j.job_id: float(times[j.job_id])
                for j in jobs if j.job_id in times
            }
        else:
            seq = np.asarray(times, dtype=np.float64)
            if seq.shape != (len(jobs),):
                raise ValueError(f"expected {len(jobs)} times, got {seq.shape}")
            per_job = {j.job_id: float(seq[i]) for i, j in enumerate(jobs)}
        for job in jobs:
            if job.job_id in per_job:
                self.watchdog.beat(job.job_id, now)
        vec = np.empty(len(jobs), dtype=np.float64)
        for job in jobs:
            if job.job_id in per_job:
                vec[job._fleet_col] = per_job[job.job_id]
            else:
                # Flat continuation of the last delivered sample keeps the
                # lockstep screen's shape; it carries no change for BOCD to
                # see, which is the point — silence is the watchdog's job.
                vec[job._fleet_col] = (
                    job._last_sample if job._last_sample > 0 else 1.0
                )
        flags = {f.worker: f for f in self._fleet.tick(vec)}

        out: list[ControlEvent] = []
        for w in sorted(by_col):
            job = by_col[w]
            try:
                if job.job_id not in per_job:
                    out += self._silent_job(job, now)
                    continue
                iter_time = float(vec[w])
                out.append(
                    Observation(
                        job_id=job.job_id, time=now, iter_time=iter_time,
                        step=job.steps,
                    )
                )
                job.steps += 1
                if tr is not None and (job.steps - 1) % tr.counter_stride == 0:
                    tr.counter(
                        (job.job_id, "iter_time"), "iter_time", now, iter_time
                    )
                job._last_sample = iter_time
                job._last_seen = now
                job._alarmed = False
                self._watched_s += (
                    job.sample_period
                    if job.sample_period is not None
                    else max(iter_time, 0.0)
                )
                had_active = job.detector.active_event is not None
                new_event: FailSlowEvent | None = None
                deduped_from: str | None = None
                flag = flags.get(w)
                if flag is not None:
                    cp = flag.change_point
                    out.append(
                        Flag(job_id=job.job_id, time=now, change_point=cp)
                    )
                    if tr is not None:
                        tr.instant(
                            (job.job_id, "detector"), "flag", now,
                            args={
                                "probability": cp.probability,
                                "mean_before": cp.mean_before,
                                "mean_after": cp.mean_after,
                            },
                        )
                    source = None
                    if (
                        cp.relative_change > 0
                        and job.detector.active_event is None
                    ):
                        source = self._dedupe_source(job)
                    if source is not None:
                        event = self._adopt(job, source, cp, now)
                        if event is not None:
                            new_event, deduped_from = event, source.job_id
                    if new_event is None and deduped_from is None:
                        new_event = job.detector.ingest_changepoint(cp, now)
                elif job.detector.active_event is not None:
                    # No flag while an event is active: mitigation may have
                    # flattened the signal — periodic O(1) re-validation is
                    # the only way to see the fault's relief (or a compound
                    # pile-on).
                    job._ticks_active += 1
                    if job._ticks_active % job.detector.revalidate_every == 0:
                        new_event = job.detector.revalidate(
                            now, iter_time=iter_time, index=job.steps - 1
                        )
                out += self._after_detection(
                    job, new_event, had_active, iter_time, now,
                    deduped_from=deduped_from,
                )
            except Exception as exc:  # noqa: BLE001 — graceful degradation
                # One bad job (adapter raising mid-pinpoint, a broken
                # detector) must not stall the fleet: surface the failure
                # as a typed event and keep ticking the other jobs.
                out.append(
                    MitigationResult(
                        job_id=job.job_id, time=now, strategy=None,
                        applied=False, kind="error", status="failed",
                        detail={"error": f"{type(exc).__name__}: {exc}"},
                    )
                )
        tuning = getattr(self._fleet, "last_tuning", None)
        if tuning is not None and tuning is not self._last_tuning:
            # The adaptive screen chose new knobs at the END of this tick
            # (FleetDetect retunes after collecting the tick's flags), so
            # the event is appended after them: every Flag *after* a
            # ScreenTuning entry was screened under its parameters.
            self._last_tuning = tuning
            out.append(ScreenTuning(
                job_id="", time=now,
                hazard=tuning["hazard"],
                max_hypotheses=tuning["max_hypotheses"],
                change_rate=tuning["change_rate"],
                flags=tuning["flags"],
                worker_ticks=tuning["worker_ticks"],
            ))
        if tr is not None:
            tr.end(
                ("fleet", "controlplane"), now,
                args={"jobs": len(jobs), "events": len(out)},
            )
        self.events += out
        return out

    # -- hang watchdog path --------------------------------------------
    def _silent_job(self, job: JobHandle, now: float) -> list[ControlEvent]:
        """One tick of a registered job whose stream produced no sample.

        While the watchdog deadline has not yet expired, only the planner
        is advanced (an already-diagnosed event keeps accumulating impact
        at the stalled rate). On expiry, a :class:`WatchdogAlarm` fires
        once and a synthesized change-point — last delivered sample as the
        before-mean, the adapter's current (stalled) iteration time as the
        after-mean — is routed through the job's own detector, so the hang
        gets the same profiling + validation pinpoint a slowdown would,
        and the resulting event is flagged ``hang`` for the abort ladder.
        """
        out: list[ControlEvent] = []
        if job.sample_period is not None:
            self._watched_s += job.sample_period
        # The stalled iteration time: what the job's clock is stuck paying.
        stalled_t = job._last_sample if job._last_sample > 0 else 1.0
        it = getattr(job.adapter, "iteration_time", None)
        if callable(it):
            try:
                stalled_t = max(float(it()), stalled_t)
            except Exception:  # noqa: BLE001 — adapter may itself be wedged
                pass
        had_active = job.detector.active_event is not None
        new_event: FailSlowEvent | None = None
        active = job.detector.active_event
        already_hang = active is not None and getattr(active, "hang", False)
        if (
            not already_hang
            and not job._alarmed
            and self.watchdog.expired(job.job_id, now)
        ):
            job._alarmed = True
            deadline = self.watchdog.deadline(job.job_id) or 0.0
            silence = self.watchdog.silence(job.job_id, now)
            out.append(WatchdogAlarm(
                job_id=job.job_id, time=now,
                last_seen=job._last_seen if job._last_seen is not None else 0.0,
                deadline_s=deadline,
                silence_s=silence,
            ))
            tr = self.tracer
            if tr is not None:
                # The silence window [last heartbeat, alarm] with the
                # calibrated deadline budget nested inside it: how far past
                # the budget the stream ran before the alarm fired.
                last = job._last_seen if job._last_seen is not None else 0.0
                track = (job.job_id, "watchdog")
                tr.span(
                    track, "silence", last, now, args={"silence_s": silence}
                )
                tr.span(
                    track, "deadline", last, last + deadline,
                    args={"deadline_s": deadline},
                )
                tr.instant(track, "alarm", now)
            base = job._last_sample if job._last_sample > 0 else 1.0
            cp = ChangePoint(
                index=max(job.steps - 1, 0), probability=1.0,
                mean_before=base, mean_after=max(stalled_t, 2.0 * base),
            )
            new_event = job.detector.ingest_changepoint(cp, now)
            if new_event is not None:
                new_event.hang = True
        out += self._after_detection(job, new_event, had_active, stalled_t, now)
        return out

    # -- shared post-detection pipeline --------------------------------
    def _after_detection(
        self,
        job: JobHandle,
        new_event: FailSlowEvent | None,
        had_active: bool,
        iter_time: float,
        now: float,
        deduped_from: str | None = None,
    ) -> list[ControlEvent]:
        out: list[ControlEvent] = []
        if new_event is not None:
            # Every onset — fresh, compound pile-on, or adopted from a
            # co-located job — is one more fault arrival hitting a job:
            # together with the job-seconds watched it yields the observed
            # incident inter-arrival time (see :meth:`incident_gap`).
            self._fresh_onsets += 1
            if (
                job._last_restart is not None
                and not job._s4_burned
                and now - job._last_restart
                <= job.effective_overheads().get(Strategy.CKPT_AND_RESTART, 0.0)
            ):
                # Fool me once: the last restart's healthy window did not
                # even pay back its own overhead before the next incident
                # landed — the fault environment, not any one fault, is
                # the bottleneck, and further restarts cannot win.
                job._s4_burned = True
            diag = Diagnosis(
                job_id=job.job_id,
                time=now,
                event=new_event,
                components_global=self._globalize(job, new_event.components),
                deduped_from=deduped_from,
                breakdown=self._breakdown(job),
            )
            out.append(diag)
            self._active_diag[job.job_id] = diag
            tr = self.tracer
            if tr is not None:
                # Fault episode span: opened at diagnosis, closed at
                # relief (or the horizon). A compound pile-on opens a
                # nested span inside the still-active episode.
                args: dict = {
                    "cause": new_event.root_cause.value,
                    "components": list(new_event.components),
                }
                if getattr(new_event, "hang", False):
                    args["hang"] = True
                if deduped_from is not None:
                    args["deduped_from"] = deduped_from
                if diag.breakdown is not None:
                    args.update(diag.breakdown.summary())
                tr.begin(
                    (job.job_id, "faults"),
                    f"fault:{new_event.root_cause.value}", now, args=args,
                )
            exclude: set[StrategyKey] = set()
            if job._s4_burned:
                exclude.add(Strategy.CKPT_AND_RESTART)
            # Quarantined rungs (executor failures) are withheld for events
            # of the cause they kept failing on, so the ladder escalates
            # past them instead of retrying into the same wall.
            exclude |= {
                s for (c, s) in job._quarantined
                if c is new_event.root_cause
            }
            job.planner = job.registry.make_planner(
                new_event,
                job.overheads,
                estimator=self.duration_model,
                work_remaining=job.work_remaining,
                incident_gap=self.incident_gap,
                exclude=exclude or None,
                knobs=self.planner_knobs,
                trace=self.planner_trace,
            )
        active = job.detector.active_event
        if active is None:
            if had_active:
                if self.tracer is not None:
                    self.tracer.close_track((job.job_id, "faults"), now)
                if self._hook_allow_relief(job.job_id, now):
                    out += self._relief(job, now)
                else:
                    out.append(
                        MitigationResult(
                            job_id=job.job_id, time=now, strategy=None,
                            applied=False, kind="suppressed", status="ok",
                            detail={"relief": True},
                        )
                    )
            job.planner = None
            self._active_diag.pop(job.job_id, None)
        elif job.planner is not None:
            # On a sampling clock, one sample stands for sample_period /
            # iter_time iterations — the ski-rental impact integral counts
            # iterations so its break-even stays in wall-clock units.
            weight = 1.0
            if job.sample_period is not None and iter_time > 0:
                weight = job.sample_period / iter_time
            strategy = job.planner.update(
                slow_iters=weight, current_time=iter_time
            )
            if strategy is not None:
                if self._hook_allow(job.job_id, strategy, now):
                    out.append(
                        MitigationAction(
                            job_id=job.job_id, time=now, strategy=strategy,
                            event=active,
                        )
                    )
                    out += self._execute(job, strategy, active, now)
                else:
                    # Counterfactually suppressed: the decision is recorded
                    # (the ladder still advances past this rung) but nothing
                    # is dispatched — no adapter mutation, no overhead, no
                    # executor-fault draw.
                    out.append(
                        MitigationResult(
                            job_id=job.job_id, time=now, strategy=strategy,
                            applied=False, kind="suppressed", status="ok",
                            detail={"event_start": active.start_time},
                        )
                    )
        if active is not None:
            for forced in self._hook_forced(job.job_id, now):
                out.append(
                    MitigationAction(
                        job_id=job.job_id, time=now, strategy=forced,
                        event=active,
                    )
                )
                out += self._execute(job, forced, active, now)
        return out

    def _breakdown(self, job: JobHandle):
        """Per-collective timing decomposition of the job's iteration, when
        the adapter can produce one (:meth:`TrainingSimulator.collective_breakdown`).
        Returns None for adapters without the capability (trace replay,
        hardware) or when the adapter is wedged — diagnosis must never fail
        because observability did."""
        fn = getattr(job.adapter, "collective_breakdown", None)
        if fn is None:
            return None
        try:
            return fn()
        except Exception:  # noqa: BLE001 — observability is best-effort
            return None

    # -- counterfactual decision intercept -------------------------------
    def _hook_allow(self, job_id: str, strategy: StrategyKey, now: float) -> bool:
        fn = getattr(self.decision_hook, "allow", None)
        return True if fn is None else bool(fn(job_id, strategy, now))

    def _hook_allow_relief(self, job_id: str, now: float) -> bool:
        fn = getattr(self.decision_hook, "allow_relief", None)
        return True if fn is None else bool(fn(job_id, now))

    def _hook_forced(self, job_id: str, now: float) -> list[StrategyKey]:
        fn = getattr(self.decision_hook, "forced", None)
        return [] if fn is None else list(fn(job_id, now))

    # -- fault-tolerant executor ---------------------------------------
    def _snapshot(self, job: JobHandle) -> dict:
        """Pre-action state: adapter snapshot (when it offers one) plus the
        injector's schedule (strategies mutate it — S4/abort clear
        episodes, and a failed attempt must put them back)."""
        snap: dict = {}
        if hasattr(job.adapter, "snapshot"):
            snap["adapter"] = job.adapter.snapshot()
        if job.injector is not None and hasattr(job.injector, "injections"):
            snap["injections"] = list(job.injector.injections)
        return snap

    def _rollback(self, job: JobHandle, snap: dict) -> bool:
        """Restore a :meth:`_snapshot`. True when state was restorable."""
        rolled = False
        if "adapter" in snap and hasattr(job.adapter, "restore"):
            job.adapter.restore(snap["adapter"])
            rolled = True
        if "injections" in snap:
            if list(job.injector.injections) != snap["injections"]:
                # Wholesale reassignment bumps the injector epoch, so
                # schedule cursors re-apply against the restored state.
                job.injector.injections = snap["injections"]
            rolled = True
        return rolled

    def _execute(
        self, job: JobHandle, strategy: StrategyKey, event, now: float
    ) -> list[ControlEvent]:
        """Fault-tolerant strategy dispatch: snapshot → apply → on failure
        roll back, back off, retry; emit one typed :class:`MitigationResult`
        per attempt (status ``ok`` / ``failed`` / ``timed_out``) plus a
        terminal ``rolled_back`` result when retries are exhausted. See
        :class:`ExecutorPolicy` and docs/control_plane.md.
        """
        pol = self.executor_policy
        max_attempts = max(pol.max_attempts, 1)
        overhead = (
            job.planner.overheads.get(strategy, 0.0)
            if job.planner is not None
            else job.effective_overheads().get(strategy, 0.0)
        )
        ctx = MitigationContext(
            adapter=job.adapter, event=event, now=now,
            job_id=job.job_id, injector=job.injector,
        )
        cause = getattr(event, "root_cause", None)
        streak_key = (cause, strategy)
        out: list[ControlEvent] = []
        rolled = False
        quarantined = False
        tr = self.tracer
        track = (job.job_id, "executor")
        label = strategy_label(strategy)
        # The executor's simulated-time cursor: attempt N's span starts
        # after the charges (timeouts, backoffs) of attempts 1..N-1, so the
        # trace shows the retry cycle laid out the way the job's wall clock
        # actually paid for it.
        t_cursor = now
        if tr is not None:
            tr.begin(track, f"dispatch:{label}", now)
        for attempt in range(1, max_attempts + 1):
            snap = self._snapshot(job)
            failure: tuple[str, dict] | None = None
            outcome = None
            try:
                outcome = job.registry.dispatch(strategy, ctx)
            except Exception as exc:  # noqa: BLE001 — typed failure capture
                failure = ("failed", {"error": f"{type(exc).__name__}: {exc}"})
            if failure is None and self.executor_faults is not None:
                verdict = self.executor_faults(
                    job.job_id, strategy, attempt, now
                )
                if verdict in ("fail", "timeout"):
                    failure = (
                        "failed" if verdict == "fail" else "timed_out",
                        {"injected": verdict},
                    )
            if failure is None:
                job._fail_streaks.pop(streak_key, None)
                if strategy is Strategy.CKPT_AND_RESTART and outcome.applied:
                    job._last_restart = now
                out.append(
                    MitigationResult(
                        job_id=job.job_id, time=now, strategy=strategy,
                        applied=outcome.applied, overhead=overhead,
                        detail=outcome.detail, attempt=attempt,
                    )
                )
                if tr is not None:
                    tr.span(
                        track, f"attempt {attempt}", t_cursor,
                        t_cursor + overhead,
                        args={"status": "ok", "applied": outcome.applied},
                    )
                    tr.end(
                        track, t_cursor + overhead,
                        args={"status": "ok", "attempts": attempt},
                    )
                return out
            status, detail = failure
            rolled = self._rollback(job, snap)
            streak = job._fail_streaks.get(streak_key, 0) + 1
            job._fail_streaks[streak_key] = streak
            if streak >= pol.quarantine_after and not quarantined:
                quarantined = True
                job._quarantined.add(streak_key)
            will_retry = attempt < max_attempts and not quarantined
            charge = pol.timeout_s if status == "timed_out" else 0.0
            if will_retry:
                charge += pol.backoff_base_s * (2.0 ** (attempt - 1))
            detail = dict(detail)
            detail["rolled_back"] = rolled
            if quarantined:
                detail["quarantined"] = True
            out.append(
                MitigationResult(
                    job_id=job.job_id, time=now, strategy=strategy,
                    applied=False, overhead=charge, detail=detail,
                    status=status, attempt=attempt,
                )
            )
            if tr is not None:
                tr.span(
                    track, f"attempt {attempt}", t_cursor, t_cursor + charge,
                    args={"status": status},
                )
                tr.instant(
                    track, "rollback", t_cursor + charge,
                    args={"rolled_back": rolled},
                )
                if quarantined:
                    tr.instant(track, "quarantine", t_cursor + charge)
            t_cursor += charge
            if not will_retry:
                break
        # Retries exhausted (or quarantine cut them short): the terminal
        # record — job state is back at the pre-action snapshot.
        out.append(
            MitigationResult(
                job_id=job.job_id, time=now, strategy=strategy,
                applied=False, overhead=0.0, status="rolled_back",
                attempt=attempt,
                detail={
                    "exhausted": True, "rolled_back": rolled,
                    **({"quarantined": True} if quarantined else {}),
                },
            )
        )
        if tr is not None:
            tr.end(
                track, t_cursor,
                args={"status": "rolled_back", "attempts": attempt},
            )
        return out

    def _relief(self, job: JobHandle, now: float) -> list[ControlEvent]:
        """The active event resolved: emit the closing diagnosis and let
        every registered strategy undo residual skew (S2 re-balances the
        micro-batch split for the recovered cluster)."""
        out: list[ControlEvent] = []
        closed = job.detector.history[-1] if job.detector.history else None
        if closed is not None and self.duration_model is not None:
            # Feed the survival curves. A fault our own restart (or
            # collective abort) cleared would have lasted longer — record
            # it right-censored so mitigation does not bias the curve
            # short. A hang is always censored: its natural duration is
            # unbounded, and whatever ended it, the observed span is a
            # lower bound, not a draw from the duration distribution.
            censored = bool(getattr(closed, "hang", False)) or (
                job.planner is not None
                and any(
                    k is Strategy.CKPT_AND_RESTART or k == "ABORT_REFORM"
                    for k in job.planner.applied
                )
            )
            self.duration_model.observe(
                closed.root_cause,
                closed.duration(now),
                censored=censored,
            )
        if closed is not None:
            out.append(
                Diagnosis(
                    job_id=job.job_id,
                    time=now,
                    event=closed,
                    components_global=self._globalize(job, closed.components),
                    resolved=True,
                )
            )
        ctx = MitigationContext(
            adapter=job.adapter, event=closed, now=now, job_id=job.job_id,
            injector=job.injector,
        )
        for key, outcome in job.registry.relieve(ctx):
            out.append(
                MitigationResult(
                    job_id=job.job_id, time=now, strategy=key,
                    applied=outcome.applied, kind="relief",
                    detail=outcome.detail,
                    status="failed" if "error" in outcome.detail else "ok",
                )
            )
        return out

    # -- cross-job hardware dedupe --------------------------------------
    def _globalize(
        self, job: JobHandle, components: Sequence[str]
    ) -> tuple[str, ...]:
        """Translate job-local component ids through the hardware/host maps.

        Device-scoped components (``gpu:``/``link:``) go through the
        hardware map; node-scoped ones (``node:`` host faults, ``nic:``
        ports) through the hosts map, so co-located jobs with disjoint
        device sets still share a dedupe identity for host-level faults.
        """
        hw = job.hardware
        hosts = job.hosts
        out = []
        for comp in components:
            kind, _, ident = comp.partition(":")
            try:
                if kind == "gpu" and hw is not None:
                    out.append(f"gpu:{hw[int(ident)]}")
                elif kind == "link" and hw is not None:
                    a, b = (int(x) for x in ident.split("-"))
                    lo, hi = sorted((hw[a], hw[b]))
                    out.append(f"link:{lo}|{hi}")
                elif kind in ("node", "nic") and hosts is not None:
                    out.append(f"{kind}:{hosts[int(ident)]}")
            except (ValueError, IndexError):
                continue
        return tuple(out)

    def _dedupe_source(self, job: JobHandle) -> Diagnosis | None:
        """An unresolved diagnosis from another job touching this job's
        hardware, if any — its pinpoint can be reused instead of re-running
        profiling + validation."""
        if job.hardware is None and job.hosts is None:
            return None
        for other_id, diag in self._active_diag.items():
            if other_id == job.job_id or not diag.components_global:
                continue
            if self._localize(job, diag.components_global):
                return diag
        return None

    def _localize(
        self, job: JobHandle, components_global: Sequence[str]
    ) -> list[str]:
        """Global component ids -> this job's local ids (unmapped dropped)."""
        inverse = job._hw_inverse
        hosts_inv = job._host_inverse
        out = []
        for comp in components_global:
            kind, _, ident = comp.partition(":")
            if kind == "gpu" and inverse is not None and ident in inverse:
                out.append(f"gpu:{inverse[ident]}")
            elif kind == "link" and inverse is not None:
                a, _, b = ident.partition("|")
                if a in inverse and b in inverse:
                    lo, hi = sorted((inverse[a], inverse[b]))
                    out.append(f"link:{lo}-{hi}")
            elif kind in ("node", "nic") and hosts_inv is not None:
                if ident in hosts_inv:
                    out.append(f"{kind}:{hosts_inv[ident]}")
        return out

    def _adopt(
        self, job: JobHandle, source: Diagnosis, cp, now: float
    ) -> FailSlowEvent | None:
        """Build this job's event from another job's diagnosis: shared root
        cause and components (translated to local ranks), this job's own
        timing from its verified change-point.

        Trust but verify: before adopting, the translated components are
        re-measured through *this* job's adapter (the detector's O(1)
        component validation). A co-located job can flag for an unrelated
        reason — e.g. its own GPU fault while a neighbour's NIC is congested
        — and blindly inheriting the neighbour's diagnosis would both
        mislabel this job's fault and leave it unpinpointed. If the shared
        components measure healthy here, the dedupe is rejected and the job
        runs its own profiling + validation.
        """
        local = self._localize(job, source.components_global)
        if not local:
            return None
        probe = FailSlowEvent(
            start_time=now, root_cause=source.event.root_cause,
            components=local,
        )
        if job.detector.components_recovered(probe):
            return None
        severity = 0.0
        if cp.mean_after > 0:
            severity = max(0.0, 1.0 - cp.mean_before / cp.mean_after)
        event = FailSlowEvent(
            start_time=now,
            root_cause=source.event.root_cause,
            components=local,
            t_healthy=cp.mean_before,
            t_slow=cp.mean_after,
            severity=severity,
        )
        return job.detector.adopt_event(event, now)

    # -- introspection ---------------------------------------------------
    def incident_gap(self) -> float:
        """Observed mean wall-clock gap between fresh incidents per job.

        Derived from the plane's own event stream (job-seconds watched over
        fresh onset diagnoses). This is the healthy window a successful
        mitigation can expect to buy before the next fault lands — under a
        fail-slow storm it, not the current fault's remaining duration,
        bounds what an expensive action (S4) is worth. The +1 is Laplace
        smoothing for the systematic undercount early in a fleet's life:
        detection warmup and latency mean arrivals are always seen late.
        """
        return self._watched_s / (self._fresh_onsets + 1)

    def diagnoses(self, job_id: str | None = None) -> list[Diagnosis]:
        return [
            e for e in self.events
            if isinstance(e, Diagnosis)
            and (job_id is None or e.job_id == job_id)
        ]
