"""FALCON control plane — the public API of the detection/mitigation stack.

    plane = ControlPlane()
    plane.register_job("job0", TrainingSimulator(...), hardware=[...])
    events = plane.tick({"job0": iter_time}, now)   # fleet screening path
    events = plane.observe("job0", iter_time, now)  # exact per-job path

See docs/control_plane.md for the event pipeline, the cluster-adapter
protocol, and how to register a custom mitigation strategy.
"""
from repro.cluster.spec import DirtySet  # noqa: F401  (cursor contract)
from repro.controlplane.adapters import ClusterAdapter, TraceReplayAdapter  # noqa: F401
from repro.controlplane.events import (  # noqa: F401
    ControlEvent,
    Diagnosis,
    Flag,
    Membership,
    MitigationAction,
    MitigationResult,
    Observation,
    ScreenTuning,
    WatchdogAlarm,
    event_log_records,
    event_record,
)
from repro.controlplane.plane import (  # noqa: F401
    ControlPlane,
    ExecutorPolicy,
    JobHandle,
)
from repro.controlplane.strategies import (  # noqa: F401
    AbortReformStrategy,
    CkptRestartStrategy,
    IgnoreStrategy,
    MicroBatchStrategy,
    MitigationContext,
    MitigationStrategy,
    PlacementMicroBatchStrategy,
    PlacementTopologyStrategy,
    StrategyOutcome,
    StrategyRegistry,
    TopologyStrategy,
    default_registry,
    placement_registry,
)
