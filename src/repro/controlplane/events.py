"""Typed events of the FALCON control plane.

The control plane's public contract is an event pipeline

    Observation -> Flag -> Diagnosis -> MitigationAction -> MitigationResult

extending the detection-layer types in :mod:`repro.core.events`: a
:class:`Flag` wraps the verified :class:`~repro.core.events.ChangePoint` the
fleet screen produced, a :class:`Diagnosis` wraps the pinpointed
:class:`~repro.core.events.FailSlowEvent`, and mitigation events carry the
:data:`~repro.core.events.StrategyKey` that was dispatched through the
strategy registry. Every event is timestamped on the *job's* clock (the
trainer's simulated wall clock, a trace's replay cursor, or real
``time.monotonic`` on hardware) so a control-plane log is coherent across
sources — see docs/control_plane.md.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, fields

from repro.core.events import ChangePoint, FailSlowEvent, StrategyKey, strategy_label


@dataclass(frozen=True)
class ControlEvent:
    """Base class: everything the control plane emits names a job + time."""

    job_id: str
    time: float


@dataclass(frozen=True)
class Observation(ControlEvent):
    """One iteration-time sample ingested for a registered job."""

    iter_time: float
    step: int = 0


@dataclass(frozen=True)
class Membership(ControlEvent):
    """A job joined or left the control plane (dynamic fleet churn).

    Emitted by :meth:`ControlPlane.register_job` / ``remove_job`` so the
    event log alone reconstructs which jobs were live at any time — the
    campaign scoring layer reads join/leave times from here.
    """

    action: str = "join"  # "join" | "leave"


@dataclass(frozen=True)
class ScreenTuning(ControlEvent):
    """The fleet screen re-derived its adaptive knobs (fleet-scoped:
    ``job_id`` is empty).

    Emitted by :meth:`ControlPlane.tick` whenever the
    :class:`~repro.core.detector.FleetDetect` adaptive layer
    (``adapt_every > 0``) chooses new values: the per-worker hazard and the
    shared run-length frontier cap derived from the observed confirmed-flag
    rate (``change_rate`` = flags / worker-ticks at re-tune time). The log
    therefore records exactly which screening parameters were live for
    every subsequent Flag.
    """

    hazard: float = 0.0
    max_hypotheses: int | None = None
    change_rate: float = 0.0
    flags: int = 0
    worker_ticks: int = 0


@dataclass(frozen=True)
class Flag(ControlEvent):
    """A verified change-point from the fleet screen (pre-pinpoint).

    Emitted only on the screening path (:meth:`ControlPlane.tick`); the
    exact per-job path verifies inside ``FalconDetect.observe`` and emits a
    :class:`Diagnosis` directly.
    """

    change_point: ChangePoint


@dataclass(frozen=True)
class Diagnosis(ControlEvent):
    """A pinpointed (or deduped) fail-slow incident for one job.

    ``components_global`` are the job's slow components translated through
    its hardware map (shared-hardware identity across jobs);
    ``deduped_from`` names the job whose pinpoint this diagnosis reuses —
    ``None`` when this job ran profiling + validation itself.

    ``breakdown`` is the per-collective timing decomposition
    (:class:`repro.obs.collectives.CollectiveBreakdown`) of the job's
    iteration at diagnosis time, when the adapter can produce one — it
    names the bottleneck collective and ring edge a hang or link fault
    stalled. The field is *transient* (``metadata={"transient": True}``):
    :func:`event_record` skips it, so committed campaign reports are
    byte-stable; the observability sidecars (trace spans, metrics) carry
    the decomposition instead. See docs/observability.md.
    """

    event: FailSlowEvent
    components_global: tuple[str, ...] = ()
    deduped_from: str | None = None
    resolved: bool = False
    breakdown: object | None = field(
        default=None, compare=False, metadata={"transient": True}
    )


@dataclass(frozen=True)
class MitigationAction(ControlEvent):
    """The planner escalated: dispatch ``strategy`` for ``event`` now."""

    strategy: StrategyKey
    event: FailSlowEvent


@dataclass(frozen=True)
class WatchdogAlarm(ControlEvent):
    """A job's sample stream went silent past its calibrated deadline.

    Emitted by :meth:`ControlPlane.tick` when the heartbeat watchdog
    expires for a registered job that produced no observation — the hang
    signature BOCD structurally cannot flag. ``last_seen`` is the job clock
    of the final heartbeat, ``deadline_s`` the jitter-calibrated silence
    budget that was exceeded, ``silence_s`` the actual silence at alarm
    time.
    """

    last_seen: float = 0.0
    deadline_s: float = 0.0
    silence_s: float = 0.0


@dataclass(frozen=True)
class MitigationResult(ControlEvent):
    """Outcome of one strategy dispatch attempt (or a relief rebalance).

    ``overhead`` is the one-off action cost the caller must charge to the
    job's wall clock; ``detail`` carries strategy-specific payload (e.g. the
    new micro-batch allocation) for the caller's runtime to mirror.

    Failure semantics (docs/control_plane.md): ``status`` is ``"ok"`` for a
    successful dispatch, ``"failed"`` / ``"timed_out"`` for one rolled-back
    attempt (the executor emits one result per attempt, ``attempt`` counting
    from 1), and ``"rolled_back"`` for the terminal result of a dispatch
    whose retries were exhausted — the job state is guaranteed back at the
    pre-action snapshot whenever ``detail["rolled_back"]`` is true.
    """

    strategy: StrategyKey | None
    applied: bool
    overhead: float = 0.0
    kind: str = "mitigate"  # "mitigate" | "relief" | "error" | "suppressed"
    detail: dict = field(default_factory=dict)
    status: str = "ok"  # "ok" | "failed" | "timed_out" | "rolled_back"
    attempt: int = 1


# --------------------------------------------------------- serialization
def _jsonify(value):
    """Deterministic JSON-safe view of an event field value.

    Floats are rounded (fixed precision keeps committed logs byte-stable
    across platforms), numpy scalars are unwrapped, enums become their
    labels, and nested dataclasses recurse through :func:`event_record`'s
    field walk.
    """
    if isinstance(value, enum.Enum):
        return strategy_label(value) if value.__class__.__name__ == "Strategy" \
            else value.value
    if isinstance(value, bool) or value is None or isinstance(value, (int, str)):
        return value
    if isinstance(value, float):
        return round(float(value), 6)
    if hasattr(value, "item") and not isinstance(value, (list, tuple, dict)):
        return _jsonify(value.item())  # numpy scalar
    if isinstance(value, dict):
        return {str(k): _jsonify(v) for k, v in sorted(value.items(), key=lambda kv: str(kv[0]))}
    if isinstance(value, (list, tuple, set, frozenset)):
        seq = sorted(value, key=str) if isinstance(value, (set, frozenset)) else value
        return [_jsonify(v) for v in seq]
    if hasattr(value, "__dataclass_fields__"):
        return {
            f.name: _jsonify(getattr(value, f.name))
            for f in fields(value)
        }
    return str(value)


def event_record(ev: ControlEvent) -> dict:
    """One control-plane event as a deterministic, JSON-safe dict.

    The replayable fleet event log: a campaign report that stores
    ``[event_record(e) for e in plane.events]`` carries every flag,
    diagnosis, action, and result with timestamps, which is sufficient
    input for the what-if engine (:mod:`repro.whatif`) to rebuild the
    decision schedule without re-running the campaign. ``type`` is the
    event class name; strategy keys serialize via
    :func:`~repro.core.events.strategy_label` so enum and string-keyed
    strategies round-trip uniformly. :class:`Observation` events are the
    caller's to filter — at fleet scale they dominate the log but carry
    no decision, so the campaign scorer drops them.
    """
    rec = {"type": type(ev).__name__}
    for f in fields(ev):
        if f.metadata.get("transient"):
            # Observability-only payload (e.g. Diagnosis.breakdown):
            # excluded so committed event logs stay byte-stable across
            # the observability layer's evolution; sidecars carry it.
            continue
        rec[f.name] = _jsonify(getattr(ev, f.name))
    return rec


def event_log_records(
    events, observation_stride: int = 0
) -> list[dict]:
    """Serialize an event stream into report-ready records.

    :class:`Observation` events are elided by default — at fleet scale
    they dominate the log (one per job per tick) and carry no decision —
    which also blanks a dashboard's timeline lanes between flags.
    ``observation_stride=N`` opts in to keeping every Nth Observation per
    job: a sampled iteration-time lane dense enough to plot, cheap enough
    to commit. ``0`` (the default) reproduces the historical
    Observation-free log byte for byte.
    """
    out: list[dict] = []
    seen: dict[str, int] = {}
    for ev in events:
        if isinstance(ev, Observation):
            if observation_stride <= 0:
                continue
            k = seen.get(ev.job_id, 0)
            seen[ev.job_id] = k + 1
            if k % observation_stride:
                continue
        out.append(event_record(ev))
    return out
