"""Paper-metric scoring of a campaign from the typed event log.

Three metric families, matching the paper's evaluation tables:

* **Detection** (Tables 4-5): per-cause precision, recall and detection
  latency of the control plane's onset :class:`Diagnosis` events against
  the ground-truth injection schedule. Ground truth is *observability-
  aware*: an episode counts toward recall only if its modeled iteration-
  time impact on that job clears the detectability threshold (the paper's
  human labels likewise only mark fail-slows that are visible in the
  trace), it starts after the job's detector warmup, and enough of it
  overlaps the job's lifetime to be seen. Overlapping episodes on one job
  are merged — the detector state-machine reports compound fail-slows as
  one incident chain, so they are scored as one.
* **Mitigation** (Fig. 20 / Table 7): per-job and fleet %-slowdown
  mitigated, computed from the JCT gap between the ``faults`` (no
  mitigation) ceiling and the ``healthy`` floor, for both the full FALCON
  ladder and the checkpoint-restart-only baseline.
* **JCT delay** (Table 7): per-job JCT inflation of the FALCON run over
  the healthy floor (the cost of living with faults + mitigation overhead).

``write_report`` persists the scored campaign to ``results/campaigns/`` as
JSON that is byte-identical for identical (preset, jobs, seed) inputs —
pinned by the determinism tests.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.cluster.injector import HANG_KINDS
from repro.cluster.traces import episodes_from_injections
from repro.controlplane import (
    Diagnosis,
    Membership,
    MitigationResult,
    WatchdogAlarm,
    event_log_records,
)
from repro.core.detector import FalconDetect, FleetDetect
from repro.core.events import RootCause
from repro.scenarios.campaign import (
    MODES,
    CampaignSpec,
    RunResult,
    build_campaign,
    run_campaign,
)
from repro.scenarios.engine import CampaignEngine
from repro.scenarios.faults import KIND_CAUSE

#: episodes below this modeled impact are invisible even in principle and
#: are excluded from the recall denominator (strict ground truth)...
DETECT_IMPACT = 0.15
#: ...while anything above this may legitimately trip the 10 % verifier, so
#: diagnoses matching such an episode are true positives (loose matching)
MATCH_IMPACT = 0.05

RESULTS_DIR = os.path.join("results", "campaigns")

# Ground-truth windows mirror the detector configuration the campaign runs
# with (the FleetDetect/FalconDetect defaults) — deriving them keeps the
# scorer honest if the detector tuning moves.
#: ticks after a job joins before its stream is screenable: fleet warmup
#: plus the verification half-window
WARM_TICKS = FleetDetect.warmup + FleetDetect.verify_window // 2 + 2
#: the drift screen's reference lag: ramps slower than threshold/lag are
#: invisible to the lagged comparison
DRIFT_REF_TICKS = FleetDetect.drift_ref
#: episodes closer than the revalidation cadence merge into one incident
MERGE_GAP_TICKS = FalconDetect.revalidate_every + 2


@dataclass
class _Candidate:
    """One (episode, job) pair in matching form."""

    global_id: int
    kind_cause: RootCause
    impact: float
    start: float
    end: float
    detectable_from: float
    expected: bool


def _cause_bucket(cause: RootCause) -> str:
    return cause.value


def _cause_compatible(diag: Diagnosis, cand: "_Candidate") -> bool:
    """Whether a diagnosis can stand for a ground-truth episode's cause.

    UNKNOWN is compound/unattributed and matches anything. So does a
    CPU_CONTENTION diagnosis with *no components*: the detector assigns it
    by elimination when validation finds no guilty part — which is exactly
    what happens for faults inside the validation blind band (e.g. a GPU
    throttled by ~20 %: iteration impact clears the 10 % verifier but the
    GEMM ratio 1/0.8 = 1.25 stays under the 1.3x component threshold). The
    detection is real; only the localization failed, and the per-cause
    table still shows where attribution landed.
    """
    cause = diag.event.root_cause
    if cause is RootCause.UNKNOWN:
        return True
    if cause is RootCause.CPU_CONTENTION and not diag.event.components:
        return True
    return cause is cand.kind_cause


def _candidates_for_job(
    placed, outcome, dt: float
) -> list[_Candidate]:
    warm_s = WARM_TICKS * dt
    min_visible_s = 10.0 * dt
    job_end = outcome.end_time if outcome.end_time is not None else float("inf")
    out: list[_Candidate] = []
    for gid, local, impact in zip(
        placed.global_ids, placed.local_schedule, placed.impacts
    ):
        if impact < MATCH_IMPACT:
            continue
        ramp_frac = min(1.0, 0.10 / impact) if local.ramp > 0 else 0.0
        detectable_from = local.start + local.ramp * ramp_frac
        # A ramp slower than the drift screen's reference lag never shows a
        # windowed shift of the full impact — only the part the lagged
        # comparison can see counts toward detectability.
        windowed = impact
        if local.ramp > 0:
            windowed = impact * min(1.0, DRIFT_REF_TICKS * dt / local.ramp)
        expected = (
            windowed >= DETECT_IMPACT
            and detectable_from >= outcome.join_time + warm_s
            and min(local.end, job_end) - detectable_from >= min_visible_s
        )
        out.append(_Candidate(
            global_id=gid,
            kind_cause=KIND_CAUSE[local.kind],
            impact=impact,
            start=local.start,
            end=min(local.end, job_end),
            detectable_from=detectable_from,
            expected=expected,
        ))
    return out


def _merge_episodes(
    cands: list[_Candidate], dt: float
) -> list[list[_Candidate]]:
    """Group expected candidates whose spans (+ a revalidation gap) overlap:
    the detector reports a compound pile-up as one incident chain."""
    gap = MERGE_GAP_TICKS * dt
    expected = sorted(
        (c for c in cands if c.expected), key=lambda c: c.detectable_from
    )
    groups: list[list[_Candidate]] = []
    for c in expected:
        if groups and c.detectable_from <= max(
            m.end for m in groups[-1]
        ) + gap:
            groups[-1].append(c)
        else:
            groups.append([c])
    return groups


def score_campaign(
    spec: CampaignSpec,
    runs: dict[str, RunResult],
    observation_stride: int = 0,
) -> dict:
    """Score a campaign's four runs into the paper-metric report dict.

    ``observation_stride`` opts the report's event log into sampled
    :class:`Observation` records (every Nth per job — a plottable
    iteration-time lane); the default ``0`` keeps the historical
    Observation-free log byte for byte. See
    :func:`~repro.controlplane.events.event_log_records`.
    """
    preset = spec.preset
    dt = preset.tick_seconds
    horizon = preset.max_ticks * dt
    falcon = runs["falcon"]
    grace = 20.0 * dt

    # ---------------------------------------------------- detection
    diags_by_job: dict[str, list[Diagnosis]] = {}
    for ev in falcon.events:
        if isinstance(ev, Diagnosis) and not ev.resolved:
            diags_by_job.setdefault(ev.job_id, []).append(ev)

    per_cause: dict[str, dict] = {}

    def bucket(name: str) -> dict:
        return per_cause.setdefault(
            name,
            {"tp": 0, "fp": 0, "episodes": 0, "detected": 0, "latencies": []},
        )

    detected_gids: dict[int, list[str]] = {}
    episode_rows: list[dict] = []
    diag_rows: list[dict] = []
    for placed in spec.jobs:
        outcome = falcon.outcomes[placed.job_id]
        cands = _candidates_for_job(placed, outcome, dt)
        diags = diags_by_job.get(placed.job_id, [])

        # Precision: every onset diagnosis must trace back to a visible
        # ground-truth episode of the matching cause.
        for diag in diags:
            cause = diag.event.root_cause
            matched = any(
                _cause_compatible(diag, c)
                and c.start - 2 * dt <= diag.time <= c.end + grace
                for c in cands
            )
            b = bucket(_cause_bucket(cause))
            b["tp" if matched else "fp"] += 1
            diag_rows.append({
                "job_id": placed.job_id,
                "time_s": round(diag.time, 2),
                "cause": cause.value,
                "components": list(diag.event.components),
                "deduped_from": diag.deduped_from,
                "matched": matched,
            })

        # Recall + latency over merged expected episodes.
        for group in _merge_episodes(cands, dt):
            causes = {c.kind_cause for c in group}
            name = (
                _cause_bucket(next(iter(causes)))
                if len(causes) == 1 else "mixed"
            )
            b = bucket(name)
            b["episodes"] += 1
            t_from = min(c.detectable_from for c in group)
            hit_times = [
                diag.time
                for diag in diags
                for c in group
                if _cause_compatible(diag, c)
                and c.start - 2 * dt <= diag.time <= c.end + grace
            ]
            row = {
                "job_id": placed.job_id,
                "causes": sorted(c.value for c in causes),
                "injections": sorted({c.global_id for c in group}),
                "detectable_from_s": round(t_from, 3),
                "detected": bool(hit_times),
                "latency_s": (
                    round(max(0.0, min(hit_times) - t_from), 3)
                    if hit_times else None
                ),
            }
            episode_rows.append(row)
            if hit_times:
                b["detected"] += 1
                b["latencies"].append(max(0.0, min(hit_times) - t_from))
                for c in group:
                    detected_gids.setdefault(c.global_id, []).append(
                        placed.job_id
                    )

    def _finalize(agg: dict) -> dict:
        tp, fp = agg["tp"], agg["fp"]
        lat = sorted(agg["latencies"])
        return {
            "diagnoses": tp + fp,
            "true_positives": tp,
            "false_positives": fp,
            "precision": round(tp / (tp + fp), 4) if tp + fp else None,
            "episodes": agg["episodes"],
            "detected": agg["detected"],
            "recall": (
                round(agg["detected"] / agg["episodes"], 4)
                if agg["episodes"] else None
            ),
            "latency_mean_s": (
                round(sum(lat) / len(lat), 3) if lat else None
            ),
            "latency_p90_s": (
                round(lat[min(len(lat) - 1, int(0.9 * len(lat)))], 3)
                if lat else None
            ),
        }

    overall = {
        "tp": sum(b["tp"] for b in per_cause.values()),
        "fp": sum(b["fp"] for b in per_cause.values()),
        "episodes": sum(b["episodes"] for b in per_cause.values()),
        "detected": sum(b["detected"] for b in per_cause.values()),
        "latencies": [
            v for b in per_cause.values() for v in b["latencies"]
        ],
    }
    detection = {
        "overall": _finalize(overall),
        "per_cause": {k: _finalize(v) for k, v in sorted(per_cause.items())},
    }

    # ---------------------------------------------------- mitigation
    job_rows: list[dict] = []
    gap_total = 0.0
    falcon_recovered = 0.0
    ckpt_recovered = 0.0
    delay_pcts: list[float] = []
    #: cause -> apportioned [slowdown_s, mitigated_s] (estimates: each
    #: job's JCT gap is split over its episodes by impact x lifetime
    #: overlap; the what-if engine's leave-one-out attribution is the
    #: counterfactual ground truth these estimates approximate)
    cause_split: dict[str, list[float]] = {}
    for placed in spec.jobs:
        jcts = {
            mode: runs[mode].outcomes[placed.job_id].jct(horizon)
            for mode in runs
        }
        finished = {
            mode: runs[mode].outcomes[placed.job_id].finished for mode in runs
        }
        gap = jcts["faults"] - jcts["healthy"]
        mitigated = jcts["faults"] - jcts["falcon"]
        mitigated_ckpt = jcts["faults"] - jcts.get("ckpt", jcts["faults"])
        if gap > 1e-9:
            gap_total += gap
            falcon_recovered += mitigated
            ckpt_recovered += mitigated_ckpt
            out_f = runs["faults"].outcomes[placed.job_id]
            end_f = (
                out_f.end_time if out_f.end_time is not None else horizon
            )
            weights: list[tuple[str, float]] = []
            for local, impact in zip(placed.local_schedule, placed.impacts):
                overlap = max(
                    0.0, min(local.end, end_f) - max(local.start, out_f.join_time)
                )
                w = impact * overlap
                if w > 0.0:
                    weights.append((KIND_CAUSE[local.kind].value, w))
            total_w = sum(w for _, w in weights)
            for cause, w in weights:
                share = w / total_w if total_w > 0 else 0.0
                acc = cause_split.setdefault(cause, [0.0, 0.0])
                acc[0] += gap * share
                acc[1] += mitigated * share
        delay_pct = 100.0 * (jcts["falcon"] - jcts["healthy"]) / jcts["healthy"]
        delay_pcts.append(delay_pct)
        t = placed.template
        job_rows.append({
            "job_id": placed.job_id,
            "arch": t.arch,
            "parallelism": f"tp{t.tp}xdp{t.dp}xpp{t.pp}",
            "devices": list(placed.devices),
            "nodes": list(placed.nodes),
            "join_tick": placed.join_tick,
            "steps": placed.steps,
            "healthy_iter_time_s": round(placed.healthy_iter_time, 4),
            "jct_s": {m: round(v, 2) for m, v in sorted(jcts.items())},
            "finished": finished,
            "jct_delay_pct": round(delay_pct, 3),
            "slowdown_mitigated_pct": (
                round(100.0 * mitigated / gap, 2) if gap > 1e-9 else None
            ),
            "mitigations": dict(sorted(
                falcon.outcomes[placed.job_id].mitigations.items()
            )),
            "ground_truth_ticks": [
                {
                    "onset": ep.onset, "relief": ep.relief,
                    "severity": round(ep.severity, 3), "ramp": ep.ramp,
                }
                for ep in episodes_from_injections(
                    placed.local_schedule, dt, preset.max_ticks
                )
            ],
        })

    mitigation = {
        "slowdown_mitigated_pct": (
            round(100.0 * falcon_recovered / gap_total, 2)
            if gap_total > 1e-9 else None
        ),
        "slowdown_mitigated_ckpt_pct": (
            round(100.0 * ckpt_recovered / gap_total, 2)
            if gap_total > 1e-9 else None
        ),
        "avg_jct_delay_pct": round(
            sum(delay_pcts) / len(delay_pcts), 3
        ) if delay_pcts else None,
        "paper_slowdown_mitigated_pct": 60.1,
        "paper_avg_jct_delay_pct": 1.34,
        "per_cause": {
            cause: {
                "slowdown_s": round(g, 2),
                "mitigated_s": round(m, 2),
                "mitigated_pct": round(100.0 * m / g, 2) if g > 1e-9 else None,
            }
            for cause, (g, m) in sorted(cause_split.items())
        },
    }

    # ---------------------------------------------------- robustness
    # Hang anomalies (watchdog path) + the fault-tolerant executor. Scored
    # from the falcon run's typed event log: WatchdogAlarm marks detection,
    # an applied ABORT_REFORM / CKPT_AND_RESTART inside the hang's window
    # ends it, and per-attempt MitigationResult statuses expose every
    # executor failure, retry, rollback and quarantine.
    alarms = [ev for ev in falcon.events if isinstance(ev, WatchdogAlarm)]
    aborts: dict[str, list[float]] = {}
    exec_counts = {"ok": 0, "failed": 0, "timed_out": 0, "rolled_back": 0}
    retries = 0
    quarantines = 0
    errors = 0
    for ev in falcon.events:
        if not isinstance(ev, MitigationResult):
            continue
        if ev.kind == "error":
            errors += 1
            continue
        if ev.kind != "mitigate":
            continue
        exec_counts[ev.status] = exec_counts.get(ev.status, 0) + 1
        if ev.attempt > 1:
            retries += 1
        if ev.detail.get("quarantined"):
            quarantines += 1
        label = (
            ev.strategy.name
            if hasattr(ev.strategy, "name") else str(ev.strategy)
        )
        if ev.applied and label in ("ABORT_REFORM", "CKPT_AND_RESTART"):
            aborts.setdefault(ev.job_id, []).append(ev.time)

    hang_rows: list[dict] = []
    tta: list[float] = []
    alarm_windows: dict[str, list[tuple[float, float]]] = {}
    def _live_during(job_id: str, inj) -> bool:
        # Observability: a hang only counts against the watchdog if the
        # job's falcon-run lifetime overlaps it — a job that finished
        # before the hang started never went silent.
        out = falcon.outcomes[job_id]
        end = out.end_time if out.end_time is not None else float("inf")
        return out.join_time < inj.end and inj.start < end

    for gi, inj in enumerate(spec.schedule):
        if inj.kind not in HANG_KINDS:
            continue
        affected = sorted(
            p.job_id for p in spec.jobs
            if gi in p.global_ids and _live_during(p.job_id, inj)
        )
        if not affected:
            continue
        lo, hi = inj.start, inj.end + grace
        for j in affected:
            alarm_windows.setdefault(j, []).append((lo, hi))
        hit = [
            a.time for a in alarms
            if a.job_id in affected and lo <= a.time <= hi
        ]
        abort_times = [
            t for j in affected for t in aborts.get(j, []) if lo <= t <= hi
        ]
        if abort_times:
            tta.append(min(abort_times) - inj.start)
        hang_rows.append({
            "injection_id": gi,
            "kind": inj.kind.value,
            "scope": inj.scope,
            "jobs": affected,
            "start_s": round(inj.start, 2),
            "alarmed": bool(hit),
            "alarm_latency_s": (
                round(min(hit) - inj.start, 3) if hit else None
            ),
            "time_to_abort_s": (
                round(min(abort_times) - inj.start, 3)
                if abort_times else None
            ),
        })
    false_alarms = sum(
        1 for a in alarms
        if not any(
            lo <= a.time <= hi
            for lo, hi in alarm_windows.get(a.job_id, [])
        )
    )
    tta.sort()
    n_hangs = len(hang_rows)
    n_alarmed = sum(1 for r in hang_rows if r["alarmed"])
    robustness = {
        "watchdog": {
            "alarms": len(alarms),
            "hangs_injected": n_hangs,
            "hangs_detected": n_alarmed,
            "hang_detection_rate": (
                round(n_alarmed / n_hangs, 4) if n_hangs else None
            ),
            "false_alarms": false_alarms,
            "median_time_to_abort_s": (
                round(tta[len(tta) // 2], 3) if tta else None
            ),
            "deadline_budget_s": round(preset.abort_budget_ticks * dt, 2),
            "hangs": hang_rows,
        },
        "executor": {
            "dispatch_results": dict(sorted(exec_counts.items())),
            "retries": retries,
            "quarantines": quarantines,
            "uncaught_errors": errors,
        },
        # GPU-seconds burned while a job sat fully stalled — the paper's
        # wasted-accelerator-time cost of hangs; mitigation shrinks it.
        "wasted_gpu_time_s": {
            mode: round(
                sum(
                    runs[mode].outcomes[p.job_id].stalled_ticks
                    * dt * len(p.devices)
                    for p in spec.jobs
                ), 2,
            )
            for mode in sorted(runs)
        },
    }

    # ---------------------------------------------------- assembled report
    inj_rows = [
        {
            "id": gi,
            "kind": inj.kind.value,
            "target": list(inj.target),
            "start_s": round(inj.start, 2),
            "duration_s": round(inj.duration, 2),
            "severity": round(inj.severity, 3),
            "ramp_s": round(inj.ramp, 2),
            "affected_jobs": sorted(
                p.job_id for p in spec.jobs if gi in p.global_ids
            ),
            "detected_by": sorted(set(detected_gids.get(gi, []))),
        }
        for gi, inj in enumerate(spec.schedule)
    ]
    membership = [
        {"time_s": round(ev.time, 2), "job_id": ev.job_id, "action": ev.action}
        for ev in falcon.events
        if isinstance(ev, Membership)
    ]
    # The replayable fleet event log (what-if input): every falcon-run
    # flag, diagnosis, action and result, with timestamps. Observations
    # are dropped by default — they dominate the log (one per job per
    # tick) and the replay re-derives them from (preset, seed) anyway —
    # unless the caller opts into a sampled stride.
    event_log = event_log_records(
        falcon.events, observation_stride=observation_stride
    )
    event_counts: dict[str, int] = {}
    for ev in falcon.events:
        name = type(ev).__name__
        event_counts[name] = event_counts.get(name, 0) + 1

    return {
        "campaign": {
            "preset": preset.name,
            "description": preset.description,
            "seed": spec.seed,
            "n_jobs": len(spec.jobs),
            "n_nodes": spec.n_nodes,
            "gpus_per_node": preset.gpus_per_node,
            "tick_seconds": dt,
            "max_ticks": preset.max_ticks,
            "ticks_run": {m: runs[m].ticks_run for m in sorted(runs)},
            "n_injections": len(spec.schedule),
        },
        "detection": detection,
        "diagnoses": diag_rows,
        "episodes": episode_rows,
        "mitigation": mitigation,
        "robustness": robustness,
        "jobs": job_rows,
        "injections": inj_rows,
        "membership": membership,
        "event_log": event_log,
        "falcon_event_counts": dict(sorted(event_counts.items())),
    }


def run_and_score(
    preset: str,
    n_jobs: int | None = None,
    seed: int = 0,
    max_ticks: int | None = None,
    obs: bool = False,
    observation_stride: int = 0,
    screening_backend: str | None = None,
    reduction_backend: str | None = None,
    engine: CampaignEngine | None = None,
    fresh: bool = False,
) -> tuple[CampaignSpec, dict[str, RunResult], dict]:
    """Build a campaign, execute all four modes, and score it.

    The four modes run on a shared-prefix :class:`CampaignEngine` — one
    recorded timeline, plane modes forked at their divergence point —
    byte-identical to four independent :func:`run_campaign` executions
    (the engine's headline invariant, pinned by tests/test_engine.py).
    Pass ``fresh=True`` to force the independent executions anyway, or
    ``engine=`` to reuse a caller-owned engine (its spec supersedes the
    identity arguments; further ``run()`` calls share its mode tree).

    ``obs=True`` turns the observability layer on for the falcon run: a
    :class:`repro.obs.SpanTracer` rides the campaign clock (returned on
    ``runs["falcon"].tracer``), ready for
    :func:`repro.obs.recorder.write_sidecars`. Only that falcon run
    executes fresh (the tracer wants the real control flow); the scored
    report is byte-identical either way — tracing never alters the run.

    ``screening_backend`` / ``reduction_backend`` override the fleet
    screen's and the simulators' compute backends (registry names — see
    docs/kernels.md); None keeps the deterministic defaults the committed
    reports pin. Backend overrides disable the engine (its snapshots only
    cover the default backends' state).
    """
    spec = (
        engine.spec if engine is not None
        else build_campaign(preset, n_jobs=n_jobs, seed=seed, max_ticks=max_ticks)
    )
    use_engine = (
        not fresh and screening_backend is None and reduction_backend is None
    )
    if use_engine and engine is None:
        engine = CampaignEngine(spec)
    runs = {}
    for mode in MODES:
        tracer = None
        if obs and mode == "falcon":
            from repro.obs import SpanTracer

            tracer = SpanTracer()
        if use_engine and tracer is None:
            runs[mode] = engine.run(mode)
            continue
        runs[mode] = run_campaign(
            spec, mode, tracer=tracer,
            screening_backend=screening_backend,
            reduction_backend=reduction_backend,
        )
    return spec, runs, score_campaign(
        spec, runs, observation_stride=observation_stride
    )


def write_report(report: dict, out_dir: str = RESULTS_DIR) -> str:
    """Persist a campaign report; the filename encodes (preset, jobs, seed).

    Serialization is canonical (sorted keys, fixed float rounding applied
    upstream, no timestamps), so identical campaigns produce byte-identical
    files — the determinism contract the tests pin.
    """
    os.makedirs(out_dir, exist_ok=True)
    c = report["campaign"]
    path = os.path.join(
        out_dir, f"{c['preset']}-j{c['n_jobs']}-s{c['seed']}.json"
    )
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
