"""Scenario campaign engine — reproduce the paper's *statistical* claims.

The paper's headline numbers (>99 % detection accuracy, 60.1 % slowdown
mitigated, 1.34 % average JCT delay) are fleet-scale statistics over diverse
fail-slow populations, not single hand-wired scenarios. This package makes
them measurable:

* :mod:`repro.scenarios.faults` — a seeded fault model sampling injection
  schedules from the §3 characterization (cause mix, log-spaced durations,
  weak/medium/severe tiers, ramped network onsets, recurring flappers).
* :mod:`repro.scenarios.presets` — named scenario presets, from a single
  GPU throttle to multi-job fail-slow storms.
* :mod:`repro.scenarios.campaign` — the campaign runner: N heterogeneous
  jobs packed onto a shared hardware map, driven through
  :meth:`repro.controlplane.ControlPlane.tick` with dynamic join/leave
  churn, under four mitigation modes (healthy / faults / ckpt / falcon).
* :mod:`repro.scenarios.engine` — the shared-prefix executor: the four
  modes are bit-identical until the control plane first intervenes, so
  :class:`~repro.scenarios.engine.CampaignEngine` records that timeline
  once, forks each plane mode from a snapshot at its divergence point,
  keeps untouched jobs riding the recording, and memoizes knob-bundle
  variants by their decision trace — byte-identical to fresh
  :func:`run_campaign` execution.
* :mod:`repro.scenarios.scoring` — paper-metric scoring from the typed
  event log: per-cause precision/recall/detection latency against the
  ground-truth schedule, %-slowdown mitigated vs the no-mitigation and
  checkpoint-restart baselines, per-job JCT delay. Reports land in
  ``results/campaigns/`` and are byte-deterministic in (preset, seed).

    PYTHONPATH=src python -m repro.launch.campaign --preset mixed_fleet \
        --jobs 8 --seed 0
"""
from repro.scenarios.campaign import (  # noqa: F401
    CampaignSpec,
    PlacedJob,
    RunResult,
    build_campaign,
    run_campaign,
)
from repro.scenarios.engine import CampaignEngine  # noqa: F401
from repro.scenarios.faults import (  # noqa: F401
    CAUSE_KINDS,
    KIND_CAUSE,
    SEVERITY_TIERS,
    ExecutorFaultModel,
    FaultModel,
)
from repro.scenarios.presets import (  # noqa: F401
    JobTemplate,
    ScenarioPreset,
    get_preset,
    list_presets,
)
from repro.scenarios.scoring import (  # noqa: F401
    run_and_score,
    score_campaign,
    write_report,
)
