"""Shared-prefix campaign execution — snapshot-forked mode tree + memoized
variant scoring.

:func:`~repro.scenarios.campaign.run_campaign` executes the four campaign
modes independently, yet ``faults`` / ``ckpt`` / ``falcon`` are
bit-identical until the control plane first *intervenes*: before the first
non-observation event the plane never touches a simulator, an injector or
a jitter stream, so three of the four runs spend most of their ticks
recomputing the same timeline. :class:`CampaignEngine` runs that timeline
once and forks at the divergence point:

* the **faults** leg runs fresh and is *recorded*: per-tick samples, each
  job's cumulative progress, stall count and jitter-draw count, joins and
  finishes. Every plane-mode prefix rides this recording.
* a **shared plane leg** (falcon screening semantics, fused fleet screen)
  is driven by the recorded samples with lazily-materialized job adapters,
  taking a rolling :meth:`ControlPlane.snapshot` each tick. It stops at
  the first event outside {Observation, Membership, ScreenTuning} — the
  divergence tick ``D`` — and also marks ``R``, the first adaptive retune
  that *changed* the screening parameters (the tick falcon's and ckpt's
  screens stop being interchangeable).
* the **falcon** branch forks from the snapshot at ``D-1`` and replays
  from ``D`` at full fidelity. The **ckpt** branch forks at ``min(R, D)-1``
  (ScreenTuning events stripped, the retune mirror scrubbed, adaptation
  off) and — when ``R < D`` — continues on its own recorded leg until its
  own divergence.
* **per-job divergence tracking**: inside a branch, a job the plane never
  intervenes on stays *virtual* — its samples, progress and stalls are
  served from the recording, and its simulator / injector / rng are
  materialized only on first touch (a flag ingest, a silent-stall read, a
  mitigation dispatch), reconstructed bit-exactly from the placement, the
  schedule and a fast-forwarded jitter stream. :attr:`RunResult.touched_jobs`
  reports which jobs actually left the recording.
* **memoized variant scoring**: identical ``(mode, knobs)`` requests
  return the cached run outright, and a new knob bundle is first re-scored
  against every cached run's recorded break-even consult trace
  (:func:`repro.core.planner.threshold_value`) — if it reproduces the same
  decision sequence, the cached leg *is* its run.

Everything the engine returns is byte-identical to fresh
:func:`run_campaign` execution — pinned by tests/test_engine.py across
presets and seeds, and re-asserted by ``benchmarks/campaign_reuse.py``.
Callers needing tracers, backend overrides, episode drops or per-job
subsets fall back to ``run_campaign`` (see
:func:`repro.scenarios.scoring.run_and_score`).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.cluster.injector import FailSlowInjector
from repro.controlplane import ControlPlane, MitigationResult
from repro.controlplane.events import Observation, ScreenTuning
from repro.core.duration import DurationModel
from repro.core.planner import PlannerKnobs, threshold_value
from repro.scenarios.campaign import (
    MODES,
    CampaignSpec,
    JobOutcome,
    RunResult,
    _changed_episodes,
    _registry_for,
    run_campaign,
)
from repro.scenarios.faults import ExecutorFaultModel


class _JobRec:
    """One job's recorded fault-mode trajectory, indexed by campaign tick."""

    __slots__ = ("join_tick", "end_tick", "iters", "stalled", "draws")

    def __init__(self, join_tick: int) -> None:
        self.join_tick = join_tick
        self.end_tick: int | None = None
        #: iters_done after each tick's work phase, tick ``join_tick + i``
        self.iters: list[float] = []
        #: stalled_ticks after each tick's sample phase
        self.stalled: list[int] = []
        #: cumulative jitter draws consumed after each tick's sample phase
        self.draws: list[int] = []

    def iters_at(self, tick: int) -> float:
        i = tick - self.join_tick
        return self.iters[i] if i >= 0 else 0.0

    def stalled_at(self, tick: int) -> int:
        i = tick - self.join_tick
        return self.stalled[i] if i >= 0 else 0

    def draws_at(self, tick: int) -> int:
        i = tick - self.join_tick
        return self.draws[i] if i >= 0 else 0


class _Recording:
    """The faults leg's full trajectory — the shared prefix every plane
    mode rides and every virtual job replays."""

    __slots__ = ("samples", "jobs", "ticks_run")

    def __init__(self) -> None:
        #: per tick, the samples dict exactly as the runner built it
        self.samples: list[dict[str, float]] = []
        self.jobs: dict[str, _JobRec] = {}
        self.ticks_run: int = 0


class _Proxy:
    """Materialize-on-first-touch stand-in for a virtual job's simulator
    or injector. Any public attribute access — read or write — first
    reconstructs the real object at the engine's current tick and then
    delegates to it (writes matter: strategies assign
    ``injector.injections`` to clear mitigated episodes, and that property
    setter must run on the real injector). The accesses the control plane
    makes — silent-stall reads, snapshot probes, strategy dispatch — are
    exactly the moments a job stops being untouched."""

    __slots__ = ("_holder", "_kind")

    def __init__(self, holder: "_JobHolder", kind: str) -> None:
        object.__setattr__(self, "_holder", holder)
        object.__setattr__(self, "_kind", kind)

    def _target(self):
        holder = self._holder
        holder.materialize()
        return holder.sim if self._kind == "sim" else holder.injector

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._target(), name)

    def __setattr__(self, name: str, value) -> None:
        setattr(self._target(), name, value)


class _JobHolder:
    """Lazy reconstruction context for one virtual job.

    Materialization is bit-exact: a fresh simulator from the placement, a
    fresh injector fully applied at the current tick's start (the
    injector's full-apply equals its incremental applies — the PR-6
    snapshot contract), and the job's jitter stream fast-forwarded by the
    recorded draw count (one batched draw is bitwise the same stream state
    as the per-tick scalar draws).
    """

    __slots__ = ("engine", "placed", "st", "sim", "injector", "sim_proxy",
                 "injector_proxy")

    def __init__(self, engine: "CampaignEngine", placed, st: dict | None = None):
        self.engine = engine
        self.placed = placed
        self.st = st
        self.sim = None
        self.injector = None
        self.sim_proxy = _Proxy(self, "sim")
        self.injector_proxy = _Proxy(self, "injector")

    def materialize(self) -> None:
        if self.sim is not None:
            return
        engine, placed = self.engine, self.placed
        spec = engine.spec
        tick = engine.cur_tick
        dt = spec.preset.tick_seconds
        sim = placed.make_sim()
        injector = FailSlowInjector(list(placed.local_schedule))
        injector.apply(sim.state, tick * dt)
        rng = np.random.default_rng([spec.seed, 7, int(placed.job_id[1:])])
        k = engine.rec.jobs[placed.job_id].draws_at(tick)
        if k:
            rng.normal(1.0, spec.preset.jitter, size=k)
        self.sim = sim
        self.injector = injector
        if self.st is not None:
            self.st["sim"] = sim
            self.st["injector"] = injector
            self.st["rng"] = rng
            self.st["epoch"] = injector.epoch
            self.st["virtual"] = False


@dataclass
class _Fork:
    """A branch's starting point: the first tick to replay at full
    fidelity, the plane snapshot at the end of the tick before it, and the
    event-log prefix that snapshot covers (``events is None`` = resolve
    from the leg's final log by the snapshot's event count)."""

    tick: int
    blob: dict
    events: list | None


class CampaignEngine:
    """Shared-prefix executor for one campaign spec (see module docstring).

    ``engine.run(mode)`` is byte-identical to ``run_campaign(spec, mode)``
    for every mode, knob bundle and decision hook; repeated and
    decision-equivalent requests are served from the mode tree instead of
    re-executed.
    """

    def __init__(self, spec: CampaignSpec) -> None:
        self.spec = spec
        #: current campaign tick of whichever leg is executing — the
        #: reconstruction clock for lazy job materialization
        self.cur_tick = 0
        self.rec: _Recording | None = None
        self._base: dict[str, RunResult] | None = None
        self._shared: dict | None = None
        self._ckpt_plan: tuple | None = None
        self._memo: dict[tuple, RunResult] = {}
        self._traces: dict[str, list[dict]] = {}
        #: reuse ledger: how the mode tree served requests
        self.stats = {
            "memo_hits": 0, "trace_hits": 0,
            "forked_runs": 0, "reused_runs": 0, "fresh_runs": 0,
        }

    # -- public API ------------------------------------------------------
    def run(
        self,
        mode: str,
        *,
        planner_knobs: PlannerKnobs | None = None,
        decision_hook: object | None = None,
    ) -> RunResult:
        """The campaign's run under ``mode`` — bit-identical to
        ``run_campaign(spec, mode, planner_knobs=..., decision_hook=...)``.

        Knobs and hooks only act through the planner and the dispatch
        gate, both strictly after the divergence point, so every variant
        shares the same fork. Hook runs are never memoized (hooks are
        stateful); knob runs are memoized by value and by decision trace.
        """
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self._ensure_base()
        if mode in ("healthy", "faults"):
            # Knobs and hooks are no-ops without a control plane.
            return self._base[mode]
        if decision_hook is not None:
            return self._branch(
                mode, planner_knobs=planner_knobs, decision_hook=decision_hook
            )
        knobs = planner_knobs if planner_knobs is not None else PlannerKnobs()
        key = (mode, knobs)
        hit = self._memo.get(key)
        if hit is not None:
            self.stats["memo_hits"] += 1
            return hit
        res = self._probe_traces(mode, knobs)
        if res is None:
            trace: list = []
            res = self._branch(
                mode, planner_knobs=planner_knobs, planner_trace=trace
            )
            self._traces.setdefault(mode, []).append(
                {"knobs": knobs, "trace": trace, "result": res}
            )
        self._memo[key] = res
        return res

    # -- base legs -------------------------------------------------------
    def _ensure_base(self) -> None:
        if self._base is not None:
            return
        self._base = {"healthy": run_campaign(self.spec, "healthy")}
        faults, rec = self._full_leg("faults", record=True)
        self.rec = rec
        self._base["faults"] = faults
        self._shared = self._recorded_leg(
            fleet_kwargs=self._fleet_kwargs("falcon"), watch_retune=True
        )

    def _fleet_kwargs(self, mode: str) -> dict:
        # The engine's legs always run the fused (single-launch) fleet
        # screen — bit-equivalent to the per-cohort default and cheaper
        # per tick, and forks restore into the same layout.
        kw: dict = {"fused": True}
        if mode == "falcon" and self.spec.preset.adapt_every:
            kw["adapt_every"] = self.spec.preset.adapt_every
        return kw

    def _join_order(self):
        return sorted(
            self.spec.jobs, key=lambda j: (j.join_tick, int(j.job_id[1:]))
        )

    # -- mode plans ------------------------------------------------------
    def _falcon_plan(self) -> tuple:
        sh = self._shared
        if sh["status"] == "completed":
            return ("done", sh["events"])
        return ("fork", sh["fork"])

    def _ckpt(self) -> tuple:
        """The ckpt branch plan, computed lazily on first ckpt run.

        Until the first value-changing retune ``R`` the falcon-semantics
        shared leg and a fresh ckpt plane are interchangeable (a neutral
        retune rewrites identical values and ckpt never consults the
        adaptive counters), so ckpt forks at ``min(R, D) - 1`` with the
        ScreenTuning events stripped and the retune mirror scrubbed. When
        ``R < D`` the fork continues on its own recorded ckpt-config leg
        until ckpt's *own* divergence.
        """
        if self._ckpt_plan is not None:
            return self._ckpt_plan
        sh = self._shared
        ret = sh.get("retune")
        if ret is not None:
            cont = self._recorded_leg(
                fleet_kwargs=self._fleet_kwargs("ckpt"),
                fork=_Fork(ret.tick, ret.blob, self._strip(ret.events)),
                scrub_tuning=True,
            )
            if cont["status"] == "completed":
                self._ckpt_plan = ("done", cont["events"])
            else:
                self._ckpt_plan = ("fork", cont["fork"])
        elif sh["status"] == "completed":
            self._ckpt_plan = ("done", self._strip(sh["events"]))
        else:
            f = sh["fork"]
            self._ckpt_plan = (
                "fork",
                _Fork(f.tick, f.blob, self._strip(f.events))
                if f is not None else None,
            )
        return self._ckpt_plan

    @staticmethod
    def _strip(events) -> list:
        return [e for e in events if not isinstance(e, ScreenTuning)]

    @staticmethod
    def _scrub_tuning(plane: ControlPlane) -> None:
        """Turn a restored falcon-semantics screen into ckpt's: adaptation
        off (restore re-applies the snapshot's ``adapt_every``) and the
        retune mirror cleared. The screening *values* at the fork are
        already ckpt's own — the fork precedes the first value-changing
        retune by construction."""
        plane._last_tuning = None
        if plane._fleet is not None:
            plane._fleet.adapt_every = 0
            plane._fleet.last_tuning = None

    def _branch(
        self,
        mode: str,
        *,
        planner_knobs=None,
        decision_hook=None,
        planner_trace=None,
    ) -> RunResult:
        kind, payload = (
            self._falcon_plan() if mode == "falcon" else self._ckpt()
        )
        if kind == "done":
            # No intervention ever happened: knobs and hooks had nothing
            # to act on, and the whole run is the recording.
            self.stats["reused_runs"] += 1
            return self._result_from_recording(mode, payload)
        if payload is None:
            # Divergence on the very first tick — nothing to share.
            self.stats["fresh_runs"] += 1
        else:
            self.stats["forked_runs"] += 1
        return self._full_leg(
            mode, fork=payload, planner_knobs=planner_knobs,
            decision_hook=decision_hook, planner_trace=planner_trace,
        )

    # -- decision-trace memo ---------------------------------------------
    def _probe_traces(self, mode: str, knobs: PlannerKnobs) -> RunResult | None:
        """A cached run whose recorded decision sequence ``knobs`` would
        reproduce exactly, if any — decisions equal implies the whole run
        is equal (knobs act on nothing else)."""
        for entry in self._traces.get(mode, ()):
            if all(
                (r["impact"] > threshold_value(knobs, r)) == r["decision"]
                for r in entry["trace"]
            ):
                self.stats["trace_hits"] += 1
                return entry["result"]
        return None

    # -- recorded results ------------------------------------------------
    def _finished_outcome(self, placed) -> JobOutcome:
        jr = self.rec.jobs[placed.job_id]
        dt = self.spec.preset.tick_seconds
        out = JobOutcome(
            job_id=placed.job_id, join_time=placed.join_tick * dt,
            steps=placed.steps,
        )
        out.iters_done = jr.iters[-1] if jr.iters else 0.0
        out.stalled_ticks = jr.stalled[-1] if jr.stalled else 0
        if jr.end_tick is not None:
            out.end_time = (jr.end_tick + 1) * dt
        return out

    def _result_from_recording(self, mode: str, events: list) -> RunResult:
        outcomes = {
            p.job_id: self._finished_outcome(p) for p in self._join_order()
        }
        return RunResult(
            mode=mode, outcomes=outcomes, events=list(events),
            ticks_run=self.rec.ticks_run,
            horizon_s=self.spec.max_ticks * self.spec.preset.tick_seconds,
            touched_jobs=frozenset(),
        )

    # -- the shared recorded plane leg -----------------------------------
    def _recorded_leg(
        self,
        *,
        fleet_kwargs: dict,
        fork: _Fork | None = None,
        watch_retune: bool = False,
        scrub_tuning: bool = False,
    ) -> dict:
        """Drive a plane over the recorded samples until it diverges.

        All jobs are virtual (lazy holders); the plane sees exactly the
        sample stream, joins and leaves the fresh run would deliver, and a
        rolling snapshot marks every prospective fork point. Returns
        ``{"status": "diverged", "fork": _Fork, "retune": _Fork | None}``
        or ``{"status": "completed", "events": [...], "retune": ...}``.
        """
        spec = self.spec
        preset = spec.preset
        dt = preset.tick_seconds
        rec = self.rec
        plane = ControlPlane(max_events=1 << 20, fleet_kwargs=fleet_kwargs)
        pending = self._join_order()
        start_tick = 0
        live: set[str] = set()
        prev_fork: _Fork | None = None
        retune: _Fork | None = None
        if fork is not None:
            start_tick = fork.tick
            pending = [p for p in pending if p.join_tick >= start_tick]
            by_id = {p.job_id: p for p in spec.jobs}
            for job_id in fork.blob["jobs"]:
                placed = by_id[job_id]
                holder = _JobHolder(self, placed)
                plane.adopt_job(
                    job_id, holder.sim_proxy, state=fork.blob["jobs"][job_id],
                    overheads=preset.overheads(),
                    injector=holder.injector_proxy,
                    hardware=placed.hardware(), hosts=placed.hosts(),
                    sample_period=dt,
                )
                live.add(job_id)
            plane.restore(fork.blob, events=fork.events)
            if scrub_tuning:
                self._scrub_tuning(plane)
            prev_fork = fork

        def _resolve(f: _Fork | None, full: list) -> _Fork | None:
            if f is not None and f.events is None:
                f.events = full[: f.blob["n_events"]]
            return f

        for tick in range(start_tick, spec.max_ticks):
            self.cur_tick = tick
            now = tick * dt
            while pending and pending[0].join_tick <= tick:
                placed = pending.pop(0)
                holder = _JobHolder(self, placed)
                plane.register_job(
                    placed.job_id, holder.sim_proxy,
                    overheads=preset.overheads(),
                    injector=holder.injector_proxy,
                    hardware=placed.hardware(), hosts=placed.hosts(),
                    sample_period=dt, now=now,
                )
                live.add(placed.job_id)
            if not live and not pending:
                break
            now_end = (tick + 1) * dt
            if live:
                samples = {
                    j: v for j, v in rec.samples[tick].items() if j in live
                }
                fleet = plane._fleet
                prev_vals = (
                    (fleet.hazard, fleet.max_hypotheses)
                    if fleet is not None else None
                )
                prev_tuning = plane._last_tuning
                new_events = plane.tick(samples, now_end)
                if any(
                    not isinstance(ev, (Observation, ScreenTuning))
                    for ev in new_events
                ):
                    full = list(plane.events)
                    return {
                        "status": "diverged",
                        "fork": _resolve(prev_fork, full),
                        "retune": _resolve(retune, full),
                    }
                if (
                    watch_retune
                    and retune is None
                    and plane._last_tuning is not prev_tuning
                ):
                    after = (
                        plane._fleet.hazard, plane._fleet.max_hypotheses
                    )
                    if prev_vals is None or after != prev_vals:
                        retune = prev_fork
                for job_id in list(live):
                    if rec.jobs[job_id].end_tick == tick:
                        plane.remove_job(job_id, now_end)
                        live.discard(job_id)
                blob = plane.snapshot()
                prev_fork = _Fork(tick + 1, blob, None)
        full = list(plane.events)
        return {
            "status": "completed",
            "events": full,
            "retune": _resolve(retune, full),
        }

    # -- the full-fidelity leg -------------------------------------------
    def _full_leg(
        self,
        mode: str,
        *,
        fork: _Fork | None = None,
        planner_knobs=None,
        decision_hook=None,
        planner_trace=None,
        record: bool = False,
    ):
        """One campaign run, mirroring :func:`run_campaign` operation for
        operation — with three extensions: it can *record* the trajectory
        (the faults leg), *fork* from a shared-prefix snapshot, and keep
        untouched jobs *virtual* on the recording until the plane touches
        them."""
        spec = self.spec
        preset = spec.preset
        dt = preset.tick_seconds
        with_faults = mode != "healthy"
        with_plane = mode in ("ckpt", "falcon")
        serve = self.rec if not record else None
        rec = _Recording() if record else None
        plane = None
        if with_plane:
            fail_p, timeout_p = preset.executor_faults
            plane = ControlPlane(
                max_events=1 << 20,
                fleet_kwargs=self._fleet_kwargs(mode),
                duration_model=DurationModel() if mode == "falcon" else None,
                executor_faults=(
                    ExecutorFaultModel(fail_p, timeout_p, seed=spec.seed)
                    if fail_p > 0.0 or timeout_p > 0.0 else None
                ),
                decision_hook=decision_hook,
                planner_knobs=planner_knobs,
                planner_trace=planner_trace,
            )

        pending = self._join_order()
        live: dict[str, dict] = {}
        outcomes: dict[str, JobOutcome] = {}
        ticks = 0
        start_tick = 0
        touched: set[str] = set()

        def _work_remaining(out, placed):
            return (
                lambda o=out, t=placed.healthy_iter_time:
                max(o.steps - o.iters_done, 0.0) * t
            )

        if fork is not None:
            start_tick = fork.tick
            ticks = fork.tick
            pending = [p for p in pending if p.join_tick >= start_tick]
            by_id = {p.job_id: p for p in spec.jobs}
            for job_id in fork.blob["jobs"]:
                placed = by_id[job_id]
                jr = serve.jobs[job_id]
                out = JobOutcome(
                    job_id=job_id, join_time=placed.join_tick * dt,
                    steps=placed.steps,
                )
                out.iters_done = jr.iters_at(start_tick - 1)
                out.stalled_ticks = jr.stalled_at(start_tick - 1)
                outcomes[job_id] = out
                st = {
                    "placed": placed, "sim": None, "injector": None,
                    "debt": 0.0, "rng": None,
                    "gids": frozenset(placed.global_ids), "epoch": None,
                    "virtual": True,
                }
                holder = _JobHolder(self, placed, st=st)
                st["holder"] = holder
                live[job_id] = st
                plane.adopt_job(
                    job_id, holder.sim_proxy,
                    state=fork.blob["jobs"][job_id],
                    registry=_registry_for(mode),
                    overheads=preset.overheads(),
                    injector=holder.injector_proxy,
                    hardware=placed.hardware(), hosts=placed.hosts(),
                    sample_period=dt,
                    work_remaining=_work_remaining(out, placed),
                )
            plane.restore(fork.blob, events=fork.events)
            if mode == "ckpt":
                self._scrub_tuning(plane)
            for placed in spec.jobs:
                if placed.job_id in outcomes or placed.join_tick >= start_tick:
                    continue
                # Finished on the shared prefix: the recording is the run.
                outcomes[placed.job_id] = self._finished_outcome(placed)

        for tick in range(start_tick, spec.max_ticks):
            self.cur_tick = tick
            now = tick * dt
            while pending and pending[0].join_tick <= tick:
                placed = pending.pop(0)
                out = JobOutcome(
                    job_id=placed.job_id, join_time=now, steps=placed.steps
                )
                outcomes[placed.job_id] = out
                if serve is not None and with_plane:
                    # Post-fork joiners start virtual too.
                    st = {
                        "placed": placed, "sim": None, "injector": None,
                        "debt": 0.0, "rng": None,
                        "gids": frozenset(placed.global_ids), "epoch": None,
                        "virtual": True,
                    }
                    holder = _JobHolder(self, placed, st=st)
                    st["holder"] = holder
                    live[placed.job_id] = st
                    plane.register_job(
                        placed.job_id, holder.sim_proxy,
                        registry=_registry_for(mode),
                        overheads=preset.overheads(),
                        injector=holder.injector_proxy,
                        hardware=placed.hardware(), hosts=placed.hosts(),
                        sample_period=dt,
                        work_remaining=_work_remaining(out, placed),
                        now=now,
                    )
                else:
                    sim = placed.make_sim()
                    injector = FailSlowInjector(
                        list(placed.local_schedule) if with_faults else []
                    )
                    st = {
                        "placed": placed, "sim": sim, "injector": injector,
                        "debt": 0.0,
                        "rng": np.random.default_rng(
                            [spec.seed, 7, int(placed.job_id[1:])]
                        ),
                        "gids": frozenset(placed.global_ids), "epoch": None,
                        "virtual": False,
                    }
                    live[placed.job_id] = st
                    if plane is not None:
                        plane.register_job(
                            placed.job_id, sim,
                            registry=_registry_for(mode),
                            overheads=preset.overheads(),
                            injector=injector,
                            hardware=placed.hardware(), hosts=placed.hosts(),
                            sample_period=dt,
                            work_remaining=_work_remaining(out, placed),
                            now=now,
                        )
                if record:
                    rec.jobs[placed.job_id] = _JobRec(tick)
            if not live and not pending:
                break
            ticks = tick + 1
            now_end = (tick + 1) * dt

            changed = (
                _changed_episodes(spec.schedule, (tick - 1) * dt, now, dt)
                if with_faults else ()
            )
            samples: dict[str, float] = {}
            for job_id, st in live.items():
                if st["virtual"]:
                    s = serve.samples[tick].get(job_id)
                    if s is None:
                        outcomes[job_id].stalled_ticks += 1
                    else:
                        samples[job_id] = s
                    continue
                injector = st["injector"]
                if st["epoch"] != injector.epoch or (
                    changed and not st["gids"].isdisjoint(changed)
                ):
                    injector.apply(st["sim"].state, now)
                    st["epoch"] = injector.epoch
                if with_faults and st["sim"].stalled():
                    outcomes[job_id].stalled_ticks += 1
                    continue
                samples[job_id] = st["sim"].iteration_time() * float(
                    st["rng"].normal(1.0, preset.jitter)
                )
            if record:
                rec.samples.append(dict(samples))
                for job_id in live:
                    jr = rec.jobs[job_id]
                    jr.draws.append(
                        (jr.draws[-1] if jr.draws else 0)
                        + (1 if job_id in samples else 0)
                    )
                    jr.stalled.append(outcomes[job_id].stalled_ticks)

            if plane is not None and live:
                new_events = plane.tick(samples, now_end)
                for ev in new_events:
                    if isinstance(ev, (Observation, ScreenTuning)):
                        continue
                    jid = getattr(ev, "job_id", "")
                    if not jid:
                        continue
                    touched.add(jid)
                    st = live.get(jid)
                    if st is not None and st["virtual"]:
                        st["holder"].materialize()
                for ev in new_events:
                    if (
                        isinstance(ev, MitigationResult)
                        and ev.kind == "mitigate"
                    ):
                        st = live.get(ev.job_id)
                        if st is None:
                            continue
                        if ev.applied or ev.status != "ok":
                            st["debt"] += ev.overhead
                        if ev.applied:
                            out = outcomes[ev.job_id]
                            label = (
                                ev.strategy.name
                                if hasattr(ev.strategy, "name")
                                else str(ev.strategy)
                            )
                            out.mitigations[label] = (
                                out.mitigations.get(label, 0) + 1
                            )

            finished: list[str] = []
            for job_id, st in live.items():
                out = outcomes[job_id]
                if st["virtual"]:
                    out.iters_done = serve.jobs[job_id].iters_at(tick)
                else:
                    budget = dt
                    pay = min(st["debt"], budget)
                    st["debt"] -= pay
                    budget -= pay
                    out.overhead_paid += pay
                    if job_id in samples:
                        out.iters_done += budget / max(samples[job_id], 1e-12)
                if out.iters_done >= out.steps:
                    out.end_time = now_end
                    finished.append(job_id)
            if record:
                for job_id in live:
                    rec.jobs[job_id].iters.append(outcomes[job_id].iters_done)
            for job_id in finished:
                if record:
                    rec.jobs[job_id].end_tick = tick
                del live[job_id]
                if plane is not None:
                    plane.remove_job(job_id, now_end)

        events = list(plane.events) if plane is not None else []
        order = {
            p.job_id: i for i, p in enumerate(self._join_order())
        }
        outcomes = dict(
            sorted(outcomes.items(), key=lambda kv: order[kv[0]])
        )
        result = RunResult(
            mode=mode, outcomes=outcomes, events=events, ticks_run=ticks,
            horizon_s=spec.max_ticks * dt,
            touched_jobs=frozenset(touched) if with_plane else None,
        )
        if record:
            rec.ticks_run = ticks
            return result, rec
        return result
