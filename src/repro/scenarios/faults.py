"""Characterization-driven fail-slow fault model (paper §3).

Samples seeded :class:`~repro.cluster.injector.Injection` schedules whose
population statistics follow the characterization study:

* **Cause mix** — computation (GPU degradation, host/CPU contention) vs
  communication (link and NIC congestion) occurrence shares (Table 1; the
  communication share dominates at fleet scale).
* **Durations** — log-uniform from tens of seconds to ~10 hours, matching
  the heavy-tailed duration CDF (Fig. 1): most episodes are minutes, a
  long tail lasts hours.
* **Severity tiers** — weak/medium/severe ~= 20 %/50 %/80 % performance
  loss, the paper's injection tiers, with per-episode jitter.
* **Ramped onsets** — a fraction of network episodes build up gradually
  (congestion accumulates), the shape fixed-offset window detectors miss.
* **Recurring flappers** — some faults relapse: the same component repeats
  its episode a few times with gaps (§3's recurring fail-slows).

Targets are sampled in *global fleet coordinates* (device index / node
index on the shared hardware map); the campaign runner translates each
episode into the local coordinates of every job it lands on, which is how
one host fault hits all co-located jobs at once.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass

import numpy as np

from repro.cluster.injector import Injection, InjectionKind
from repro.core.events import RootCause

#: fault-model cause name -> injection kind
CAUSE_KINDS: dict[str, InjectionKind] = {
    "gpu": InjectionKind.GPU_SLOW,
    "cpu": InjectionKind.CPU_CONTENTION,
    "link": InjectionKind.LINK_CONGESTION,
    "nic": InjectionKind.NIC_CONGESTION,
    "gpu_hang": InjectionKind.GPU_HANG,
    "collective_hang": InjectionKind.COLLECTIVE_HANG,
}

#: injection kind -> the root cause a correct diagnosis reports (scoring)
KIND_CAUSE: dict[InjectionKind, RootCause] = {
    InjectionKind.GPU_SLOW: RootCause.GPU_DEGRADATION,
    InjectionKind.CPU_CONTENTION: RootCause.CPU_CONTENTION,
    InjectionKind.LINK_CONGESTION: RootCause.NETWORK_CONGESTION,
    InjectionKind.NIC_CONGESTION: RootCause.NETWORK_CONGESTION,
    InjectionKind.GPU_HANG: RootCause.GPU_DEGRADATION,
    InjectionKind.COLLECTIVE_HANG: RootCause.NETWORK_CONGESTION,
}

#: the paper's injection tiers: fraction of performance lost
SEVERITY_TIERS: dict[str, float] = {"weak": 0.2, "medium": 0.5, "severe": 0.8}


@dataclass(frozen=True)
class FaultModel:
    """Seeded sampler of fleet-level fail-slow schedules (§3 statistics)."""

    #: fleet-wide fail-slow arrival rate (episodes per hour)
    rate_per_hour: float = 12.0
    #: occurrence share per cause (normalized at sample time)
    cause_mix: tuple[tuple[str, float], ...] = (
        ("gpu", 0.30), ("cpu", 0.20), ("link", 0.30), ("nic", 0.20),
    )
    #: log-uniform episode duration range in seconds (tens of s .. ~10 h)
    duration_range_s: tuple[float, float] = (20.0, 36_000.0)
    #: weak/medium/severe tier weights (normalized at sample time)
    tier_weights: tuple[tuple[str, float], ...] = (
        ("weak", 0.25), ("medium", 0.45), ("severe", 0.30),
    )
    #: uniform jitter added to the tier's base severity
    severity_jitter: float = 0.05
    #: probability that a network episode (link/NIC) has a ramped onset
    ramp_prob: float = 0.5
    #: ramp length as a fraction of the episode duration
    ramp_frac: tuple[float, float] = (0.1, 0.4)
    #: probability a sampled episode is a *hang* instead of a slowdown
    #: (near-infinite multiplier; compute episodes become GPU_HANG,
    #: communication episodes COLLECTIVE_HANG). Every rng draw the hang
    #: path makes is guarded behind this knob, so schedules of presets
    #: with ``hang_prob == 0`` are bit-identical to before it existed.
    hang_prob: float = 0.0
    #: probability an episode is a flapper (recurs on the same component)
    flap_prob: float = 0.15
    #: how many relapses a flapper produces (inclusive integer range)
    flap_repeats: tuple[int, int] = (1, 3)
    #: first occurrences start within this fraction of the horizon
    start_window: float = 0.75

    # ------------------------------------------------------------------
    def sample_schedule(
        self,
        rng: np.random.Generator,
        n_nodes: int,
        gpus_per_node: int,
        horizon_s: float,
    ) -> list[Injection]:
        """One seeded fleet schedule over ``[0, horizon_s)`` seconds."""
        n_devices = n_nodes * gpus_per_node
        causes, cause_w = zip(*self.cause_mix)
        cause_p = np.asarray(cause_w, dtype=np.float64)
        cause_p /= cause_p.sum()
        tiers, tier_w = zip(*self.tier_weights)
        tier_p = np.asarray(tier_w, dtype=np.float64)
        tier_p /= tier_p.sum()
        lo, hi = self.duration_range_s

        out: list[Injection] = []
        n_events = int(rng.poisson(self.rate_per_hour * horizon_s / 3600.0))
        for _ in range(n_events):
            cause = str(rng.choice(causes, p=cause_p))
            kind = CAUSE_KINDS[cause]
            if kind is InjectionKind.LINK_CONGESTION and n_devices < 2:
                kind = InjectionKind.GPU_SLOW  # a 1-device fleet has no links
            start = float(rng.uniform(0.0, self.start_window * horizon_s))
            duration = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
            tier = str(rng.choice(tiers, p=tier_p))
            severity = float(np.clip(
                SEVERITY_TIERS[tier]
                + rng.uniform(-self.severity_jitter, self.severity_jitter),
                0.08, 0.92,
            ))
            target = self._sample_target(rng, kind, n_nodes, gpus_per_node)
            ramp = 0.0
            if (
                kind in (InjectionKind.LINK_CONGESTION,
                         InjectionKind.NIC_CONGESTION)
                and rng.random() < self.ramp_prob
            ):
                ramp = duration * float(rng.uniform(*self.ramp_frac))
            episode = Injection(
                start=start, duration=duration, kind=kind, target=target,
                severity=severity, ramp=ramp,
            )
            if self.hang_prob > 0.0 and rng.random() < self.hang_prob:
                comm = kind in (InjectionKind.LINK_CONGESTION,
                                InjectionKind.NIC_CONGESTION)
                hang_kind = (
                    InjectionKind.COLLECTIVE_HANG
                    if comm and n_devices >= 2
                    else InjectionKind.GPU_HANG
                )
                hang_target = (
                    target
                    if kind is InjectionKind.LINK_CONGESTION
                    and hang_kind is InjectionKind.COLLECTIVE_HANG
                    else self._sample_target(
                        rng, hang_kind, n_nodes, gpus_per_node
                    )
                )
                episode = Injection(
                    start=start, duration=duration, kind=hang_kind,
                    target=hang_target, severity=1.0,
                    scope="dp" if hang_kind is InjectionKind.COLLECTIVE_HANG
                    else "",
                )
            out.append(episode)
            if rng.random() < self.flap_prob:
                out += self._flap(rng, episode)
        out.sort(key=lambda i: (i.start, i.kind.value, i.target))
        # Drop whatever starts beyond the horizon (flapper tails).
        return [i for i in out if i.start < horizon_s]

    # ------------------------------------------------------------------
    def _sample_target(
        self,
        rng: np.random.Generator,
        kind: InjectionKind,
        n_nodes: int,
        gpus_per_node: int,
    ) -> tuple[int, ...]:
        n_devices = n_nodes * gpus_per_node
        if kind in (InjectionKind.GPU_SLOW, InjectionKind.GPU_HANG):
            return (int(rng.integers(n_devices)),)
        if kind in (InjectionKind.CPU_CONTENTION, InjectionKind.NIC_CONGESTION):
            return (int(rng.integers(n_nodes)),)
        # Link congestion: one inter-node path (the paper's side-channel
        # bandwidth contention hits RDMA flows).
        a = int(rng.integers(n_devices))
        if n_nodes <= 1:
            b = int(rng.integers(gpus_per_node))
            while b == a:
                b = int(rng.integers(gpus_per_node))
            return (a, b)
        other = [n for n in range(n_nodes) if n != a // gpus_per_node]
        node_b = int(rng.choice(other))
        b = node_b * gpus_per_node + int(rng.integers(gpus_per_node))
        return (a, b)

    def _flap(
        self, rng: np.random.Generator, first: Injection
    ) -> list[Injection]:
        """Relapses of ``first`` on the same component, with gaps."""
        out: list[Injection] = []
        cursor = first.end
        for _ in range(int(rng.integers(self.flap_repeats[0],
                                        self.flap_repeats[1] + 1))):
            gap = first.duration * float(rng.uniform(0.5, 1.5))
            duration = first.duration * float(rng.uniform(0.5, 1.5))
            severity = float(np.clip(
                first.severity
                + rng.uniform(-self.severity_jitter, self.severity_jitter),
                0.08, 0.92,
            ))
            out.append(Injection(
                start=cursor + gap, duration=duration, kind=first.kind,
                target=first.target, severity=severity, ramp=first.ramp,
            ))
            cursor = out[-1].end
        return out


class ExecutorFaultModel:
    """Seeded flaky-executor fault injection: mitigations themselves fail.

    A callable matching the control plane's ``executor_faults`` protocol —
    ``(job_id, strategy, attempt, now) -> None | "fail" | "timeout"`` —
    that makes strategy dispatches flakily fail or time out with the given
    per-attempt probabilities, so campaigns can score the executor's
    retry/backoff/rollback/quarantine machinery. Draws come from a private
    seeded generator consumed in dispatch order, which is deterministic
    per (preset, seed) run; build a fresh instance per campaign mode so
    modes do not share a draw stream. S1 (IGNORE) never faults: it is pure
    bookkeeping with no mechanism to fail (and no rng draw is consumed, so
    its exemption cannot shift later verdicts).
    """

    def __init__(
        self, fail_prob: float = 0.0, timeout_prob: float = 0.0, seed: int = 0
    ) -> None:
        self.fail_prob = float(fail_prob)
        self.timeout_prob = float(timeout_prob)
        self.seed = int(seed)
        self._rng = np.random.default_rng([self.seed, 0xEC5])
        self.calls = 0

    def __call__(
        self, job_id: str, strategy, attempt: int, now: float
    ) -> str | None:
        from repro.core.events import Strategy

        if strategy is Strategy.IGNORE:
            return None
        if self.fail_prob <= 0.0 and self.timeout_prob <= 0.0:
            return None
        self.calls += 1
        u = float(self._rng.random())
        if u < self.fail_prob:
            return "fail"
        if u < self.fail_prob + self.timeout_prob:
            return "timeout"
        return None

    # -- state capture (campaign fork/restore contract) ----------------
    def snapshot(self) -> dict:
        """Draw-stream position as a private copy (the generator state is
        a nested dict; deep-copy keeps forks independent)."""
        return {
            "rng": copy.deepcopy(self._rng.bit_generator.state),
            "calls": self.calls,
        }

    def restore(self, snap: dict) -> None:
        self._rng.bit_generator.state = copy.deepcopy(snap["rng"])
        self.calls = snap["calls"]
