"""Campaign runner — multi-job fleets under fail-slow workloads.

Builds a deterministic :class:`CampaignSpec` from a preset + seed (job
placement on the shared hardware map, join schedule, fleet-level fault
schedule and its per-job translations), then executes it under one of four
mitigation modes:

* ``healthy`` — no faults, no control plane: the JCT floor.
* ``faults``  — faults on, no mitigation: the JCT ceiling.
* ``ckpt``    — faults on, detection + checkpoint-restart-only ladder: the
  baseline the paper compares its multi-level mitigation against.
* ``falcon``  — faults on, full S1-S4 ski-rental ladder.

The clock is a *sampling* clock: one tick = ``preset.tick_seconds`` of
simulated wall time, in which every live job's current iteration time is
sampled once (exactly how a fleet monitor scrapes heterogeneous jobs whose
iteration periods differ). A job completes ``tick_seconds / iter_time``
iterations per tick — minus time spent paying one-off mitigation overheads
— and *leaves the campaign* when its quota is done, while later jobs join
mid-flight: the control plane's dynamic-membership path (warming cohorts,
frontier sub-slicing) is on the hot path of every churny campaign.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ClusterState
from repro.controlplane import (
    CkptRestartStrategy,
    ControlPlane,
    IgnoreStrategy,
    MitigationResult,
    StrategyRegistry,
    placement_registry,
)
from repro.core.duration import DurationModel
from repro.scenarios.faults import ExecutorFaultModel
from repro.scenarios.presets import JobTemplate, ScenarioPreset, get_preset

MODES = ("healthy", "faults", "ckpt", "falcon")


@dataclass(frozen=True)
class PlacedJob:
    """One job instance pinned to its slice of the shared hardware map."""

    job_id: str
    template: JobTemplate
    #: global device ids, in local-rank order
    devices: tuple[int, ...]
    #: global node ids, in local-node order
    nodes: tuple[int, ...]
    join_tick: int
    steps: int
    #: this job's view of the fleet schedule, in local coordinates
    local_schedule: tuple[Injection, ...]
    #: relative iteration-time impact of each local episode applied alone
    #: to a healthy cluster at full severity (parallel to local_schedule)
    impacts: tuple[float, ...]
    #: indices into the campaign's global schedule (parallel again)
    global_ids: tuple[int, ...]
    healthy_iter_time: float

    @property
    def local_cluster(self) -> ClusterSpec:
        q = len(self.devices) // len(self.nodes)
        return ClusterSpec(n_nodes=len(self.nodes), gpus_per_node=q)

    def make_sim(self) -> TrainingSimulator:
        return TrainingSimulator(
            cluster=self.local_cluster,
            job=JobSpec(
                model=self.template.model_spec(),
                tp=self.template.tp,
                dp=self.template.dp,
                pp=self.template.pp,
                micro_batches=self.template.micro_batches,
            ),
        )

    def hardware(self) -> list[str]:
        return [f"g{d}" for d in self.devices]

    def hosts(self) -> list[str]:
        return [f"n{n}" for n in self.nodes]


@dataclass(frozen=True)
class CampaignSpec:
    """Everything a campaign run needs, fixed by (preset, jobs, seed)."""

    preset: ScenarioPreset
    seed: int
    n_nodes: int
    jobs: tuple[PlacedJob, ...]
    schedule: tuple[Injection, ...]  # fleet coordinates

    @property
    def tick_seconds(self) -> float:
        return self.preset.tick_seconds

    @property
    def max_ticks(self) -> int:
        return self.preset.max_ticks


@dataclass
class JobOutcome:
    """Per-job result of one campaign run."""

    job_id: str
    join_time: float
    end_time: float | None = None  # None = censored at the horizon
    iters_done: float = 0.0
    steps: int = 0
    overhead_paid: float = 0.0
    #: ticks spent fully stalled (hang active, no samples emitted)
    stalled_ticks: int = 0
    mitigations: dict = field(default_factory=dict)  # strategy label -> count

    @property
    def finished(self) -> bool:
        return self.end_time is not None

    def jct(self, horizon_s: float) -> float:
        return (self.end_time if self.finished else horizon_s) - self.join_time


@dataclass
class RunResult:
    mode: str
    outcomes: dict[str, JobOutcome]
    events: list  # control-plane event log ([] for plane-less modes)
    ticks_run: int
    horizon_s: float
    #: the run's :class:`repro.obs.SpanTracer` when observability was on
    #: (``run_campaign(..., tracer=...)``); None otherwise
    tracer: object | None = None
    #: jobs the control plane actually intervened on (flagged, alarmed,
    #: or mitigated) when the shared-prefix engine produced this run —
    #: every other job rode the recorded fault-mode trajectory verbatim.
    #: None = fresh execution (no divergence tracking was performed).
    touched_jobs: frozenset | None = None


# ------------------------------------------------------------------ build
class _Packer:
    """First-fit placement of job slices onto the shared fleet.

    Whole-node jobs take nodes outright; sub-node jobs (``span_nodes``
    slices of q devices) take the lowest free q-block of each chosen node,
    so two 4-GPU jobs land co-located on one 8-GPU node and two half-node
    slices of a 2-node job straddle a node pair — the co-location patterns
    the dedupe scenarios need. The fleet grows as needed.
    """

    def __init__(self, gpus_per_node: int) -> None:
        self.gpn = gpus_per_node
        self.free: list[list[int]] = []  # per node, ascending free devices

    def _grow(self) -> int:
        node = len(self.free)
        self.free.append(
            [node * self.gpn + i for i in range(self.gpn)]
        )
        return node

    def place(self, template: JobTemplate) -> tuple[list[int], list[int]]:
        n = template.n_devices
        span = template.span_nodes
        if span == 0:
            span = max(1, n // self.gpn) if n % self.gpn == 0 else 1
        if n % span:
            raise ValueError(f"{n} devices cannot span {span} nodes evenly")
        q = n // span
        if q > self.gpn:
            raise ValueError(
                f"{q} devices per node > {self.gpn} gpus_per_node"
            )
        nodes: list[int] = []
        for node, free in enumerate(self.free):
            if len(free) >= q:
                nodes.append(node)
                if len(nodes) == span:
                    break
        while len(nodes) < span:
            nodes.append(self._grow())
        devices: list[int] = []
        for node in nodes:
            take, self.free[node] = (
                self.free[node][:q], self.free[node][q:]
            )
            devices += take
        return devices, nodes

    @property
    def n_nodes(self) -> int:
        return len(self.free)


def _translate(
    inj: Injection,
    dev_inverse: dict[int, int],
    node_inverse: dict[int, int],
) -> Injection | None:
    """A fleet-coordinate episode in one job's local coordinates (None =
    the job's slice is untouched by it)."""
    if inj.kind in (InjectionKind.GPU_SLOW, InjectionKind.GPU_HANG):
        (d,) = inj.target
        if d in dev_inverse:
            return replace(inj, target=(dev_inverse[d],))
        return None
    if inj.kind in (InjectionKind.CPU_CONTENTION, InjectionKind.NIC_CONGESTION):
        (n,) = inj.target
        if n in node_inverse:
            return replace(inj, target=(node_inverse[n],))
        return None
    a, b = inj.target
    if a in dev_inverse and b in dev_inverse:
        return replace(inj, target=(dev_inverse[a], dev_inverse[b]))
    return None


def _impacts(
    sim: TrainingSimulator, episodes: list[Injection]
) -> list[float]:
    """Relative iteration-time increase of each episode at full severity,
    applied alone to a healthy cluster — the ground-truth observability of
    the fault for this job (a congested link no ring traverses is harmless).

    One probe state and one injector are reused across the whole schedule:
    swapping episode ``i`` out for ``i+1`` restores and degrades only the
    two episodes' components (the injector's diff-apply), so every probe
    evaluation after the first re-reduces only the touched cells instead of
    rebuilding the vectorized pass per episode.
    """
    t_h = sim.healthy_iteration_time()
    probe = ClusterState(sim.cluster)
    inj = FailSlowInjector()
    saved = sim.state
    sim.state = probe
    try:
        out = []
        for local in episodes:
            inj.injections = [
                replace(local, start=0.0, duration=1.0, ramp=0.0)
            ]
            inj.apply(probe, 0.5)
            out.append(sim.iteration_time() / t_h - 1.0)
    finally:
        sim.state = saved
    return out


def build_campaign(
    preset: ScenarioPreset | str,
    n_jobs: int | None = None,
    seed: int = 0,
    max_ticks: int | None = None,
) -> CampaignSpec:
    """Deterministically expand (preset, jobs, seed) into a campaign spec."""
    if isinstance(preset, str):
        preset = get_preset(preset)
    if max_ticks is not None:
        preset = replace(preset, max_ticks=max_ticks)
    n_jobs = n_jobs or preset.default_jobs
    rng = np.random.default_rng([seed, 0xFA1C])
    dt = preset.tick_seconds
    horizon_s = preset.max_ticks * dt

    packer = _Packer(preset.gpus_per_node)
    for _ in range(preset.n_nodes):
        packer._grow()

    # Joins: job 0 anchors the fleet at tick 0, the rest stagger (churn).
    joins = [0] + sorted(
        int(rng.integers(0, preset.join_spread_ticks + 1))
        for _ in range(n_jobs - 1)
    )

    placements = []
    for i in range(n_jobs):
        template = preset.job_templates[i % len(preset.job_templates)]
        devices, nodes = packer.place(template)
        placements.append((template, devices, nodes))

    # Fleet-level fault schedule: preset's fixed episodes + sampled model.
    schedule: list[Injection] = []
    if preset.fixed_schedule is not None:
        schedule += preset.fixed_schedule(
            packer.n_nodes, preset.gpus_per_node, dt
        )
    if preset.fault_model is not None:
        schedule += preset.fault_model.sample_schedule(
            rng, packer.n_nodes, preset.gpus_per_node, horizon_s
        )
    schedule.sort(key=lambda i: (i.start, i.kind.value, i.target))

    jobs: list[PlacedJob] = []
    for i, (template, devices, nodes) in enumerate(placements):
        dev_inverse = {d: k for k, d in enumerate(devices)}
        node_inverse = {n: k for k, n in enumerate(nodes)}
        placed = PlacedJob(
            job_id=f"j{i}", template=template, devices=tuple(devices),
            nodes=tuple(nodes), join_tick=joins[i], steps=0,
            local_schedule=(), impacts=(), global_ids=(),
            healthy_iter_time=0.0,
        )
        sim = placed.make_sim()
        it_h = sim.healthy_iteration_time()
        translated: list[tuple[int, Injection]] = []
        for gi, inj in enumerate(schedule):
            local = _translate(inj, dev_inverse, node_inverse)
            if local is not None:
                translated.append((gi, local))
        probed = _impacts(sim, [local for _, local in translated])
        locals_: list[Injection] = []
        impacts: list[float] = []
        gids: list[int] = []
        for (gi, local), impact in zip(translated, probed):
            if impact <= 1e-9:
                continue
            locals_.append(local)
            impacts.append(impact)
            gids.append(gi)
        # Auto quota: finish well inside the horizon even when fail-slows
        # stretch the job's effective iteration time (censored JCTs would
        # void the healthy/faults/falcon comparison).
        steps = template.steps or max(
            30,
            int(
                float(rng.uniform(0.3, 0.5))
                * (preset.max_ticks - joins[i]) * dt / it_h
            ),
        )
        jobs.append(replace(
            placed,
            steps=steps,
            local_schedule=tuple(locals_),
            impacts=tuple(impacts),
            global_ids=tuple(gids),
            healthy_iter_time=it_h,
        ))
    return CampaignSpec(
        preset=preset, seed=seed, n_nodes=packer.n_nodes,
        jobs=tuple(jobs), schedule=tuple(schedule),
    )


# -------------------------------------------------------------------- run
def _changed_episodes(
    schedule: tuple[Injection, ...], prev: float, now: float, dt: float
) -> set[int]:
    """Global schedule indices whose activity or effective severity can
    differ between ``prev`` and ``now`` — the fleet-level event feed the
    per-job fault cursors consume. Episodes starting or ending inside the
    window transition; a ramping episode moves every tick until one full
    tick after its ramp completes (the first tick *at* full severity is
    itself a change from the last partial value)."""
    out: set[int] = set()
    for gi, inj in enumerate(schedule):
        if prev < inj.start <= now or prev < inj.end <= now:
            out.add(gi)
        elif (
            inj.active(now)
            and inj.ramp > 0.0
            and now - inj.start < inj.ramp + dt
        ):
            out.add(gi)
    return out


def _registry_for(mode: str):
    if mode == "falcon":
        # The full ladder including the placement rungs (S2P/S3P).
        return placement_registry()
    # Checkpoint-restart baseline: detection on, but the only mitigation
    # mechanism is the paper's S4 (what pre-FALCON production systems do).
    return (
        StrategyRegistry()
        .register(IgnoreStrategy())
        .register(CkptRestartStrategy())
    )


def run_campaign(
    spec: CampaignSpec,
    mode: str,
    *,
    drop_episodes=None,
    decision_hook=None,
    planner_knobs=None,
    only_jobs=None,
    tracer=None,
    screening_backend=None,
    reduction_backend=None,
) -> RunResult:
    """Execute one campaign under the given mitigation mode.

    The keyword surface is the what-if engine's replay contract
    (:mod:`repro.whatif`, docs/whatif.md):

    * ``drop_episodes`` — global schedule indices to remove before the run
      (counterfactual "what if this fault never happened"). Dropping every
      episode reproduces the ``healthy`` run bit-exactly: the per-job rng
      streams depend only on (seed, job) and an empty injector leaves the
      simulator state untouched.
    * ``decision_hook`` — forwarded to :class:`ControlPlane`: suppress /
      force individual mitigation decisions (see the plane's hook
      contract). Suppressing everything reproduces the ``faults`` run
      bit-exactly for the same reason.
    * ``planner_knobs`` — a :class:`~repro.core.planner.PlannerKnobs`
      bundle applied to every planner the plane builds (the auto-tuner's
      injection point).
    * ``only_jobs`` — run just these job ids. Valid only for the
      plane-less modes (``healthy`` / ``faults``), where jobs never
      interact: each job's trajectory there is bit-identical whether or
      not its neighbours run, which is what makes affected-jobs-only
      replay exact and cheap.
    * ``screening_backend`` — fleet-screen backend override forwarded to
      :class:`ControlPlane` (a ``SCREENING_BACKENDS`` registry name or
      factory instance); None keeps the plane's default.
    * ``reduction_backend`` — per-simulator reduction backend override (a
      ``REDUCTION_BACKENDS`` registry name or instance) assigned to every
      job simulator this run builds; None keeps the simulator default
      ("auto").
    * ``tracer`` — a :class:`repro.obs.SpanTracer` on the campaign's
      simulated clock. The runner records each job's lifetime span and its
      injected fault episodes (ground truth lanes); the control plane adds
      tick, detector, watchdog, executor, and diagnosed-fault spans. The
      tracer is returned on :attr:`RunResult.tracer` with every track
      closed at the horizon. Tracing never alters the run: all call sites
      are guarded, rng streams and event logs are bit-identical with or
      without it.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    preset = spec.preset
    dt = preset.tick_seconds
    with_faults = mode != "healthy"
    with_plane = mode in ("ckpt", "falcon")
    campaign_jobs = spec.jobs
    if only_jobs is not None:
        if with_plane:
            raise ValueError(
                "only_jobs is exact only for plane-less modes: under a "
                "control plane jobs couple through dedupe, the shared "
                "duration model, and the incident gap"
            )
        keep = set(only_jobs)
        campaign_jobs = tuple(j for j in campaign_jobs if j.job_id in keep)
    drop = frozenset(drop_episodes or ())
    if drop:
        campaign_jobs = tuple(
            replace(
                p,
                local_schedule=tuple(
                    l for l, g in zip(p.local_schedule, p.global_ids)
                    if g not in drop
                ),
                impacts=tuple(
                    i for i, g in zip(p.impacts, p.global_ids)
                    if g not in drop
                ),
                global_ids=tuple(g for g in p.global_ids if g not in drop),
            )
            for p in campaign_jobs
        )
    plane = None
    if with_plane:
        # Only the full FALCON mode gets the predictive ski-rental horizon;
        # the ckpt baseline keeps the classic fixed-horizon break-even.
        fail_p, timeout_p = preset.executor_faults
        plane = ControlPlane(
            max_events=1 << 20,
            # Adaptive screening re-tunes are a falcon-mode feature; the
            # ckpt baseline keeps the fixed constructor knobs.
            fleet_kwargs=(
                {"adapt_every": preset.adapt_every}
                if mode == "falcon" and preset.adapt_every else None
            ),
            screening_backend=screening_backend,
            duration_model=DurationModel() if mode == "falcon" else None,
            # Fresh per run so ckpt and falcon modes draw identical streams.
            executor_faults=(
                ExecutorFaultModel(fail_p, timeout_p, seed=spec.seed)
                if fail_p > 0.0 or timeout_p > 0.0 else None
            ),
            decision_hook=decision_hook,
            planner_knobs=planner_knobs,
            tracer=tracer,
        )

    pending = sorted(
        campaign_jobs, key=lambda j: (j.join_tick, int(j.job_id[1:]))
    )
    live: dict[str, dict] = {}
    outcomes: dict[str, JobOutcome] = {}
    ticks = 0

    for tick in range(spec.max_ticks):
        now = tick * dt
        while pending and pending[0].join_tick <= tick:
            placed = pending.pop(0)
            sim = placed.make_sim()
            if reduction_backend is not None:
                sim.reduction = reduction_backend
            injector = FailSlowInjector(
                list(placed.local_schedule) if with_faults else []
            )
            live[placed.job_id] = {
                "placed": placed,
                "sim": sim,
                "injector": injector,
                "debt": 0.0,
                "rng": np.random.default_rng(
                    [spec.seed, 7, int(placed.job_id[1:])]
                ),
                # per-job fault cursor over the fleet schedule: which global
                # episodes touch this job, and the injector epoch last
                # applied (None forces the join-tick apply)
                "gids": frozenset(placed.global_ids),
                "epoch": None,
            }
            out = JobOutcome(
                job_id=placed.job_id, join_time=now, steps=placed.steps
            )
            outcomes[placed.job_id] = out
            if tracer is not None:
                horizon_s = spec.max_ticks * dt
                tracer.begin(
                    (placed.job_id, "job"), "job", now,
                    args={
                        "devices": len(placed.devices),
                        "steps": placed.steps,
                        "template": placed.template.arch,
                    },
                )
                if with_faults:
                    # Ground-truth lane: the injected episodes as scheduled,
                    # before any detection — lining this track up against
                    # the plane's "faults" track is the detection-latency /
                    # miss picture a dashboard wants.
                    for inj in placed.local_schedule:
                        tracer.span(
                            (placed.job_id, "injected"),
                            f"inject:{inj.kind.value}",
                            inj.start, min(inj.end, horizon_s),
                            args={
                                "target": list(inj.target),
                                "severity": inj.severity,
                            },
                        )
            if plane is not None:
                plane.register_job(
                    placed.job_id, sim,
                    registry=_registry_for(mode),
                    overheads=preset.overheads(),
                    injector=injector,
                    hardware=placed.hardware(),
                    hosts=placed.hosts(),
                    sample_period=dt,
                    # The predictive break-even caps any mitigation's
                    # benefit by the job's remaining useful work.
                    work_remaining=(
                        lambda o=out, t=placed.healthy_iter_time:
                        max(o.steps - o.iters_done, 0.0) * t
                    ),
                    now=now,
                )
        if not live and not pending:
            break
        ticks = tick + 1
        now_end = (tick + 1) * dt

        # Fleet-level fault transitions this tick; each job consumes them
        # through its own cursor (episode subset + injector epoch), so jobs
        # untouched by an event pay nothing — no per-job schedule scan, no
        # cross-job invalidation of memoized iteration times.
        changed = (
            _changed_episodes(spec.schedule, (tick - 1) * dt, now, dt)
            if with_faults else ()
        )
        samples: dict[str, float] = {}
        for job_id, st in live.items():
            injector = st["injector"]
            if st["epoch"] != injector.epoch or (
                changed and not st["gids"].isdisjoint(changed)
            ):
                injector.apply(st["sim"].state, now)
                st["epoch"] = injector.epoch
            if with_faults and st["sim"].stalled():
                # A hung job emits nothing: the collective never returns, so
                # there is no iteration-time sample this tick (and no jitter
                # draw — the rng stream restarts when the job resumes).
                outcomes[job_id].stalled_ticks += 1
                continue
            samples[job_id] = st["sim"].iteration_time() * float(
                st["rng"].normal(1.0, preset.jitter)
            )

        # Tick whenever jobs are live, even if every one of them is stalled
        # this tick — the silent path IS the watchdog's input.
        if plane is not None and live:
            new_events = plane.tick(samples, now_end)
            for ev in new_events:
                if isinstance(ev, MitigationResult) and ev.kind == "mitigate":
                    st = live.get(ev.job_id)
                    if st is None:
                        continue
                    # Applied dispatches pay the strategy overhead; failed
                    # attempts pay their timeout/backoff charge. A declined
                    # dispatch (ok but not applied — e.g. no better
                    # placement) did nothing and costs nothing.
                    if ev.applied or ev.status != "ok":
                        st["debt"] += ev.overhead
                    if ev.applied:
                        out = outcomes[ev.job_id]
                        label = (
                            ev.strategy.name
                            if hasattr(ev.strategy, "name")
                            else str(ev.strategy)
                        )
                        out.mitigations[label] = (
                            out.mitigations.get(label, 0) + 1
                        )

        finished: list[str] = []
        for job_id, st in live.items():
            budget = dt
            pay = min(st["debt"], budget)
            st["debt"] -= pay
            budget -= pay
            out = outcomes[job_id]
            out.overhead_paid += pay
            if job_id in samples:
                out.iters_done += budget / max(samples[job_id], 1e-12)
            if out.iters_done >= out.steps:
                out.end_time = now_end
                finished.append(job_id)
        for job_id in finished:
            del live[job_id]
            if tracer is not None:
                tracer.end(
                    (job_id, "job"), now_end,
                    args={"iters": round(outcomes[job_id].iters_done, 3)},
                )
            if plane is not None:
                plane.remove_job(job_id, now_end)

    events = list(plane.events) if plane is not None else []
    if tracer is not None:
        # Censor everything still open (jobs that ran out the clock, fault
        # episodes never relieved) at the horizon so the trace exports.
        tracer.close_all(spec.max_ticks * dt)
    return RunResult(
        mode=mode, outcomes=outcomes, events=events, ticks_run=ticks,
        horizon_s=spec.max_ticks * dt, tracer=tracer,
    )
