"""Named scenario presets — the campaign catalog.

A :class:`ScenarioPreset` fixes everything about a campaign except the seed
and the job count: the shared fleet, the tick (sampling) interval, the job
templates cycled to fill ``--jobs N``, the churn window, and the fault
workload (a :class:`~repro.scenarios.faults.FaultModel`, a hand-built fixed
schedule, or both). See docs/scenarios.md for the catalog rationale and how
each preset maps onto the paper's evaluation scenarios.

Job templates draw their transformer shapes from the architecture registry
(``repro.configs``), so a campaign fleet is *heterogeneous*: a 9B dense job
and a 20B job disagree about iteration time, communication volume, and
therefore about how the same fault hurts them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.cluster.injector import Injection, InjectionKind
from repro.cluster.spec import ModelSpec
from repro.configs.base import get_config
from repro.core.events import Strategy, StrategyKey
from repro.scenarios.faults import FaultModel


@dataclass(frozen=True)
class JobTemplate:
    """One hybrid-parallel job shape, cycled to fill the requested fleet.

    ``span_nodes`` is how many fleet nodes the job's devices spread over
    (0 = auto: whole nodes for node-multiple jobs, one node otherwise; 2
    with a sub-node device count places half the job on each of two nodes,
    which is what makes DP rings cross the NIC).
    """

    arch: str
    tp: int = 1
    dp: int = 4
    pp: int = 1
    micro_batches: int = 16
    span_nodes: int = 0
    #: fixed iteration quota; 0 = auto-sized to finish inside the horizon
    steps: int = 0
    seq_len: int = 2048

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp * self.pp

    def model_spec(self) -> ModelSpec:
        cfg = get_config(self.arch)
        return ModelSpec(
            layers=cfg.num_layers,
            hidden=cfg.d_model,
            seq_len=self.seq_len,
            vocab=cfg.vocab_size,
        )


#: a fixed-schedule builder: (n_nodes, gpus_per_node, tick_seconds) -> injections
ScheduleFn = Callable[[int, int, float], list[Injection]]


@dataclass(frozen=True)
class ScenarioPreset:
    name: str
    description: str
    #: minimum fleet size; the packer grows it to fit the requested jobs
    n_nodes: int = 2
    gpus_per_node: int = 8
    #: fleet-monitor sampling interval (seconds of simulated wall clock)
    tick_seconds: float = 5.0
    max_ticks: int = 600
    default_jobs: int = 4
    job_templates: tuple[JobTemplate, ...] = ()
    #: joins are staggered uniformly over [0, join_spread_ticks] (0 = all
    #: jobs start at tick 0; job 0 always starts at 0 so the campaign has a
    #: fleet from the first tick)
    join_spread_ticks: int = 0
    fault_model: FaultModel | None = None
    fixed_schedule: ScheduleFn | None = None
    #: checkpoint-restart one-off cost in ticks (the other ladder rungs are
    #: fixed fractions of a tick; the paper's ratios, scaled to the clock)
    ckpt_overhead_ticks: float = 60.0
    #: jitter std-dev of sampled iteration times (healthy noise floor)
    jitter: float = 0.003
    #: (fail_prob, timeout_prob) per mitigation dispatch attempt — wired
    #: into an :class:`~repro.scenarios.faults.ExecutorFaultModel` by the
    #: campaign runner; (0, 0) disables executor faults (and consumes no
    #: rng, keeping existing presets byte-identical)
    executor_faults: tuple[float, float] = (0.0, 0.0)
    #: scoring budget: a hang should be aborted within this many ticks of
    #: its injection (robustness report's deadline_budget_s)
    abort_budget_ticks: float = 12.0
    #: fleet-screen adaptive re-tune period in ticks, applied to the
    #: *falcon* mode only (the ckpt baseline keeps fixed screening knobs so
    #: the comparison stays honest): every this-many ticks FleetDetect
    #: re-derives the hazard / run-length cap from the observed flag rate
    #: (:meth:`repro.core.detector.FleetDetect._retune`). 0 disables.
    adapt_every: int = 50

    def overheads(self) -> dict[StrategyKey, float]:
        """Ski-rental one-off action costs on this preset's clock.

        The placement rungs (S2P/S3P) sit between their paper siblings:
        a group re-shape moves optimizer/parameter shards between the
        swapped ranks, heavier than an S2 re-split but in the same class
        as an S3 placement swap.
        """
        dt = self.tick_seconds
        return {
            Strategy.IGNORE: 0.0,
            Strategy.ADJUST_MICROBATCH: 0.5 * dt,
            "S2P": 1.5 * dt,
            Strategy.ADJUST_TOPOLOGY: 3.0 * dt,
            "S3P": 4.0 * dt,
            "ABORT_REFORM": 6.0 * dt,
            Strategy.CKPT_AND_RESTART: self.ckpt_overhead_ticks * dt,
        }


# ---------------------------------------------------------------- catalog
def _single_gpu_throttle(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """The paper's simplest injection: one SM-frequency-locked GPU."""
    return [Injection(start=150 * dt, duration=250 * dt,
                      kind=InjectionKind.GPU_SLOW, target=(3,), severity=0.5)]


def _rack_nic(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """Rack-wide congestion: every NIC of the rack's nodes degrades, with
    ramped onsets staggered across nodes (congestion spreads)."""
    return [
        Injection(start=(120 + 30 * n) * dt, duration=220 * dt,
                  kind=InjectionKind.NIC_CONGESTION, target=(n,),
                  severity=0.7, ramp=40 * dt)
        for n in range(min(2, n_nodes))
    ]


def _cascading_hosts(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """Host contention cascading node to node (co-located jobs each see it)."""
    return [
        Injection(start=(100 + 90 * n) * dt, duration=260 * dt,
                  kind=InjectionKind.CPU_CONTENTION, target=(n,),
                  severity=0.5)
        for n in range(min(3, n_nodes))
    ]


def _long_tail(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """A weak degradation that lasts ~10 simulated hours (Fig. 1's tail)."""
    return [Injection(start=200 * dt, duration=36_000.0,
                      kind=InjectionKind.GPU_SLOW, target=(1,),
                      severity=0.25)]


def _collective_hang(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """Two hangs (tentpole scenario): a DP all-reduce collective freezes on
    a cross-node link, then a single GPU hard-hangs on another job. Both
    last far past the horizon budget — only an abort ends them."""
    return [
        Injection(start=150 * dt, duration=400 * dt,
                  kind=InjectionKind.COLLECTIVE_HANG, target=(0, gpn),
                  severity=1.0, scope="dp"),
        Injection(start=220 * dt, duration=400 * dt,
                  kind=InjectionKind.GPU_HANG, target=(4,), severity=1.0),
    ]


def _flaky_faults(n_nodes: int, gpn: int, dt: float) -> list[Injection]:
    """Moderate slowdowns for the flaky-executor preset: ordinary ladder
    work whose dispatches the ExecutorFaultModel then makes fail."""
    return [
        Injection(start=120 * dt, duration=250 * dt,
                  kind=InjectionKind.GPU_SLOW, target=(2,), severity=0.5),
        Injection(start=180 * dt, duration=220 * dt,
                  kind=InjectionKind.NIC_CONGESTION, target=(1,),
                  severity=0.6, ramp=20 * dt),
    ]


_T = JobTemplate  # brevity below

PRESETS: dict[str, ScenarioPreset] = {
    p.name: p
    for p in (
        ScenarioPreset(
            name="single_gpu_throttle",
            description="One job, one SM-throttled GPU (paper §7.1 tier run)",
            n_nodes=1, default_jobs=1, max_ticks=500,
            job_templates=(_T("yi-9b", tp=1, dp=4, pp=2, micro_batches=32),),
            fixed_schedule=_single_gpu_throttle,
        ),
        ScenarioPreset(
            name="rack_nic_congestion",
            description="Rack-wide NIC congestion with ramped onsets; jobs "
                        "straddle node pairs so DP rings cross the NIC",
            n_nodes=4, default_jobs=4, max_ticks=500,
            job_templates=(
                _T("granite-3-8b", tp=4, dp=2, pp=1, micro_batches=16,
                   span_nodes=2),
            ),
            fixed_schedule=_rack_nic,
        ),
        ScenarioPreset(
            name="cascading_host_contention",
            description="CPU contention cascading across nodes; jobs pairwise "
                        "share hosts (node-scoped dedupe) and straddle a "
                        "healthy node, so S2 has skew to exploit",
            n_nodes=4, default_jobs=4, max_ticks=500,
            job_templates=(
                _T("granite-3-8b", tp=2, dp=2, pp=1, micro_batches=16,
                   span_nodes=2),
                _T("yi-9b", tp=1, dp=4, pp=1, micro_batches=32,
                   span_nodes=2),
            ),
            fixed_schedule=_cascading_hosts,
        ),
        ScenarioPreset(
            name="long_tail_degradation",
            description="A weak ~10-hour degradation (the duration CDF's "
                        "tail); coarse 30 s sampling clock",
            n_nodes=2, default_jobs=2, tick_seconds=30.0, max_ticks=1400,
            ckpt_overhead_ticks=60.0,
            job_templates=(
                _T("granite-20b", tp=1, dp=8, pp=2, micro_batches=32),
                _T("yi-9b", tp=1, dp=4, pp=2, micro_batches=32),
            ),
            fixed_schedule=_long_tail,
        ),
        ScenarioPreset(
            name="collective_hang",
            description="Hang anomalies: a frozen DP collective on one job "
                        "and a hard GPU hang on another — the watchdog, not "
                        "BOCD, must flag them and ABORT_REFORM must end them",
            n_nodes=4, default_jobs=2, max_ticks=500,
            job_templates=(
                _T("granite-3-8b", tp=4, dp=2, pp=1, micro_batches=16,
                   span_nodes=2),
            ),
            fixed_schedule=_collective_hang,
        ),
        ScenarioPreset(
            name="flaky_executor",
            description="Ordinary slowdowns but a flaky mitigation executor: "
                        "35% of dispatches fail, 15% time out — exercises "
                        "retry/backoff/rollback/quarantine",
            n_nodes=2, default_jobs=2, max_ticks=500,
            job_templates=(
                _T("yi-9b", tp=1, dp=4, pp=1, micro_batches=32,
                   span_nodes=2),
            ),
            fixed_schedule=_flaky_faults,
            executor_faults=(0.35, 0.15),
        ),
        ScenarioPreset(
            name="failslow_storm",
            description="Fail-slows at fleet rate: a dense sampled schedule "
                        "over a churning multi-job fleet",
            n_nodes=4, default_jobs=6, max_ticks=500, join_spread_ticks=120,
            job_templates=(
                _T("yi-9b", tp=1, dp=4, pp=2, micro_batches=32),
                _T("granite-3-8b", tp=2, dp=2, pp=1, micro_batches=16,
                   span_nodes=1),
                _T("mistral-nemo-12b", tp=1, dp=8, pp=2, micro_batches=32),
            ),
            fault_model=FaultModel(rate_per_hour=90.0, flap_prob=0.25),
        ),
        ScenarioPreset(
            name="mixed_fleet",
            description="The default evaluation campaign: heterogeneous jobs, "
                        "staggered joins, characterization-mix faults",
            n_nodes=4, default_jobs=8, max_ticks=600, join_spread_ticks=150,
            job_templates=(
                _T("yi-9b", tp=1, dp=4, pp=2, micro_batches=32),
                _T("mistral-nemo-12b", tp=1, dp=8, pp=2, micro_batches=32),
                _T("granite-3-8b", tp=2, dp=2, pp=1, micro_batches=16,
                   span_nodes=1),
                _T("granite-20b", tp=4, dp=2, pp=1, micro_batches=16,
                   span_nodes=2),
            ),
            fault_model=FaultModel(rate_per_hour=22.0),
        ),
    )
}


def list_presets() -> list[str]:
    return list(PRESETS)


def get_preset(name: str) -> ScenarioPreset:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]
