"""Per-cause and per-decision JCT attribution via counterfactual replay.

Leave-one-out (LOO) attribution answers the two questions a campaign
score cannot: *which faults* cost the fleet its slowdown, and *which
planner decisions* earned the mitigation back.

* **Per cause** — remove every episode of one root cause and replay:
  the cause's slowdown contribution is how much the fleet JCT gap
  shrinks, its mitigated contribution how much the recovered time
  shrinks. Both are counterfactual ground truth, not the impact-weighted
  estimate the scorer's ``mitigation.per_cause`` table carries.
* **Per decision** — suppress one recorded decision and replay the
  falcon run: the decision's value is how much the fleet JCT worsens
  without it (negative value = the decision was a net loss; its overhead
  outweighed what it fixed).

LOO contributions need not sum to the total — faults compound and
decisions interact — so every table carries an explicit ``residual_s``
against the report totals; reconciliation means |residual| is small
relative to the total, and the tests pin a tolerance on a two-episode
preset. For small episode sets :func:`shapley` averages marginal
contributions over sampled episode orderings (Shapley values), which
distributes exactly by construction (the sampled estimate carries the
permutation count).
"""
from __future__ import annotations

import numpy as np

from repro.whatif.replay import Variant, WhatIfEngine, decisions_of


def _round_dict(d: dict, nd: int = 3) -> dict:
    return {
        k: (round(v, nd) if isinstance(v, float) else v)
        for k, v in d.items()
    }


def leave_one_out(
    engine: WhatIfEngine, per_decision: bool = True
) -> dict:
    """Full LOO attribution of one recorded campaign (deterministic)."""
    totals = engine.totals()
    per_cause: dict[str, dict] = {}
    for cause, gids in engine.episodes_by_cause().items():
        variant = Variant(drop_episodes=frozenset(gids))
        faults_wo = engine.run_variant("faults", variant)
        falcon_wo = engine.run_variant("falcon", variant)
        t_wo = engine.totals(faults=faults_wo, falcon=falcon_wo)
        slowdown = totals["gap_s"] - t_wo["gap_s"]
        mitigated = totals["mitigated_s"] - t_wo["mitigated_s"]
        per_cause[cause] = _round_dict({
            "episodes": gids,
            "slowdown_s": slowdown,
            "mitigated_s": mitigated,
            "mitigated_pct": (
                100.0 * mitigated / slowdown if abs(slowdown) > 1e-9 else None
            ),
        })
    cause_slowdown = sum(r["slowdown_s"] for r in per_cause.values())
    cause_mitigated = sum(r["mitigated_s"] for r in per_cause.values())

    decision_rows: list[dict] = []
    decision_total = 0.0
    if per_decision:
        for ref in decisions_of(engine.baseline["falcon"]):
            sup = engine.run_variant(
                "falcon", Variant(suppress=(ref,))
            )
            # Suppressing the decision lowers the recovery by its value
            # (the faults/healthy legs are untouched by a decision edit).
            value = (
                totals["mitigated_s"]
                - engine.totals(falcon=sup)["mitigated_s"]
            )
            decision_total += value
            decision_rows.append(_round_dict({
                "job_id": ref.job_id,
                "strategy": ref.strategy,
                "time_s": round(ref.time, 2),
                "cause": ref.cause,
                "value_s": value,
            }))
        decision_rows.sort(
            key=lambda r: (-r["value_s"], r["time_s"], r["job_id"])
        )

    out = {
        "totals": _round_dict(totals),
        "per_cause": per_cause,
        "per_cause_residual_s": round(
            totals["gap_s"] - cause_slowdown, 3
        ),
        "per_cause_mitigated_residual_s": round(
            totals["mitigated_s"] - cause_mitigated, 3
        ),
    }
    if per_decision:
        out["per_decision"] = decision_rows
        out["per_decision_total_s"] = round(decision_total, 3)
        out["per_decision_residual_s"] = round(
            totals["mitigated_s"] - decision_total, 3
        )
    return out


def shapley(
    engine: WhatIfEngine,
    permutations: int = 16,
    max_episodes: int = 10,
    seed: int = 0,
) -> dict:
    """Sampled-permutation Shapley attribution of the fleet slowdown.

    The value function over an episode subset ``S`` is the fleet JCT gap
    when only ``S`` is injected (everything else dropped); an episode's
    Shapley value is its marginal gap increase averaged over sampled
    orderings. Unlike LOO, Shapley values sum to the total gap exactly
    (per permutation, the telescoping marginals do), so compound-fault
    interaction is *distributed* rather than left in a residual. Costs
    O(permutations x episodes) faults replays — affected-jobs-only and
    cached across permutations sharing prefixes, but still reserved for
    small episode sets (``max_episodes`` guards it).
    """
    touched = sorted(
        {g for p in engine.spec.jobs for g in p.global_ids}
    )
    if len(touched) > max_episodes:
        raise ValueError(
            f"{len(touched)} episodes > max_episodes={max_episodes}: "
            "Shapley sampling is for small episode sets; use leave_one_out"
        )
    all_set = frozenset(touched)

    def gap_of(present: frozenset) -> float:
        run = engine.run_variant(
            "faults", Variant(drop_episodes=all_set - present)
        )
        return engine.totals(faults=run)["gap_s"]

    rng = np.random.default_rng([seed, 0x5A9])
    values = {g: 0.0 for g in touched}
    for _ in range(permutations):
        order = [touched[i] for i in rng.permutation(len(touched))]
        present: frozenset = frozenset()
        prev = 0.0
        for g in order:
            present = present | {g}
            cur = gap_of(present)
            values[g] += cur - prev
            prev = cur
    values = {g: v / permutations for g, v in values.items()}
    total = engine.totals()["gap_s"]
    cause_of = {
        g: c for c, gids in engine.episodes_by_cause().items() for g in gids
    }
    return {
        "permutations": permutations,
        "per_episode": {
            str(g): {
                "cause": cause_of[g],
                "slowdown_s": round(v, 3),
                "share_pct": (
                    round(100.0 * v / total, 2) if total > 1e-9 else None
                ),
            }
            for g, v in sorted(values.items())
        },
        "total_gap_s": round(total, 3),
        "residual_s": round(total - sum(values.values()), 3),
    }
