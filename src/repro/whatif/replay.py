"""Counterfactual campaign replay (ROADMAP item 2; arXiv 2505.05713).

The campaign runner is deterministic in (preset, jobs, seed), which makes
counterfactuals exact rather than estimated: re-run the *same* campaign
with a fault episode removed, a mitigation decision suppressed, or a
decision forced at a chosen time, and every difference in the outcome is
caused by that change alone. :class:`WhatIfEngine` owns one recorded
campaign (its spec + the four baseline mode runs) and serves such variant
runs, reusing everything the variant cannot change:

* the **spec build** (job packing, fault translation, per-episode impact
  probes — the expensive vectorized part) is built once and shared by
  every variant;
* the **healthy** run is never re-run — no counterfactual changes it;
* **faults**-mode variants re-run only the jobs an edit touches: without
  a control plane jobs never interact (independent rng streams, private
  simulators), so the untouched jobs' baseline outcomes are bit-exact
  for the variant too;
* **falcon**/**ckpt** variants re-run the whole fleet — the plane couples
  jobs through diagnosis dedupe, the shared duration model and the
  incident gap — but identical variants are served from a cache keyed by
  the exact edit.

The replay contract this module relies on (pinned by
tests/test_whatif.py): dropping every episode reproduces the ``healthy``
run bit-exactly, and suppressing every decision reproduces the ``faults``
run bit-exactly — see :func:`repro.scenarios.campaign.run_campaign`.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.events import Strategy, StrategyKey, strategy_label
from repro.core.planner import PlannerKnobs
from repro.controlplane import MitigationAction
from repro.scenarios.campaign import (
    MODES,
    CampaignSpec,
    RunResult,
    build_campaign,
    run_campaign,
)
from repro.scenarios.engine import CampaignEngine
from repro.scenarios.faults import KIND_CAUSE

#: decision times are matched to this resolution (the campaign clock is a
#: tick grid, so exact equality holds; rounding only guards float repr)
TIME_NDIGITS = 6


def _strategy_key(label: str) -> StrategyKey:
    """Inverse of :func:`~repro.core.events.strategy_label`."""
    try:
        return Strategy[label]
    except KeyError:
        return label


@dataclass(frozen=True)
class DecisionRef:
    """Identity of one planner decision inside a recorded campaign.

    ``(job_id, strategy, time)`` is an exact identity: the replay is
    bit-deterministic up to the first edit, so the original run's decision
    at time *t* is the *same* decision in the variant run — there is no
    fuzzy matching to do.
    """

    job_id: str
    strategy: str  # strategy_label() form, e.g. "ADJUST_MICROBATCH", "S2P"
    time: float
    cause: str = ""  # root-cause label of the event it acted on (metadata)

    def key(self) -> tuple[str, str, float]:
        return (self.job_id, self.strategy, round(self.time, TIME_NDIGITS))

    @classmethod
    def from_action(cls, ev: MitigationAction) -> "DecisionRef":
        return cls(
            job_id=ev.job_id,
            strategy=strategy_label(ev.strategy),
            time=float(ev.time),
            cause=ev.event.root_cause.value,
        )


def decisions_of(run: RunResult) -> list[DecisionRef]:
    """The unique planner decisions a recorded run dispatched, in order."""
    seen: dict[tuple, DecisionRef] = {}
    for ev in run.events:
        if isinstance(ev, MitigationAction):
            ref = DecisionRef.from_action(ev)
            seen.setdefault(ref.key(), ref)
    return list(seen.values())


class DecisionScript:
    """A :class:`~repro.controlplane.plane.ControlPlane` decision hook
    that suppresses / forces specific decisions during a replay.

    * ``suppress`` — decisions (by exact :class:`DecisionRef` identity)
      whose dispatch is skipped; the ladder still advances past the rung.
    * ``force`` — decisions dispatched at the first tick at or after
      ``ref.time`` on which the job has an active diagnosis (moving a
      decision to time *t* = suppress the original + force a copy at *t*).
    * ``suppress_all`` — skip every dispatch *and* every relief (the
      faults-mode reproduction; relief must be gated too, because a
      relief rebalance mutates the simulator).
    """

    def __init__(
        self,
        suppress: tuple[DecisionRef, ...] | list[DecisionRef] = (),
        force: tuple[DecisionRef, ...] | list[DecisionRef] = (),
        suppress_all: bool = False,
    ) -> None:
        self.suppress_all = suppress_all
        self._suppress = {d.key() for d in suppress}
        self._force = sorted(force, key=lambda d: (d.time, d.job_id))
        self._forced_done: set[tuple] = set()
        #: suppressions that actually matched a decision during the run
        self.hits: list[tuple[str, str, float]] = []

    def allow(self, job_id: str, strategy: StrategyKey, now: float) -> bool:
        key = (job_id, strategy_label(strategy), round(now, TIME_NDIGITS))
        if self.suppress_all or key in self._suppress:
            self.hits.append(key)
            return False
        return True

    def allow_relief(self, job_id: str, now: float) -> bool:
        return not self.suppress_all

    def forced(self, job_id: str, now: float) -> list[StrategyKey]:
        if self.suppress_all:
            return []
        out: list[StrategyKey] = []
        for ref in self._force:
            k = ref.key()
            if k in self._forced_done or ref.job_id != job_id:
                continue
            if now >= ref.time:
                # The plane only consults us while the job has an active
                # diagnosis, so a returned key IS dispatched.
                self._forced_done.add(k)
                out.append(_strategy_key(ref.strategy))
        return out


@dataclass(frozen=True)
class Variant:
    """One counterfactual edit: what to change relative to the recording."""

    drop_episodes: frozenset[int] = frozenset()
    suppress: tuple[DecisionRef, ...] = ()
    force: tuple[DecisionRef, ...] = ()
    suppress_all: bool = False
    knobs: PlannerKnobs | None = None

    def cache_key(self, mode: str) -> tuple:
        return (
            mode,
            self.drop_episodes,
            tuple(sorted(d.key() for d in self.suppress)),
            tuple(sorted(d.key() for d in self.force)),
            self.suppress_all,
            self.knobs,
        )

    def script(self) -> DecisionScript | None:
        if not (self.suppress or self.force or self.suppress_all):
            return None
        return DecisionScript(
            suppress=self.suppress, force=self.force,
            suppress_all=self.suppress_all,
        )


class WhatIfEngine:
    """Counterfactual replay over one recorded campaign."""

    def __init__(
        self,
        spec: CampaignSpec,
        baseline: dict[str, RunResult] | None = None,
        campaign_engine: CampaignEngine | None = None,
    ) -> None:
        self.spec = spec
        #: replay-cost ledger: job-mode runs actually executed vs what the
        #: same variants would have cost fresh (4 modes x all jobs each)
        self.stats = {
            "variants": 0,
            "variant_job_runs": 0,
            "fresh_job_runs_equiv": 0,
            "cache_hits": 0,
        }
        #: shared-prefix executor serving baseline and plane-mode variants
        #: (knob bundles ride its decision-trace memo; decision scripts
        #: replay only the forked leg) — byte-identical to fresh runs
        self._campaign = campaign_engine
        if baseline is None:
            baseline = {mode: self._engine().run(mode) for mode in MODES}
        self.baseline = baseline
        self._cache: dict[tuple, RunResult] = {}

    def _engine(self) -> CampaignEngine:
        if self._campaign is None:
            self._campaign = CampaignEngine(self.spec)
        return self._campaign

    # -- construction ----------------------------------------------------
    @classmethod
    def from_preset(
        cls,
        preset: str,
        n_jobs: int | None = None,
        seed: int = 0,
        max_ticks: int | None = None,
    ) -> "WhatIfEngine":
        spec = build_campaign(
            preset, n_jobs=n_jobs, seed=seed, max_ticks=max_ticks
        )
        return cls(spec)

    @classmethod
    def from_report(cls, report: dict) -> "WhatIfEngine":
        """Rebuild the campaign a committed report records, and verify the
        rebuild reproduces the report's JCTs exactly.

        The report's ``campaign`` section carries the full identity
        (preset, jobs, seed, horizon) and its ``event_log`` the recorded
        decision schedule; determinism means rebuilding from the identity
        *is* loading the recording. The verification guards the one way
        that can silently break — a report committed by a different code
        version — by comparing every job's per-mode JCT (and the decision
        schedule, when an event log is present) against the rebuilt run.
        """
        c = report["campaign"]
        spec = build_campaign(
            c["preset"], n_jobs=c["n_jobs"], seed=c["seed"],
            max_ticks=c["max_ticks"],
        )
        engine = cls(spec)
        horizon = engine.baseline["falcon"].horizon_s
        for row in report.get("jobs", ()):
            for mode, want in row.get("jct_s", {}).items():
                got = round(
                    engine.baseline[mode].outcomes[row["job_id"]].jct(horizon),
                    2,
                )
                if abs(got - want) > 0.011:
                    raise ValueError(
                        f"report/replay divergence: {row['job_id']} {mode} "
                        f"JCT {want} in report vs {got} replayed — the "
                        "report predates the current campaign code; "
                        "regenerate it via repro.launch.campaign"
                    )
        recorded = [
            (e["job_id"], e["strategy"], round(e["time"], TIME_NDIGITS))
            for e in report.get("event_log", ())
            if e.get("type") == "MitigationAction"
        ]
        if recorded:
            replayed = [
                d.key() for d in decisions_of(engine.baseline["falcon"])
            ]
            if sorted(recorded) != sorted(replayed):
                raise ValueError(
                    "report/replay divergence: the recorded decision "
                    "schedule does not match the rebuilt campaign's"
                )
        return engine

    # -- variant execution -----------------------------------------------
    def affected_jobs(self, drop: frozenset[int]) -> list[str]:
        return [
            p.job_id for p in self.spec.jobs
            if not drop.isdisjoint(p.global_ids)
        ]

    def run_variant(self, mode: str, variant: Variant) -> RunResult:
        """The variant's run for one mode, reusing whatever is exact."""
        self.stats["fresh_job_runs_equiv"] += len(self.spec.jobs)
        if mode == "healthy":
            # No counterfactual edit can change the no-fault floor.
            return self.baseline["healthy"]
        if mode == "faults" and not variant.drop_episodes:
            # Decision edits and knobs are no-ops without a control plane.
            return self.baseline["faults"]
        key = variant.cache_key(mode)
        hit = self._cache.get(key)
        if hit is not None:
            self.stats["cache_hits"] += 1
            return hit
        self.stats["variants"] += 1
        if mode == "faults":
            rerun = self.affected_jobs(variant.drop_episodes)
            self.stats["variant_job_runs"] += len(rerun)
            partial = run_campaign(
                self.spec, "faults",
                drop_episodes=variant.drop_episodes, only_jobs=rerun,
            )
            base = self.baseline["faults"]
            merged = RunResult(
                mode="faults",
                outcomes={**base.outcomes, **partial.outcomes},
                events=[],
                ticks_run=base.ticks_run,
                horizon_s=base.horizon_s,
            )
            self._cache[key] = merged
            return merged
        self.stats["variant_job_runs"] += len(self.spec.jobs)
        if variant.drop_episodes:
            # Episode edits change the shared prefix itself — only a
            # fresh run is exact.
            out = run_campaign(
                self.spec, mode,
                drop_episodes=variant.drop_episodes,
                decision_hook=variant.script(),
                planner_knobs=variant.knobs,
            )
        else:
            out = self._engine().run(
                mode,
                decision_hook=variant.script(),
                planner_knobs=variant.knobs,
            )
        self._cache[key] = out
        return out

    # -- fleet metrics ----------------------------------------------------
    def totals(
        self,
        faults: RunResult | None = None,
        falcon: RunResult | None = None,
    ) -> dict:
        """Fleet slowdown / mitigated totals, the scorer's clipping rule.

        ``gap_s`` sums each job's (faults − healthy) JCT gap over jobs
        actually slowed; ``mitigated_s`` the (faults − falcon) recovery
        over the same jobs; ``mitigated_pct`` their ratio — exactly the
        report's %-slowdown-mitigated, so attribution deltas reconcile
        against the committed number.
        """
        healthy = self.baseline["healthy"]
        faults = faults if faults is not None else self.baseline["faults"]
        falcon = falcon if falcon is not None else self.baseline["falcon"]
        horizon = healthy.horizon_s
        gap_total = 0.0
        recovered = 0.0
        for p in self.spec.jobs:
            jh = healthy.outcomes[p.job_id].jct(horizon)
            jf = faults.outcomes[p.job_id].jct(horizon)
            jm = falcon.outcomes[p.job_id].jct(horizon)
            gap = jf - jh
            if gap > 1e-9:
                gap_total += gap
                recovered += jf - jm
        return {
            "gap_s": gap_total,
            "mitigated_s": recovered,
            "mitigated_pct": (
                100.0 * recovered / gap_total if gap_total > 1e-9 else None
            ),
        }

    def episodes_by_cause(self) -> dict[str, list[int]]:
        """Global episode ids grouped by root cause, visible episodes only
        (an episode no job's slice feels attributes nothing)."""
        touched = {g for p in self.spec.jobs for g in p.global_ids}
        out: dict[str, list[int]] = {}
        for gi, inj in enumerate(self.spec.schedule):
            if gi in touched:
                out.setdefault(KIND_CAUSE[inj.kind].value, []).append(gi)
        return {k: sorted(v) for k, v in sorted(out.items())}

    def with_knobs(self, knobs: PlannerKnobs) -> RunResult:
        """The falcon run under a knob bundle (the auto-tuner's probe)."""
        return self.run_variant("falcon", Variant(knobs=knobs))
