"""Planner knob auto-tuning against counterfactual value.

A batch_size_finder-style search (the Lightning binary-search-callback
idiom: probe, measure, narrow) over the
:class:`~repro.core.planner.PlannerKnobs` surface, using the what-if
engine's falcon replay as the measurement: a knob candidate's value is
the fleet time it recovers (``mitigated_s``) on the recorded
campaign(s), averaged across seeds so the tuner optimizes the sweep
mean, not one seed's anecdote.

The search is golden-section over each knob's :data:`KNOB_BOUNDS`
domain (log-spaced where the bound says so), one knob at a time in
coordinate-descent order. The measured objective is steppy — decisions
fire on discrete ticks — so golden-section is used as a robust bracketing
probe rather than a convergence guarantee, and the *default* knob value
is always in the candidate set: the tuner returns the best measured
candidate, which makes the reported gain non-negative by construction.
Whether the gain is real (not one-seed noise) is exactly what averaging
over seeds measures.
"""
from __future__ import annotations

import json
import math
import os

from repro.core.planner import KNOB_BOUNDS, PlannerKnobs
from repro.whatif.replay import WhatIfEngine

RESULTS_DIR = os.path.join("results", "whatif")

#: golden ratio complement: interval shrink factor per iteration
_INV_PHI = (math.sqrt(5.0) - 1.0) / 2.0


def objective(engines: list[WhatIfEngine], knobs: PlannerKnobs) -> float:
    """Mean fleet %-slowdown-mitigated under a knob bundle across seeds.

    The percentage (not raw seconds) is averaged so every seed's campaign
    weighs equally — the same normalization the sweep tables report.
    """
    vals = []
    for engine in engines:
        t = engine.totals(falcon=engine.with_knobs(knobs))
        if t["mitigated_pct"] is not None:
            vals.append(t["mitigated_pct"])
    return sum(vals) / len(vals) if vals else 0.0


def tune_knob(
    engines: list[WhatIfEngine],
    name: str,
    base: PlannerKnobs,
    iters: int = 8,
) -> tuple[PlannerKnobs, list[dict]]:
    """Golden-section search of one knob, others held at ``base``.

    Returns the best knob bundle found (>= the base by measured
    objective) and the evaluation trace.
    """
    lo, hi, log_scale = KNOB_BOUNDS[name]
    fwd = math.log if log_scale else (lambda x: x)
    inv = math.exp if log_scale else (lambda x: x)
    a, b = fwd(lo), fwd(hi)

    trace: list[dict] = []

    def measure(x: float) -> float:
        knobs = base.replaced(**{name: round(inv(x), 6)})
        val = objective(engines, knobs)
        trace.append({
            "knob": name,
            "value": round(inv(x), 6),
            "objective_pct": round(val, 4),
        })
        return val

    c = b - _INV_PHI * (b - a)
    d = a + _INV_PHI * (b - a)
    fc, fd = measure(c), measure(d)
    for _ in range(max(iters - 2, 0)):
        if fc >= fd:
            b, d, fd = d, c, fc
            c = b - _INV_PHI * (b - a)
            fc = measure(c)
        else:
            a, c, fc = c, d, fd
            d = a + _INV_PHI * (b - a)
            fd = measure(d)

    # The incumbent default only moves on a strict measured improvement:
    # the tuner never regresses, and ties (the objective is steppy) keep
    # the shipped behavior rather than drifting knobs for nothing.
    best_value, best_obj = getattr(base, name), objective(engines, base)
    for t in trace:
        if t["objective_pct"] > best_obj + 1e-9:
            best_value, best_obj = t["value"], t["objective_pct"]
    return base.replaced(**{name: best_value}), trace


def tune(
    engines: list[WhatIfEngine],
    knob_names: tuple[str, ...] = ("breakeven_scale", "prediction_margin"),
    iters: int = 8,
) -> dict:
    """Coordinate-descent auto-tune over the named knobs.

    Returns the tuning artifact: default vs tuned knob values, the
    measured objective for both (mean %-mitigated across the engines'
    seeds), the non-negative gain, and the full evaluation trace.
    """
    for name in knob_names:
        if name not in KNOB_BOUNDS:
            raise KeyError(
                f"unknown knob {name!r}; tunable: {sorted(KNOB_BOUNDS)}"
            )
    base = PlannerKnobs()
    base_obj = objective(engines, base)
    knobs = base
    trace: list[dict] = []
    for name in knob_names:
        knobs, t = tune_knob(engines, name, knobs, iters=iters)
        trace += t
    tuned_obj = objective(engines, knobs)
    if tuned_obj < base_obj:
        # Interaction between sequentially tuned knobs can in principle
        # lose to the defaults; the contract is non-negative gain.
        knobs, tuned_obj = base, base_obj
    seeds = sorted(e.spec.seed for e in engines)
    return {
        "preset": engines[0].spec.preset.name,
        "n_jobs": len(engines[0].spec.jobs),
        "seeds": seeds,
        "knobs_tuned": list(knob_names),
        "default": {
            n: getattr(base, n) for n in sorted(KNOB_BOUNDS)
        },
        "tuned": {
            n: getattr(knobs, n) for n in sorted(KNOB_BOUNDS)
        },
        "objective": "mean slowdown_mitigated_pct over seeds",
        "objective_default_pct": round(base_obj, 4),
        "objective_tuned_pct": round(tuned_obj, 4),
        "gain_pct_points": round(tuned_obj - base_obj, 4),
        "evaluations": trace,
    }


def write_tuning(result: dict, out_dir: str = RESULTS_DIR) -> str:
    """Persist a tuning artifact (deterministic serialization)."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir,
        f"{result['preset']}-j{result['n_jobs']}"
        f"-s{len(result['seeds'])}seeds-tuning.json",
    )
    with open(path, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
