"""What-if engine: counterfactual replay, attribution, knob auto-tuning.

    engine = WhatIfEngine.from_preset("mixed_fleet", n_jobs=8, seed=0)
    attribution = leave_one_out(engine)      # per-cause / per-decision
    tuned = tune([engine])                   # planner knob auto-tuning

Built on the deterministic campaign runner's replay contract (see
docs/whatif.md): a recorded campaign can be re-run with a fault episode
removed, a decision suppressed or forced, or different planner knobs,
and every outcome difference is attributable to that edit alone.
CLI: ``python -m repro.launch.whatif``.
"""
from repro.whatif.attribution import leave_one_out, shapley  # noqa: F401
from repro.whatif.replay import (  # noqa: F401
    DecisionRef,
    DecisionScript,
    Variant,
    WhatIfEngine,
    decisions_of,
)
from repro.whatif.tuning import (  # noqa: F401
    objective,
    tune,
    tune_knob,
    write_tuning,
)
