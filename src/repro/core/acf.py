"""ACF-based recurring-period detection (paper §4.2, "Iteration time analysis").

In iterative training, collective-communication calls repeat with a fixed
period (Fig. 8). Because the framework and model are unknown (R1), the period
is recovered from the raw call sequence with the autocorrelation function:

    ACF(X)_k = Cov(X_t, X_{t+k}) / Var(X_t)

and ``Period = argmin_k (ACF(X)_k > M)`` with threshold M = 0.95.

Two encodings are supported:
  * a symbol sequence of op types (periodicity in *what* is called), and
  * the timestamp deltas (periodicity in *when*), used to derive per-iteration
    times once the symbol period is known.
"""
from __future__ import annotations

from collections.abc import Sequence

import numpy as np

from repro.core.events import CommEvent

DEFAULT_THRESHOLD = 0.95


def acf(series: np.ndarray, max_lag: int) -> np.ndarray:
    """Return ACF values for lags 1..max_lag (index 0 <-> lag 1).

    Uses the length-normalized (jackknifed) estimator — mean cross-product
    over the n-k overlapping pairs divided by the series variance — so a
    perfectly periodic series scores exactly 1.0 at its period, making the
    paper's M = 0.95 threshold meaningful at any lag (Chatfield, 2013).
    """
    x = np.asarray(series, dtype=np.float64)
    n = x.size
    if n < 2:
        return np.zeros(max_lag)
    mu = x.mean()
    dev = x - mu
    var = float(np.dot(dev, dev)) / n
    if var <= 1e-12:  # constant series: perfectly periodic at every lag
        return np.ones(max_lag)
    out = np.empty(max_lag)
    for k in range(1, max_lag + 1):
        if k >= n:
            out[k - 1] = 0.0
        else:
            out[k - 1] = float(np.dot(dev[:-k], dev[k:])) / (n - k) / var
    return out


def find_period(
    series: np.ndarray,
    max_lag: int | None = None,
    threshold: float = DEFAULT_THRESHOLD,
) -> int | None:
    """First lag k whose ACF exceeds ``threshold`` (paper: argmin_k ACF>M).

    Returns None when no lag qualifies (not enough data / aperiodic).
    """
    x = np.asarray(series, dtype=np.float64)
    if max_lag is None:
        max_lag = max(1, x.size // 3)
    values = acf(x, max_lag)
    hits = np.nonzero(values > threshold)[0]
    if hits.size == 0:
        return None
    return int(hits[0]) + 1


def encode_ops(events: Sequence[CommEvent]) -> np.ndarray:
    """Encode the op-type sequence as floats for ACF computation."""
    symbols: dict[str, int] = {}
    out = np.empty(len(events))
    for i, ev in enumerate(events):
        out[i] = symbols.setdefault(ev.op.value, len(symbols))
    return out


def iteration_times_from_events(
    events: Sequence[CommEvent],
    threshold: float = DEFAULT_THRESHOLD,
) -> tuple[np.ndarray, int | None]:
    """Infer per-iteration times from a raw communication-call log.

    1. Find the recurring period P of the op-type sequence via ACF.
    2. The iteration time is the timestamp difference between a call and the
       same call one period later (paper §4.2).

    Returns (iteration_times, period). Empty array when no period is found.
    """
    if len(events) < 4:
        return np.empty(0), None
    seq = encode_ops(events)
    ts = np.array([ev.timestamp for ev in events])
    # Combine symbol periodicity with timing periodicity: a period must repeat
    # the op pattern; verify candidates on the symbol sequence first.
    period = None
    if np.ptp(seq) > 0:  # symbol sequence is informative
        period = find_period(seq, threshold=threshold)
    if period is None:
        # Fall back to timing deltas: op types may all be identical (e.g.
        # pure-DP training logs only AllReduce), but the *call phases* within
        # an iteration still repeat, so the inter-call gap sequence is
        # periodic with the same period (k gaps per iteration incl. the
        # iteration-boundary gap).
        period = find_period(np.diff(ts), threshold=threshold)
    if period is None:
        return np.empty(0), None
    if period >= len(events):
        return np.empty(0), None
    iter_times = ts[period:] - ts[:-period]
    # One estimate per period (non-overlapping) is the iteration-time series.
    return iter_times[::period], period
