"""O(1) validation of ring/tree communicators (paper §4.3, Fig. 9).

Collective communicators are decomposed into *non-overlapping* P2P
send-receive passes so each pass runs fully in parallel: link validation
takes a constant number of passes regardless of group size —

  * even ring: 2 passes,
  * odd ring:  3 passes,
  * binary tree: 4 passes (left/right children x even/odd levels).

Every pass is a list of disjoint (src, dst) pairs. Since all transfers move
identical payloads, a slow link simply measures a longer time than the
pass median and is flagged.
"""
from __future__ import annotations

from collections.abc import Callable, Sequence

Pair = tuple[int, int]


def ring_links(n: int) -> list[Pair]:
    """All links of an n-rank ring: (i, i+1 mod n)."""
    if n < 2:
        return []
    if n == 2:
        return [(0, 1)]
    return [(i, (i + 1) % n) for i in range(n)]


def ring_passes(n: int) -> list[list[Pair]]:
    """Decompose an n-ring into 2 (even n) or 3 (odd n) disjoint passes."""
    if n < 2:
        return []
    if n == 2:
        return [[(0, 1)]]
    even_pass = [(i, i + 1) for i in range(0, n - 1, 2)]
    odd_pass = [(i, i + 1) for i in range(1, n - 1, 2)]
    if n % 2 == 0:
        odd_pass.append((n - 1, 0))
        return [even_pass, odd_pass]
    return [even_pass, odd_pass, [(n - 1, 0)]]


def tree_links(parents: Sequence[int | None]) -> list[Pair]:
    """All (child, parent) links of a tree given a parent array."""
    return [(c, p) for c, p in enumerate(parents) if p is not None]


def binary_tree_parents(n: int) -> list[int | None]:
    """Parent array of the implicit complete binary tree on ranks 0..n-1."""
    return [None if i == 0 else (i - 1) // 2 for i in range(n)]


def tree_passes(parents: Sequence[int | None]) -> list[list[Pair]]:
    """Decompose a binary tree into exactly 4 disjoint passes (Fig. 9 right).

    Pass 1: left children at even depths -> parent.
    Pass 2: right children at even depths -> parent.
    Pass 3: left children at odd depths -> parent.
    Pass 4: right children at odd depths -> parent.

    Within a pass, every parent receives from at most one child and acts as
    receiver only (its own uplink is exercised in a pass of opposite depth
    parity), so pairs are node-disjoint.
    """
    n = len(parents)
    depth = [0] * n
    for i in range(n):
        p = parents[i]
        if p is not None:
            depth[i] = depth[p] + 1
    is_left: dict[int, bool] = {}
    seen_children: dict[int, int] = {}
    for i in range(n):
        p = parents[i]
        if p is None:
            continue
        seen_children[p] = seen_children.get(p, 0) + 1
        is_left[i] = seen_children[p] == 1
    passes: list[list[Pair]] = [[], [], [], []]
    for i in range(n):
        p = parents[i]
        if p is None:
            continue
        # Child depth parity: children at odd depth have parents at even
        # levels ("even-level children" in the paper's phrasing counts the
        # parent level); group by parent-level parity.
        parent_even = depth[p] % 2 == 0
        idx = (0 if is_left[i] else 1) if parent_even else (2 if is_left[i] else 3)
        passes[idx].append((i, p))
    return [p for p in passes]


def validate_links(
    passes: Sequence[Sequence[Pair]],
    measure: Callable[[Pair], float],
    slow_factor: float = 1.5,
    reference: Callable[[Pair], float] | None = None,
) -> tuple[list[Pair], dict[Pair, float]]:
    """Execute the pass schedule and flag slow links.

    ``measure`` returns the transfer time for one P2P pair (in the real
    system this is the benchmark executor; in tests/benchmarks it queries the
    cluster simulator). When ``reference`` supplies the link's *expected*
    healthy time (links have heterogeneous classes: NVLink vs PCIe vs RDMA —
    the paper's executor knows the fabric), a link is slow when it exceeds
    ``slow_factor`` x its own reference. Without a reference, payloads are
    identical so the median across all links is the yardstick.
    """
    times: dict[Pair, float] = {}
    for p in passes:
        for pair in p:
            times[pair] = measure(pair)
    if not times:
        return [], {}
    if reference is not None:
        slow = [
            pair for pair, t in times.items()
            if t > slow_factor * max(reference(pair), 1e-12)
        ]
        return slow, times
    vals = sorted(times.values())
    median = vals[len(vals) // 2]
    slow = [pair for pair, t in times.items() if t > slow_factor * median]
    return slow, times


def check_disjoint(passes: Sequence[Sequence[Pair]]) -> bool:
    """True iff every pass uses each rank at most once (fully parallel)."""
    for p in passes:
        used: set[int] = set()
        for a, b in p:
            if a in used or b in used:
                return False
            used.update((a, b))
    return True
