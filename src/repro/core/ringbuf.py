"""Bounded ring buffers for online detector state.

The detectors track per-worker iteration-time history only to (re)estimate
the jitter scale and to verify candidate change-points over small windows —
both read bounded trailing slices. Storing the full stream (as the seed's
``list.append`` + ``np.asarray`` did) makes every observation O(n) and the
run O(n²); these buffers keep appends O(1) and window reads O(window) while
preserving *absolute* stream indices, so callers keep reasoning in
change-point indices even after old samples are evicted.
"""
from __future__ import annotations

import numpy as np


class RingBuffer:
    """Fixed-capacity float ring buffer addressed by absolute index.

    ``buf.append(x)`` assigns x absolute index ``len(buf) - 1`` (total
    samples ever seen); ``buf.view(lo, hi)`` returns samples ``[lo, hi)`` as
    a contiguous array, clamping ``lo`` to the oldest retained sample.
    """

    def __init__(self, capacity: int) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self._data = np.empty(capacity)
        self._n = 0  # total samples ever appended

    def __len__(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return self._data.size

    @property
    def start(self) -> int:
        """Absolute index of the oldest retained sample."""
        return max(0, self._n - self._data.size)

    def append(self, x: float) -> None:
        self._data[self._n % self._data.size] = x
        self._n += 1

    def view(self, lo: int, hi: int | None = None) -> np.ndarray:
        """Samples with absolute indices ``[lo, hi)`` (clamped to retained)."""
        cap = self._data.size
        if hi is None or hi > self._n:
            hi = self._n
        lo = max(lo, self.start, 0)
        if hi <= lo:
            return np.empty(0)
        idx = np.arange(lo, hi) % cap
        return self._data[idx]

    def last(self, k: int) -> np.ndarray:
        """The most recent ``k`` samples (fewer if not yet retained)."""
        return self.view(self._n - k, self._n)

    def __getitem__(self, i: int) -> float:
        if not self.start <= i < self._n:
            raise IndexError(f"absolute index {i} not retained")
        return float(self._data[i % self._data.size])


class MatrixRingBuffer:
    """Ring buffer over ``(B,)`` row vectors: the fleet's recent history.

    Rows are ticks (absolute-indexed like :class:`RingBuffer`), columns are
    workers. ``column(w, lo, hi)`` extracts one worker's trailing window for
    escalation without materializing the full fleet history.
    """

    def __init__(self, capacity: int, width: int) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self._data = np.empty((capacity, width))
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return self._data.shape[0]

    @property
    def width(self) -> int:
        return self._data.shape[1]

    @property
    def start(self) -> int:
        return max(0, self._n - self._data.shape[0])

    def append(self, row: np.ndarray) -> None:
        self._data[self._n % self._data.shape[0]] = row
        self._n += 1

    def add_column(self, fill: float = np.nan) -> int:
        """Append one worker column (dynamic fleet membership).

        Already-retained ticks get ``fill`` for the new worker — its history
        genuinely starts now, and NaN-filled rows poison any window that
        reaches before the join, which is exactly the failure mode we want
        loud. Returns the new column's index.
        """
        cap, w = self._data.shape
        data = np.empty((cap, w + 1))
        data[:, :w] = self._data
        data[:, w] = fill
        self._data = data
        return w

    def remove_column(self, idx: int) -> None:
        """Drop one worker column; columns above ``idx`` shift down by one."""
        self._data = np.delete(self._data, idx, axis=1)

    def rows(self, lo: int, hi: int | None = None) -> np.ndarray:
        """Tick rows ``[lo, hi)`` as a ``(hi - lo, B)`` array (clamped)."""
        cap = self._data.shape[0]
        if hi is None or hi > self._n:
            hi = self._n
        lo = max(lo, self.start, 0)
        if hi <= lo:
            return np.empty((0, self._data.shape[1]))
        idx = np.arange(lo, hi) % cap
        return self._data[idx]

    def column(self, worker: int, lo: int, hi: int | None = None) -> np.ndarray:
        cap = self._data.shape[0]
        if hi is None or hi > self._n:
            hi = self._n
        lo = max(lo, self.start, 0)
        if hi <= lo:
            return np.empty(0)
        idx = np.arange(lo, hi) % cap
        return self._data[idx, worker]
