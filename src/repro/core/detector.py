"""FALCON-DETECT — tracking, profiling, validation (paper §4).

The three-phase workflow:

1. *Tracking*: per-worker iteration times (ACF over the comm-event log) are
   scanned online with BOCD; candidate change-points pass a +/-10 %
   verification step to reject jitter (BOCD+V).
2. *Profiling*: per-communication-group transfer times are compared; groups
   slower than 1.1x the median are *suspicious*.
3. *Validation*: training is briefly paused (the trainer simply withholds
   the next step) and suspicious groups run GEMM compute benchmarks and the
   O(1) ring/tree link sweep to pinpoint slow GPUs / congested links.

The detector talks to the system under test through the small
:class:`ClusterInterface` protocol so it works identically against the real
JAX trainer and the cluster simulator (R1, framework-agnostic).

Fleet fast path: :class:`FleetDetect` screens thousands of worker streams
per tick with one :class:`repro.core.bocd.BatchedBOCD` (a bounded shared
hypothesis frontier keeps the per-tick cost flat) and escalates only flagged
workers to the exact per-worker verification used here. Per-worker history
lives in bounded ring buffers — an observation is O(1), never O(n) in the
stream length.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol

import numpy as np

from repro.core import bocd, validation
from repro.core.events import ChangePoint, FailSlowEvent, RootCause
from repro.core.ringbuf import MatrixRingBuffer, RingBuffer

VERIFY_THRESHOLD = 0.10  # <10 % before/after difference => jitter (§4.2)
SUSPICIOUS_FACTOR = 1.1  # >1.1x median transfer time => suspicious (§4.3)
SLOW_COMPONENT_FACTOR = 1.3  # benchmark time vs median => flagged


class ClusterInterface(Protocol):
    """What FALCON-DETECT needs from the system under test."""

    def profile_groups(self) -> dict[str, float]:
        """Per-communication-group mean transfer time (profiling phase)."""
        ...

    def group_ranks(self, group: str) -> list[int]:
        """Ranks participating in a communication group."""
        ...

    def benchmark_compute(self, ranks: list[int]) -> dict[int, float]:
        """GEMM benchmark time per rank (validation phase)."""
        ...

    def measure_link(self, pair: tuple[int, int]) -> float:
        """P2P transfer time for one link (validation phase)."""
        ...

    def healthy_link_time(self, pair: tuple[int, int]) -> float:
        """Expected healthy P2P time for the link's class (NVLink vs PCIe vs
        RDMA) — the benchmark executor knows the fabric topology."""
        ...


def verify_change_points(
    series: np.ndarray,
    indices: list[int],
    window: int = 10,
    threshold: float = VERIFY_THRESHOLD,
) -> list[ChangePoint]:
    """Change-point verification (§4.2): drop <10 % before/after deltas."""
    x = np.asarray(series, dtype=np.float64)
    out: list[ChangePoint] = []
    for idx in indices:
        lo = max(0, idx - window)
        hi = min(x.size, idx + window)
        if idx - lo < 2 or hi - idx < 2:
            continue
        before = float(np.mean(x[lo:idx]))
        after = float(np.mean(x[idx:hi]))
        if before <= 0:
            continue
        rel = abs(after - before) / before
        if rel >= threshold:
            out.append(
                ChangePoint(
                    index=idx,
                    probability=1.0,
                    mean_before=before,
                    mean_after=after,
                )
            )
    return out


def _verify_windows(
    before_win: np.ndarray,
    after_win: np.ndarray,
    idx: int,
    threshold: float,
) -> ChangePoint | None:
    """The +/-10 % rule over extracted before/after windows (single source
    of truth for both the per-job and the fleet escalation paths)."""
    if before_win.size < 2 or after_win.size < 2:
        return None
    before = float(np.mean(before_win))
    after = float(np.mean(after_win))
    if before <= 0 or abs(after - before) / before < threshold:
        return None
    return ChangePoint(
        index=idx, probability=1.0, mean_before=before, mean_after=after
    )


def _verify_ring(
    series: RingBuffer,
    idx: int,
    window: int,
    threshold: float = VERIFY_THRESHOLD,
) -> ChangePoint | None:
    """:func:`verify_change_points` against a bounded ring buffer.

    Reads only the +/-``window`` slice around the candidate (absolute index
    ``idx``), so verification cost is independent of the stream length.
    Candidates older than the buffer's retention cannot be verified and are
    dropped — with any sane ``history_cap`` BOCD flags changes within a few
    steps of onset, far inside retention.
    """
    n = len(series)
    lo = max(0, idx - window, series.start)
    hi = min(n, idx + window)
    return _verify_windows(
        series.view(lo, idx), series.view(idx, hi), idx, threshold
    )


def detect_slow_iterations(
    iteration_times: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = bocd.DEFAULT_CP_THRESHOLD,
    verify_threshold: float = VERIFY_THRESHOLD,
    verify_windows: tuple[int, ...] = (5, 10, 30),
) -> list[ChangePoint]:
    """BOCD + verification over an iteration-time series (offline helper).

    Verification is multi-scale: a change-point is confirmed if the
    before/after means differ by >=10 % at ANY window scale — short windows
    catch brief transients; wide windows catch gradual (ramped) onsets whose
    local slope never reaches the threshold.
    """
    idx = bocd.detect_change_points(
        iteration_times, hazard=hazard, cp_threshold=cp_threshold
    )
    confirmed: dict[int, ChangePoint] = {}
    for w in verify_windows:
        for cp in verify_change_points(
            iteration_times, idx, window=w, threshold=verify_threshold
        ):
            confirmed.setdefault(cp.index, cp)
    return [confirmed[i] for i in sorted(confirmed)]


def detect_slow_iterations_sliding_window(
    iteration_times: np.ndarray,
    window: int = 10,
    threshold: float = VERIFY_THRESHOLD,
) -> list[ChangePoint]:
    """Baseline detector (paper §7.2): flag a >10 % change of the current
    sliding-window mean vs the preceding window's median. Used only for the
    detection-accuracy comparison."""
    x = np.asarray(iteration_times, dtype=np.float64)
    out: list[ChangePoint] = []
    state_slow = False
    for i in range(2 * window, x.size):
        med = float(np.median(x[i - 2 * window : i - window]))
        cur = float(np.mean(x[i - window : i]))
        if med <= 0:
            continue
        rel = (cur - med) / med
        if not state_slow and rel > threshold:
            out.append(
                ChangePoint(index=i, probability=1.0, mean_before=med, mean_after=cur)
            )
            state_slow = True
        elif state_slow and abs(rel) < threshold / 2:
            state_slow = False
    return out


class _ScalarView:
    """Scalar facade over a one-column batched screening backend, so
    :class:`FalconDetect` can run any registry backend on its single
    stream through the scalar ``float -> float`` interface."""

    def __init__(self, backend: bocd.ScreeningBackend) -> None:
        self._b = backend

    def update(self, x: float) -> float:
        return float(self._b.update(np.array([x], dtype=np.float64))[0])

    def p_recent_change(self, window: int = 2) -> float:
        return float(self._b.p_recent_change(window)[0])

    def map_runlength(self) -> int:
        return int(self._b.map_runlength()[0])

    def retune(self, hazard: float | None = None,
               max_hypotheses: int | None = None) -> None:
        self._b.retune(hazard=hazard, max_hypotheses=max_hypotheses)


@dataclass
class FalconDetect:
    """Online detector: feed iteration times, get pinpointed fail-slows."""

    cluster: ClusterInterface
    hazard: float = 1.0 / 100.0
    cp_threshold: float = bocd.DEFAULT_CP_THRESHOLD
    verify_window: int = 10
    #: while an event is active, re-run the O(1) component validation every
    #: this many iterations. Needed because successful mitigation (S2/S3)
    #: flattens the iteration-time signal: the *fault's* relief no longer
    #: shows up as a change-point, only re-validation can see it.
    revalidate_every: int = 10
    #: screening backend for the per-job stream: ``"scalar"`` (the exact
    #: per-series recursion, the default) or any registry name / factory
    #: from :mod:`repro.core.bocd` — non-scalar backends run one-column
    #: batched state behind a scalar facade.
    backend: object = "scalar"

    warmup: int = 8
    #: retained iteration-time samples. Only trailing windows are ever read
    #: (jitter scale at warmup, +/-verify_window around a candidate), so a
    #: bounded ring keeps observe() O(1) instead of O(n) per step.
    history_cap: int = 512

    _series: RingBuffer = field(init=False)
    _bocd: object | None = field(init=False, default=None)
    _scale: float = field(init=False, default=1.0)
    _healthy: float = field(init=False, default=0.0)
    active_event: FailSlowEvent | None = field(init=False, default=None)
    history: list[FailSlowEvent] = field(init=False, default_factory=list)

    def __post_init__(self) -> None:
        self._series = RingBuffer(
            max(self.history_cap, self.warmup, 4 * self.verify_window)
        )

    # ------------------------------------------------------------------
    def observe(self, iter_time: float, now: float) -> FailSlowEvent | None:
        """Feed one iteration time; returns a new FailSlowEvent on onset."""
        self._series.append(iter_time)
        n = len(self._series)
        if self._bocd is None:
            # Warm up: estimate the jitter scale from the first samples,
            # then replay them into a freshly-parameterized detector.
            if n < self.warmup:
                return None
            warm = self._series.view(0, n)
            self._scale = bocd.noise_scale(warm)
            factory = bocd.resolve_screening_backend(self.backend)
            if factory.name == "scalar":
                # Exact per-series recursion, no facade indirection.
                self._bocd = bocd.BOCD(
                    hazard=self.hazard,
                    cp_threshold=self.cp_threshold,
                    mu0=float(warm[0]) / self._scale,
                    beta0=1.0,
                )
            else:
                self._bocd = _ScalarView(factory.make(
                    1,
                    hazard=self.hazard,
                    cp_threshold=self.cp_threshold,
                    mu0=float(warm[0]) / self._scale,
                    beta0=1.0,
                ))
            for v in warm[:-1]:
                self._bocd.update(float(v) / self._scale)
        self._bocd.update(iter_time / self._scale)
        if (
            self.active_event is not None
            and self.active_event.components
            and n % self.revalidate_every == 0
        ):
            had_active = self.active_event
            event = self.revalidate(now, iter_time=iter_time, index=n - 1)
            if event is not None:
                return event
            if self.active_event is not had_active:
                return None  # closed on recovery
        if n < 3 or self._bocd.p_recent_change() <= self.cp_threshold:
            return None
        cp_idx = max(1, n - 1 - self._bocd.map_runlength())
        cp = _verify_ring(
            self._series, cp_idx, window=self.verify_window,
            threshold=VERIFY_THRESHOLD,
        )
        if cp is None:
            return None
        return self.ingest_changepoint(cp, now)

    # ------------------------------------------------------------------
    def ingest_changepoint(
        self, cp: ChangePoint, now: float
    ) -> FailSlowEvent | None:
        """Onset / compound / relief state machine over one *verified*
        change-point.

        This is the escalation entry point the fleet screen routes into
        (:class:`repro.controlplane.ControlPlane`): ``FleetDetect`` verifies
        the change-point cheaply against the worker's history ring, then this
        method runs the full profiling + validation pinpoint exactly as the
        per-job ``observe`` path would.
        """
        if cp.relative_change > 0:
            if self.active_event is None:
                # Onset of a fail-slow: run profiling + validation.
                self._healthy = cp.mean_before
                event = self._pinpoint(now, cp)
                self.active_event = event
                return event
            # Compound fail-slow (paper Fig. 6/17): a second degradation on
            # top of an active one. Close the old event and re-pinpoint —
            # the caller starts a fresh mitigation ladder for the new state.
            if cp.mean_after > 1.05 * self.active_event.t_slow:
                self._close(now)
                event = self._pinpoint(now, cp)
                event.t_healthy = self._healthy or cp.mean_before
                self.active_event = event
                return event
            return None
        if cp.relative_change < 0 and self.active_event is not None:
            # A drop in iteration time can be the fault's relief OR the
            # effect of our own mitigation: when the slow components are
            # known, confirm with the O(1) re-validation before closing.
            if self.active_event.components and not self.components_recovered(
                self.active_event
            ):
                return None
            self._close(now)
        return None

    def revalidate(
        self, now: float, iter_time: float | None = None, index: int = -1
    ) -> FailSlowEvent | None:
        """Re-run the O(1) component validation of the active event.

        Closes the event when its components measure healthy again (needed
        because successful mitigation flattens the iteration-time signal —
        only re-validation can see the fault's relief). When ``iter_time``
        is supplied and is >1.15x the event's recorded severity, the fault
        persists AND got worse: a compound fail-slow piled on (paper
        Fig. 6) — close the stale event, re-pinpoint, and return the new
        event so the caller restarts the mitigation ladder.
        """
        if self.active_event is None or not self.active_event.components:
            return None
        if self.components_recovered(self.active_event):
            self._close(now)
            return None
        if iter_time is not None and iter_time > 1.15 * self.active_event.t_slow:
            stale = self.active_event
            self._close(now)
            cp = ChangePoint(
                index=index,
                probability=1.0,
                mean_before=self._healthy or stale.t_healthy,
                mean_after=iter_time,
            )
            event = self._pinpoint(now, cp)
            event.t_healthy = cp.mean_before
            self.active_event = event
            return event
        return None

    def adopt_event(self, event: FailSlowEvent, now: float) -> FailSlowEvent:
        """Install an externally produced diagnosis as this job's active
        event without re-running profiling + validation (cross-job dedupe:
        another job sharing the hardware already pinpointed the fault)."""
        if self.active_event is not None:
            self._close(now)
        if event.t_healthy > 0:
            self._healthy = event.t_healthy
        self.active_event = event
        return event

    def _close(self, now: float) -> None:
        self.active_event.end_time = now
        self.history.append(self.active_event)
        self.active_event = None

    # ------------------------------------------------------------------
    def components_recovered(self, event: FailSlowEvent) -> bool:
        """Cheap re-validation of the flagged components only (O(1))."""
        ref_link = getattr(self.cluster, "healthy_link_time", None)
        ref_gemm = getattr(self.cluster, "healthy_compute_time", None)
        for comp in event.components:
            kind, _, ident = comp.partition(":")
            if kind == "gpu":
                r = int(ident)
                t = self.cluster.benchmark_compute([r]).get(r)
                if t is None:
                    return False
                if ref_gemm is not None and t > SLOW_COMPONENT_FACTOR * ref_gemm():
                    return False
            elif kind == "link":
                a, b = (int(x) for x in ident.split("-"))
                t = self.cluster.measure_link((a, b))
                if ref_link is not None and t > 1.5 * ref_link((a, b)):
                    return False
            elif kind == "node":
                bench = getattr(self.cluster, "benchmark_host", None)
                ref = getattr(self.cluster, "healthy_host_time", None)
                if bench is None or ref is None:
                    continue  # node comps only come from adapters that have it
                nd = int(ident)
                t = bench([nd]).get(nd)
                if t is None or t > SLOW_COMPONENT_FACTOR * ref():
                    return False
            elif kind == "nic":
                meas = getattr(self.cluster, "measure_nic", None)
                ref = getattr(self.cluster, "healthy_nic_time", None)
                if meas is None or ref is None:
                    continue
                if meas(int(ident)) > 1.5 * ref():
                    return False
        return True

    # ------------------------------------------------------------------
    def _pinpoint(self, now: float, cp: ChangePoint) -> FailSlowEvent:
        """Profiling + validation phases (§4.3).

        The validation sweeps are batched: one ``benchmark_compute`` call
        covers every suspicious group's ranks and one ``measure_links`` /
        ``healthy_link_times`` call (when the adapter provides the batch
        methods) covers every group's ring passes, with the per-group
        median/threshold math done as array ops — the flagging rules are
        unchanged from the per-group loop this replaces.
        """
        group_times = self.cluster.profile_groups()
        suspicious = suspicious_groups(group_times)
        if not suspicious:
            # No group stands out relative to the median — either the
            # degradation is uniform (host-level) or there are too few
            # groups to compare. Validate everything (still cheap: GEMMs in
            # parallel + O(1) link passes per group).
            suspicious = list(group_times)

        group_ranks = [self.cluster.group_ranks(g) for g in suspicious]
        slow_gpus = self._validate_compute(group_ranks)
        slow_links, pair_list, slow_mask = self._validate_links(group_ranks)
        slow_nics = self._nic_components(pair_list, slow_mask)
        slow_hosts: list[str] = []
        if not slow_gpus and not slow_links:
            slow_hosts = self._validate_hosts(group_ranks)

        if slow_gpus and slow_links:
            cause = RootCause.UNKNOWN  # compound; planner treats as generic
        elif slow_gpus:
            cause = RootCause.GPU_DEGRADATION
        elif slow_links:
            cause = RootCause.NETWORK_CONGESTION
        else:
            # Uniform slowdown with healthy GPUs and links points at the host
            # (paper case study 1: CPU contention shows no GPU degradation).
            # When the adapter exposes a host benchmark, the slow node(s) are
            # pinpointed so co-located jobs can dedupe the diagnosis.
            cause = RootCause.CPU_CONTENTION

        severity = 0.0
        if cp.mean_after > 0:
            severity = max(0.0, 1.0 - cp.mean_before / cp.mean_after)
        return FailSlowEvent(
            start_time=now,
            root_cause=cause,
            components=slow_gpus + slow_links + slow_nics + slow_hosts,
            t_healthy=cp.mean_before,
            t_slow=cp.mean_after,
            severity=severity,
        )

    # ------------------------------------------------------------------
    def _validate_hosts(self, group_ranks: list[list[int]]) -> list[str]:
        """Host validation: CPU benchmarks on the nodes spanned by the
        suspicious groups (paper case study 1 — a host-level fault shows
        healthy GPUs and links but a degraded CPU-side benchmark). Requires
        the ``node_of_rank`` / ``benchmark_host`` / ``healthy_host_time``
        adapter surface; adapters without it (e.g. scalar trace replay)
        yield the component-less CPU_CONTENTION diagnosis as before.
        """
        node_of = getattr(self.cluster, "node_of_rank", None)
        bench = getattr(self.cluster, "benchmark_host", None)
        ref = getattr(self.cluster, "healthy_host_time", None)
        if node_of is None or bench is None or ref is None:
            return []
        nodes = sorted({node_of(r) for ranks in group_ranks for r in ranks})
        if not nodes:
            return []
        times = bench(nodes)
        healthy = ref()
        return [
            f"node:{k}" for k in nodes
            if times.get(k, 0.0) > SLOW_COMPONENT_FACTOR * healthy
        ]

    def _nic_components(
        self, pair_list: list[tuple[int, int]], slow_mask: np.ndarray
    ) -> list[str]:
        """Cluster slow inter-node links by NIC port (node-scoped dedupe).

        A congested NIC degrades *every* inter-node flow of its node, so a
        node whose measured inter-node pairs are all slow — at least two
        distinct ones, ruling out a single bad cable — is flagged as
        ``nic:<node>``. Needs ``node_of_rank``; the per-link components are
        kept alongside (mitigation still routes around individual links).
        """
        node_of = getattr(self.cluster, "node_of_rank", None)
        if node_of is None or not pair_list:
            return []
        slow: dict[int, set] = {}
        total: dict[int, set] = {}
        for (a, b), is_slow in zip(pair_list, slow_mask, strict=True):
            na, nb = node_of(a), node_of(b)
            if na == nb:
                continue
            key = (min(a, b), max(a, b))
            for nd in (na, nb):
                total.setdefault(nd, set()).add(key)
                if is_slow:
                    slow.setdefault(nd, set()).add(key)
        return [
            f"nic:{nd}"
            for nd in sorted(total)
            if len(slow.get(nd, ())) >= 2 and slow[nd] == total[nd]
        ]

    # ------------------------------------------------------------------
    def _validate_compute(self, group_ranks: list[list[int]]) -> list[str]:
        """Computation validation (parallel GEMM), batched over groups.

        One ``benchmark_compute`` call covers the union of all groups'
        ranks; a rank is flagged per group against that group's median, so
        results (order and duplicates included) match the former
        one-call-per-group loop.
        """
        all_ranks: list[int] = []
        seen: set[int] = set()
        for ranks in group_ranks:
            for r in ranks:
                if r not in seen:
                    seen.add(r)
                    all_ranks.append(r)
        comp = self.cluster.benchmark_compute(all_ranks) if all_ranks else {}
        if not comp:
            return []
        # Bucket groups by size so each bucket's medians/thresholds are one
        # vectorized pass; bucket order preserves first-appearance order.
        buckets: dict[int, list[int]] = {}
        for gi, ranks in enumerate(group_ranks):
            sub = [r for r in ranks if r in comp]
            if sub:
                buckets.setdefault(len(sub), []).append(gi)
        flags: list[list[str]] = [[] for _ in group_ranks]
        for size, gis in buckets.items():
            mat = np.array(
                [[comp[r] for r in group_ranks[gi] if r in comp] for gi in gis],
                dtype=np.float64,
            )
            med = np.median(mat, axis=1)
            mask = mat > SLOW_COMPONENT_FACTOR * med[:, None]
            for row, gi in enumerate(gis):
                sub = [r for r in group_ranks[gi] if r in comp]
                flags[gi] = [f"gpu:{sub[j]}" for j in np.flatnonzero(mask[row])]
        return [f for per_group in flags for f in per_group]

    def _validate_links(
        self, group_ranks: list[list[int]]
    ) -> tuple[list[str], list[tuple[int, int]], np.ndarray]:
        """Communication validation (O(1) ring sweep), batched over groups.

        All groups' pass-schedule pairs are measured in one
        ``measure_links`` / ``healthy_link_times`` adapter call when
        available (falling back to per-pair scalars otherwise); the slow
        rule is then applied per group exactly as
        :func:`repro.core.validation.validate_links` does. Returns the slow
        components plus the raw (pair, slow) sweep so the caller can cluster
        link faults by NIC port.
        """
        pair_list: list[tuple[int, int]] = []
        slices: list[tuple[int, int]] = []  # [start, end) into pair_list
        for ranks in group_ranks:
            start = len(pair_list)
            if len(ranks) >= 2:
                for p in validation.ring_passes(len(ranks)):
                    pair_list += [(ranks[a], ranks[b]) for a, b in p]
            slices.append((start, len(pair_list)))
        if not pair_list:
            return [], [], np.zeros(0, dtype=bool)
        pairs = np.asarray(pair_list, dtype=np.int64)
        measure_many = getattr(self.cluster, "measure_links", None)
        if measure_many is not None:
            t = np.asarray(measure_many(pairs), dtype=np.float64)
        else:
            t = np.array(
                [self.cluster.measure_link((a, b)) for a, b in pair_list]
            )
        reference = getattr(self.cluster, "healthy_link_time", None)
        if reference is not None:
            ref_many = getattr(self.cluster, "healthy_link_times", None)
            if ref_many is not None:
                ref = np.asarray(ref_many(pairs), dtype=np.float64)
            else:
                ref = np.array([reference((a, b)) for a, b in pair_list])
            slow_mask = t > 1.5 * np.maximum(ref, 1e-12)
        else:
            # No healthy reference: each group's own median is the yardstick.
            slow_mask = np.zeros(t.size, dtype=bool)
            for lo, hi in slices:
                if hi > lo:
                    vals = np.sort(t[lo:hi])
                    slow_mask[lo:hi] = t[lo:hi] > 1.5 * vals[(hi - lo) // 2]
        comps = [
            f"link:{a}-{b}"
            for (a, b), slow in zip(pair_list, slow_mask, strict=True)
            if slow
        ]
        return comps, pair_list, slow_mask


@dataclass(frozen=True)
class FleetFlag:
    """One verified change-point on one worker's stream."""

    worker: int
    change_point: ChangePoint


@dataclass
class _Cohort:
    """Workers warmed together share one :class:`~repro.core.bocd.BatchedBOCD`.

    ``cols`` are current column indices into the fleet history matrix (kept
    in ascending order; re-indexed on removals), ``start`` is the absolute
    tick index of the cohort's first sample — its members joined then and
    have no earlier history. ``batch`` stays None while the cohort warms up.
    """

    cols: list[int]
    start: int
    batch: bocd.ScreeningBackend | None = None
    #: cached int64 array form of ``cols`` (membership edits reset it);
    #: the per-tick loops index the history matrix with it
    arr: np.ndarray | None = None

    def cols_array(self) -> np.ndarray:
        if self.arr is None or self.arr.size != len(self.cols):
            self.arr = np.asarray(self.cols, dtype=np.int64)
        return self.arr


@dataclass
class FleetDetect:
    """Fleet-tier screening over thousands of concurrent worker streams.

    One :class:`repro.core.bocd.BatchedBOCD` advances every worker's
    run-length recursion in lockstep per tick; only workers whose recent
    change probability crosses the threshold are escalated to the exact
    per-worker verification (the same +/-10 % rule FalconDetect applies),
    reading that worker's trailing window from a bounded history ring.
    Confirmed flags are returned for the caller to route into the per-job
    pinpoint/validation path (:class:`FalconDetect` against that job's
    cluster interface).

    ``max_hypotheses`` bounds the shared run-length frontier so the per-tick
    cost is flat in stream length; the escalation path re-checks flagged
    workers exactly, so the screen only needs to be sensitive, not precise.

    Dynamic membership (multi-job campaigns with churn): workers
    :meth:`add_worker` / :meth:`remove_worker` at any point. A leave
    sub-slices the owning batch (:meth:`~repro.core.bocd.BatchedBOCD.
    take_columns` — survivors' posteriors carry over exactly). A join opens
    a warming *cohort*: its stream buffers in the history ring until it has
    ``warmup`` samples, then warms its own batch — established workers keep
    their run-length state untouched. :meth:`consolidate` re-warms every
    warmed cohort into one shared frontier by replaying the common retained
    window (from the youngest member's join), equivalent to a fresh
    ``FleetDetect`` fed that window; ``max_cohorts`` triggers it
    automatically so per-tick cost stays one batched update per cohort,
    bounded.
    """

    n_workers: int
    hazard: float = 1.0 / 100.0
    cp_threshold: float = bocd.DEFAULT_CP_THRESHOLD
    verify_threshold: float = VERIFY_THRESHOLD
    verify_window: int = 10
    #: extra verification scales tried after ``verify_window`` (the same
    #: multi-scale rule as :func:`detect_slow_iterations`): short windows
    #: catch brief transients, wide windows catch ramped onsets whose local
    #: slope never crosses the 10 % threshold at one scale
    verify_windows: tuple[int, ...] = (5, 30)
    #: drift screen: BOCD's run-length posterior *tracks* a gradual ramp
    #: (each step is barely surprising, so Pr(r=0) never spikes — congestion
    #: building up over minutes is invisible to the change-point rule). The
    #: complementary screen compares each worker's trailing mean against a
    #: reference window ``drift_ref`` ticks back and escalates when they
    #: differ by the verification threshold; 0 disables it.
    drift_ref: int = 40
    drift_ref_window: int = 10
    drift_cur_window: int = 5
    #: consecutive ticks the drift condition must hold before escalating.
    #: An abrupt step also trips the lagged comparison (the trailing mean
    #: mixes pre/post samples), but BOCD flags it exactly within a tick or
    #: two — the hold gives BOCD first claim so one physical change never
    #: produces both a change-point flag and a sloppier drift flag.
    drift_hold: int = 5
    #: long-horizon screen: the lagged comparison above still misses creeps
    #: slower than threshold over ``drift_ref`` ticks (a 10 %/hour ramp at a
    #: 30 s tick moves ~3 % per 40 ticks). Each stream additionally tracks a
    #: slow EWMA baseline (span ``ewma_span`` ticks); when the trailing mean
    #: departs from it by the verification threshold for ``ewma_hold``
    #: consecutive ticks, the stream is escalated with the baseline as
    #: ``mean_before``. A linear creep of slope ``r``/tick settles at a
    #: ``r * span/2`` gap above the baseline, so the screen catches creeps
    #: down to ``2*threshold/span`` per tick (span 2000, threshold 10 %:
    #: 0.01 %/tick — a 10 %/hour ramp on a 5 s tick is ~0.014 %/tick). The
    #: baseline lives outside the history ring (O(1) memory), so long spans
    #: are free. It re-anchors (and its maturity resets) on *every*
    #: confirmed flag, so step changes stay BOCD's: after any flag the
    #: screen needs ``ewma_min_age`` ticks of fresh baseline before it may
    #: fire again. 0 disables.
    ewma_span: int = 2000
    ewma_min_age: int = 64
    ewma_hold: int = 8
    warmup: int = 8
    min_gap: int = 3
    recent_window: int = 2
    history_cap: int = 128
    max_hypotheses: int | None = 32
    #: auto-consolidate when more than this many cohorts are warmed
    #: (None = never; joins then cost one extra batch each, forever)
    max_cohorts: int | None = 4
    #: adaptive screening knobs: every this many ticks, re-derive the
    #: per-worker hazard (and the shared frontier cap, when one is set)
    #: from the observed confirmed-flag rate instead of trusting the
    #: constructor constants forever — see :meth:`_retune`. 0 keeps the
    #: fixed constants (the default; campaign determinism depends on it).
    adapt_every: int = 0
    hazard_bounds: tuple[float, float] = (1.0 / 20000.0, 1.0 / 20.0)
    cap_bounds: tuple[int, int] = (8, 256)
    #: screening backend: a registry name (``"scalar"`` / ``"batched"`` /
    #: ``"pallas"``), ``"auto"`` (Pallas where jax compiles it, vectorized
    #: numpy elsewhere — :func:`repro.core.bocd.select_backend`), or a
    #: :class:`repro.core.bocd.ScreeningBackendFactory` instance. Passing a
    #: backend *class* (the pre-backend-API style) still works but warns.
    backend: object = "auto"
    #: fuse all warmed cohorts into one :class:`repro.core.bocd.MultiBOCD`
    #: frontier so each tick runs ONE batched update instead of one per
    #: cohort (bit-identical per column — see MultiBOCD's contract). Only
    #: takes effect on the vectorized numpy backend; the scalar and pallas
    #: backends keep the per-cohort path. Off by default — the campaign
    #: engine (scenarios/engine.py) opts in where the fused frontier's
    #: snapshot/restore support pays for itself.
    fused: bool = False
    #: last re-tune's chosen values (None until the first retune); the
    #: control plane mirrors this into its typed event log as ScreenTuning
    last_tuning: dict | None = field(init=False, default=None)

    _history: MatrixRingBuffer = field(init=False)
    _cohorts: list[_Cohort] = field(init=False)
    _scale: np.ndarray = field(init=False)
    _last_flag: np.ndarray = field(init=False)

    def __post_init__(self) -> None:
        self._backend = bocd.resolve_screening_backend(self.backend)
        self._fused = bool(self.fused) and isinstance(
            self._backend, bocd.BatchedScreening
        )
        self._multi = bocd.MultiBOCD() if self._fused else None
        self._hazard0 = self.hazard
        self._flags_total = 0
        self._worker_ticks = 0
        self._ticks = 0
        # The ring must retain every window any screen reads: the widest
        # verification scale and the drift screen's reference lookback — a
        # smaller user-set history_cap would silently blind those paths.
        lookback = (
            self.drift_ref + self.drift_ref_window if self.drift_ref else 0
        )
        widest = 2 * max((self.verify_window, *self.verify_windows))
        self._history = MatrixRingBuffer(
            max(self.history_cap, self.warmup, 4 * self.verify_window,
                lookback, widest),
            self.n_workers,
        )
        self._scale = np.full(self.n_workers, np.nan)
        self._last_flag = np.full(self.n_workers, -(10**9), dtype=np.int64)
        self._drift_count = np.zeros(self.n_workers, dtype=np.int64)
        self._ewma = np.full(self.n_workers, np.nan)
        self._ewma_age = np.zeros(self.n_workers, dtype=np.int64)
        self._ewma_count = np.zeros(self.n_workers, dtype=np.int64)
        self._cohorts = (
            [_Cohort(cols=list(range(self.n_workers)), start=0)]
            if self.n_workers
            else []
        )

    # -- dynamic membership --------------------------------------------
    @property
    def n_cohorts(self) -> int:
        return len(self._cohorts)

    def add_worker(self) -> int:
        """Register one more stream; returns its column index.

        The worker joins a warming cohort anchored at the current tick:
        screening for it starts once it has ``warmup`` samples, while every
        established cohort's run-length state is left untouched.
        """
        w = self._history.add_column(np.nan)
        self._scale = np.append(self._scale, np.nan)
        self._last_flag = np.append(self._last_flag, -(10**9))
        self._drift_count = np.append(self._drift_count, 0)
        self._ewma = np.append(self._ewma, np.nan)
        self._ewma_age = np.append(self._ewma_age, 0)
        self._ewma_count = np.append(self._ewma_count, 0)
        now = len(self._history)
        if (
            self._cohorts
            and self._cohorts[-1].batch is None
            and self._cohorts[-1].start == now
        ):
            self._cohorts[-1].cols.append(w)  # joined in the same gap
            self._cohorts[-1].arr = None
        else:
            self._cohorts.append(_Cohort(cols=[w], start=now))
        self.n_workers += 1
        return w

    def remove_worker(self, w: int) -> None:
        """Drop one stream; columns above ``w`` shift down by one.

        The owning cohort's batch is column-sub-sliced in place, so the
        surviving members' posteriors (and future flags) are exactly what
        they would have been had the departed stream never been tracked
        (uncapped; under ``max_hypotheses`` the shared frontier may differ).
        """
        self._history.remove_column(w)
        self._scale = np.delete(self._scale, w)
        self._last_flag = np.delete(self._last_flag, w)
        self._drift_count = np.delete(self._drift_count, w)
        self._ewma = np.delete(self._ewma, w)
        self._ewma_age = np.delete(self._ewma_age, w)
        self._ewma_count = np.delete(self._ewma_count, w)
        for cohort in list(self._cohorts):
            if w in cohort.cols:
                if cohort.batch is not None:
                    keep = [i for i, c in enumerate(cohort.cols) if c != w]
                    cohort.batch.take_columns(np.asarray(keep, dtype=np.int64))
                cohort.cols.remove(w)
                if not cohort.cols:
                    self._cohorts.remove(cohort)
                    continue
            cohort.cols = [c - 1 if c > w else c for c in cohort.cols]
            cohort.arr = None
        self.n_workers -= 1

    def consolidate(self) -> None:
        """Re-warm all warmed cohorts into one shared frontier.

        Rebuilds a single :class:`~repro.core.bocd.BatchedBOCD` by replaying
        the retained history window common to every warmed worker (the
        youngest member's join forward): noise scales are re-estimated from
        the window's first ``warmup`` rows, so the result is identical to a
        fresh ``FleetDetect`` fed exactly that window. Run-length memory
        older than the window is forgotten — the escalation path re-verifies
        against the full history ring, so sensitivity to *future* changes is
        what matters. Warming cohorts are left to finish on their own.
        """
        warmed = [c for c in self._cohorts if c.batch is not None]
        if len(warmed) <= 1:
            return
        n = len(self._history)
        start = max(max(c.start for c in warmed), self._history.start)
        if n - start < self.warmup:
            return  # not enough common history to re-estimate scales
        cols = sorted(c for cohort in warmed for c in cohort.cols)
        warm = self._history.rows(start, n)[:, cols]
        scale = bocd.noise_scale_batch(warm[: self.warmup])
        batch = self._backend.make(
            len(cols),
            hazard=self.hazard,
            mu0=warm[0] / scale,
            cp_threshold=self.cp_threshold,
            max_hypotheses=self.max_hypotheses,
        )
        for row in warm:
            batch.update(row / scale)
        self._scale[cols] = scale
        merged = _Cohort(cols=cols, start=start, batch=batch)
        self._cohorts = [merged] + [
            c for c in self._cohorts if c.batch is None
        ]
        if self._fused:
            self._rebuild_multi()

    def _rebuild_multi(self) -> None:
        """Re-absorb every warmed cohort into a fresh fused frontier (after
        consolidation replaced the warmed batches with one standalone)."""
        self._multi = bocd.MultiBOCD()
        for cohort in self._cohorts:
            batch = cohort.batch
            if batch is None:
                continue
            if isinstance(batch, bocd.MultiGroupHandle):
                batch = batch.export()
            cohort.batch = self._multi.absorb(batch)

    # -- snapshot / restore --------------------------------------------
    def snapshot(self) -> dict:
        """Full mutable state as private copies (engine fork support).

        Cohort batches are encoded as ``None`` (warming), a standalone
        backend snapshot, or the index of their group inside the fused
        frontier; backends without snapshot support (scalar fan-out,
        pallas) raise so callers can fall back to fresh execution.
        """
        cohorts: list[dict] = []
        group_index: dict[int, int] = {}
        if self._multi is not None:
            group_index = {
                id(g): i for i, g in enumerate(self._multi._groups)
            }
        for cohort in self._cohorts:
            batch: object = None
            if isinstance(cohort.batch, bocd.MultiGroupHandle):
                batch = ("multi", group_index[id(cohort.batch.group)])
            elif cohort.batch is not None:
                if not hasattr(cohort.batch, "snapshot"):
                    raise NotImplementedError(
                        "screening backend "
                        f"{type(cohort.batch).__name__} has no snapshot()"
                    )
                batch = ("batch", cohort.batch.snapshot())
            cohorts.append(
                {"cols": list(cohort.cols), "start": cohort.start,
                 "batch": batch}
            )
        return {
            "fused": self._fused,
            "hazard": self.hazard,
            "max_hypotheses": self.max_hypotheses,
            "adapt_every": self.adapt_every,
            "n_workers": self.n_workers,
            "last_tuning": (
                dict(self.last_tuning) if self.last_tuning else None
            ),
            "flags_total": self._flags_total,
            "worker_ticks": self._worker_ticks,
            "ticks": self._ticks,
            "history": (self._history._data.copy(), self._history._n),
            "scale": self._scale.copy(),
            "last_flag": self._last_flag.copy(),
            "drift_count": self._drift_count.copy(),
            "ewma": self._ewma.copy(),
            "ewma_age": self._ewma_age.copy(),
            "ewma_count": self._ewma_count.copy(),
            "cohorts": cohorts,
            "multi": (
                self._multi.snapshot() if self._multi is not None else None
            ),
        }

    def restore(self, snap: dict) -> None:
        """Reinstate :meth:`snapshot` state; the instance must have been
        built with the same constructor constants (backend, windows,
        thresholds) — only mutable state is carried in the snapshot."""
        if snap["fused"] != self._fused:
            raise ValueError("snapshot fused mode differs from instance")
        self.hazard = snap["hazard"]
        self.max_hypotheses = snap["max_hypotheses"]
        self.adapt_every = snap["adapt_every"]
        self.n_workers = snap["n_workers"]
        self.last_tuning = (
            dict(snap["last_tuning"]) if snap["last_tuning"] else None
        )
        self._flags_total = snap["flags_total"]
        self._worker_ticks = snap["worker_ticks"]
        self._ticks = snap["ticks"]
        data, n_hist = snap["history"]
        self._history._data = data.copy()
        self._history._n = n_hist
        self._scale = snap["scale"].copy()
        self._last_flag = snap["last_flag"].copy()
        self._drift_count = snap["drift_count"].copy()
        self._ewma = snap["ewma"].copy()
        self._ewma_age = snap["ewma_age"].copy()
        self._ewma_count = snap["ewma_count"].copy()
        if snap["multi"] is not None:
            if self._multi is None:
                self._multi = bocd.MultiBOCD()
            self._multi.restore(snap["multi"])
        self._cohorts = []
        for rec in snap["cohorts"]:
            cohort = _Cohort(cols=list(rec["cols"]), start=rec["start"])
            batch = rec["batch"]
            if batch is not None:
                kind, payload = batch
                if kind == "multi":
                    cohort.batch = bocd.MultiGroupHandle(
                        self._multi, self._multi._groups[payload]
                    )
                else:
                    fresh = self._backend.make(
                        len(cohort.cols),
                        hazard=self.hazard,
                        mu0=np.zeros(len(cohort.cols)),
                        cp_threshold=self.cp_threshold,
                        max_hypotheses=self.max_hypotheses,
                    )
                    fresh.restore(payload)
                    cohort.batch = fresh
            self._cohorts.append(cohort)

    # ------------------------------------------------------------------
    def tick(self, times: np.ndarray) -> list[FleetFlag]:
        """Feed one iteration time per worker; returns verified flags."""
        times = np.asarray(times, dtype=np.float64)
        if times.shape != (self.n_workers,):
            raise ValueError(
                f"expected shape ({self.n_workers},), got {times.shape}"
            )
        self._history.append(times)
        n = len(self._history)
        i = n - 1
        if self.ewma_span:
            # Long-horizon baseline: slow EWMA per stream, seeded on the
            # first sample, re-anchored on every confirmed flag.
            fresh = np.isnan(self._ewma)
            if fresh.any():
                self._ewma[fresh] = times[fresh]
            alpha = 2.0 / (self.ewma_span + 1.0)
            self._ewma += alpha * (times - self._ewma)
            self._ewma_age += 1
        out: list[FleetFlag] = []
        if self._fused:
            # Fused pre-pass: warm any ready cohorts into the shared
            # MultiBOCD frontier, then advance every group with ONE fused
            # update instead of one batched update per cohort.
            for cohort in self._cohorts:
                if cohort.batch is None and n - cohort.start >= self.warmup:
                    cohort.batch = self._multi.absorb(
                        self._warm_cohort(cohort, n)
                    )
            if self._multi.n_series:
                x = np.empty(self._multi.n_series)
                for cohort in self._cohorts:
                    if cohort.batch is not None:
                        cols = cohort.cols_array()
                        x[cohort.batch.cols] = (
                            times[cols] / self._scale[cols]
                        )
                self._multi.update(x)
        drift_ref_mean, drift_cur_mean = self._drift_means(n)
        for cohort in self._cohorts:
            cols = cohort.cols_array()
            if cohort.batch is None:
                if n - cohort.start < self.warmup:
                    continue
                cohort.batch = self._warm_cohort(cohort, n)
            if not self._fused:
                cohort.batch.update(times[cols] / self._scale[cols])
            if i - cohort.start <= self.recent_window:
                continue
            p = cohort.batch.p_recent_change(self.recent_window)
            flagged = np.flatnonzero(p > self.cp_threshold)
            if flagged.size:
                run_lengths = cohort.batch.map_runlength()
                for local_w in flagged:
                    w = cohort.cols[int(local_w)]
                    idx = i - int(run_lengths[local_w])
                    if (
                        idx <= cohort.start
                        or idx - self._last_flag[w] < self.min_gap
                    ):
                        continue
                    cp = self._verify(w, idx, n, floor=cohort.start)
                    if cp is not None:
                        # Dedup on *confirmed* flags only: the first
                        # post-onset ticks may lack the 2 after-samples
                        # verification needs, and the detection burst must
                        # be allowed to retry until one sticks.
                        self._last_flag[w] = idx
                        self._anchor(w, cp.mean_after)
                        out.append(FleetFlag(worker=w, change_point=cp))
            out += self._drift_screen(
                cohort, cols, n, drift_ref_mean, drift_cur_mean
            )
        out += self._long_drift_screen(n, drift_cur_mean)
        if (
            self.max_cohorts is not None
            and sum(1 for c in self._cohorts if c.batch is not None)
            > self.max_cohorts
        ):
            self.consolidate()
        self._flags_total += len(out)
        self._worker_ticks += self.n_workers
        self._ticks += 1
        if self.adapt_every and self._ticks % self.adapt_every == 0:
            self._retune()
        return out

    def _warm_cohort(self, cohort: _Cohort, n: int) -> bocd.ScreeningBackend:
        """Warm one cohort: estimate noise scales from its retained window,
        build a standalone batch, and replay every row but the current one
        (the caller feeds that through the per-tick update path)."""
        cols = np.asarray(cohort.cols, dtype=np.int64)
        warm = self._history.rows(cohort.start, n)[:, cols]
        scale = bocd.noise_scale_batch(warm)
        self._scale[cols] = scale
        batch = self._backend.make(
            cols.size,
            hazard=self.hazard,
            mu0=warm[0] / scale,
            cp_threshold=self.cp_threshold,
            max_hypotheses=self.max_hypotheses,
        )
        for row in warm[:-1]:
            batch.update(row / scale)
        return batch

    def _drift_means(
        self, n: int
    ) -> tuple[np.ndarray | None, np.ndarray | None]:
        """Full-width reference/current trailing means for the drift screen,
        computed once per tick and column-sliced per cohort (bit-identical
        to the per-cohort means for cohorts of >= 2 workers; single-worker
        cohorts recompute on the per-cohort shape — see MultiBOCD)."""
        if not self.drift_ref:
            return None, None
        lag_lo = n - self.drift_ref - self.drift_ref_window
        if lag_lo < self._history.start or lag_lo < 0:
            return None, None
        ref = self._history.rows(
            lag_lo, lag_lo + self.drift_ref_window
        ).mean(axis=0)
        cur = self._history.rows(n - self.drift_cur_window, n).mean(axis=0)
        return ref, cur

    def _anchor(self, w: int, level: float) -> None:
        """Re-anchor worker ``w``'s long-horizon baseline at ``level``
        (the verified post-change mean of a confirmed flag) and restart its
        maturity clock — the baseline always describes the level since the
        last confirmed change, so one physical change never fires both a
        change-point flag and a later long-drift flag."""
        if not self.ewma_span:
            return
        self._ewma[w] = level
        self._ewma_age[w] = 0
        self._ewma_count[w] = 0

    def _long_drift_screen(
        self, n: int, cur_full: np.ndarray | None = None
    ) -> list[FleetFlag]:
        """Creep candidates: trailing mean vs the long-horizon EWMA baseline
        (see ``ewma_span``). No local-window verification is possible — a
        slow creep has no step for the ±window rule to see — so the flag's
        change-point carries (baseline, trailing mean) directly and the real
        verification is the escalation path's component validation. On
        firing, the stream's jitter scale is re-estimated from the trailing
        window (it was frozen at warmup, and under drift the old scale
        mis-standardizes the new level's noise) and the baseline re-anchors.
        """
        if not self.ewma_span:
            return []
        i = n - 1
        w = self.drift_cur_window
        lo = n - w
        if lo < self._history.start or lo < 0:
            return []
        # cur_full (from _drift_means) is this exact expression, computed
        # once per tick when the drift screen also ran.
        cur = (
            cur_full
            if cur_full is not None
            else self._history.rows(lo, n).mean(axis=0)
        )
        base = self._ewma
        with np.errstate(invalid="ignore"):
            ok = (
                (self._ewma_age >= self.ewma_min_age)
                & ~np.isnan(cur)
                & (base > 0)
            )
            rel = np.abs(cur - base) / np.maximum(base, 1e-12)
            over = ok & (rel >= self.verify_threshold)
        self._ewma_count[over] += 1
        self._ewma_count[~over] = 0
        out: list[FleetFlag] = []
        for col in np.flatnonzero(over):
            wk = int(col)
            if (
                self._ewma_count[wk] < self.ewma_hold
                or i - self._last_flag[wk] < self.min_gap
            ):
                continue
            idx = i - w + 1
            cp = ChangePoint(
                index=idx,
                probability=1.0,
                mean_before=float(base[wk]),
                mean_after=float(cur[wk]),
            )
            self._last_flag[wk] = idx
            m = min(n - self._history.start, 4 * self.warmup)
            self._scale[wk] = bocd.noise_scale(
                self._history.column(wk, n - m, n)
            )
            self._anchor(wk, float(cur[wk]))
            out.append(FleetFlag(worker=wk, change_point=cp))
        return out

    def _retune(self) -> None:
        """Adaptive screening knobs (see ``adapt_every``): re-derive the
        hazard from the observed confirmed-flag rate (Laplace-smoothed
        toward the constructor prior, so zero evidence keeps it) and size
        the shared run-length frontier to the expected segment length —
        longer quiet segments need deeper run-length memory to stay exact,
        shorter ones don't. Applied to every warmed batch in place; new
        cohorts pick the values up at warmup."""
        rate = self._flags_total / max(self._worker_ticks, 1)
        hazard = (self._flags_total + 1.0) / (
            self._worker_ticks + 1.0 / self._hazard0
        )
        hazard = float(min(max(hazard, self.hazard_bounds[0]),
                           self.hazard_bounds[1]))
        cap = None
        if self.max_hypotheses is not None:
            cap = int(min(max(round(4.0 / hazard ** 0.5), self.cap_bounds[0]),
                          self.cap_bounds[1]))
            self.max_hypotheses = cap
        self.hazard = hazard
        for cohort in self._cohorts:
            if cohort.batch is not None:
                cohort.batch.retune(hazard=hazard, max_hypotheses=cap)
        self.last_tuning = {
            "tick": self._ticks,
            "hazard": hazard,
            "max_hypotheses": cap,
            "change_rate": rate,
            "flags": self._flags_total,
            "worker_ticks": self._worker_ticks,
        }

    def _drift_screen(
        self,
        cohort: _Cohort,
        cols: np.ndarray,
        n: int,
        ref_full: np.ndarray | None = None,
        cur_full: np.ndarray | None = None,
    ) -> list[FleetFlag]:
        """Lagged-window drift candidates for one cohort (see ``drift_ref``).

        One vectorized mean-vs-mean comparison per tick; candidates go
        through the exact multi-scale verification like BOCD flags do, so
        the screen adds sensitivity to gradual onsets without adding a new
        false-positive source.
        """
        if not self.drift_ref:
            return []
        i = n - 1
        lag_lo = n - self.drift_ref - self.drift_ref_window
        if lag_lo < max(cohort.start, self._history.start):
            return []
        if ref_full is not None and cols.size >= 2:
            # Column-slice the precomputed full-width means (bit-identical:
            # numpy's axis-0 reduction is per-column for >= 2 columns). A
            # single-worker cohort reduces on numpy's 1-D pairwise path, so
            # it recomputes on the per-cohort operand below.
            ref = ref_full[cols]
            cur = cur_full[cols]
        else:
            ref = self._history.rows(lag_lo, lag_lo + self.drift_ref_window)[
                :, cols
            ].mean(axis=0)
            cur = self._history.rows(n - self.drift_cur_window, n)[
                :, cols
            ].mean(axis=0)
        rel = np.abs(cur - ref) / np.maximum(ref, 1e-12)
        over = rel >= self.verify_threshold
        self._drift_count[cols[over]] += 1
        self._drift_count[cols[~over]] = 0
        out: list[FleetFlag] = []
        for local_w in np.flatnonzero(over):
            w = cohort.cols[int(local_w)]
            # The reference window must postdate the worker's last confirmed
            # change-point: a drift candidate whose baseline straddles an
            # already-flagged change is that change re-detected against a
            # stale reference, not a new fault.
            if (
                self._drift_count[w] < self.drift_hold
                or lag_lo <= self._last_flag[w]
            ):
                continue
            idx = i - self.drift_cur_window + 1
            cp = self._verify(w, idx, n, floor=cohort.start)
            if cp is not None:
                self._last_flag[w] = idx
                self._anchor(w, cp.mean_after)
                out.append(FleetFlag(worker=w, change_point=cp))
        return out

    def _verify(
        self, worker: int, idx: int, n: int, floor: int = 0
    ) -> ChangePoint | None:
        for w in (self.verify_window, *self.verify_windows):
            lo = max(floor, idx - w, self._history.start)
            hi = min(n, idx + w)
            cp = _verify_windows(
                self._history.column(worker, lo, idx),
                self._history.column(worker, idx, hi),
                idx,
                self.verify_threshold,
            )
            if cp is not None:
                return cp
        return None


def suspicious_groups(
    group_times: dict[str, float], factor: float = SUSPICIOUS_FACTOR
) -> list[str]:
    """Groups with transfer time > factor x median (§4.3 profiling)."""
    if not group_times:
        return []
    med = float(np.median(list(group_times.values())))
    if med <= 0:
        return []
    return [g for g, t in group_times.items() if t > factor * med]


@dataclass
class Watchdog:
    """Missing-observation heartbeat monitor (hang detection).

    BOCD — batched or not — structurally cannot flag a stream that *stops
    emitting samples*: with no new observation the run-length recursion
    simply does not advance. A hang looks exactly like that (the current
    iteration never completes), so hang detection keys off silence, not
    values: every delivered sample is a :meth:`beat`, and :meth:`expired`
    fires once the silence exceeds a deadline calibrated to that stream's
    own inter-arrival jitter,

        deadline = max(floor_gaps * mean_gap, mean_gap + k_sigma * std_gap)

    with mean/std tracked as EWMAs of the observed gaps. A stream that
    always reports on a metronomic cadence gets a tight ``floor_gaps``
    deadline; a stream whose delivery jitters gets proportionally more
    slack, keeping the false-positive rate at zero on healthy-but-noisy
    streams. Nothing fires before ``min_beats`` heartbeats — there is no
    calibrated cadence to miss yet.
    """

    #: minimum deadline, in multiples of the mean inter-arrival gap
    floor_gaps: float = 3.0
    #: jitter slack: deadline stretches this many gap std-devs past the mean
    k_sigma: float = 8.0
    #: heartbeats required before a stream's deadline is armed
    min_beats: int = 2
    #: EWMA smoothing factor for the gap mean/variance
    alpha: float = 0.2

    _last: dict = field(init=False, default_factory=dict)
    _mean: dict = field(init=False, default_factory=dict)
    _var: dict = field(init=False, default_factory=dict)
    _beats: dict = field(init=False, default_factory=dict)

    def beat(self, key, now: float) -> None:
        """Record a delivered observation for stream ``key`` at ``now``."""
        prev = self._last.get(key)
        self._last[key] = now
        self._beats[key] = self._beats.get(key, 0) + 1
        if prev is None:
            return
        gap = now - prev
        dl = self._deadline_gap(key)
        if dl is not None and gap > dl:
            # Resume after a stall (or a delivery outage): folding the
            # silent stretch into the cadence statistics would poison every
            # future deadline, so re-anchor without updating them.
            return
        mean = self._mean.get(key)
        if mean is None:
            self._mean[key] = gap
            self._var[key] = 0.0
            return
        a = self.alpha
        delta = gap - mean
        self._mean[key] = mean + a * delta
        self._var[key] = (1.0 - a) * (self._var[key] + a * delta * delta)

    def _deadline_gap(self, key) -> float | None:
        """Allowed silence in seconds, or None while uncalibrated."""
        mean = self._mean.get(key)
        if mean is None or self._beats.get(key, 0) < self.min_beats:
            return None
        std = float(np.sqrt(max(self._var.get(key, 0.0), 0.0)))
        return max(self.floor_gaps * mean, mean + self.k_sigma * std)

    def deadline(self, key) -> float | None:
        """Public view of the stream's current silence budget (seconds)."""
        return self._deadline_gap(key)

    def silence(self, key, now: float) -> float:
        """Seconds since the stream's last heartbeat (0 if never seen)."""
        last = self._last.get(key)
        return 0.0 if last is None else max(now - last, 0.0)

    def expired(self, key, now: float) -> bool:
        """True when ``key`` has been silent past its calibrated deadline."""
        dl = self._deadline_gap(key)
        return dl is not None and self.silence(key, now) > dl

    def forget(self, key) -> None:
        """Drop all state for a departed stream (job leave)."""
        for d in (self._last, self._mean, self._var, self._beats):
            d.pop(key, None)

    # -- state capture (campaign fork/restore contract) -----------------
    def snapshot(self) -> dict:
        """All cadence state as private copies (keys are job ids: shallow
        dict copies suffice — values are floats/ints)."""
        return {
            "last": dict(self._last),
            "mean": dict(self._mean),
            "var": dict(self._var),
            "beats": dict(self._beats),
        }

    def restore(self, snap: dict) -> None:
        self._last = dict(snap["last"])
        self._mean = dict(snap["mean"])
        self._var = dict(snap["var"])
        self._beats = dict(snap["beats"])
