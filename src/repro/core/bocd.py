"""Bayesian Online Change-point Detection (paper §4.2 + Appendix 9.1).

Implements the Adams/MacKay-style run-length recursion the paper uses
(eqs. 2-5): at each step maintain the run-length posterior Pr(r_t | x_{1:t}),
with a Normal-Gamma underlying probabilistic model (Student-t predictive) and
a constant-hazard change-point prior. A timestamp t is reported as a
change-point when Pr(r_t = 0 | x_{1:t}) exceeds a threshold (0.9 in the
paper's experiments). Time and memory are kept linear by truncating
negligible run-length mass.

Fast-path architecture (fleet scale)
------------------------------------
Two implementations share the recursion:

* :class:`BOCD` — one scalar series. Sufficient statistics live in
  capacity-doubling buffers that are shifted and updated **in place**, so an
  update allocates O(1) small temporaries instead of re-concatenating the
  prior onto every array (the seed did four ``np.concatenate`` per
  observation).
* :class:`BatchedBOCD` — B independent series advanced in lockstep as 2-D
  ``(K, B)`` array operations: one vectorized Student-t log-predictive, one
  per-column normalization, one shared truncation frontier per tick. Row
  ``i`` holds the run-length-``rl[i]`` hypothesis of *every* series; a
  ``-inf`` posterior entry marks a hypothesis that one series has truncated
  while another still tracks it. Rows dead in every column are compacted
  away, bounding K exactly like the scalar truncation. Per column the
  posterior (and therefore the change-point indices) matches the scalar
  recursion — :class:`repro.core.detector.FleetDetect` relies on this to
  screen thousands of workers per tick and escalate only flagged ones.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

DEFAULT_CP_THRESHOLD = 0.9

_MIN_CAPACITY = 64


@dataclass
class BOCD:
    """Online change-point detector over a scalar series (iteration times).

    Parameters mirror the standard Normal-Gamma conjugate prior:
      mu0/kappa0: prior mean and its pseudo-count,
      alpha0/beta0: precision-Gamma shape/rate,
      hazard: constant change-point hazard rate 1/expected-run-length.
    """

    hazard: float = 1.0 / 100.0
    mu0: float = 0.0
    kappa0: float = 1.0
    alpha0: float = 1.0
    beta0: float = 1.0
    cp_threshold: float = DEFAULT_CP_THRESHOLD
    truncation: float = 1e-6
    #: optional hard bound on run-length hypotheses (fleet fast path): after
    #: mass truncation, keep r=0 plus the top ``max_hypotheses - 1`` rows by
    #: posterior mass (stable tie-break on run length). None = paper-exact.
    max_hypotheses: int | None = None

    # --- state: views of length _len into the capacity buffers below ---
    _log_r: np.ndarray = field(init=False)
    _mu: np.ndarray = field(init=False)
    _kappa: np.ndarray = field(init=False)
    _alpha: np.ndarray = field(init=False)
    _beta: np.ndarray = field(init=False)
    _rl: np.ndarray = field(init=False)
    _t: int = field(init=False, default=0)
    _len: int = field(init=False, default=1)

    def __post_init__(self) -> None:
        cap = _MIN_CAPACITY
        self._log_r_buf = np.zeros(cap)
        self._mu_buf = np.empty(cap)
        self._kappa_buf = np.empty(cap)
        self._alpha_buf = np.empty(cap)
        self._beta_buf = np.empty(cap)
        self._rl_buf = np.zeros(cap, dtype=np.int64)
        self._mu_buf[0] = self.mu0
        self._kappa_buf[0] = self.kappa0
        self._alpha_buf[0] = self.alpha0
        self._beta_buf[0] = self.beta0
        self._refresh_views()

    def _refresh_views(self) -> None:
        n = self._len
        self._log_r = self._log_r_buf[:n]
        self._mu = self._mu_buf[:n]
        self._kappa = self._kappa_buf[:n]
        self._alpha = self._alpha_buf[:n]
        self._beta = self._beta_buf[:n]
        self._rl = self._rl_buf[:n]

    def _grow(self) -> None:
        cap = 2 * self._log_r_buf.size
        for name in ("_log_r_buf", "_mu_buf", "_kappa_buf", "_alpha_buf",
                     "_beta_buf", "_rl_buf"):
            old = getattr(self, name)
            buf = np.empty(cap, dtype=old.dtype)
            buf[: old.size] = old
            setattr(self, name, buf)

    # ------------------------------------------------------------------
    def _log_pred(self, x: float) -> np.ndarray:
        """Student-t log predictive for each current run-length hypothesis."""
        return _student_t_logpdf(x, self._mu, self._kappa, self._alpha, self._beta)

    def _log_prior_pred(self, x: float) -> float:
        """Student-t log predictive under the (fresh-segment) prior."""
        return float(
            _student_t_logpdf(
                x,
                np.array([self.mu0]),
                np.array([self.kappa0]),
                np.array([self.alpha0]),
                np.array([self.beta0]),
            )[0]
        )

    def update(self, x: float) -> float:
        """Feed one observation; return Pr(r_t = 0 | x_{1:t}).

        Convention: ``r_t = 0`` means x_t is the *first* observation of a new
        segment, so the change-point path scores x_t under the **prior**
        predictive while growth paths score it under each run's posterior
        predictive. (In the alternative Adams-MacKay message convention the
        CP path reuses the old run's predictive and Pr(r_t=0) degenerates to
        the hazard whenever predictives coincide — useless for the paper's
        "probability > 0.9" detection rule.)
        """
        n = self._len
        if n + 1 > self._log_r_buf.size:
            self._grow()
        log_h = math.log(self.hazard)
        log_1mh = math.log1p(-self.hazard)

        # Growth probabilities: run continues (r -> r+1).
        log_growth = self._log_pred(x)
        log_growth += self._log_r
        log_growth += log_1mh
        # Change-point: new segment begins at t; x_t scored under the prior.
        log_cp = self._log_prior_pred(x) + log_h  # sum_r P(r) = 1 (normalized)

        lr = self._log_r_buf
        lr[1 : n + 1] = log_growth
        lr[0] = log_cp
        new_log_r = lr[: n + 1]
        new_log_r -= _logsumexp(new_log_r)

        # Shift the sufficient statistics one slot (the new r=0 hypothesis is
        # the prior) and apply the Normal-Gamma update in place.
        for buf, prior in (
            (self._mu_buf, self.mu0),
            (self._kappa_buf, self.kappa0),
            (self._alpha_buf, self.alpha0),
            (self._beta_buf, self.beta0),
        ):
            buf[1 : n + 1] = buf[:n]
            buf[0] = prior
        mu = self._mu_buf[: n + 1]
        kappa = self._kappa_buf[: n + 1]
        denom = kappa + 1.0
        upd = 0.5 * kappa
        upd *= (x - mu) ** 2
        upd /= denom
        self._beta_buf[: n + 1] += upd
        mu *= kappa
        mu += x
        mu /= denom
        kappa += 1.0
        self._alpha_buf[: n + 1] += 0.5
        rl = self._rl_buf
        rl[1 : n + 1] = rl[:n]
        rl[1 : n + 1] += 1
        rl[0] = 0
        self._len = n + 1
        self._t += 1

        # Truncate negligible run-length mass -> linear time overall (R2).
        keep = new_log_r > math.log(self.truncation)
        keep[0] = True
        if not keep.all():
            self._compact(np.flatnonzero(keep))
        cap = self.max_hypotheses
        if cap is not None and self._len > cap:
            lr = self._log_r_buf[: self._len]
            order = np.argsort(lr[1:], kind="stable")  # ascending mass
            keep = np.ones(self._len, dtype=bool)
            keep[order[: self._len - cap] + 1] = False
            self._compact(np.flatnonzero(keep))
        self._refresh_views()
        return float(math.exp(self._log_r[0]))

    def _compact(self, idx: np.ndarray) -> None:
        """Keep only hypothesis rows ``idx`` (ascending) and renormalize."""
        m = idx.size
        n = self._len
        for buf in (self._log_r_buf, self._mu_buf, self._kappa_buf,
                    self._alpha_buf, self._beta_buf, self._rl_buf):
            buf[:m] = buf[:n][idx]
        self._len = m
        self._log_r_buf[:m] -= _logsumexp(self._log_r_buf[:m])

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        """Adjust the change-point prior / frontier cap mid-stream.

        Both only affect *future* updates (the hazard enters each step's
        growth/change mixture; the cap is applied per update), so the
        adaptive screening layer can re-derive them from observed change
        rates without rebuilding run-length state."""
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses

    # -- detection statistics ------------------------------------------
    def p_recent_change(self, window: int = 2) -> float:
        """Posterior probability that a change-point occurred within the
        last ``window`` observations: Pr(r_t <= window | x_{1:t})."""
        # _rl is strictly increasing, so the recent rows are a prefix.
        j = int(np.searchsorted(self._rl, window, side="right"))
        if j == 0:
            return 0.0
        return float(np.exp(_logsumexp(self._log_r[:j])))

    def map_runlength(self) -> int:
        """MAP run length (distance back to the most likely change-point)."""
        return int(self._rl[int(np.argmax(self._log_r))])


class BatchedBOCD:
    """B independent BOCD recursions advanced in lockstep (fleet fast path).

    All state is ``(K, B)``: row ``i`` holds the run-length-``rl[i]``
    hypothesis of every series. Per-column truncation marks a series'
    negligible hypotheses with ``-inf`` posterior (they can never revive:
    growth adds finite log-predictives to ``-inf``); the shared frontier
    compacts rows that are dead in **every** column, so K stays bounded
    exactly like the scalar detector's. Each series' posterior matches the
    scalar :class:`BOCD` recursion step for step.
    """

    def __init__(
        self,
        n_series: int,
        hazard: float = 1.0 / 100.0,
        mu0: float | np.ndarray = 0.0,
        kappa0: float = 1.0,
        alpha0: float = 1.0,
        beta0: float = 1.0,
        cp_threshold: float = DEFAULT_CP_THRESHOLD,
        truncation: float = 1e-6,
        max_hypotheses: int | None = None,
    ) -> None:
        b = int(n_series)
        self.n_series = b
        self.hazard = hazard
        self.kappa0 = kappa0
        self.alpha0 = alpha0
        self.beta0 = beta0
        self.cp_threshold = cp_threshold
        self.truncation = truncation
        self.max_hypotheses = max_hypotheses
        self._mu0 = np.broadcast_to(
            np.asarray(mu0, dtype=np.float64), (b,)
        ).copy()
        self._log_r = np.zeros((1, b))
        self._mu = self._mu0[None, :].copy()
        self._beta = np.full((1, b), beta0)
        # kappa/alpha receive the same +1.0/+0.5 per step in every column
        # (shared prior, lockstep updates), so they are row-constant: store
        # them once per run-length hypothesis, not per series. This keeps the
        # expensive gammaln terms of the Student-t at O(K) instead of O(K*B).
        self._kappa_row = np.full(1, kappa0)
        self._alpha_row = np.full(1, alpha0)
        self._rl = np.zeros(1, dtype=np.int64)
        self._t = 0

    @property
    def n_hypotheses(self) -> int:
        return self._rl.size

    def take_columns(self, idx: np.ndarray) -> None:
        """Sub-slice the batch to the series in ``idx`` (dynamic membership).

        Columns are statistically independent — truncation in uncapped mode
        is per-column, and the shared ``max_hypotheses`` frontier only
        couples which *rows* survive — so each kept column's posterior is
        carried over unchanged: in uncapped mode it is exactly what a fresh
        recursion over that column alone would hold. Hypothesis rows now
        dead in every surviving column are compacted away, shrinking the
        frontier like the per-tick truncation does.
        """
        idx = np.asarray(idx, dtype=np.int64)
        self.n_series = int(idx.size)
        self._mu0 = self._mu0[idx]
        self._log_r = self._log_r[:, idx]
        self._mu = self._mu[:, idx]
        self._beta = self._beta[:, idx]
        alive = np.isfinite(self._log_r).any(axis=1)
        if alive.size:
            alive[0] = True
        if not alive.all():
            self._log_r = self._log_r[alive]
            self._mu = self._mu[alive]
            self._beta = self._beta[alive]
            self._kappa_row = self._kappa_row[alive]
            self._alpha_row = self._alpha_row[alive]
            self._rl = self._rl[alive]

    def update(self, x: np.ndarray) -> np.ndarray:
        """Feed one observation per series; return Pr(r_t = 0) per series."""
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.n_series,):
            raise ValueError(f"expected shape ({self.n_series},), got {x.shape}")
        log_h = math.log(self.hazard)
        log_1mh = math.log1p(-self.hazard)

        log_growth = _student_t_logpdf_rows(
            x, self._mu, self._kappa_row, self._alpha_row, self._beta
        )
        log_growth += self._log_r  # -inf (dead) rows stay -inf
        log_growth += log_1mh
        log_cp = _student_t_logpdf(
            x, self._mu0, np.float64(self.kappa0), np.float64(self.alpha0),
            np.float64(self.beta0),
        )
        log_cp += log_h

        k, b = self._log_r.shape
        new_log_r = np.empty((k + 1, b))
        new_log_r[0] = log_cp
        new_log_r[1:] = log_growth
        new_log_r -= _logsumexp_cols(new_log_r)

        mu_all = np.empty((k + 1, b))
        mu_all[0] = self._mu0
        mu_all[1:] = self._mu
        beta_all = np.empty((k + 1, b))
        beta_all[0] = self.beta0
        beta_all[1:] = self._beta
        kappa_all = np.empty(k + 1)
        kappa_all[0] = self.kappa0
        kappa_all[1:] = self._kappa_row
        alpha_all = np.empty(k + 1)
        alpha_all[0] = self.alpha0
        alpha_all[1:] = self._alpha_row
        denom = kappa_all + 1.0
        # In-place chains mirror the scalar operation order exactly.
        upd = x - mu_all
        np.multiply(upd, upd, out=upd)
        upd *= (0.5 * kappa_all)[:, None]
        upd /= denom[:, None]
        beta_all += upd
        self._beta = beta_all
        mu_all *= kappa_all[:, None]
        mu_all += x
        mu_all /= denom[:, None]
        self._mu = mu_all
        self._kappa_row = denom
        self._alpha_row = alpha_all + 0.5
        rl = np.empty(k + 1, dtype=np.int64)
        rl[0] = 0
        rl[1:] = self._rl
        rl[1:] += 1
        self._rl = rl
        self._t += 1

        # Per-column truncation: kill sub-threshold live hypotheses
        # (scalar-equivalent), plus the shared truncation frontier: keep r=0
        # and the cap-1 hypothesis rows with the highest column-max mass, so
        # K stays <= cap and every per-tick array op is bounded. With B=1
        # the cap is exactly the scalar rule; for B>1 it trades per-column
        # exactness for bounded fleet cost (flagged workers re-run the exact
        # scalar path during escalation anyway). One renormalization +
        # compaction pass covers both kill sources.
        dead = new_log_r <= math.log(self.truncation)
        dead[0] = False
        dead &= np.isfinite(new_log_r)
        if dead.any():
            new_log_r[dead] = -np.inf
        cap = self.max_hypotheses
        if cap is not None and new_log_r.shape[0] > cap:
            k1 = new_log_r.shape[0]
            strength = np.max(new_log_r, axis=1)
            order = np.argsort(strength[1:], kind="stable")  # ascending
            kill = np.zeros((k1, b), dtype=bool)
            kill[order[: k1 - cap] + 1] = True
            kill &= np.isfinite(new_log_r)
            if kill.any():
                new_log_r[kill] = -np.inf
                dead |= kill
        self._log_r = self._kill(new_log_r, dead)
        return np.exp(self._log_r[0])

    def _kill(self, log_r: np.ndarray, dead: np.ndarray) -> np.ndarray:
        """Renormalize columns with ``dead`` (-inf-marked) entries and
        compact hypothesis rows that are dead in every column."""
        if not dead.any():
            return log_r
        cols = dead.any(axis=0)
        if cols.mean() > 0.5:
            # Most columns affected: renormalizing everything avoids the
            # fancy-index copies (a no-op ~0 shift for untouched columns).
            log_r -= _logsumexp_cols(log_r)
        else:
            log_r[:, cols] -= _logsumexp_cols(log_r[:, cols])
        alive = np.isfinite(log_r).any(axis=1)
        alive[0] = True
        if not alive.all():
            log_r = log_r[alive]
            self._mu = self._mu[alive]
            self._beta = self._beta[alive]
            self._kappa_row = self._kappa_row[alive]
            self._alpha_row = self._alpha_row[alive]
            self._rl = self._rl[alive]
        return log_r

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        """Adjust the change-point prior / shared frontier cap mid-stream
        (future updates only — run-length state carries over unchanged)."""
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses

    # -- detection statistics (vectorized analogues of BOCD's) ----------
    def p_recent_change(self, window: int = 2) -> np.ndarray:
        """Pr(r_t <= window | x_{1:t}) for every series, shape (B,)."""
        # _rl is strictly increasing, so the recent rows are a prefix: a
        # view slice, not a boolean-mask copy of the (K, B) posterior.
        j = int(np.searchsorted(self._rl, window, side="right"))
        if j == 0:
            return np.zeros(self.n_series)
        return np.exp(_logsumexp_cols(self._log_r[:j]))

    def map_runlength(self) -> np.ndarray:
        """MAP run length per series, shape (B,) ints."""
        return self._rl[np.argmax(self._log_r, axis=0)]


def noise_scale(series: np.ndarray) -> float:
    """Robust per-step noise estimate: MAD of first differences.

    First differences cancel slow level drift, so this measures *jitter*;
    BOCD observations are standardized by it, making the detector sensitive
    to any statistically significant level shift regardless of its relative
    size (the 10 % relevance filter is the separate verification step).
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size < 3:
        return max(float(np.median(np.abs(x))) * 1e-2, 1e-9)
    d = np.diff(x)
    mad = float(np.median(np.abs(d - np.median(d))))
    sigma = 1.4826 * mad / np.sqrt(2.0)
    floor = max(float(np.median(np.abs(x))) * 1e-3, 1e-9)
    return max(sigma, floor)


def noise_scale_batch(series: np.ndarray) -> np.ndarray:
    """Column-wise :func:`noise_scale` over a ``(T, B)`` matrix, shape (B,)."""
    x = np.asarray(series, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("expected a (T, B) matrix")
    absmed = np.median(np.abs(x), axis=0)
    if x.shape[0] < 3:
        return np.maximum(absmed * 1e-2, 1e-9)
    d = np.diff(x, axis=0)
    mad = np.median(np.abs(d - np.median(d, axis=0)), axis=0)
    sigma = 1.4826 * mad / np.sqrt(2.0)
    floor = np.maximum(absmed * 1e-3, 1e-9)
    return np.maximum(sigma, floor)


def detect_change_points(
    series: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = DEFAULT_CP_THRESHOLD,
    min_gap: int = 3,
    recent_window: int = 2,
) -> list[int]:
    """Run BOCD over ``series``; return change-point indices.

    A change is reported at index ``i - map_runlength`` whenever the
    posterior probability of a change within the last ``recent_window``
    observations exceeds ``cp_threshold`` (paper: likelihood of r_t = 0
    above 0.9 — evaluated over a tiny window so the single-step hazard
    factor does not suppress genuine onsets). ``min_gap`` merges the burst
    of detections that one physical change produces.
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size == 0:
        return []
    scale = noise_scale(x)
    det = BOCD(
        hazard=hazard,
        mu0=float(x[0] / scale),
        kappa0=1.0,
        alpha0=1.0,
        beta0=1.0,
        cp_threshold=cp_threshold,
    )
    out: list[int] = []
    for i, xi in enumerate(x):
        det.update(float(xi / scale))
        if i <= recent_window:  # p_recent is trivially 1 in the first steps
            continue
        if det.p_recent_change(recent_window) > cp_threshold:
            idx = i - det.map_runlength()
            if idx > 0 and (not out or idx - out[-1] >= min_gap):
                out.append(idx)
    return out


def detect_change_points_batch(
    series: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = DEFAULT_CP_THRESHOLD,
    min_gap: int = 3,
    recent_window: int = 2,
) -> list[list[int]]:
    """Batched :func:`detect_change_points` over a ``(T, B)`` matrix.

    Returns one change-point index list per column, matching what the scalar
    routine reports on that column alone.
    """
    x = np.asarray(series, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("expected a (T, B) matrix")
    t_steps, b = x.shape
    out: list[list[int]] = [[] for _ in range(b)]
    if t_steps == 0 or b == 0:
        return out
    scale = noise_scale_batch(x)
    det = BatchedBOCD(
        b, hazard=hazard, mu0=x[0] / scale, cp_threshold=cp_threshold
    )
    xs = x / scale
    for i in range(t_steps):
        det.update(xs[i])
        if i <= recent_window:
            continue
        flagged = np.flatnonzero(det.p_recent_change(recent_window) > cp_threshold)
        if flagged.size == 0:
            continue
        run_lengths = det.map_runlength()
        for col in flagged:
            idx = i - int(run_lengths[col])
            dst = out[col]
            if idx > 0 and (not dst or idx - dst[-1] >= min_gap):
                dst.append(idx)
    return out


def _student_t_logpdf(
    x: float | np.ndarray,
    mu: np.ndarray,
    kappa: np.ndarray,
    alpha: np.ndarray,
    beta: np.ndarray,
) -> np.ndarray:
    """Posterior-predictive Student-t of the Normal-Gamma model.

    Broadcasts over any leading hypothesis/batch axes: scalar ``x`` against
    1-D stats (scalar BOCD) or ``(B,)`` observations against ``(K, B)``
    stats (batched BOCD).
    """
    df = 2.0 * alpha
    scale2 = beta * (kappa + 1.0) / (alpha * kappa)
    z2 = (x - mu) ** 2 / scale2
    return (
        _gammaln((df + 1.0) / 2.0)
        - _gammaln(df / 2.0)
        - 0.5 * np.log(np.pi * df * scale2)
        - (df + 1.0) / 2.0 * np.log1p(z2 / df)
    )


def _student_t_logpdf_rows(
    x: np.ndarray,
    mu: np.ndarray,
    kappa_row: np.ndarray,
    alpha_row: np.ndarray,
    beta: np.ndarray,
) -> np.ndarray:
    """:func:`_student_t_logpdf` with row-constant kappa/alpha ``(K,)``
    against ``(K, B)`` mu/beta — the gammaln terms collapse to O(K). Applies
    the exact same per-element operation chain, so results are bit-identical
    to the generic version."""
    df = 2.0 * alpha_row
    const = _gammaln((df + 1.0) / 2.0) - _gammaln(df / 2.0)
    scale2 = beta * (kappa_row + 1.0)[:, None]
    scale2 /= (alpha_row * kappa_row)[:, None]
    z2 = x - mu
    np.multiply(z2, z2, out=z2)
    z2 /= scale2
    z2 /= df[:, None]
    np.log1p(z2, out=z2)
    z2 *= ((df + 1.0) / 2.0)[:, None]
    scale2 *= (np.pi * df)[:, None]
    np.log(scale2, out=scale2)
    scale2 *= 0.5
    np.subtract(const[:, None], scale2, out=scale2)
    scale2 -= z2
    return scale2


def _logsumexp(a: np.ndarray) -> float:
    m = float(np.max(a))
    if math.isinf(m):
        return m
    return m + math.log(float(np.sum(np.exp(a - m))))


def _logsumexp_cols(a: np.ndarray) -> np.ndarray:
    """Column-wise logsumexp of a (K, B) matrix; all ``-inf`` columns -> -inf."""
    m = np.max(a, axis=0)
    shift = np.where(np.isfinite(m), m, 0.0)
    with np.errstate(divide="ignore"):
        return np.log(np.sum(np.exp(a - shift), axis=0)) + shift


try:  # scipy is available in this environment; keep a pure fallback anyway.
    from scipy.special import gammaln as _gammaln
except ImportError:  # pragma: no cover
    def _gammaln(x):
        return np.vectorize(math.lgamma)(x)


# ----------------------------------------------------------------------
# Screening backends (docs/kernels.md)
#
# The fleet screening loop talks to an *instance* implementing the batched
# interface below; FleetDetect / ControlPlane select a backend *factory*
# (scalar / batched-numpy / pallas) instead of hard-wiring BatchedBOCD, so
# implementations stay interchangeable and equivalence-tested from one
# registry.
# ----------------------------------------------------------------------

@runtime_checkable
class ScreeningBackend(Protocol):
    """Batched run-length screening state over ``n_series`` streams.

    The contract (shapes per :class:`BatchedBOCD`, the reference semantics):
    ``update(x)`` consumes one observation per stream and returns
    ``Pr(r_t = 0)`` per stream; ``p_recent_change``/``map_runlength`` report
    posterior statistics; ``take_columns`` sub-slices streams on membership
    churn; ``retune`` adjusts hazard / frontier cap for future updates.
    """

    n_series: int

    def update(self, x: np.ndarray) -> np.ndarray: ...
    def p_recent_change(self, window: int = 2) -> np.ndarray: ...
    def map_runlength(self) -> np.ndarray: ...
    def take_columns(self, idx: np.ndarray) -> None: ...
    def retune(self, hazard: float | None = None,
               max_hypotheses: int | None = None) -> None: ...


class ScalarFanout:
    """B independent scalar :class:`BOCD` detectors behind the batched
    screening interface — the per-column oracle as "just another backend".

    O(B) Python-loop cost per tick; useful for tiny fleets and as the
    ground truth the vectorized/Pallas backends are equivalence-tested
    against (per column it *is* the scalar recursion, bit for bit).
    """

    def __init__(
        self,
        n_series: int,
        hazard: float = 1.0 / 100.0,
        mu0: float | np.ndarray = 0.0,
        kappa0: float = 1.0,
        alpha0: float = 1.0,
        beta0: float = 1.0,
        cp_threshold: float = DEFAULT_CP_THRESHOLD,
        truncation: float = 1e-6,
        max_hypotheses: int | None = None,
    ) -> None:
        b = int(n_series)
        mu0 = np.broadcast_to(np.asarray(mu0, dtype=np.float64), (b,))
        self.n_series = b
        self.hazard = hazard
        self.cp_threshold = cp_threshold
        self.max_hypotheses = max_hypotheses
        self._dets = [
            BOCD(
                hazard=hazard, mu0=float(m), kappa0=kappa0, alpha0=alpha0,
                beta0=beta0, cp_threshold=cp_threshold, truncation=truncation,
                max_hypotheses=max_hypotheses,
            )
            for m in mu0
        ]

    def update(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.n_series,):
            raise ValueError(f"expected shape ({self.n_series},), got {x.shape}")
        return np.fromiter(
            (d.update(float(xi)) for d, xi in zip(self._dets, x)),
            dtype=np.float64, count=self.n_series,
        )

    def p_recent_change(self, window: int = 2) -> np.ndarray:
        return np.fromiter(
            (d.p_recent_change(window) for d in self._dets),
            dtype=np.float64, count=self.n_series,
        )

    def map_runlength(self) -> np.ndarray:
        return np.fromiter(
            (d.map_runlength() for d in self._dets),
            dtype=np.int64, count=self.n_series,
        )

    def take_columns(self, idx: np.ndarray) -> None:
        idx = np.asarray(idx, dtype=np.int64)
        self._dets = [self._dets[int(i)] for i in idx]
        self.n_series = int(idx.size)

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses
        for d in self._dets:
            d.retune(hazard=hazard, max_hypotheses=max_hypotheses)


class ScreeningBackendFactory:
    """Constructs :class:`ScreeningBackend` instances.

    The screening layer creates backend state dynamically (one instance per
    warmed cohort, sized to the cohort and seeded with its per-stream
    ``mu0``), so the pluggable unit is a *factory*, not an instance.
    """

    name = "abstract"

    def make(self, n_series: int, **kwargs) -> ScreeningBackend:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<ScreeningBackendFactory {self.name!r}>"


class ScalarScreening(ScreeningBackendFactory):
    name = "scalar"

    def make(self, n_series: int, **kwargs) -> ScalarFanout:
        return ScalarFanout(n_series, **kwargs)


class BatchedScreening(ScreeningBackendFactory):
    name = "batched"

    def make(self, n_series: int, **kwargs) -> BatchedBOCD:
        return BatchedBOCD(n_series, **kwargs)


class PallasScreening(ScreeningBackendFactory):
    """Fused Pallas step kernel (``repro.kernels.bocd_step.PallasBOCD``).

    ``interpret``/``dtype`` override the kernel defaults (interpret mode is
    auto-enabled on CPU jax; dtype defaults to float32 — see
    docs/kernels.md for the tolerance policy).
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, dtype=None) -> None:
        self.interpret = interpret
        self.dtype = dtype

    def make(self, n_series: int, **kwargs):
        from repro.kernels.bocd_step import PallasBOCD

        if self.interpret is not None:
            kwargs.setdefault("interpret", self.interpret)
        if self.dtype is not None:
            kwargs.setdefault("dtype", self.dtype)
        return PallasBOCD(n_series, **kwargs)


#: Registry enumerated by the backend-equivalence tests; ``numpy`` is an
#: alias for the vectorized numpy implementation.
SCREENING_BACKENDS: dict[str, ScreeningBackendFactory] = {
    "scalar": ScalarScreening(),
    "batched": BatchedScreening(),
    "pallas": PallasScreening(),
}
SCREENING_BACKENDS["numpy"] = SCREENING_BACKENDS["batched"]


def pallas_is_compiled() -> bool:
    """True when jax will *compile* Pallas kernels (non-CPU backend).

    On this container's CPU jax, Pallas runs in interpret mode — correct
    but slow, so auto-selection prefers the vectorized numpy backend there
    and only tests/CI opt into ``pallas`` explicitly.
    """
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - jax always present here
        return False


def select_backend(name: str | None = None) -> ScreeningBackendFactory:
    """Resolve a screening backend by name.

    ``None``/``"auto"`` auto-detects: Pallas where jax compiles it (GPU/TPU),
    the vectorized numpy ``batched`` backend everywhere else.
    """
    if name is None or name == "auto":
        return SCREENING_BACKENDS["pallas" if pallas_is_compiled() else "batched"]
    try:
        return SCREENING_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown screening backend {name!r}; "
            f"registered: {sorted(SCREENING_BACKENDS)}"
        ) from None


class _ClassShim(ScreeningBackendFactory):
    """Deprecation shim: wraps a backend *class* passed where a factory
    instance is now expected (the pre-backend-API constructor style)."""

    def __init__(self, cls: type) -> None:
        self._cls = cls
        self.name = getattr(cls, "__name__", "class")

    def make(self, n_series: int, **kwargs) -> ScreeningBackend:
        return self._cls(n_series, **kwargs)


def resolve_screening_backend(spec) -> ScreeningBackendFactory:
    """Accept a backend name, ``None``/``"auto"``, a factory instance, or
    (deprecated, with a warning) a backend class such as ``BatchedBOCD``."""
    if spec is None or isinstance(spec, str):
        return select_backend(spec)
    if isinstance(spec, type):
        warnings.warn(
            "passing a screening backend class is deprecated; pass a "
            "ScreeningBackendFactory instance or a registry name "
            f"(e.g. {sorted(set(SCREENING_BACKENDS))!r})",
            DeprecationWarning,
            stacklevel=2,
        )
        if spec is BatchedBOCD:
            return SCREENING_BACKENDS["batched"]
        if spec is BOCD:
            return SCREENING_BACKENDS["scalar"]
        return _ClassShim(spec)
    if isinstance(spec, ScreeningBackendFactory) or hasattr(spec, "make"):
        return spec
    raise TypeError(
        f"screening backend must be a name, factory, or class; got {spec!r}"
    )
