"""Bayesian Online Change-point Detection (paper §4.2 + Appendix 9.1).

Implements the Adams/MacKay-style run-length recursion the paper uses
(eqs. 2-5): at each step maintain the run-length posterior Pr(r_t | x_{1:t}),
with a Normal-Gamma underlying probabilistic model (Student-t predictive) and
a constant-hazard change-point prior. A timestamp t is reported as a
change-point when Pr(r_t = 0 | x_{1:t}) exceeds a threshold (0.9 in the
paper's experiments). Time and memory are kept linear by truncating
negligible run-length mass.

Fast-path architecture (fleet scale)
------------------------------------
Two implementations share the recursion:

* :class:`BOCD` — one scalar series. Sufficient statistics live in
  capacity-doubling buffers that are shifted and updated **in place**, so an
  update allocates O(1) small temporaries instead of re-concatenating the
  prior onto every array (the seed did four ``np.concatenate`` per
  observation).
* :class:`BatchedBOCD` — B independent series advanced in lockstep as 2-D
  ``(K, B)`` array operations: one vectorized Student-t log-predictive, one
  per-column normalization, one shared truncation frontier per tick. Row
  ``i`` holds the run-length-``rl[i]`` hypothesis of *every* series; a
  ``-inf`` posterior entry marks a hypothesis that one series has truncated
  while another still tracks it. Rows dead in every column are compacted
  away, bounding K exactly like the scalar truncation. Per column the
  posterior (and therefore the change-point indices) matches the scalar
  recursion — :class:`repro.core.detector.FleetDetect` relies on this to
  screen thousands of workers per tick and escalate only flagged ones.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

DEFAULT_CP_THRESHOLD = 0.9

_MIN_CAPACITY = 64


@dataclass
class BOCD:
    """Online change-point detector over a scalar series (iteration times).

    Parameters mirror the standard Normal-Gamma conjugate prior:
      mu0/kappa0: prior mean and its pseudo-count,
      alpha0/beta0: precision-Gamma shape/rate,
      hazard: constant change-point hazard rate 1/expected-run-length.
    """

    hazard: float = 1.0 / 100.0
    mu0: float = 0.0
    kappa0: float = 1.0
    alpha0: float = 1.0
    beta0: float = 1.0
    cp_threshold: float = DEFAULT_CP_THRESHOLD
    truncation: float = 1e-6
    #: optional hard bound on run-length hypotheses (fleet fast path): after
    #: mass truncation, keep r=0 plus the top ``max_hypotheses - 1`` rows by
    #: posterior mass (stable tie-break on run length). None = paper-exact.
    max_hypotheses: int | None = None

    # --- state: views of length _len into the capacity buffers below ---
    _log_r: np.ndarray = field(init=False)
    _mu: np.ndarray = field(init=False)
    _kappa: np.ndarray = field(init=False)
    _alpha: np.ndarray = field(init=False)
    _beta: np.ndarray = field(init=False)
    _rl: np.ndarray = field(init=False)
    _t: int = field(init=False, default=0)
    _len: int = field(init=False, default=1)

    def __post_init__(self) -> None:
        cap = _MIN_CAPACITY
        self._log_r_buf = np.zeros(cap)
        self._mu_buf = np.empty(cap)
        self._kappa_buf = np.empty(cap)
        self._alpha_buf = np.empty(cap)
        self._beta_buf = np.empty(cap)
        self._rl_buf = np.zeros(cap, dtype=np.int64)
        self._mu_buf[0] = self.mu0
        self._kappa_buf[0] = self.kappa0
        self._alpha_buf[0] = self.alpha0
        self._beta_buf[0] = self.beta0
        self._refresh_views()

    def _refresh_views(self) -> None:
        n = self._len
        self._log_r = self._log_r_buf[:n]
        self._mu = self._mu_buf[:n]
        self._kappa = self._kappa_buf[:n]
        self._alpha = self._alpha_buf[:n]
        self._beta = self._beta_buf[:n]
        self._rl = self._rl_buf[:n]

    def _grow(self) -> None:
        cap = 2 * self._log_r_buf.size
        for name in ("_log_r_buf", "_mu_buf", "_kappa_buf", "_alpha_buf",
                     "_beta_buf", "_rl_buf"):
            old = getattr(self, name)
            buf = np.empty(cap, dtype=old.dtype)
            buf[: old.size] = old
            setattr(self, name, buf)

    # ------------------------------------------------------------------
    def _log_pred(self, x: float) -> np.ndarray:
        """Student-t log predictive for each current run-length hypothesis."""
        return _student_t_logpdf(x, self._mu, self._kappa, self._alpha, self._beta)

    def _log_prior_pred(self, x: float) -> float:
        """Student-t log predictive under the (fresh-segment) prior."""
        return float(
            _student_t_logpdf(
                x,
                np.array([self.mu0]),
                np.array([self.kappa0]),
                np.array([self.alpha0]),
                np.array([self.beta0]),
            )[0]
        )

    def update(self, x: float) -> float:
        """Feed one observation; return Pr(r_t = 0 | x_{1:t}).

        Convention: ``r_t = 0`` means x_t is the *first* observation of a new
        segment, so the change-point path scores x_t under the **prior**
        predictive while growth paths score it under each run's posterior
        predictive. (In the alternative Adams-MacKay message convention the
        CP path reuses the old run's predictive and Pr(r_t=0) degenerates to
        the hazard whenever predictives coincide — useless for the paper's
        "probability > 0.9" detection rule.)
        """
        n = self._len
        if n + 1 > self._log_r_buf.size:
            self._grow()
        log_h = math.log(self.hazard)
        log_1mh = math.log1p(-self.hazard)

        # Growth probabilities: run continues (r -> r+1).
        log_growth = self._log_pred(x)
        log_growth += self._log_r
        log_growth += log_1mh
        # Change-point: new segment begins at t; x_t scored under the prior.
        log_cp = self._log_prior_pred(x) + log_h  # sum_r P(r) = 1 (normalized)

        lr = self._log_r_buf
        lr[1 : n + 1] = log_growth
        lr[0] = log_cp
        new_log_r = lr[: n + 1]
        new_log_r -= _logsumexp(new_log_r)

        # Shift the sufficient statistics one slot (the new r=0 hypothesis is
        # the prior) and apply the Normal-Gamma update in place.
        for buf, prior in (
            (self._mu_buf, self.mu0),
            (self._kappa_buf, self.kappa0),
            (self._alpha_buf, self.alpha0),
            (self._beta_buf, self.beta0),
        ):
            buf[1 : n + 1] = buf[:n]
            buf[0] = prior
        mu = self._mu_buf[: n + 1]
        kappa = self._kappa_buf[: n + 1]
        denom = kappa + 1.0
        upd = 0.5 * kappa
        upd *= (x - mu) ** 2
        upd /= denom
        self._beta_buf[: n + 1] += upd
        mu *= kappa
        mu += x
        mu /= denom
        kappa += 1.0
        self._alpha_buf[: n + 1] += 0.5
        rl = self._rl_buf
        rl[1 : n + 1] = rl[:n]
        rl[1 : n + 1] += 1
        rl[0] = 0
        self._len = n + 1
        self._t += 1

        # Truncate negligible run-length mass -> linear time overall (R2).
        keep = new_log_r > math.log(self.truncation)
        keep[0] = True
        if not keep.all():
            self._compact(np.flatnonzero(keep))
        cap = self.max_hypotheses
        if cap is not None and self._len > cap:
            lr = self._log_r_buf[: self._len]
            order = np.argsort(lr[1:], kind="stable")  # ascending mass
            keep = np.ones(self._len, dtype=bool)
            keep[order[: self._len - cap] + 1] = False
            self._compact(np.flatnonzero(keep))
        self._refresh_views()
        return float(math.exp(self._log_r[0]))

    def _compact(self, idx: np.ndarray) -> None:
        """Keep only hypothesis rows ``idx`` (ascending) and renormalize."""
        m = idx.size
        n = self._len
        for buf in (self._log_r_buf, self._mu_buf, self._kappa_buf,
                    self._alpha_buf, self._beta_buf, self._rl_buf):
            buf[:m] = buf[:n][idx]
        self._len = m
        self._log_r_buf[:m] -= _logsumexp(self._log_r_buf[:m])

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        """Adjust the change-point prior / frontier cap mid-stream.

        Both only affect *future* updates (the hazard enters each step's
        growth/change mixture; the cap is applied per update), so the
        adaptive screening layer can re-derive them from observed change
        rates without rebuilding run-length state."""
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses

    # -- detection statistics ------------------------------------------
    def p_recent_change(self, window: int = 2) -> float:
        """Posterior probability that a change-point occurred within the
        last ``window`` observations: Pr(r_t <= window | x_{1:t})."""
        # _rl is strictly increasing, so the recent rows are a prefix.
        j = int(np.searchsorted(self._rl, window, side="right"))
        if j == 0:
            return 0.0
        return float(np.exp(_logsumexp(self._log_r[:j])))

    def map_runlength(self) -> int:
        """MAP run length (distance back to the most likely change-point)."""
        return int(self._rl[int(np.argmax(self._log_r))])


class BatchedBOCD:
    """B independent BOCD recursions advanced in lockstep (fleet fast path).

    All state is ``(K, B)``: row ``i`` holds the run-length-``rl[i]``
    hypothesis of every series. Per-column truncation marks a series'
    negligible hypotheses with ``-inf`` posterior (they can never revive:
    growth adds finite log-predictives to ``-inf``); the shared frontier
    compacts rows that are dead in **every** column, so K stays bounded
    exactly like the scalar detector's. Each series' posterior matches the
    scalar :class:`BOCD` recursion step for step.
    """

    def __init__(
        self,
        n_series: int,
        hazard: float = 1.0 / 100.0,
        mu0: float | np.ndarray = 0.0,
        kappa0: float = 1.0,
        alpha0: float = 1.0,
        beta0: float = 1.0,
        cp_threshold: float = DEFAULT_CP_THRESHOLD,
        truncation: float = 1e-6,
        max_hypotheses: int | None = None,
    ) -> None:
        b = int(n_series)
        self.n_series = b
        self.hazard = hazard
        self.kappa0 = kappa0
        self.alpha0 = alpha0
        self.beta0 = beta0
        self.cp_threshold = cp_threshold
        self.truncation = truncation
        self.max_hypotheses = max_hypotheses
        self._mu0 = np.broadcast_to(
            np.asarray(mu0, dtype=np.float64), (b,)
        ).copy()
        self._log_r = np.zeros((1, b))
        self._mu = self._mu0[None, :].copy()
        self._beta = np.full((1, b), beta0)
        # kappa/alpha receive the same +1.0/+0.5 per step in every column
        # (shared prior, lockstep updates), so they are row-constant: store
        # them once per run-length hypothesis, not per series. This keeps the
        # expensive gammaln terms of the Student-t at O(K) instead of O(K*B).
        self._kappa_row = np.full(1, kappa0)
        self._alpha_row = np.full(1, alpha0)
        self._rl = np.zeros(1, dtype=np.int64)
        self._t = 0

    @property
    def n_hypotheses(self) -> int:
        return self._rl.size

    def take_columns(self, idx: np.ndarray) -> None:
        """Sub-slice the batch to the series in ``idx`` (dynamic membership).

        Columns are statistically independent — truncation in uncapped mode
        is per-column, and the shared ``max_hypotheses`` frontier only
        couples which *rows* survive — so each kept column's posterior is
        carried over unchanged: in uncapped mode it is exactly what a fresh
        recursion over that column alone would hold. Hypothesis rows now
        dead in every surviving column are compacted away, shrinking the
        frontier like the per-tick truncation does.
        """
        idx = np.asarray(idx, dtype=np.int64)
        self.n_series = int(idx.size)
        self._mu0 = self._mu0[idx]
        self._log_r = self._log_r[:, idx]
        self._mu = self._mu[:, idx]
        self._beta = self._beta[:, idx]
        alive = np.isfinite(self._log_r).any(axis=1)
        if alive.size:
            alive[0] = True
        if not alive.all():
            self._log_r = self._log_r[alive]
            self._mu = self._mu[alive]
            self._beta = self._beta[alive]
            self._kappa_row = self._kappa_row[alive]
            self._alpha_row = self._alpha_row[alive]
            self._rl = self._rl[alive]

    def update(self, x: np.ndarray) -> np.ndarray:
        """Feed one observation per series; return Pr(r_t = 0) per series."""
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.n_series,):
            raise ValueError(f"expected shape ({self.n_series},), got {x.shape}")
        log_h = math.log(self.hazard)
        log_1mh = math.log1p(-self.hazard)

        log_growth = _student_t_logpdf_rows(
            x, self._mu, self._kappa_row, self._alpha_row, self._beta
        )
        log_growth += self._log_r  # -inf (dead) rows stay -inf
        log_growth += log_1mh
        log_cp = _student_t_logpdf(
            x, self._mu0, np.float64(self.kappa0), np.float64(self.alpha0),
            np.float64(self.beta0),
        )
        log_cp += log_h

        k, b = self._log_r.shape
        new_log_r = np.empty((k + 1, b))
        new_log_r[0] = log_cp
        new_log_r[1:] = log_growth
        new_log_r -= _logsumexp_cols(new_log_r)

        mu_all = np.empty((k + 1, b))
        mu_all[0] = self._mu0
        mu_all[1:] = self._mu
        beta_all = np.empty((k + 1, b))
        beta_all[0] = self.beta0
        beta_all[1:] = self._beta
        kappa_all = np.empty(k + 1)
        kappa_all[0] = self.kappa0
        kappa_all[1:] = self._kappa_row
        alpha_all = np.empty(k + 1)
        alpha_all[0] = self.alpha0
        alpha_all[1:] = self._alpha_row
        denom = kappa_all + 1.0
        # In-place chains mirror the scalar operation order exactly.
        upd = x - mu_all
        np.multiply(upd, upd, out=upd)
        upd *= (0.5 * kappa_all)[:, None]
        upd /= denom[:, None]
        beta_all += upd
        self._beta = beta_all
        mu_all *= kappa_all[:, None]
        mu_all += x
        mu_all /= denom[:, None]
        self._mu = mu_all
        self._kappa_row = denom
        self._alpha_row = alpha_all + 0.5
        rl = np.empty(k + 1, dtype=np.int64)
        rl[0] = 0
        rl[1:] = self._rl
        rl[1:] += 1
        self._rl = rl
        self._t += 1

        # Per-column truncation: kill sub-threshold live hypotheses
        # (scalar-equivalent), plus the shared truncation frontier: keep r=0
        # and the cap-1 hypothesis rows with the highest column-max mass, so
        # K stays <= cap and every per-tick array op is bounded. With B=1
        # the cap is exactly the scalar rule; for B>1 it trades per-column
        # exactness for bounded fleet cost (flagged workers re-run the exact
        # scalar path during escalation anyway). One renormalization +
        # compaction pass covers both kill sources.
        dead = new_log_r <= math.log(self.truncation)
        dead[0] = False
        dead &= np.isfinite(new_log_r)
        if dead.any():
            new_log_r[dead] = -np.inf
        cap = self.max_hypotheses
        if cap is not None and new_log_r.shape[0] > cap:
            k1 = new_log_r.shape[0]
            strength = np.max(new_log_r, axis=1)
            order = np.argsort(strength[1:], kind="stable")  # ascending
            kill = np.zeros((k1, b), dtype=bool)
            kill[order[: k1 - cap] + 1] = True
            kill &= np.isfinite(new_log_r)
            if kill.any():
                new_log_r[kill] = -np.inf
                dead |= kill
        self._log_r = self._kill(new_log_r, dead)
        return np.exp(self._log_r[0])

    def _kill(self, log_r: np.ndarray, dead: np.ndarray) -> np.ndarray:
        """Renormalize columns with ``dead`` (-inf-marked) entries and
        compact hypothesis rows that are dead in every column."""
        if not dead.any():
            return log_r
        cols = dead.any(axis=0)
        if cols.mean() > 0.5:
            # Most columns affected: renormalizing everything avoids the
            # fancy-index copies (a no-op ~0 shift for untouched columns).
            log_r -= _logsumexp_cols(log_r)
        else:
            log_r[:, cols] -= _logsumexp_cols(log_r[:, cols])
        alive = np.isfinite(log_r).any(axis=1)
        alive[0] = True
        if not alive.all():
            log_r = log_r[alive]
            self._mu = self._mu[alive]
            self._beta = self._beta[alive]
            self._kappa_row = self._kappa_row[alive]
            self._alpha_row = self._alpha_row[alive]
            self._rl = self._rl[alive]
        return log_r

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        """Adjust the change-point prior / shared frontier cap mid-stream
        (future updates only — run-length state carries over unchanged)."""
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses

    # -- detection statistics (vectorized analogues of BOCD's) ----------
    def p_recent_change(self, window: int = 2) -> np.ndarray:
        """Pr(r_t <= window | x_{1:t}) for every series, shape (B,)."""
        # _rl is strictly increasing, so the recent rows are a prefix: a
        # view slice, not a boolean-mask copy of the (K, B) posterior.
        j = int(np.searchsorted(self._rl, window, side="right"))
        if j == 0:
            return np.zeros(self.n_series)
        return np.exp(_logsumexp_cols(self._log_r[:j]))

    def map_runlength(self) -> np.ndarray:
        """MAP run length per series, shape (B,) ints."""
        return self._rl[np.argmax(self._log_r, axis=0)]

    # -- state capture (campaign fork/restore contract) ------------------
    def snapshot(self) -> dict:
        """Full posterior state as private copies (restore-many safe)."""
        return {
            "n_series": self.n_series,
            "hazard": self.hazard,
            "max_hypotheses": self.max_hypotheses,
            "mu0": self._mu0.copy(),
            "log_r": self._log_r.copy(),
            "mu": self._mu.copy(),
            "beta": self._beta.copy(),
            "kappa_row": self._kappa_row.copy(),
            "alpha_row": self._alpha_row.copy(),
            "rl": self._rl.copy(),
            "t": self._t,
        }

    def restore(self, snap: dict) -> None:
        """Reinstate a :meth:`snapshot` bit-exactly (copies again, so the
        same blob can seed any number of forks)."""
        self.n_series = snap["n_series"]
        self.hazard = snap["hazard"]
        self.max_hypotheses = snap["max_hypotheses"]
        self._mu0 = snap["mu0"].copy()
        self._log_r = snap["log_r"].copy()
        self._mu = snap["mu"].copy()
        self._beta = snap["beta"].copy()
        self._kappa_row = snap["kappa_row"].copy()
        self._alpha_row = snap["alpha_row"].copy()
        self._rl = snap["rl"].copy()
        self._t = snap["t"]


@dataclass(eq=False)
class _MultiGroup:
    """One cohort's slice of a :class:`MultiBOCD` frontier.

    ``cols`` are this group's column indices into the shared ``(K, B)``
    arrays; the group's live hypothesis rows are the prefix ``0..k-1`` (cells
    below are ``-inf``-posterior voids). ``rl`` is the group's row-constant
    run length, exactly as a standalone :class:`BatchedBOCD` would hold it;
    the row-constant ``kappa``/``alpha`` statistics are *derived* —
    ``kappa0 + (rl+1)`` steps of +1.0 / ``alpha0 + (rl+1)`` steps of +0.5 —
    and read from the owner's shared age ladders.

    ``cols`` is always a contiguous ascending range ``c0..c1-1``: absorb
    appends a fresh ``arange`` block, and column removal deletes columns
    from inside a group's own range while shifting every other group's
    block uniformly, which preserves contiguity. The range bounds are
    cached so the per-tick loops can slice (views) instead of fancy-index
    (copies).
    """

    cols: np.ndarray
    hazard: float
    cap: int | None
    k: int
    rl: np.ndarray
    #: updates this group has absorbed (the standalone batch's ``_t`` —
    #: warm replay plus fused ticks; export hands it back verbatim)
    t: int = 0
    c0: int = 0
    c1: int = 0

    def refresh_range(self) -> None:
        self.c0 = int(self.cols[0])
        self.c1 = int(self.cols[-1]) + 1
        if self.c1 - self.c0 != self.cols.size:
            raise AssertionError(
                "MultiBOCD group columns are no longer contiguous"
            )


class MultiBOCD:
    """Several independent :class:`BatchedBOCD` groups advanced in ONE fused
    per-tick pass (the multi-cohort screen, ROADMAP item 4 residual).

    :class:`repro.core.detector.FleetDetect` keeps one batch per cohort, so a
    churny fleet pays the fixed cost of every small numpy op once *per
    cohort* per tick. This class holds all cohorts in shared ``(K, B)`` cell
    arrays (``K`` = largest group frontier, ``B`` = total streams) and runs
    the expensive elementwise chains — the Student-t log-predictive, the
    posterior normalization, the Normal-Gamma statistics update — once per
    tick across every group.

    Bit-exactness contract: each group's posterior is **bit-identical** to
    what its standalone :class:`BatchedBOCD` would hold. This relies on
    three properties, each covered by the equivalence tests:

    * elementwise chains are applied per cell in the exact same operation
      order as :func:`_student_t_logpdf_rows` / :meth:`BatchedBOCD.update`,
      so every cell sees the same float sequence;
    * numpy's axis-0 reductions accumulate row-sequentially for matrices
      with ``>= 2`` columns, so void rows (``exp(-inf) == 0.0``) and column
      sub-slices reduce bit-identically to the per-cohort operands;
    * single-column operands take numpy's 1-D pairwise-summation path, which
      *does* reassociate under padding — so any reduction whose per-cohort
      equivalent ran on one column is recomputed on a contiguous
      ``(k, 1)`` copy of exactly the per-cohort shape.

    Groups enter via :meth:`absorb` (adopting a warmed standalone batch) and
    are driven through :class:`MultiGroupHandle`, which implements the
    per-cohort :class:`ScreeningBackend` surface minus ``update``.
    """

    def __init__(self) -> None:
        self.kappa0 = 1.0
        self.alpha0 = 1.0
        self.beta0 = 1.0
        self.truncation = 1e-6
        self.cp_threshold = DEFAULT_CP_THRESHOLD
        self._groups: list[_MultiGroup] = []
        self._log_r = np.zeros((0, 0))
        self._mu = np.zeros((0, 0))
        self._beta = np.zeros((0, 0))
        self._mu0 = np.zeros(0)
        #: per-cell hypothesis age: the number of +1.0 kappa / +0.5 alpha
        #: prior-update steps the cell's run-length hypothesis has absorbed
        #: (live rows: rl + 1, row-constant per group; void cells just keep
        #: counting — their posterior is -inf so the values are never read).
        #: Ages index the shared ladders below, turning the per-group
        #: row-to-cell scatter of kappa/alpha/const into three gathers.
        self._age = np.zeros((0, 0), dtype=np.int64)
        self._age_hi = 0
        self._kap_lad = np.array([self.kappa0])
        self._alp_lad = np.array([self.alpha0])
        self._const_lad = _gammaln((2.0 * self._alp_lad + 1.0) / 2.0) - \
            _gammaln(2.0 * self._alp_lad / 2.0)
        #: per-column log hazard / log(1-hazard), maintained on membership
        #: and retune instead of rebuilt every tick
        self._log_h = np.zeros(0)
        self._log_1mh = np.zeros(0)
        self._t = 0

    def _ensure_ladder(self, hi: int) -> None:
        """Extend the shared kappa/alpha/const ladders to cover age ``hi``.

        Values are chained incrementally (``+1.0`` / ``+0.5`` per step from
        the prior), the exact accumulation a per-tick row update performs,
        so a ladder read is bit-identical to the incrementally maintained
        row statistic it replaces.
        """
        n0 = self._kap_lad.size
        if n0 > hi:
            return
        kl = np.empty(hi + 1)
        al = np.empty(hi + 1)
        kl[:n0] = self._kap_lad
        al[:n0] = self._alp_lad
        for j in range(n0, hi + 1):
            kl[j] = kl[j - 1] + 1.0
            al[j] = al[j - 1] + 0.5
        cl = np.empty(hi + 1)
        cl[:n0] = self._const_lad
        df = 2.0 * al[n0:]
        cl[n0:] = _gammaln((df + 1.0) / 2.0) - _gammaln(df / 2.0)
        self._kap_lad, self._alp_lad, self._const_lad = kl, al, cl

    @property
    def n_series(self) -> int:
        return int(self._mu0.size)

    @property
    def n_groups(self) -> int:
        return len(self._groups)

    # -- membership ----------------------------------------------------
    def absorb(self, batch: BatchedBOCD) -> "MultiGroupHandle":
        """Adopt a warmed standalone batch as a new group; returns its
        handle. The batch's posterior state is copied verbatim — its columns
        append to the shared arrays, its rows land in the group's prefix."""
        if not isinstance(batch, BatchedBOCD):
            raise TypeError(f"MultiBOCD absorbs BatchedBOCD, got {type(batch)!r}")
        if self._groups:
            for name in ("kappa0", "alpha0", "beta0", "truncation",
                         "cp_threshold"):
                if getattr(batch, name) != getattr(self, name):
                    raise ValueError(
                        f"group {name}={getattr(batch, name)!r} differs from "
                        f"the shared frontier's {getattr(self, name)!r}"
                    )
        else:
            self.kappa0 = batch.kappa0
            self.alpha0 = batch.alpha0
            self.beta0 = batch.beta0
            self.truncation = batch.truncation
            self.cp_threshold = batch.cp_threshold
            self._kap_lad = np.array([self.kappa0])
            self._alp_lad = np.array([self.alpha0])
            self._const_lad = _gammaln((2.0 * self._alp_lad + 1.0) / 2.0) - \
                _gammaln(2.0 * self._alp_lad / 2.0)
        kb, bb = batch._log_r.shape
        b0 = self.n_series
        k_new = max(self._log_r.shape[0], kb)

        def _pad(a: np.ndarray, fill: float) -> np.ndarray:
            if a.shape[0] == k_new:
                return a
            out = np.full((k_new, a.shape[1]), fill)
            out[: a.shape[0]] = a
            return out

        self._log_r = np.hstack(
            [_pad(self._log_r, -np.inf), _pad(batch._log_r, -np.inf)]
        )
        # Void-cell stats only need to stay finite (their posterior is -inf
        # forever); pad with the prior.
        self._mu = np.hstack([_pad(self._mu, 0.0), _pad(batch._mu, 0.0)])
        self._beta = np.hstack(
            [_pad(self._beta, self.beta0), _pad(batch._beta, self.beta0)]
        )
        self._mu0 = np.concatenate([self._mu0, batch._mu0])
        # Row age = number of +1.0 kappa updates absorbed since the prior:
        # recovered exactly from the batch's kappa row (small-integer float
        # arithmetic — the original prior-seeded row has age == rl, rows
        # born by an update have age == rl + 1, so rl alone is ambiguous).
        ages = np.zeros((k_new, bb), dtype=np.int64)
        ages[:kb] = np.rint(
            batch._kappa_row - self.kappa0
        ).astype(np.int64)[:, None]
        age_pad = self._age
        if age_pad.shape[0] != k_new:
            grown = np.zeros((k_new, age_pad.shape[1]), dtype=np.int64)
            grown[: age_pad.shape[0]] = age_pad
            age_pad = grown
        self._age = np.hstack([age_pad, ages])
        hi = int(ages[:kb].max()) if kb else 0
        self._age_hi = max(self._age_hi, hi)
        self._ensure_ladder(self._age_hi)
        self._log_h = np.concatenate(
            [self._log_h, np.full(bb, math.log(batch.hazard))]
        )
        self._log_1mh = np.concatenate(
            [self._log_1mh, np.full(bb, math.log1p(-batch.hazard))]
        )
        grp = _MultiGroup(
            cols=np.arange(b0, b0 + bb, dtype=np.int64),
            hazard=batch.hazard,
            cap=batch.max_hypotheses,
            k=kb,
            rl=batch._rl.copy(),
            t=batch._t,
        )
        grp.refresh_range()
        self._groups.append(grp)
        return MultiGroupHandle(self, grp)

    def export(self, grp: _MultiGroup) -> BatchedBOCD:
        """Materialize one group back into a standalone batch (bit-equal
        state; used for consolidation rebuilds and equivalence tests)."""
        out = BatchedBOCD(
            grp.cols.size,
            hazard=grp.hazard,
            mu0=self._mu0[grp.cols],
            kappa0=self.kappa0,
            alpha0=self.alpha0,
            beta0=self.beta0,
            cp_threshold=self.cp_threshold,
            truncation=self.truncation,
            max_hypotheses=grp.cap,
        )
        # ascontiguousarray: the shared arrays are C-ordered but wider than
        # the group, and numpy's reduction path (row-sequential vs 1-D
        # pairwise) depends on layout — a standalone batch's arrays are
        # compact C-ordered.
        out._log_r = np.ascontiguousarray(self._log_r[: grp.k, grp.c0:grp.c1])
        out._mu = np.ascontiguousarray(self._mu[: grp.k, grp.c0:grp.c1])
        out._beta = np.ascontiguousarray(self._beta[: grp.k, grp.c0:grp.c1])
        # Row-constant kappa/alpha reconstruct from the age ladders (the
        # ladder is the same +1.0/+0.5 accumulation chain, so the values
        # are bit-identical to an incrementally maintained row).
        ages = self._age[: grp.k, grp.c0]
        out._kappa_row = self._kap_lad[ages]
        out._alpha_row = self._alp_lad[ages]
        out._rl = grp.rl.copy()
        out._t = grp.t
        return out

    def take_group_columns(self, grp: _MultiGroup, idx: np.ndarray) -> None:
        """Per-group :meth:`BatchedBOCD.take_columns`: keep the group's
        local columns ``idx``, drop the rest from the shared arrays, then
        compact the group's rows exactly like the standalone would."""
        idx = np.asarray(idx, dtype=np.int64)
        kept = grp.cols[idx]
        removed = np.setdiff1d(grp.cols, kept)
        if removed.size:
            mask = np.ones(self.n_series, dtype=bool)
            mask[removed] = False
            self._log_r = self._log_r[:, mask]
            self._mu = self._mu[:, mask]
            self._beta = self._beta[:, mask]
            self._age = self._age[:, mask]
            self._mu0 = self._mu0[mask]
            self._log_h = self._log_h[mask]
            self._log_1mh = self._log_1mh[mask]
            remap = np.cumsum(mask) - 1
            for g in self._groups:
                if g is grp:
                    continue
                g.cols = remap[g.cols]
                g.refresh_range()
            grp.cols = remap[kept]
        if grp.cols.size == 0:
            self._groups.remove(grp)
            self._shrink()
            return
        grp.refresh_range()
        sub = self._log_r[: grp.k, grp.c0:grp.c1]
        alive = np.isfinite(sub).any(axis=1)
        if alive.size:
            alive[0] = True
        if not alive.all():
            self._pack_group(grp, np.flatnonzero(alive), grp.k)
        self._shrink()

    def retune_group(
        self,
        grp: _MultiGroup,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        if hazard is not None:
            grp.hazard = hazard
            self._log_h[grp.c0:grp.c1] = math.log(hazard)
            self._log_1mh[grp.c0:grp.c1] = math.log1p(-hazard)
        if max_hypotheses is not None:
            grp.cap = max_hypotheses

    # -- fused tick ----------------------------------------------------
    def update(self, x: np.ndarray) -> None:
        """Feed one observation per stream; advances every group at once."""
        b = self.n_series
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (b,):
            raise ValueError(f"expected shape ({b},), got {x.shape}")
        groups = self._groups
        if not groups:
            return
        k = self._log_r.shape[0]
        # Per-cell kappa/alpha/const via one ladder gather each (ages are
        # row-constant per group on live cells; void cells keep counting but
        # their posterior is -inf, so only finiteness matters there). The
        # (k+1)-tall age array built here doubles as the post-update ages.
        self._age_hi += 1
        self._ensure_ladder(self._age_hi)
        age_all = np.empty((k + 1, b), dtype=np.int64)
        age_all[0] = 0
        age_all[1:] = self._age
        kappa_all = self._kap_lad[age_all]
        kappa = kappa_all[1:]
        alpha = self._alp_lad[self._age]
        const = self._const_lad[self._age]

        # Student-t log-predictive: the exact _student_t_logpdf_rows chain,
        # with the row-constant factors materialized per cell (same floats,
        # same op order -> bit-identical).
        df = 2.0 * alpha
        scale2 = self._beta * (kappa + 1.0)
        scale2 /= alpha * kappa
        z2 = x - self._mu
        np.multiply(z2, z2, out=z2)
        z2 /= scale2
        z2 /= df
        np.log1p(z2, out=z2)
        z2 *= (df + 1.0) / 2.0
        scale2 *= np.pi * df
        np.log(scale2, out=scale2)
        scale2 *= 0.5
        np.subtract(const, scale2, out=scale2)
        scale2 -= z2
        log_growth = scale2
        log_growth += self._log_r
        log_growth += self._log_1mh
        log_cp = _student_t_logpdf(
            x, self._mu0, np.float64(self.kappa0), np.float64(self.alpha0),
            np.float64(self.beta0),
        )
        log_cp = log_cp + self._log_h

        new_log_r = np.empty((k + 1, b))
        new_log_r[0] = log_cp
        new_log_r[1:] = log_growth
        norm = _logsumexp_cols(new_log_r)
        for g in groups:
            # Single-column groups normalize over a contiguous (k+1, 1)
            # array in the standalone batch, which numpy reduces on the 1-D
            # pairwise path — recompute on the per-cohort shape.
            if g.c1 - g.c0 == 1:
                norm[g.c0] = _logsumexp_cols(
                    np.ascontiguousarray(new_log_r[: g.k + 1, g.c0:g.c1])
                )[0]
        new_log_r -= norm

        mu_all = np.empty((k + 1, b))
        mu_all[0] = self._mu0
        mu_all[1:] = self._mu
        beta_all = np.empty((k + 1, b))
        beta_all[0] = self.beta0
        beta_all[1:] = self._beta
        denom = kappa_all + 1.0
        upd = x - mu_all
        np.multiply(upd, upd, out=upd)
        upd *= 0.5 * kappa_all
        upd /= denom
        beta_all += upd
        mu_all *= kappa_all
        mu_all += x
        mu_all /= denom
        age_all += 1
        self._age = age_all
        for g in groups:
            rl = np.empty(g.k + 1, dtype=np.int64)
            rl[0] = 0
            rl[1:] = g.rl
            rl[1:] += 1
            g.rl = rl
            g.k += 1
            g.t += 1
        self._t += 1

        # Per-column truncation (global: void cells are excluded by the
        # isfinite mask) ...
        dead = new_log_r <= math.log(self.truncation)
        dead[0] = False
        dead &= np.isfinite(new_log_r)
        if dead.any():
            new_log_r[dead] = -np.inf
        # ... the shared frontier cap, per group (contiguous column ranges:
        # slices are views, so the kill writes through) ...
        for g in groups:
            cap = g.cap
            k1 = g.k
            if cap is None or k1 <= cap:
                continue
            sub = new_log_r[:k1, g.c0:g.c1]
            strength = np.max(sub, axis=1)
            order = np.argsort(strength[1:], kind="stable")  # ascending
            kill = np.zeros((k1, g.c1 - g.c0), dtype=bool)
            kill[order[: k1 - cap] + 1] = True
            kill &= np.isfinite(sub)
            if kill.any():
                sub[kill] = -np.inf
                dead[:k1, g.c0:g.c1] |= kill
        # ... and one renormalize + compact pass per affected group, on
        # operands shaped exactly like the standalone batch's.
        for g in groups:
            k1 = g.k
            gdead = dead[:k1, g.c0:g.c1]
            if not gdead.any():
                continue
            cols_aff = gdead.any(axis=0)
            # Memory layout decides numpy's reduction path, so each branch
            # mirrors the standalone operand's layout exactly: the >0.5
            # branch renormalizes the full C-ordered posterior, the other
            # renormalizes an F-ordered axis-1 fancy copy.
            if cols_aff.mean() > 0.5:
                operand = np.ascontiguousarray(new_log_r[:k1, g.c0:g.c1])
                gnorm = _logsumexp_cols(operand)
                new_log_r[:k1, g.c0:g.c1] -= gnorm
            else:
                sel = g.cols[cols_aff]
                operand = new_log_r[:k1][:, sel]
                gnorm = _logsumexp_cols(operand)
                new_log_r[np.arange(k1)[:, None], sel[None, :]] -= gnorm
            alive = np.isfinite(new_log_r[:k1, g.c0:g.c1]).any(axis=1)
            alive[0] = True
            if not alive.all():
                self._pack_group(
                    grp=g, rows=np.flatnonzero(alive), k1=k1,
                    log_r=new_log_r, mu=mu_all, beta=beta_all,
                )
        k_max = max(g.k for g in groups)
        self._log_r = new_log_r[:k_max]
        self._mu = mu_all[:k_max]
        self._beta = beta_all[:k_max]
        if self._age.shape[0] > k_max:
            self._age = self._age[:k_max]

    def _pack_group(
        self,
        grp: _MultiGroup,
        rows: np.ndarray,
        k1: int,
        log_r: np.ndarray | None = None,
        mu: np.ndarray | None = None,
        beta: np.ndarray | None = None,
    ) -> None:
        """Compact ``grp``'s live rows to the prefix, voiding the tail."""
        log_r = self._log_r if log_r is None else log_r
        mu = self._mu if mu is None else mu
        beta = self._beta if beta is None else beta
        m = rows.size
        c0, c1 = grp.c0, grp.c1
        for arr in (log_r, mu, beta, self._age):
            arr[:m, c0:c1] = arr[rows, c0:c1]
        log_r[m:k1, c0:c1] = -np.inf
        grp.rl = grp.rl[rows]
        grp.k = m

    def _shrink(self) -> None:
        if not self._groups:
            self._log_r = np.zeros((0, self.n_series))
            self._mu = np.zeros((0, self.n_series))
            self._beta = np.zeros((0, self.n_series))
            self._age = np.zeros((0, self.n_series), dtype=np.int64)
            return
        k_max = max(g.k for g in self._groups)
        if k_max < self._log_r.shape[0]:
            self._log_r = self._log_r[:k_max]
            self._mu = self._mu[:k_max]
            self._beta = self._beta[:k_max]
            self._age = self._age[:k_max]

    # -- per-group detection statistics --------------------------------
    def p_recent_group(self, grp: _MultiGroup, window: int = 2) -> np.ndarray:
        j = int(np.searchsorted(grp.rl, window, side="right"))
        if j == 0:
            return np.zeros(grp.cols.size)
        return np.exp(
            _logsumexp_cols(
                np.ascontiguousarray(self._log_r[:j, grp.c0:grp.c1])
            )
        )

    def map_runlength_group(self, grp: _MultiGroup) -> np.ndarray:
        return grp.rl[
            np.argmax(self._log_r[: grp.k, grp.c0:grp.c1], axis=0)
        ]

    # -- state capture (campaign fork/restore contract) ------------------
    def snapshot(self) -> dict:
        """Full fused-frontier state as private copies. Group order is
        preserved, so a caller holding per-group handles can re-associate
        them by index after :meth:`restore`."""
        return {
            "params": (self.kappa0, self.alpha0, self.beta0,
                       self.truncation, self.cp_threshold),
            "log_r": self._log_r.copy(),
            "mu": self._mu.copy(),
            "beta": self._beta.copy(),
            "mu0": self._mu0.copy(),
            "age": self._age.copy(),
            "age_hi": self._age_hi,
            "t": self._t,
            "groups": [
                {
                    "cols": g.cols.copy(),
                    "hazard": g.hazard,
                    "cap": g.cap,
                    "k": g.k,
                    "rl": g.rl.copy(),
                    "t": g.t,
                }
                for g in self._groups
            ],
        }

    def restore(self, snap: dict) -> None:
        """Reinstate a :meth:`snapshot` bit-exactly. The age ladders are
        pure functions of the priors and only ever extend, so the current
        (possibly longer) ladders are kept. Existing group objects are
        replaced — callers must rebind handles via ``_groups`` order."""
        (self.kappa0, self.alpha0, self.beta0,
         self.truncation, self.cp_threshold) = snap["params"]
        self._log_r = snap["log_r"].copy()
        self._mu = snap["mu"].copy()
        self._beta = snap["beta"].copy()
        self._mu0 = snap["mu0"].copy()
        self._age = snap["age"].copy()
        self._age_hi = snap["age_hi"]
        if (self._kap_lad[0] != self.kappa0
                or self._alp_lad[0] != self.alpha0):
            self._kap_lad = np.array([self.kappa0])
            self._alp_lad = np.array([self.alpha0])
            self._const_lad = _gammaln((2.0 * self._alp_lad + 1.0) / 2.0) - \
                _gammaln(2.0 * self._alp_lad / 2.0)
        self._ensure_ladder(self._age_hi)
        self._t = snap["t"]
        self._groups = []
        for g in snap["groups"]:
            grp = _MultiGroup(
                cols=g["cols"].copy(), hazard=g["hazard"], cap=g["cap"],
                k=g["k"], rl=g["rl"].copy(), t=g["t"],
            )
            grp.refresh_range()
            self._groups.append(grp)
        b = self.n_series
        self._log_h = np.empty(b)
        self._log_1mh = np.empty(b)
        for grp in self._groups:
            self._log_h[grp.c0:grp.c1] = math.log(grp.hazard)
            self._log_1mh[grp.c0:grp.c1] = math.log1p(-grp.hazard)


class MultiGroupHandle:
    """Per-cohort :class:`ScreeningBackend` facade over one
    :class:`MultiBOCD` group — everything except ``update`` (observations
    flow through the owner's fused :meth:`MultiBOCD.update`)."""

    def __init__(self, multi: MultiBOCD, grp: _MultiGroup) -> None:
        self.multi = multi
        self.group = grp

    @property
    def n_series(self) -> int:
        return int(self.group.cols.size)

    @property
    def cols(self) -> np.ndarray:
        """This group's column indices into the fused input vector."""
        return self.group.cols

    @property
    def hazard(self) -> float:
        return self.group.hazard

    @property
    def max_hypotheses(self) -> int | None:
        return self.group.cap

    def update(self, x: np.ndarray) -> np.ndarray:
        raise RuntimeError(
            "MultiGroupHandle does not update per group; feed the fused "
            "MultiBOCD.update once per tick"
        )

    def p_recent_change(self, window: int = 2) -> np.ndarray:
        return self.multi.p_recent_group(self.group, window)

    def map_runlength(self) -> np.ndarray:
        return self.multi.map_runlength_group(self.group)

    def take_columns(self, idx: np.ndarray) -> None:
        self.multi.take_group_columns(self.group, idx)

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        self.multi.retune_group(
            self.group, hazard=hazard, max_hypotheses=max_hypotheses
        )

    def export(self) -> BatchedBOCD:
        return self.multi.export(self.group)


def noise_scale(series: np.ndarray) -> float:
    """Robust per-step noise estimate: MAD of first differences.

    First differences cancel slow level drift, so this measures *jitter*;
    BOCD observations are standardized by it, making the detector sensitive
    to any statistically significant level shift regardless of its relative
    size (the 10 % relevance filter is the separate verification step).
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size < 3:
        return max(float(np.median(np.abs(x))) * 1e-2, 1e-9)
    d = np.diff(x)
    mad = float(np.median(np.abs(d - np.median(d))))
    sigma = 1.4826 * mad / np.sqrt(2.0)
    floor = max(float(np.median(np.abs(x))) * 1e-3, 1e-9)
    return max(sigma, floor)


def noise_scale_batch(series: np.ndarray) -> np.ndarray:
    """Column-wise :func:`noise_scale` over a ``(T, B)`` matrix, shape (B,)."""
    x = np.asarray(series, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("expected a (T, B) matrix")
    absmed = np.median(np.abs(x), axis=0)
    if x.shape[0] < 3:
        return np.maximum(absmed * 1e-2, 1e-9)
    d = np.diff(x, axis=0)
    mad = np.median(np.abs(d - np.median(d, axis=0)), axis=0)
    sigma = 1.4826 * mad / np.sqrt(2.0)
    floor = np.maximum(absmed * 1e-3, 1e-9)
    return np.maximum(sigma, floor)


def detect_change_points(
    series: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = DEFAULT_CP_THRESHOLD,
    min_gap: int = 3,
    recent_window: int = 2,
) -> list[int]:
    """Run BOCD over ``series``; return change-point indices.

    A change is reported at index ``i - map_runlength`` whenever the
    posterior probability of a change within the last ``recent_window``
    observations exceeds ``cp_threshold`` (paper: likelihood of r_t = 0
    above 0.9 — evaluated over a tiny window so the single-step hazard
    factor does not suppress genuine onsets). ``min_gap`` merges the burst
    of detections that one physical change produces.
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size == 0:
        return []
    scale = noise_scale(x)
    det = BOCD(
        hazard=hazard,
        mu0=float(x[0] / scale),
        kappa0=1.0,
        alpha0=1.0,
        beta0=1.0,
        cp_threshold=cp_threshold,
    )
    out: list[int] = []
    for i, xi in enumerate(x):
        det.update(float(xi / scale))
        if i <= recent_window:  # p_recent is trivially 1 in the first steps
            continue
        if det.p_recent_change(recent_window) > cp_threshold:
            idx = i - det.map_runlength()
            if idx > 0 and (not out or idx - out[-1] >= min_gap):
                out.append(idx)
    return out


def detect_change_points_batch(
    series: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = DEFAULT_CP_THRESHOLD,
    min_gap: int = 3,
    recent_window: int = 2,
) -> list[list[int]]:
    """Batched :func:`detect_change_points` over a ``(T, B)`` matrix.

    Returns one change-point index list per column, matching what the scalar
    routine reports on that column alone.
    """
    x = np.asarray(series, dtype=np.float64)
    if x.ndim != 2:
        raise ValueError("expected a (T, B) matrix")
    t_steps, b = x.shape
    out: list[list[int]] = [[] for _ in range(b)]
    if t_steps == 0 or b == 0:
        return out
    scale = noise_scale_batch(x)
    det = BatchedBOCD(
        b, hazard=hazard, mu0=x[0] / scale, cp_threshold=cp_threshold
    )
    xs = x / scale
    for i in range(t_steps):
        det.update(xs[i])
        if i <= recent_window:
            continue
        flagged = np.flatnonzero(det.p_recent_change(recent_window) > cp_threshold)
        if flagged.size == 0:
            continue
        run_lengths = det.map_runlength()
        for col in flagged:
            idx = i - int(run_lengths[col])
            dst = out[col]
            if idx > 0 and (not dst or idx - dst[-1] >= min_gap):
                dst.append(idx)
    return out


def _student_t_logpdf(
    x: float | np.ndarray,
    mu: np.ndarray,
    kappa: np.ndarray,
    alpha: np.ndarray,
    beta: np.ndarray,
) -> np.ndarray:
    """Posterior-predictive Student-t of the Normal-Gamma model.

    Broadcasts over any leading hypothesis/batch axes: scalar ``x`` against
    1-D stats (scalar BOCD) or ``(B,)`` observations against ``(K, B)``
    stats (batched BOCD).
    """
    df = 2.0 * alpha
    scale2 = beta * (kappa + 1.0) / (alpha * kappa)
    z2 = (x - mu) ** 2 / scale2
    return (
        _gammaln((df + 1.0) / 2.0)
        - _gammaln(df / 2.0)
        - 0.5 * np.log(np.pi * df * scale2)
        - (df + 1.0) / 2.0 * np.log1p(z2 / df)
    )


def _student_t_logpdf_rows(
    x: np.ndarray,
    mu: np.ndarray,
    kappa_row: np.ndarray,
    alpha_row: np.ndarray,
    beta: np.ndarray,
) -> np.ndarray:
    """:func:`_student_t_logpdf` with row-constant kappa/alpha ``(K,)``
    against ``(K, B)`` mu/beta — the gammaln terms collapse to O(K). Applies
    the exact same per-element operation chain, so results are bit-identical
    to the generic version."""
    df = 2.0 * alpha_row
    const = _gammaln((df + 1.0) / 2.0) - _gammaln(df / 2.0)
    scale2 = beta * (kappa_row + 1.0)[:, None]
    scale2 /= (alpha_row * kappa_row)[:, None]
    z2 = x - mu
    np.multiply(z2, z2, out=z2)
    z2 /= scale2
    z2 /= df[:, None]
    np.log1p(z2, out=z2)
    z2 *= ((df + 1.0) / 2.0)[:, None]
    scale2 *= (np.pi * df)[:, None]
    np.log(scale2, out=scale2)
    scale2 *= 0.5
    np.subtract(const[:, None], scale2, out=scale2)
    scale2 -= z2
    return scale2


def _logsumexp(a: np.ndarray) -> float:
    m = float(np.max(a))
    if math.isinf(m):
        return m
    return m + math.log(float(np.sum(np.exp(a - m))))


def _logsumexp_cols(a: np.ndarray) -> np.ndarray:
    """Column-wise logsumexp of a (K, B) matrix; all ``-inf`` columns -> -inf."""
    m = np.max(a, axis=0)
    shift = np.where(np.isfinite(m), m, 0.0)
    with np.errstate(divide="ignore"):
        return np.log(np.sum(np.exp(a - shift), axis=0)) + shift


try:  # scipy is available in this environment; keep a pure fallback anyway.
    from scipy.special import gammaln as _gammaln
except ImportError:  # pragma: no cover
    def _gammaln(x):
        return np.vectorize(math.lgamma)(x)


# ----------------------------------------------------------------------
# Screening backends (docs/kernels.md)
#
# The fleet screening loop talks to an *instance* implementing the batched
# interface below; FleetDetect / ControlPlane select a backend *factory*
# (scalar / batched-numpy / pallas) instead of hard-wiring BatchedBOCD, so
# implementations stay interchangeable and equivalence-tested from one
# registry.
# ----------------------------------------------------------------------

@runtime_checkable
class ScreeningBackend(Protocol):
    """Batched run-length screening state over ``n_series`` streams.

    The contract (shapes per :class:`BatchedBOCD`, the reference semantics):
    ``update(x)`` consumes one observation per stream and returns
    ``Pr(r_t = 0)`` per stream; ``p_recent_change``/``map_runlength`` report
    posterior statistics; ``take_columns`` sub-slices streams on membership
    churn; ``retune`` adjusts hazard / frontier cap for future updates.
    """

    n_series: int

    def update(self, x: np.ndarray) -> np.ndarray: ...
    def p_recent_change(self, window: int = 2) -> np.ndarray: ...
    def map_runlength(self) -> np.ndarray: ...
    def take_columns(self, idx: np.ndarray) -> None: ...
    def retune(self, hazard: float | None = None,
               max_hypotheses: int | None = None) -> None: ...


class ScalarFanout:
    """B independent scalar :class:`BOCD` detectors behind the batched
    screening interface — the per-column oracle as "just another backend".

    O(B) Python-loop cost per tick; useful for tiny fleets and as the
    ground truth the vectorized/Pallas backends are equivalence-tested
    against (per column it *is* the scalar recursion, bit for bit).
    """

    def __init__(
        self,
        n_series: int,
        hazard: float = 1.0 / 100.0,
        mu0: float | np.ndarray = 0.0,
        kappa0: float = 1.0,
        alpha0: float = 1.0,
        beta0: float = 1.0,
        cp_threshold: float = DEFAULT_CP_THRESHOLD,
        truncation: float = 1e-6,
        max_hypotheses: int | None = None,
    ) -> None:
        b = int(n_series)
        mu0 = np.broadcast_to(np.asarray(mu0, dtype=np.float64), (b,))
        self.n_series = b
        self.hazard = hazard
        self.cp_threshold = cp_threshold
        self.max_hypotheses = max_hypotheses
        self._dets = [
            BOCD(
                hazard=hazard, mu0=float(m), kappa0=kappa0, alpha0=alpha0,
                beta0=beta0, cp_threshold=cp_threshold, truncation=truncation,
                max_hypotheses=max_hypotheses,
            )
            for m in mu0
        ]

    def update(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.n_series,):
            raise ValueError(f"expected shape ({self.n_series},), got {x.shape}")
        return np.fromiter(
            (d.update(float(xi)) for d, xi in zip(self._dets, x)),
            dtype=np.float64, count=self.n_series,
        )

    def p_recent_change(self, window: int = 2) -> np.ndarray:
        return np.fromiter(
            (d.p_recent_change(window) for d in self._dets),
            dtype=np.float64, count=self.n_series,
        )

    def map_runlength(self) -> np.ndarray:
        return np.fromiter(
            (d.map_runlength() for d in self._dets),
            dtype=np.int64, count=self.n_series,
        )

    def take_columns(self, idx: np.ndarray) -> None:
        idx = np.asarray(idx, dtype=np.int64)
        self._dets = [self._dets[int(i)] for i in idx]
        self.n_series = int(idx.size)

    def retune(
        self,
        hazard: float | None = None,
        max_hypotheses: int | None = None,
    ) -> None:
        if hazard is not None:
            self.hazard = hazard
        if max_hypotheses is not None:
            self.max_hypotheses = max_hypotheses
        for d in self._dets:
            d.retune(hazard=hazard, max_hypotheses=max_hypotheses)


class ScreeningBackendFactory:
    """Constructs :class:`ScreeningBackend` instances.

    The screening layer creates backend state dynamically (one instance per
    warmed cohort, sized to the cohort and seeded with its per-stream
    ``mu0``), so the pluggable unit is a *factory*, not an instance.
    """

    name = "abstract"

    def make(self, n_series: int, **kwargs) -> ScreeningBackend:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<ScreeningBackendFactory {self.name!r}>"


class ScalarScreening(ScreeningBackendFactory):
    name = "scalar"

    def make(self, n_series: int, **kwargs) -> ScalarFanout:
        return ScalarFanout(n_series, **kwargs)


class BatchedScreening(ScreeningBackendFactory):
    name = "batched"

    def make(self, n_series: int, **kwargs) -> BatchedBOCD:
        return BatchedBOCD(n_series, **kwargs)


class PallasScreening(ScreeningBackendFactory):
    """Fused Pallas step kernel (``repro.kernels.bocd_step.PallasBOCD``).

    ``interpret``/``dtype`` override the kernel defaults (interpret mode is
    auto-enabled on CPU jax; dtype defaults to float32 — see
    docs/kernels.md for the tolerance policy).
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, dtype=None) -> None:
        self.interpret = interpret
        self.dtype = dtype

    def make(self, n_series: int, **kwargs):
        from repro.kernels.bocd_step import PallasBOCD

        if self.interpret is not None:
            kwargs.setdefault("interpret", self.interpret)
        if self.dtype is not None:
            kwargs.setdefault("dtype", self.dtype)
        return PallasBOCD(n_series, **kwargs)


#: Registry enumerated by the backend-equivalence tests; ``numpy`` is an
#: alias for the vectorized numpy implementation.
SCREENING_BACKENDS: dict[str, ScreeningBackendFactory] = {
    "scalar": ScalarScreening(),
    "batched": BatchedScreening(),
    "pallas": PallasScreening(),
}
SCREENING_BACKENDS["numpy"] = SCREENING_BACKENDS["batched"]


def pallas_is_compiled() -> bool:
    """True when jax will *compile* Pallas kernels (non-CPU backend).

    On this container's CPU jax, Pallas runs in interpret mode — correct
    but slow, so auto-selection prefers the vectorized numpy backend there
    and only tests/CI opt into ``pallas`` explicitly.
    """
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - jax always present here
        return False


def select_backend(name: str | None = None) -> ScreeningBackendFactory:
    """Resolve a screening backend by name.

    ``None``/``"auto"`` auto-detects: Pallas where jax compiles it (GPU/TPU),
    the vectorized numpy ``batched`` backend everywhere else.
    """
    if name is None or name == "auto":
        return SCREENING_BACKENDS["pallas" if pallas_is_compiled() else "batched"]
    try:
        return SCREENING_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown screening backend {name!r}; "
            f"registered: {sorted(SCREENING_BACKENDS)}"
        ) from None


class _ClassShim(ScreeningBackendFactory):
    """Deprecation shim: wraps a backend *class* passed where a factory
    instance is now expected (the pre-backend-API constructor style)."""

    def __init__(self, cls: type) -> None:
        self._cls = cls
        self.name = getattr(cls, "__name__", "class")

    def make(self, n_series: int, **kwargs) -> ScreeningBackend:
        return self._cls(n_series, **kwargs)


def resolve_screening_backend(spec) -> ScreeningBackendFactory:
    """Accept a backend name, ``None``/``"auto"``, a factory instance, or
    (deprecated, with a warning) a backend class such as ``BatchedBOCD``."""
    if spec is None or isinstance(spec, str):
        return select_backend(spec)
    if isinstance(spec, type):
        warnings.warn(
            "passing a screening backend class is deprecated; pass a "
            "ScreeningBackendFactory instance or a registry name "
            f"(e.g. {sorted(set(SCREENING_BACKENDS))!r})",
            DeprecationWarning,
            stacklevel=2,
        )
        if spec is BatchedBOCD:
            return SCREENING_BACKENDS["batched"]
        if spec is BOCD:
            return SCREENING_BACKENDS["scalar"]
        return _ClassShim(spec)
    if isinstance(spec, ScreeningBackendFactory) or hasattr(spec, "make"):
        return spec
    raise TypeError(
        f"screening backend must be a name, factory, or class; got {spec!r}"
    )
