"""Bayesian Online Change-point Detection (paper §4.2 + Appendix 9.1).

Implements the Adams/MacKay-style run-length recursion the paper uses
(eqs. 2-5): at each step maintain the run-length posterior Pr(r_t | x_{1:t}),
with a Normal-Gamma underlying probabilistic model (Student-t predictive) and
a constant-hazard change-point prior. A timestamp t is reported as a
change-point when Pr(r_t = 0 | x_{1:t}) exceeds a threshold (0.9 in the
paper's experiments). Time and memory are kept linear by truncating
negligible run-length mass.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

DEFAULT_CP_THRESHOLD = 0.9


@dataclass
class BOCD:
    """Online change-point detector over a scalar series (iteration times).

    Parameters mirror the standard Normal-Gamma conjugate prior:
      mu0/kappa0: prior mean and its pseudo-count,
      alpha0/beta0: precision-Gamma shape/rate,
      hazard: constant change-point hazard rate 1/expected-run-length.
    """

    hazard: float = 1.0 / 100.0
    mu0: float = 0.0
    kappa0: float = 1.0
    alpha0: float = 1.0
    beta0: float = 1.0
    cp_threshold: float = DEFAULT_CP_THRESHOLD
    truncation: float = 1e-6

    # --- state (run-length posterior and per-run sufficient statistics) ---
    _log_r: np.ndarray = field(default_factory=lambda: np.array([0.0]))
    _mu: np.ndarray = field(init=False)
    _kappa: np.ndarray = field(init=False)
    _alpha: np.ndarray = field(init=False)
    _beta: np.ndarray = field(init=False)
    _t: int = 0

    _rl: np.ndarray = field(init=False)

    def __post_init__(self) -> None:
        self._mu = np.array([self.mu0])
        self._kappa = np.array([self.kappa0])
        self._alpha = np.array([self.alpha0])
        self._beta = np.array([self.beta0])
        self._rl = np.array([0])

    # ------------------------------------------------------------------
    def _log_pred(self, x: float) -> np.ndarray:
        """Student-t log predictive for each current run-length hypothesis."""
        return _student_t_logpdf(x, self._mu, self._kappa, self._alpha, self._beta)

    def _log_prior_pred(self, x: float) -> float:
        """Student-t log predictive under the (fresh-segment) prior."""
        return float(
            _student_t_logpdf(
                x,
                np.array([self.mu0]),
                np.array([self.kappa0]),
                np.array([self.alpha0]),
                np.array([self.beta0]),
            )[0]
        )

    def update(self, x: float) -> float:
        """Feed one observation; return Pr(r_t = 0 | x_{1:t}).

        Convention: ``r_t = 0`` means x_t is the *first* observation of a new
        segment, so the change-point path scores x_t under the **prior**
        predictive while growth paths score it under each run's posterior
        predictive. (In the alternative Adams-MacKay message convention the
        CP path reuses the old run's predictive and Pr(r_t=0) degenerates to
        the hazard whenever predictives coincide — useless for the paper's
        "probability > 0.9" detection rule.)
        """
        log_pred = self._log_pred(x)
        log_h = math.log(self.hazard)
        log_1mh = math.log1p(-self.hazard)

        # Growth probabilities: run continues (r -> r+1).
        log_growth = self._log_r + log_pred + log_1mh
        # Change-point: new segment begins at t; x_t scored under the prior.
        log_cp = self._log_prior_pred(x) + log_h  # sum_r P(r) = 1 (normalized)

        new_log_r = np.empty(log_growth.size + 1)
        new_log_r[0] = log_cp
        new_log_r[1:] = log_growth
        new_log_r -= _logsumexp(new_log_r)

        # Update sufficient statistics for each run-length hypothesis; the
        # new r=0 hypothesis is the prior updated with x_t.
        mu_all = np.concatenate(([self.mu0], self._mu))
        kappa_all = np.concatenate(([self.kappa0], self._kappa))
        alpha_all = np.concatenate(([self.alpha0], self._alpha))
        beta_all = np.concatenate(([self.beta0], self._beta))
        self._mu = (kappa_all * mu_all + x) / (kappa_all + 1.0)
        self._beta = beta_all + 0.5 * kappa_all * (x - mu_all) ** 2 / (
            kappa_all + 1.0
        )
        self._kappa = kappa_all + 1.0
        self._alpha = alpha_all + 0.5
        self._rl = np.concatenate(([0], self._rl + 1))
        self._log_r = new_log_r
        self._t += 1

        # Truncate negligible run-length mass -> linear time overall (R2).
        keep = self._log_r > math.log(self.truncation)
        keep[0] = True
        if not keep.all():
            self._log_r = self._log_r[keep]
            self._log_r -= _logsumexp(self._log_r)
            self._mu = self._mu[keep]
            self._kappa = self._kappa[keep]
            self._alpha = self._alpha[keep]
            self._beta = self._beta[keep]
            self._rl = self._rl[keep]
        return float(math.exp(self._log_r[0]))

    # -- detection statistics ------------------------------------------
    def p_recent_change(self, window: int = 2) -> float:
        """Posterior probability that a change-point occurred within the
        last ``window`` observations: Pr(r_t <= window | x_{1:t})."""
        mask = self._rl <= window
        if not mask.any():
            return 0.0
        return float(np.exp(_logsumexp(self._log_r[mask])))

    def map_runlength(self) -> int:
        """MAP run length (distance back to the most likely change-point)."""
        return int(self._rl[int(np.argmax(self._log_r))])


def noise_scale(series: np.ndarray) -> float:
    """Robust per-step noise estimate: MAD of first differences.

    First differences cancel slow level drift, so this measures *jitter*;
    BOCD observations are standardized by it, making the detector sensitive
    to any statistically significant level shift regardless of its relative
    size (the 10 % relevance filter is the separate verification step).
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size < 3:
        return max(float(np.median(np.abs(x))) * 1e-2, 1e-9)
    d = np.diff(x)
    mad = float(np.median(np.abs(d - np.median(d))))
    sigma = 1.4826 * mad / np.sqrt(2.0)
    floor = max(float(np.median(np.abs(x))) * 1e-3, 1e-9)
    return max(sigma, floor)


def detect_change_points(
    series: np.ndarray,
    hazard: float = 1.0 / 100.0,
    cp_threshold: float = DEFAULT_CP_THRESHOLD,
    min_gap: int = 3,
    recent_window: int = 2,
) -> list[int]:
    """Run BOCD over ``series``; return change-point indices.

    A change is reported at index ``i - map_runlength`` whenever the
    posterior probability of a change within the last ``recent_window``
    observations exceeds ``cp_threshold`` (paper: likelihood of r_t = 0
    above 0.9 — evaluated over a tiny window so the single-step hazard
    factor does not suppress genuine onsets). ``min_gap`` merges the burst
    of detections that one physical change produces.
    """
    x = np.asarray(series, dtype=np.float64)
    if x.size == 0:
        return []
    scale = noise_scale(x)
    det = BOCD(
        hazard=hazard,
        mu0=float(x[0] / scale),
        kappa0=1.0,
        alpha0=1.0,
        beta0=1.0,
        cp_threshold=cp_threshold,
    )
    out: list[int] = []
    for i, xi in enumerate(x):
        det.update(float(xi / scale))
        if i <= recent_window:  # p_recent is trivially 1 in the first steps
            continue
        if det.p_recent_change(recent_window) > cp_threshold:
            idx = i - det.map_runlength()
            if idx > 0 and (not out or idx - out[-1] >= min_gap):
                out.append(idx)
    return out


def _student_t_logpdf(
    x: float,
    mu: np.ndarray,
    kappa: np.ndarray,
    alpha: np.ndarray,
    beta: np.ndarray,
) -> np.ndarray:
    """Posterior-predictive Student-t of the Normal-Gamma model."""
    df = 2.0 * alpha
    scale2 = beta * (kappa + 1.0) / (alpha * kappa)
    z2 = (x - mu) ** 2 / scale2
    return (
        _gammaln((df + 1.0) / 2.0)
        - _gammaln(df / 2.0)
        - 0.5 * np.log(np.pi * df * scale2)
        - (df + 1.0) / 2.0 * np.log1p(z2 / df)
    )


def _logsumexp(a: np.ndarray) -> float:
    m = float(np.max(a))
    if math.isinf(m):
        return m
    return m + math.log(float(np.sum(np.exp(a - m))))


try:  # scipy is available in this environment; keep a pure fallback anyway.
    from scipy.special import gammaln as _gammaln
except ImportError:  # pragma: no cover
    def _gammaln(x):
        return np.vectorize(math.lgamma)(x)
