"""S2 — micro-batch redistribution across DP groups (paper §5.3, Eq. 1).

The paper solves

    min  max_i m_i * t_i    s.t.  m_i in N+,  sum_i m_i = M

with a quadratic-programming relaxation (cvxpy). Because micro-batches are
*unit* jobs on *uniform-speed* machines, the exact integer optimum is reached
greedily: give every group one micro-batch, then repeatedly hand the next
micro-batch to the group whose completion time after receiving it is
smallest. This is list scheduling of identical jobs on uniform machines,
which is optimal for the makespan objective (simple exchange argument; also
property-tested against brute force in tests/test_microbatch.py). It runs in
O(M log D) — microseconds even for 512 DP groups (paper Table 6 reports
~36 s for cvxpy at 512 DP).
"""
from __future__ import annotations

import heapq
from collections.abc import Sequence

import numpy as np


def solve_allocation(
    per_batch_times: Sequence[float], total: int, offset: int = 0
) -> list[int]:
    """Return optimal micro-batch counts m_i for per-micro-batch times t_i.

    ``per_batch_times`` are the profiled per-micro-batch processing times of
    each DP group (FALCON-DETECT's profiling phase, §4.3). ``total`` is M,
    the number of micro-batches in the global batch.

    ``offset`` generalizes Eq. 1 to pipelined groups (beyond-paper): under
    1F1B each DP group's iteration takes (m_i + P - 1) * t_i, so balancing
    m_i*t_i alone leaves the slow group's fill/drain term unpaid. Passing
    offset = P - 1 minimizes max_i (m_i + offset) * t_i instead; offset = 0
    recovers the paper's objective exactly.
    """
    t = [float(x) for x in per_batch_times]
    d = len(t)
    if d == 0:
        raise ValueError("need at least one DP group")
    if any(x <= 0 for x in t):
        raise ValueError("per-micro-batch times must be positive")
    if total < d:
        raise ValueError(f"need at least one micro-batch per group ({total} < {d})")

    counts = [1] * d
    # Min-heap keyed by the completion time if the group got one more batch.
    heap = [((counts[i] + 1 + offset) * t[i], i) for i in range(d)]
    heapq.heapify(heap)
    for _ in range(total - d):
        _, i = heapq.heappop(heap)
        counts[i] += 1
        heapq.heappush(heap, ((counts[i] + 1 + offset) * t[i], i))
    return counts


def makespan(counts: Sequence[int], per_batch_times: Sequence[float]) -> float:
    """Iteration compute time implied by an allocation: max_i m_i * t_i."""
    return max(m * t for m, t in zip(counts, per_batch_times, strict=True))


def gradient_weights(counts: Sequence[int]) -> np.ndarray:
    """Weighted gradient-aggregation weights (paper cites [5]).

    Each DP group's gradient is averaged over its own m_i micro-batches; to
    keep the global update an unbiased mean over all M micro-batches, group i
    gets weight m_i / M.
    """
    m = np.asarray(counts, dtype=np.float64)
    return m / m.sum()


def speedup(
    per_batch_times: Sequence[float], total: int
) -> tuple[list[int], float, float]:
    """Convenience: (allocation, balanced-makespan, even-split-makespan)."""
    d = len(per_batch_times)
    counts = solve_allocation(per_batch_times, total)
    # Without S2, schedulers split evenly: ceil(M/D) micro-batches everywhere,
    # so the slowest group dictates the iteration time.
    even_makespan = max(-(-total // d) * t for t in per_batch_times)
    return counts, makespan(counts, per_batch_times), even_makespan
