"""S3 — parallelism-topology adjustment (paper §5.3, Figs. 10-11).

Two sub-mechanisms:

1. **Congested-link reassignment** — permute the node->position mapping so a
   congested physical link carries the *lightest*-traffic logical group
   (paper: move it from a heavy DP ring into a light PP edge; Appendix 9.2
   shows Comm_DP = Theta(h^2) >> Comm_PP = Theta(h)). We formulate it as a
   (small) quadratic-assignment instance: logical traffic matrix x physical
   bandwidth matrix, minimized by greedy pairwise-swap local search — the
   paper's own adjustment is a single node swap, so the heuristic subsumes it.

2. **Straggler consolidation** — when several devices are slow, pack them
   into ceil(#stragglers / devices-per-stage) pipeline stages (Fig. 11:
   2 stragglers in one stage cost 8 s; scattered over two stages, 8.5 s),
   preferring *interior* stages since first/last carry embedding/head extras.

Both return **permutations** ``perm`` with the meaning: logical position
``p`` is hosted by physical device ``perm[p]``. The JAX runtime applies them
by rebuilding the Mesh with ``devices[perm]`` and re-sharding the live state
(see train/trainer.py); the simulator applies them to its placement map.
"""
from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HybridTopology:
    """A (TP, DP, PP) hybrid-parallel layout over tp*dp*pp positions.

    Position index = ((pp_stage * dp + dp_rank) * tp + tp_rank) — PP outermost
    so that "a PP stage" is a contiguous block of dp*tp positions, matching
    Megatron rank ordering.
    """

    tp: int
    dp: int
    pp: int

    @property
    def size(self) -> int:
        return self.tp * self.dp * self.pp

    def position(self, stage: int, dp_rank: int, tp_rank: int) -> int:
        return (stage * self.dp + dp_rank) * self.tp + tp_rank

    def stage_of(self, pos: int) -> int:
        return pos // (self.dp * self.tp)


def build_traffic_matrix(
    topo: HybridTopology,
    comm_tp: float,
    comm_dp: float,
    comm_pp: float,
) -> np.ndarray:
    """Per-iteration traffic volume (bytes) between logical positions.

    Volumes follow Appendix 9.2: TP all-reduces within a (stage, dp) cell,
    DP ring all-reduce among replicas of the same (stage, tp) shard, PP
    activations between adjacent stages at the same (dp, tp) coordinate.
    Ring collectives put ~volume/size on each ring edge; we charge each
    adjacent pair accordingly.
    """
    n = topo.size
    t = np.zeros((n, n))

    def add(a: int, b: int, v: float) -> None:
        t[a, b] += v
        t[b, a] += v

    for s in range(topo.pp):
        for d in range(topo.dp):
            # TP ring within the cell.
            if topo.tp > 1:
                per_edge = comm_tp / topo.tp
                for k in range(topo.tp):
                    a = topo.position(s, d, k)
                    b = topo.position(s, d, (k + 1) % topo.tp)
                    add(a, b, per_edge)
        for k in range(topo.tp):
            # DP ring across replicas.
            if topo.dp > 1:
                per_edge = comm_dp / topo.dp
                for d in range(topo.dp):
                    a = topo.position(s, d, k)
                    b = topo.position(s, (d + 1) % topo.dp, k)
                    add(a, b, per_edge)
    for s in range(topo.pp - 1):
        for d in range(topo.dp):
            for k in range(topo.tp):
                add(
                    topo.position(s, d, k),
                    topo.position(s + 1, d, k),
                    comm_pp,
                )
    return t


#: upper-triangle (k=1) index pairs per matrix size — the swap search
#: evaluates thousands of same-size cost calls, so the index build is
#: hoisted out of the hot path (the pairs themselves are size-only).
_TRIU_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _triu(n: int) -> tuple[np.ndarray, np.ndarray]:
    got = _TRIU_CACHE.get(n)
    if got is None:
        got = _TRIU_CACHE[n] = np.triu_indices(n, k=1)
    return got


def assignment_cost(
    perm: Sequence[int],
    traffic: np.ndarray,
    bandwidth: np.ndarray,
) -> tuple[float, float]:
    """(bottleneck, total) communication time of placement ``perm``.

    ``bandwidth[a, b]`` is the physical bandwidth between devices a and b
    (bytes/s); traffic between logical positions i, j flows over the physical
    pair (perm[i], perm[j]).

    Only the strict upper triangle is materialized (both matrices are
    symmetric): each extracted element is the same ``traffic/bandwidth``
    quotient the full-matrix formulation produced, in the same order, so
    the (max, sum) pair is bit-identical to the original full-matrix code.
    """
    p = np.asarray(perm)
    iu0, iu1 = _triu(traffic.shape[0])
    t_vals = traffic[iu0, iu1]
    bw_vals = bandwidth[p[iu0], p[iu1]]
    with np.errstate(divide="ignore", invalid="ignore"):
        vals = np.where(t_vals > 0, t_vals / bw_vals, 0.0)
    return float(vals.max(initial=0.0)), float(vals.sum())


def _greedy_swaps(
    perm: list[int],
    traffic: np.ndarray,
    bandwidth: np.ndarray,
    max_rounds: int,
) -> tuple[list[int], tuple[float, float]]:
    """Best-improving pairwise-swap local search from ``perm``.

    Each round scores *every* candidate swap in one vectorized batch
    instead of n*(n-1)/2 Python-level cost calls. Equivalence with the
    scalar scan is bitwise: candidate rows hold the same quotients the
    scalar ``assignment_cost`` would produce (elementwise ops), the
    per-row total uses the same contiguous 1-D pairwise ``.sum()`` on the
    same values, ``max`` is order-independent, and the winner is the
    first row attaining the minimal (bottleneck, total) pair — exactly
    what the strict-improvement scan over (i, j) in lexicographic order
    kept.
    """
    n = traffic.shape[0]
    perm = list(perm)
    best = assignment_cost(perm, traffic, bandwidth)
    iu0, iu1 = _triu(n)
    m = iu0.size
    if m == 0:
        return perm, best
    t_vals = traffic[iu0, iu1]
    t_pos = t_vals > 0
    for _ in range(max_rounds):
        p = np.asarray(perm)
        # Row r of cands is perm with pair (iu0[r], iu1[r]) swapped — the
        # same (i, j), i < j scan order as the nested loop.
        cands = np.broadcast_to(p, (m, n)).copy()
        rows = np.arange(m)
        cands[rows, iu0] = p[iu1]
        cands[rows, iu1] = p[iu0]
        bw_vals = bandwidth[cands[:, iu0], cands[:, iu1]]
        with np.errstate(divide="ignore", invalid="ignore"):
            times = np.where(t_pos, t_vals / bw_vals, 0.0)
        bott = times.max(axis=1, initial=0.0)
        tot = np.empty(m)
        for r in range(m):
            tot[r] = times[r].sum()
        bb, bs = best
        improved = (bott < bb) | ((bott == bb) & (tot < bs))
        if not improved.any():
            break
        mn_b = bott[improved].min()
        cand = improved & (bott == mn_b)
        mn_s = tot[cand].min()
        r = int(np.flatnonzero(cand & (tot == mn_s))[0])
        i, j = int(iu0[r]), int(iu1[r])
        perm[i], perm[j] = perm[j], perm[i]
        best = (float(mn_b), float(mn_s))
    return perm, best


def plan_topology_adjustment(
    traffic: np.ndarray,
    bandwidth: np.ndarray,
    max_rounds: int = 4,
    n_starts: int = 4,
    seed: int = 0,
) -> list[int]:
    """Multi-start greedy pairwise-swap search minimizing (bottleneck, total).

    Single-swap local search from the identity placement (the running job)
    can plateau: when every DP ring crosses a congested NIC, any one swap
    leaves the congested-crossing count unchanged. Deterministic random
    restarts escape such plateaus; the best local optimum across starts is
    returned (identity is always a candidate, so the result never regresses).
    Complexity O(starts * rounds * n^2) cost evaluations — fine up to a few
    hundred positions; the paper's own mechanism swaps a single node pair.
    """
    n = traffic.shape[0]
    rng = np.random.default_rng(seed)
    starts = [list(range(n))] + [
        list(map(int, rng.permutation(n))) for _ in range(n_starts)
    ]
    best_perm, best_cost = None, (float("inf"), float("inf"))
    for s in starts:
        perm, cost = _greedy_swaps(s, traffic, bandwidth, max_rounds)
        if cost < best_cost:
            best_perm, best_cost = perm, cost
    return best_perm


def plan_targeted_swap(
    traffic: np.ndarray,
    bandwidth: np.ndarray,
    slow_positions: Sequence[int],
    max_rounds: int | None = None,
) -> list[int]:
    """Targeted congestion swap (paper Fig. 10): FALCON-DETECT pinpointed the
    congested links, so instead of a blind QAP search, try swapping only the
    positions *touching* those links against every other position and take
    the best improving swap — the paper's own mechanism is exactly one such
    node swap. O(k*n) cost evaluations per round for k slow endpoints.
    """
    n = traffic.shape[0]
    perm = list(range(n))
    slow = [p for p in slow_positions if 0 <= p < n]
    if not slow:
        return perm
    best = assignment_cost(perm, traffic, bandwidth)
    rounds = max_rounds if max_rounds is not None else len(slow) + 2
    for _ in range(rounds):
        best_swap: tuple[int, int] | None = None
        best_cost = best
        for i in slow:
            pi = perm.index(i)  # position currently hosting endpoint i
            for q in range(n):
                if q == pi:
                    continue
                perm[pi], perm[q] = perm[q], perm[pi]
                c = assignment_cost(perm, traffic, bandwidth)
                perm[pi], perm[q] = perm[q], perm[pi]
                if c < best_cost:
                    best_cost = c
                    best_swap = (pi, q)
        if best_swap is None:
            break
        i, j = best_swap
        perm[i], perm[j] = perm[j], perm[i]
        best = best_cost
    return perm


def consolidate_stragglers(
    stragglers: Sequence[int],
    topo: HybridTopology,
) -> list[int]:
    """Permutation packing straggler devices into the fewest PP stages.

    Returns ``perm`` (logical position -> physical device). Stragglers are
    packed into ceil(k / per_stage) stages; interior stages are preferred
    (paper: first/last stages carry embedding and head extras). Healthy
    devices fill the remaining positions preserving their relative order.
    """
    n = topo.size
    per_stage = topo.dp * topo.tp
    slow = [s for s in stragglers if 0 <= s < n]
    if not slow or topo.pp <= 1:
        return list(range(n))
    k = len(slow)
    n_stages = -(-k // per_stage)
    # Interior-first stage order: 1, 2, ..., pp-2, then 0, pp-1.
    interior = list(range(1, topo.pp - 1))
    order = interior + [0, topo.pp - 1]
    target_stages = sorted(order[:n_stages])

    slow_set = set(slow)
    healthy = [d for d in range(n) if d not in slow_set]
    target_positions: list[int] = []
    for s in target_stages:
        start = s * per_stage
        target_positions.extend(range(start, start + per_stage))
    target_positions = target_positions[: len(slow)]
    target_set = set(target_positions)

    perm: list[int] = [-1] * n
    for pos, dev in zip(target_positions, slow, strict=True):
        perm[pos] = dev
    it = iter(healthy)
    for pos in range(n):
        if pos not in target_set:
            perm[pos] = next(it)
    # Positions in target stages beyond len(slow) still need devices.
    for pos in range(n):
        if perm[pos] == -1:
            perm[pos] = next(it)
    return perm


def straggler_stage_count(perm: Sequence[int], stragglers: Sequence[int], topo: HybridTopology) -> int:
    """Number of PP stages containing at least one straggler under ``perm``."""
    slow = set(stragglers)
    stages = {topo.stage_of(pos) for pos, dev in enumerate(perm) if dev in slow}
    return len(stages)
