"""Placement-aware DP-group re-shaping (Malleus-style malleability).

The paper's S2 exploits *skew*: when some DP groups are slower than others,
micro-batches shift toward the fast groups. A host-level fault on a
node-spanning job destroys that skew — with the default stage-major
placement every DP group has exactly one cell on the slow host, so all
groups degrade equally and the S2 solver returns the even split (the
campaign engine's biggest mitigation loss, ROADMAP "node-spanning DP
groups").

:class:`PlacementPlanner` restores the skew by *re-shaping the groups
around the fault* (the malleable re-partitioning of Malleus,
arXiv:2410.13333, applied at the DP-group level): swap ranks across DP
groups so the slow host's members concentrate in as few groups as
possible. The concentrated groups are very slow, the rest fully healthy —
exactly the skew S2/S3 know how to exploit. Whether the trade is worth it
(a concentrated layout sends DP rings across the inter-node fabric) is
decided by the caller measuring the modeled iteration time before
committing, the same measure-before-commit rule as S3.

The planner only *proposes*; :meth:`TrainingSimulator.remap_groups` (or
any :class:`~repro.controlplane.adapters.ClusterAdapter` implementing it)
applies the proposal.
"""
from __future__ import annotations

from collections.abc import Callable, Sequence
from dataclasses import dataclass

from repro.core.events import FailSlowEvent


@dataclass(frozen=True)
class GroupRemap:
    """A proposed DP-group re-shape.

    ``placement`` is the full logical-position -> physical-device list in
    :class:`~repro.core.topology.HybridTopology` order (stage-major), a
    permutation of the job's current devices. ``slow_groups`` are the DP
    ranks that host every slow device after the re-shape.
    """

    placement: tuple[int, ...]
    slow_groups: tuple[int, ...]
    #: DP groups containing a slow device before / after the re-shape
    groups_hit_before: int
    groups_hit_after: int

    @property
    def concentrates(self) -> bool:
        return self.groups_hit_after < self.groups_hit_before


def slow_devices_for(
    event: FailSlowEvent,
    n_devices: int,
    node_of: Callable[[int], int] | None = None,
) -> set[int]:
    """Physical devices implicated by a diagnosis.

    ``gpu:<rank>`` components name devices directly; ``node:<n>`` (host
    fault) and ``nic:<n>`` (congested port) expand to every device of the
    node when the adapter exposes the node map.
    """
    slow: set[int] = set()
    for comp in event.components:
        kind, _, ident = comp.partition(":")
        try:
            if kind == "gpu":
                slow.add(int(ident))
            elif kind in ("node", "nic") and node_of is not None:
                node = int(ident)
                slow.update(
                    d for d in range(n_devices) if node_of(d) == node
                )
        except ValueError:
            continue
    return {d for d in slow if 0 <= d < n_devices}


@dataclass
class PlacementPlanner:
    """Propose rank swaps that concentrate slow devices into few DP groups."""

    def plan(
        self,
        *,
        tp: int,
        dp: int,
        pp: int,
        placement: Sequence[int],
        slow: set[int],
        node_of: Callable[[int], int] | None = None,
    ) -> GroupRemap | None:
        """Concentrating re-shape of ``placement``, or None if pointless.

        Devices are re-dealt to logical positions group by group (healthy
        devices fill the leading DP ranks, slow devices the trailing ones),
        each class sorted by (node, id) so TP cells and DP-ring segments
        stay node-contiguous — the heavy TP traffic never leaves a node
        that it did not already span. Returns None when the slow set is
        empty, covers every group anyway, or is already maximally
        concentrated (the proposal would be a no-op).
        """
        place = [int(d) for d in placement]
        n = tp * dp * pp
        if len(place) != n:
            raise ValueError(
                f"placement has {len(place)} entries for {n} positions"
            )
        present = set(place)
        slow = {d for d in slow if d in present}
        if not slow:
            return None
        capacity = tp * pp  # devices per DP group
        min_groups = -(-len(slow) // capacity)  # ceil
        hit_before = self._groups_hit(place, slow, tp, dp, pp)
        if min_groups >= dp or len(hit_before) <= min_groups:
            return None

        key = (lambda d: (node_of(d), d)) if node_of is not None else (lambda d: d)
        healthy = sorted((d for d in place if d not in slow), key=key)
        slow_sorted = sorted(slow, key=key)
        order = healthy + slow_sorted
        new_place = list(place)
        i = 0
        for d in range(dp):
            for s in range(pp):
                for k in range(tp):
                    new_place[(s * dp + d) * tp + k] = order[i]
                    i += 1
        hit_after = self._groups_hit(new_place, slow, tp, dp, pp)
        return GroupRemap(
            placement=tuple(new_place),
            slow_groups=tuple(sorted(hit_after)),
            groups_hit_before=len(hit_before),
            groups_hit_after=len(hit_after),
        )

    @staticmethod
    def _groups_hit(
        placement: Sequence[int], slow: set[int], tp: int, dp: int, pp: int
    ) -> set[int]:
        """DP ranks whose group holds at least one slow device."""
        hit: set[int] = set()
        for d in range(dp):
            for s in range(pp):
                base = (s * dp + d) * tp
                if any(placement[base + k] in slow for k in range(tp)):
                    hit.add(d)
                    break
        return hit
