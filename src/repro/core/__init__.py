"""FALCON core: detection (ACF, BOCD, validation) and mitigation (S1-S4)."""

from repro.core.events import (  # noqa: F401
    ChangePoint,
    CommEvent,
    CommOp,
    FailSlowEvent,
    RootCause,
    Strategy,
)
