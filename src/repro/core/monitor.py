"""Shim monitoring layer (paper §4.2, Fig. 7).

The paper's Monitor LD_PRELOAD-hooks NCCL calls and keeps (type, timestamp)
logs in shared memory. In JAX the collectives live inside a compiled XLA
program, so the shim sits one level up: the framework's comm wrappers and
the trainer's step boundary emit :class:`CommEvent`s into this Monitor, and
the cluster simulator emits the same events for at-scale studies. Everything
downstream (ACF -> BOCD -> profiling -> validation) only sees the event
stream, preserving the framework-agnostic contract (R1).
"""
from __future__ import annotations

import time
from collections import deque
from collections.abc import Callable
from dataclasses import dataclass, field

import numpy as np

from repro.core import acf
from repro.core.events import CommEvent, CommOp


@dataclass
class Monitor:
    """Per-worker communication-event log with iteration-time inference.

    ``clock`` supplies the timestamp for :meth:`record` calls that don't
    pass one explicitly. It defaults to ``time.monotonic`` (real hardware),
    but a driver running on a modeled clock — the trainer's simulated wall
    time, a trace replay cursor — must inject its own so the event log and
    the control-plane events downstream share one timebase.
    """

    max_events: int = 65536
    clock: Callable[[], float] = time.monotonic
    _events: deque[CommEvent] = field(init=False)

    def __post_init__(self) -> None:
        self._events = deque(maxlen=self.max_events)

    # -- logging -------------------------------------------------------
    def record(
        self,
        op: CommOp,
        timestamp: float | None = None,
        group: str = "",
        rank: int = 0,
        duration: float = 0.0,
    ) -> None:
        self._events.append(
            CommEvent(
                op=op,
                timestamp=self.clock() if timestamp is None else timestamp,
                group=group,
                rank=rank,
                duration=duration,
            )
        )

    def extend(self, events: list[CommEvent]) -> None:
        self._events.extend(events)

    def clear(self) -> None:
        self._events.clear()

    @property
    def events(self) -> list[CommEvent]:
        return list(self._events)

    # -- analysis ------------------------------------------------------
    def iteration_times(self, window: int | None = None) -> np.ndarray:
        """Infer the iteration-time series via ACF period detection."""
        evs = self.events
        if window is not None:
            evs = evs[-window:]
        times, _ = acf.iteration_times_from_events(evs)
        return times

    def group_transfer_times(self) -> dict[str, float]:
        """Mean measured transfer duration per communication group.

        Populated during the profiling phase, when durations are attached to
        events (the paper injects CUDA events; the simulator fills them in).
        """
        sums: dict[str, float] = {}
        counts: dict[str, int] = {}
        for ev in self._events:
            if ev.duration > 0.0 and ev.group:
                sums[ev.group] = sums.get(ev.group, 0.0) + ev.duration
                counts[ev.group] = counts.get(ev.group, 0) + 1
        return {g: sums[g] / counts[g] for g in sums}
